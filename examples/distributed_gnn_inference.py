"""Distributed binary-GNN inference: 1-D block-row partition of the FRDC
adjacency + packed-activation all-gather (DESIGN.md §6) — the paper's memory
saving turned into a 32x collective saving at multi-chip scale.

    PYTHONPATH=src python examples/distributed_gnn_inference.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops, frdc
from repro.core.binarize import BinTensor
from repro.core.bspmm import bspmm
from repro.graphs import partition
from repro.graphs.datasets import make_dataset


def main():
    d = make_dataset("cora", seed=0, scale=0.3)
    n_shards = 4
    shards = partition.partition_rows(d.edges[0], d.edges[1], d.n_nodes,
                                      n_shards, kind="gcn")
    print("shard balance:", partition.shard_stats(shards))

    rng = np.random.default_rng(0)
    act = rng.choice([-1.0, 1.0], size=(d.n_nodes, 64)).astype(np.float32)
    xt = BinTensor(packed=bitops.pack_bits(act > 0),
                   scale=jnp.ones((d.n_nodes, 1)), n=64)

    # each "chip" aggregates its block-rows from the globally-gathered PACKED
    # activations (the all-gather payload is bits: 64 feats -> 2 words/node)
    outs = []
    for s in shards:
        local = bspmm(s.adj, xt, "BBF")
        outs.append(np.asarray(local)[: s.row_end - s.row_start])
    dist = np.concatenate(outs)[: d.n_nodes]

    full = frdc.gcn_normalized(d.edges[0], d.edges[1], d.n_nodes)
    want = np.asarray(bspmm(full, xt, "BBF"))
    err = np.abs(dist - want).max()
    print(f"distributed == global: max|err| = {err:.2e}")

    packed_payload = d.n_nodes * 2 * 4          # 2 uint32 words / node
    fp_payload = d.n_nodes * 64 * 4
    print(f"all-gather payload: packed {packed_payload/1e3:.1f} KB vs "
          f"fp32 {fp_payload/1e3:.1f} KB ({fp_payload/packed_payload:.0f}x)")


if __name__ == "__main__":
    main()
