"""Sharded GNN serving demo: partition a graph across P shards and answer
node queries with cross-shard k-hop routing + halo exchange.

    PYTHONPATH=src python examples/serve_sharded.py [--shards 4] [--scale 0.2]

Walkthrough:
  1. a GraphStore registers a synthetic Table-2 graph + a binary GCN;
  2. ``store.sharded_session(graph, model, P)`` runs the ShardPlanner
     (edge-balanced tile-row cuts via graphs/partition.py), builds per-shard
     intra FRDC + bit-packed halo adjacencies and a routing table, compiles
     one bucketed serve core per shard and calibrates BN once;
  3. the distributed full pass fills the per-shard logits caches, exchanging
     activations layer-wise — PACKED words on the binary-aggregation layer;
  4. the ShardedServeEngine routes micro-batched queries to their owning
     shards (per-owner queues with HALO-AWARE batch formation: seeds whose
     closures request the same halo tiles are co-batched under a staleness
     bound) and serves them with ZERO steady-state recompiles per shard;
     answers are bit-exact vs single-host serving. A second pass runs the
     PIPELINED loop (extraction overlapped with the in-flight forward) and
     reports the overlap ratio + estimated halo bytes saved;
  5. with enough devices, the SPMD layer executor re-runs the full pass as
     one shard_map program per layer (fused halo exchange) — bit-identical
     to the host-orchestrated pass — and the distributed BN calibration
     (psum moments, no single-host anchor pass) is compared to it;
  6. artifacts (per-shard FRDC + routing.json, incl. the ``spmd`` plan)
     roundtrip through the checkpointer without re-partitioning;
  7. multi-tenant serving: two tenants with 4:1 scheduler weights share the
     sharded engine — queues are keyed by (owner, tenant), so batches stay
     single-owner AND single-tenant (the bit-exactness invariant survives
     tenancy) and ``snapshot()`` breaks QPS/latency out per tenant;
  8. observability: the pipelined run records a span tree per batch
     (queue wait / extract / launch / compute, tagged with the owning
     shard, halo bytes moved and formation savings) — exported as a
     Chrome trace with one track per shard, watchdog counters alongside.

Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to move the
halo exchange onto real per-shard devices (shard_map + ppermute collectives)
and enable the SPMD executor section.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.graphs.datasets import make_dataset
from repro.launch.mesh import make_shard_mesh
from repro.models import gnn
from repro.serve import (AdmissionController, GraphStore,
                         ShardedServeEngine, SpanTracer, TenantPolicy,
                         write_chrome_trace)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()
    jax.config.update("jax_platform_name", "cpu")

    # 1. graph + model -------------------------------------------------------
    d = make_dataset("cora", seed=0, scale=args.scale)
    print(f"graph: cora-like, {d.n_nodes} nodes / {d.n_edges} edges")
    params = gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1], 32,
                          d.n_classes)
    with tempfile.TemporaryDirectory() as cache:
        store = GraphStore(cache_dir=cache, max_batch=args.batch)
        store.register_graph("cora", d)
        store.register_model("gcn", "gcn", params)

        # 2. plan + compile the sharded session -----------------------------
        mesh = make_shard_mesh(args.shards)
        print(f"halo transport: "
              f"{'mesh collectives' if mesh is not None else 'host loopback'}"
              f" ({len(jax.devices())} devices)")
        t0 = time.perf_counter()
        sess = store.sharded_session("cora", "gcn", args.shards, mesh=mesh)
        stats = sess.shard_plan.stats()
        print(f"planned + compiled {sess.key!r} in "
              f"{time.perf_counter()-t0:.1f}s")
        print(f"  local nodes per shard: {stats['local_nodes']}, halo "
              f"nodes: {stats['halo_nodes']}, edge-cut "
              f"{stats['edge_cut_fraction']:.1%}, imbalance "
              f"{stats['imbalance']:.2f}")

        # 3. distributed full pass already ran in sync(): halo per layer ----
        for tag, b in sorted(sess.halo_stats.bytes_by_tag.items()):
            print(f"  halo[{tag}]: {b} bytes")

        # 4. routed micro-batched serving -----------------------------------
        engine = ShardedServeEngine(store, args.shards, max_batch=args.batch,
                                    mode="subgraph", mesh=mesh)
        warm = engine.warmup("cora", "gcn")
        c0 = engine.compile_count
        rng = np.random.default_rng(1)
        nodes = rng.integers(0, d.n_nodes, size=args.queries)
        for i in range(0, nodes.size, args.batch):
            engine.submit_many("cora", "gcn", nodes[i:i + args.batch])
            engine.tick()
        engine.run_until_drained()
        snap = engine.snapshot()
        lat = snap["latency"]
        print(f"  warmup compiles {warm} | steady-state recompiles "
              f"{engine.compile_count - c0} (per shard: "
              f"{snap['compiles_by_shard']})")
        print(f"  {snap['queries']} queries -> {snap['qps']:.1f} QPS | "
              f"p50 {lat['p50_ms']:.2f}ms p99 {lat['p99_ms']:.2f}ms | "
              f"serve halo {snap['halo_bytes_by_tag'].get('serve/x', 0)} B")
        assert engine.compile_count == c0, "steady-state recompile!"

        # 4b. pipelined + halo-aware: overlap + halo sharing ----------------
        pipe = ShardedServeEngine(store, args.shards, max_batch=args.batch,
                                  mode="subgraph", mesh=mesh,
                                  pipeline_depth=2,
                                  tracer=SpanTracer(sample_every=1))
        pipe.warmup("cora", "gcn")
        pipe.submit_many("cora", "gcn", nodes)
        pipe.run_until_drained()
        ps = pipe.snapshot()
        print(f"  [pipelined d=2] {ps['qps']:.1f} QPS | overlap "
              f"{ps['overlap_ratio']:.2f} | halo tiles co-batched "
              f"{ps['halo_tiles_shared']} (~{ps['halo_bytes_saved']} B of "
              f"serve/x gathers deduplicated)")
        pipe.close()

        # 8. observability: per-shard span traces + watchdogs ---------------
        trs = pipe.tracer.batch_traces()
        wd = ps["watchdogs"]
        print(f"  [trace] {len(trs)} batch span trees across shards "
              f"{sorted({t.shard for t in trs})} | steady recompiles "
              f"{wd['recompile']['steady_recompiles']} | unexpected "
              f"transfers {wd['transfer']['host_sync_in_launch']}")
        t = trs[0]
        print(f"    e.g. trace {t.trace_id} (shard {t.shard}): "
              f"extract {t.stage_s('extract')*1e3:.2f}ms / compute "
              f"{t.stage_s('compute')*1e3:.2f}ms | halo {t.halo}")
        write_chrome_trace(pipe.tracer, "/tmp/serve_sharded_trace.json")
        print("    Chrome trace (one track per shard) -> "
              "/tmp/serve_sharded_trace.json")

        # 5. SPMD executor + distributed BN calibration ---------------------
        if mesh is not None:
            spmd = store.sharded_session("cora", "gcn", args.shards,
                                         executor="spmd")
            spmd.run_distributed_pass()        # warm: compile the programs
            sess.run_distributed_pass()
            t0 = time.perf_counter()
            spmd.run_distributed_pass()
            dt_spmd = time.perf_counter() - t0
            t0 = time.perf_counter()
            sess.run_distributed_pass()
            dt_host = time.perf_counter() - t0
            exact = np.array_equal(spmd.full_logits(), sess.full_logits())
            print(f"SPMD executor: full pass {dt_spmd*1e3:.1f}ms vs host "
                  f"{dt_host*1e3:.1f}ms | bit-exact={exact} | "
                  f"{spmd.executor_compile_count} compiles for "
                  f"{len(spmd.program)} layer programs")
            dist = store.sharded_session("cora", "gcn", args.shards,
                                         executor="spmd",
                                         bn_mode="distributed")
            da, aa = dist.full_logits(), sess.full_logits()
            print(f"distributed BN calibration: max|logit delta| "
                  f"{np.abs(da-aa).max():.2e}, argmax agreement "
                  f"{(np.argmax(da,-1)==np.argmax(aa,-1)).mean():.2%}")
        else:
            print("(< P devices: SPMD executor section skipped)")

        # 6. sanity vs single host + artifact restore -----------------------
        single = store.session("cora", "gcn")
        sample = nodes[: args.batch]
        owners = sess.routing.owner(sample)
        for o in np.unique(owners):
            grp = sample[owners == o]
            a = sess.serve_subgraph(grp)
            b = single.serve_subgraph(grp)
            assert np.array_equal(a, b), "sharded != single-host!"
        print("sharded answers are bit-exact vs the single-host session")

        store2 = GraphStore(cache_dir=cache, max_batch=args.batch)
        store2.register_graph("cora", d)
        store2.register_model("gcn", "gcn", params)
        restored = store2.sharded_session("cora", "gcn", args.shards)
        assert np.array_equal(restored.routing.bounds, sess.routing.bounds)
        print("artifact restored from cache without re-partitioning")

        # 7. multi-tenant sharded serving ------------------------------------
        admission = AdmissionController(policies={
            "gold": TenantPolicy(weight=4),
            "base": TenantPolicy(weight=1)})
        mt = ShardedServeEngine(store, args.shards, max_batch=args.batch,
                                mode="subgraph", mesh=mesh,
                                admission=admission)
        mt.warmup("cora", "gcn")
        for i, n in enumerate(nodes):
            mt.submit("cora", "gcn", int(n),
                      tenant=("gold" if i % 2 else "base"))
        mt.run_until_drained()
        mixed = sum(len({q.tenant for q in b}) != 1 for b in mt.batch_log)
        assert mixed == 0, "a served batch mixed tenants!"
        for name, t in sorted(mt.snapshot()["tenants"].items()):
            print(f"  [tenant {name}] served {t['queries']} @ "
                  f"{t['qps']:.1f} QPS | p99 {t['latency']['p99_ms']:.2f}ms")
        print("  batches stayed single-owner and single-tenant")
        mt.close()


if __name__ == "__main__":
    main()
