"""Batched serving example: continuous-batching engine over a reduced LM,
optionally with BitGNN bit-packed weights (32x smaller projections).

    PYTHONPATH=src python examples/serve_llm.py --requests 6 --quant
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.quant.binary_linear import quantize_params, quantized_param_bytes
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--quant", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(get_config("stablelm-1.6b")).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    fp_bytes = quantized_param_bytes(params)
    if args.quant:
        params = quantize_params(params)
        print(f"bitgnn quantized params: {quantized_param_bytes(params)/1e6:.2f} MB "
              f"(fp: {fp_bytes/1e6:.2f} MB)")

    eng = ServeEngine(cfg, params, max_batch=4, max_len=128)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, rng.integers(3, 10)),
                           max_new_tokens=args.max_new))
    done = eng.run_until_done()
    for req in sorted(done, key=lambda r: r.rid):
        print(f"req {req.rid}: prompt[{len(req.prompt)}] -> {req.out_tokens}")


if __name__ == "__main__":
    main()
