"""Token serving example: the family-adapter serving core driving a reduced
binary LM through TokenServeEngine — admission, cost attribution and span
tracing shared with the GNN engines, zero steady-state recompiles, and the
served streams asserted BITWISE equal to a direct ``decode_step`` loop.
Optionally with BitGNN bit-packed weights (32x smaller projections).

    PYTHONPATH=src python examples/serve_llm.py --requests 6 --quant
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.quant.binary_linear import quantize_params, quantized_param_bytes
from repro.serve.token_engine import TokenServeEngine
from repro.serve.token_session import TokenStore


def direct_reference(cfg, params, prompt, max_new):
    """Ground truth: a python loop of jit(decode_step) with argmax feedback
    — exactly the program the serving path must reproduce bitwise."""
    step = jax.jit(lambda p, c, t, pos: transformer.decode_step(
        p, cfg, c, t, pos))
    total = prompt.size + max_new
    cache = transformer.init_cache(
        cfg, 1, max(64, int(2 ** np.ceil(np.log2(total)))))
    out, prev = [], None
    for t in range(prompt.size + max_new - 1):
        tok = prompt[t] if t < prompt.size else prev
        logits, cache = step(params, cache, jnp.asarray([[tok]], jnp.int32), t)
        prev = int(np.argmax(np.asarray(logits[0, 0, :cfg.vocab])))
        if t >= prompt.size - 1:
            out.append(prev)
    return np.asarray(out[:max_new], np.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--quant", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(get_config("stablelm-1.6b")).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    fp_bytes = quantized_param_bytes(params)
    if args.quant:
        print(f"bitgnn quantized params: "
              f"{quantized_param_bytes(quantize_params(params))/1e6:.2f} MB "
              f"(fp: {fp_bytes/1e6:.2f} MB)")

    store = TokenStore(max_batch=4, max_len=128, chunk=8,
                       warm_len=12, warm_new=args.max_new)
    store.register_model("lm", cfg, params, quantize=args.quant)
    eng = TokenServeEngine(store)
    warm = eng.warmup("lm")
    c0 = eng.compile_count

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(3, 10)).astype(np.int32)
               for _ in range(args.requests)]
    queries = eng.submit_many("lm", prompts, max_new=args.max_new)
    eng.run_until_drained()
    eng.close()

    qparams = quantize_params(params) if args.quant else params
    for q, prompt in zip(queries, prompts):
        ref = direct_reference(cfg, qparams, prompt, args.max_new)
        assert np.array_equal(q.tokens, ref), \
            f"query {q.qid}: served stream diverged from decode_step loop"
        print(f"req {q.qid}: prompt[{prompt.size}] -> {q.tokens.tolist()} "
              f"(ttft {q.ttft_s*1e3:.1f} ms)")
    steady = eng.compile_count - c0
    print(f"served == direct decode_step loop for all {len(queries)} "
          f"requests; warmup compiles {warm}, steady-state compiles {steady}")


if __name__ == "__main__":
    main()
