"""End-to-end driver: train the ~135M smollm architecture for a few hundred
steps with the full production stack — sharded train step, async
checkpointing, fault-tolerant restart loop, straggler-tolerant loader.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --layers 6

(--layers reduces depth for CPU wall time; pass 30 for the full config.)
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import PrefetchLoader, SyntheticLM
from repro.models import transformer
from repro.optim.optimizer import AdamW, cosine_schedule
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig, run_with_restarts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a node failure at this step (demo)")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    cfg = dataclasses.replace(
        cfg, n_layers=args.layers, d_model=args.d_model,
        d_ff=args.d_model * 8 // 3 // 64 * 64 or 256,
        n_heads=4, n_kv_heads=2, head_dim=args.d_model // 4,
        vocab=2048).resolve_for_mesh(tp=1)
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")

    opt = AdamW(lr=cosine_schedule(3e-3, 20, args.steps), weight_decay=0.01,
                clip_norm=1.0)
    step = make_train_step(cfg, opt, unroll=False)   # scanned layers

    # ONE injector across restarts — a node dies once, not on every retry
    from repro.train.trainer import FailureInjector
    failer = FailureInjector(args.fail_at) if args.fail_at >= 0 else None

    def make_trainer():
        loader = PrefetchLoader(SyntheticLM(cfg.vocab, args.seq, seed=0),
                                batch=args.batch, seed=0)

        def init_state():
            params = transformer.init_params(jax.random.PRNGKey(0), cfg)
            return params, opt.init(params), ()

        return Trainer(cfg, step, init_state, loader, args.ckpt_dir,
                       TrainerConfig(total_steps=args.steps, ckpt_every=20,
                                     log_every=20),
                       failer=failer)

    out = run_with_restarts(make_trainer, max_failures=2)
    print(f"done: steps={out['steps']} final_loss={out['final_loss']:.4f} "
          f"restarts={out['restarts']} wall={out['wall_s']:.1f}s "
          f"straggler_misses={out['straggler_misses']}")
    for h in make_trainer().history:
        pass
    print("loss curve:", [round(l, 3) for l in out["losses"][::20]])


if __name__ == "__main__":
    main()
