"""Quickstart: convert a GCN into a binary GCN with BitGNN's two-level
abstraction, run packed-bit inference, and inspect the memory saving.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import abstraction, frdc
from repro.core.bmm import quantize_weight
from repro.graphs.datasets import make_dataset
from repro.models import gnn


def main():
    # 1. a stat-matched synthetic Cora + a trained(-ish) GCN
    d = make_dataset("cora", seed=0, scale=0.25)
    adj = frdc.gcn_normalized(d.edges[0], d.edges[1], d.n_nodes)   # exact D^-1/2(A+I)D^-1/2
    adj_bin = d.adjacency("binary")                                 # 0/1 bits
    params = gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1], 64, d.n_classes)
    params, loss = gnn.train_node_classifier(
        gnn.gcn_forward_bigcn, params,
        (jnp.asarray(d.x), frdc.to_dense(adj)),
        jnp.asarray(d.y), jnp.asarray(d.train_mask), epochs=150, lr=3e-2)
    print(f"trained Bi-GCN, loss={loss:.3f}")

    # 2. drop-in replacement: high-level fused blocks (paper Fig. 2)
    layer1 = abstraction.MMSpMM("BMM.FBB", "BSpMM.BBB")   # binary aggregation
    layer2 = abstraction.MMSpMM("BMM.BBF", "BSpMM.FBF")   # fp aggregation
    abstraction.check_chain("BMM.FBB", "BSpMM.BBB")        # type-checked

    q = gnn.quantize_gcn(params)   # offline weight bit-packing
    x = jnp.asarray(d.x)
    h = layer1(gnn.batch_norm(x), q.w1, adj_bin, out_scale=False)
    logits = layer2(h, q.w2, adj)
    acc = gnn.accuracy(logits, jnp.asarray(d.y), jnp.asarray(d.test_mask))
    print(f"binary GCN test accuracy: {acc:.3f}")

    # 3. space accounting (paper Tables 3-5 Peak Mem)
    st = frdc.stats(adj_bin)
    print(f"adjacency: FRDC {st['frdc_bytes']/1e3:.1f} KB vs "
          f"CSR-fp32 {st['csr_fp32_bytes']/1e3:.1f} KB "
          f"({st['vs_csr']:.1f}x smaller)")
    w_fp = sum(w.size * 4 for w in params)
    w_bit = sum(int(np.prod(t.packed.shape)) * 4 + t.scale.size * 4
                for t in q)
    print(f"weights: packed {w_bit/1e3:.1f} KB vs fp32 {w_fp/1e3:.1f} KB "
          f"({w_fp/w_bit:.1f}x smaller)")


if __name__ == "__main__":
    main()
