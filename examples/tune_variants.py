"""Paper §3.4 tuning utilities demo: enumerate type-correct precision-variant
assignments for a 2-layer binary GCN, time each on the actual graph, and
report the accuracy/latency frontier.

    PYTHONPATH=src python examples/tune_variants.py
"""
import jax
import jax.numpy as jnp

from repro.core import abstraction, frdc, tuner
from repro.core.bmm import quantize_weight
from repro.graphs.datasets import make_dataset
from repro.models import gnn


def main():
    d = make_dataset("cora", seed=0, scale=0.2)
    adj = frdc.gcn_normalized(d.edges[0], d.edges[1], d.n_nodes)
    adj_bin = d.adjacency("binary")
    x = jnp.asarray(d.x)
    params = gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1], 32, d.n_classes)
    q = gnn.quantize_gcn(params)
    reference = gnn.gcn_forward_fp(params, x, frdc.to_dense(adj))

    candidates = tuner.legal_two_layer_candidates(first_in="F", last_out="F")
    print(f"{len(candidates)} type-correct candidates")

    def build(cand: tuner.Candidate):
        (m1, s1), (m2, s2) = cand.layer_variants
        l1 = abstraction.MMSpMM(m1, s1)
        l2 = abstraction.MMSpMM(m2, s2)

        def fwd(x):
            a1 = adj_bin if s1.endswith("BBB") or "BB" in s1 else adj
            h = l1(gnn.batch_norm(x), q.w1, a1,
                   trinary_mode=cand.trinary_mode, out_scale=False)
            if not isinstance(h, jax.Array):
                return l2(h, q.w2, adj)
            return l2(gnn.batch_norm(h), q.w2, adj)
        return fwd

    results = tuner.tune(build, (x,), candidates[:8], reference=reference,
                         repeats=2)
    print(f"{'candidate':70s} {'ms':>8s} {'delta':>8s}")
    for r in results:
        print(f"{r.candidate.name():70s} {r.latency_s*1e3:8.2f} "
              f"{r.output_delta:8.3f}")
    best = tuner.best(results)
    print(f"\nbest: {best.candidate.name()} @ {best.latency_s*1e3:.2f} ms")


if __name__ == "__main__":
    main()
