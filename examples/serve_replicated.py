"""Fault-tolerant replicated serving demo: front-door routing over a
replica tier, health-checked failover under injected faults, and a live
reshard — ending in an asserted kill-and-recover run.

    PYTHONPATH=src python examples/serve_replicated.py [--replicas 2]
        [--shards 2] [--scale 0.1]

Walkthrough:
  1. ``build_replica`` stands up P=2 replicas, each a full sharded serving
     stack (own GraphStore + ShardedServeEngine over 2 shards), wired to a
     shared ``FaultInjector`` chaos seam and span tracer;
  2. a ``FrontDoor`` owns global admission and spreads queries across the
     healthy replicas; a steady wave establishes the baseline — zero
     steady-state recompiles, availability 1.0;
  3. transient faults: the injector fails the next extract once, the
     engine retries with exponential backoff and the query still answers;
     a poisoned tenant (100% launch failures) is typed-shed after
     ``max_retries`` without starving anyone else;
  4. KILL: one replica dies mid-wave. The health monitor misses its
     heartbeat, the front door evacuates its in-flight + queued work and
     replays it on the survivor — every accepted query completes, the
     survivor takes zero recompiles, and the batch logs replay bit-exact
     against a single-host oracle;
  5. RECOVER: the replica is revived, passes the recovery hysteresis
     (consecutive good beats) and is re-admitted to the routing set;
  6. live reshard: the survivor's engine is rebuilt P=2 -> P=4 in the
     background from checkpointer artifacts while the old engine keeps
     serving, then atomically swapped in with zero drops.

The demo ASSERTS the invariants as it goes — it is a runnable spec of the
fault-tolerance contract, not just a printout.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import (FaultInjector, FrontDoor, GraphStore,
                         HealthPolicy, Resharder, SpanTracer,
                         build_replica)


def replay_bit_exact(engine, single) -> bool:
    """Replay the engine's batch log against the single-host oracle."""
    for batch in engine.batch_log:
        seeds = np.asarray([q.node for q in batch], np.int64)
        want = np.asarray(single.serve_subgraph(seeds))
        for i, q in enumerate(batch):
            if not np.array_equal(np.asarray(q.logits), want[i]):
                return False
    return True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    jax.config.update("jax_platform_name", "cpu")

    # 1. replica tier ------------------------------------------------------
    d = make_dataset("cora", seed=0, scale=args.scale)
    print(f"graph: cora-like, {d.n_nodes} nodes / {d.n_edges} edges")
    params = gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1], 16,
                          d.n_classes)
    models = {"gcn": ("gcn", params)}
    faults = FaultInjector(seed=0)
    tracer = SpanTracer()
    reps = [build_replica(f"r{i}", d, models, n_shards=args.shards,
                          faults=faults, tracer=tracer,
                          max_batch=args.batch, mode="subgraph",
                          retry_backoff_s=0.001)
            for i in range(args.replicas)]
    fd = FrontDoor(reps, faults=faults, tracer=tracer, spread="query",
                   policy=HealthPolicy(deadline_s=0.05))
    t0 = time.perf_counter()
    for r in reps:
        r.engine.warmup("g", "gcn")
    print(f"tier: {args.replicas} replicas x {args.shards} shards "
          f"(warmed in {time.perf_counter()-t0:.1f}s)")

    # oracle for bit-exactness checks
    st = GraphStore(max_batch=args.batch)
    st.register_graph("g", d)
    st.register_model("gcn", "gcn", params)
    single = st.session("g", "gcn")

    # 2. steady wave -------------------------------------------------------
    rng = np.random.default_rng(0)
    c0 = sum(r.engine.compile_count for r in reps)
    qs = fd.submit_many("g", "gcn",
                        rng.integers(0, d.n_nodes, size=args.queries))
    fd.run_until_drained(max_ticks=100_000)
    assert all(q.done for q in qs if not q.rejected)
    assert sum(r.engine.compile_count for r in reps) == c0
    print(f"  steady: {fd.metrics.queries} answered @ "
          f"{fd.metrics.qps:.1f} QPS | steady-state recompiles 0")

    # 3. transient fault + retry; poisoned tenant typed-shed ---------------
    faults.fail_next("extract", n=1)
    q = fd.submit("g", "gcn", 0)
    try:
        fd.tick()                      # the injected fault fires here
    except Exception:
        pass                           # replica absorbs it via requeue
    fd.run_until_drained(max_ticks=100_000)
    requeues = sum(r.engine.metrics.requeues for r in reps)
    assert q.done and requeues >= 1
    print(f"  transient extract fault: retried and answered "
          f"(requeues={requeues})")

    # a poisoned replica: 100% launch failures scoped to r0. Bounded retry
    # typed-sheds the stuck queries after max_retries instead of wedging
    # the queue; the replica serves again once the fault clears.
    eng = reps[0].engine
    faults.fail("launch", rate=1.0, scope=reps[0].name)
    bad = eng.submit_many("g", "gcn", np.arange(4), tenant="poisoned")
    eng.drain(timeout_s=10.0)          # absorbs the injected failures
    faults.clear()
    eng.resume_intake()
    assert all(b.failed for b in bad)
    assert all(b.failure.reason == "max_retries" for b in bad)
    shed = eng.metrics.retry_shed
    assert shed >= len(bad)
    print(f"  poisoned replica: {shed} queries typed-shed after "
          f"max_retries (stage={bad[0].failure.stage}), healthy again")

    # 4. KILL a replica mid-wave ------------------------------------------
    wave = fd.submit_many("g", "gcn",
                          rng.integers(0, d.n_nodes, size=args.queries))
    for _ in range(3):
        fd.tick()                      # both replicas hold in-flight work
    survivor = reps[0].engine
    cs = survivor.compile_count
    victim = reps[-1].name
    faults.kill(victim)
    print(f"  KILL {victim} mid-wave ({fd.pending} queries outstanding)")
    time.sleep(0.06)                   # let the heartbeat deadline lapse
    fd.run_until_drained(max_ticks=100_000)
    assert all(q.done for q in wave if not q.rejected), "query lost!"
    assert fd.failovers == 1
    assert survivor.compile_count == cs, "survivor recompiled!"
    assert replay_bit_exact(survivor, single), "replay diverged!"
    print(f"  failover: {fd.failover_queries} queries evacuated to the "
          f"survivor, all answered, 0 recompiles, replay bit-exact")

    # 5. RECOVER: revive + hysteresis + re-admission -----------------------
    faults.revive(victim)
    for _ in range(4):                 # recovery_beats good heartbeats
        fd.tick()
    assert fd.health.healthy(victim), "replica not re-admitted!"
    post = fd.submit_many("g", "gcn",
                          rng.integers(0, d.n_nodes, size=args.queries))
    fd.run_until_drained(max_ticks=100_000)
    assert all(q.done for q in post if not q.rejected)
    served = {q.replica for q in post if q.done}
    assert len(served) == args.replicas, "revived replica not serving!"
    print(f"  recovery: {victim} re-admitted after hysteresis "
          f"(readmissions={fd.readmissions}), both replicas serving again")

    # 6. live reshard P -> 2P under load -----------------------------------
    with tempfile.TemporaryDirectory() as artifacts:
        mid = fd.submit_many("g", "gcn",
                             rng.integers(0, d.n_nodes, size=args.queries))
        for _ in range(2):
            fd.tick()                  # queries in flight across the swap
        rs = Resharder(reps[0], "g", "gcn", 2 * args.shards,
                       artifact_dir=artifacts, tracer=tracer)
        rs.prepare(block=False)        # P' builds in the background ...
        while not rs.ready:
            fd.tick()                  # ... while the old engine serves
        report = rs.swap()
        fd.run_until_drained(max_ticks=100_000)
        assert report.drain.shed == 0, "reshard dropped queries!"
        assert reps[0].engine.n_shards == 2 * args.shards
        assert all(q.done for q in mid if not q.rejected)
        assert replay_bit_exact(reps[0].engine, single)
        print(f"  reshard: P={report.from_shards} -> P={report.to_shards} "
              f"(prepare {report.prepare_s:.1f}s in background, swap "
              f"{report.swap_s*1e3:.0f}ms), 0 drops, replay bit-exact")

    snap = fd.snapshot()
    print(f"tier summary: {snap['metrics']['queries']} answered | "
          f"failovers {snap['failovers']} | readmissions "
          f"{snap['readmissions']} | retry_shed {shed}")
    for r in reps:
        r.engine.close()
    print("all fault-tolerance invariants held")


if __name__ == "__main__":
    main()
