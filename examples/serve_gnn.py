"""End-to-end GNN serving demo: register a synthetic Table-2 graph, compile
a tuner-selected session, and answer node-classification queries under load
through the micro-batching engine.

    PYTHONPATH=src python examples/serve_gnn.py [--queries 200] [--scale 0.2]

Walkthrough:
  1. GraphStore registers the graph + a briefly STE-trained binary GCN;
  2. ``store.session(tune=True)`` compiles the serving artifact: FRDC
     adjacencies, bit-packed weights, full-graph BN calibration, and the
     variant plan the tuner picked by timing the legal BMM/BSpMM pairings
     on this graph (paper §3.4);
  3. the engine warms the jit shape buckets, then serves the query stream
     through the micro-batched k-hop subgraph path — the jit cache-miss
     counter verifies ZERO steady-state recompiles;
  4. the same stream through the PIPELINED loop (``pipeline_depth=2``):
     extraction of batch i+1 runs on a background worker while batch i's
     jitted forward is in flight — bit-exact vs the serial loop, with the
     overlap ratio and per-stage breakdown reported;
  5. the same queries through the cached full-graph fast path, plus a
     feature-update to show invalidation;
  6. QPS / p50 / p99 and cache counters are printed for all paths;
  7. multi-tenant admission: a rate-limited "hog" tenant floods the engine
     10x over its quota and is throttled/shed with typed rejections while
     a weighted "gold" tenant keeps serving — per-tenant counters and
     latency come out of the same ``snapshot()``;
  8. observability: every batch left a span tree (queue wait / extract /
     launch / compute) in the engine's trace ring buffer — exported here
     as a Perfetto-loadable Chrome trace and a Prometheus text snapshot,
     with the recompile/transfer watchdog counters alongside;
  9. cost accounting + SLOs: a ``CostEstimator`` predicts each query's cost
     units at submit time (k-hop closure via the CSR index) and an
     ``SLOTracker`` turns rejections/latency into error-budget burn. An
     "ml-batch" tenant floods hub-node whales while staying nominally
     under its QPS quota — the COST budget is what throttles it, its burn
     rate breaches both alert windows (a structured ``slo_burn`` warning
     fires into the span tracer), and the feedback loop shrinks its
     effective queue depth, all while the "gold" tenant keeps serving.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import frdc
from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import (AdmissionController, CostEstimator, GNNServeEngine,
                         GraphStore, SLOPolicy, SLOTracker, SpanTracer,
                         TenantPolicy, prometheus_text, write_chrome_trace)


def _report(tag: str, snap: dict) -> None:
    lat = snap["latency"]
    print(f"  [{tag}] {snap['queries']} queries in {snap['elapsed_s']:.2f}s"
          f" -> {snap['qps']:.1f} QPS | p50 {lat['p50_ms']:.2f}ms"
          f" p99 {lat['p99_ms']:.2f}ms | cache hit-rate"
          f" {snap['cache_hit_rate']:.2f} | compiles {snap['compiles']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=30)
    args = ap.parse_args()
    jax.config.update("jax_platform_name", "cpu")

    # 1. graph + briefly-trained binary GCN ---------------------------------
    d = make_dataset("cora", seed=0, scale=args.scale)
    print(f"graph: cora-like, {d.n_nodes} nodes / {d.n_edges} edges / "
          f"{d.x.shape[1]} features / {d.n_classes} classes")
    params = gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1], 32,
                          d.n_classes)
    if args.epochs:
        adj_dense = frdc.to_dense(d.adjacency("gcn"))
        params, loss = gnn.train_node_classifier(
            gnn.gcn_forward_bigcn, params,
            (jnp.asarray(d.x), adj_dense), jnp.asarray(d.y),
            jnp.asarray(d.train_mask), epochs=args.epochs)
        print(f"trained Bi-GCN STE for {args.epochs} epochs, loss {loss:.3f}")

    store = GraphStore(max_batch=args.batch)
    store.register_graph("cora", d)
    store.register_model("gcn", "gcn", params)

    # 2. compile the session (tuner-selected plan) --------------------------
    t0 = time.perf_counter()
    sess = store.session("cora", "gcn", tune=True)
    print(f"compiled session {sess.key!r} in {time.perf_counter()-t0:.1f}s")
    print(f"  plan: {sess.plan.name()}  "
          f"({sess.plan.tuned_latency_s*1e3:.1f}ms full-graph fwd)")

    # 3. micro-batched subgraph serving, zero steady-state recompiles -------
    engine = GNNServeEngine(store, max_batch=args.batch, mode="subgraph")
    warm = engine.warmup("cora", "gcn")
    print(f"warmup: {warm} jit compiles (shape buckets populated)")
    c0 = engine.compile_count
    rng = np.random.default_rng(1)
    nodes = rng.integers(0, d.n_nodes, size=args.queries)
    for i in range(0, nodes.size, args.batch):   # arrival in waves
        engine.submit_many("cora", "gcn", nodes[i:i + args.batch])
        engine.tick()
    engine.run_until_drained()
    steady = engine.compile_count - c0
    _report("subgraph", engine.snapshot())
    print(f"  steady-state recompiles: {steady}")
    assert steady == 0, "jit cache-miss counter moved in steady state!"

    # 4. pipelined serving: overlapped extraction, bit-exact ----------------
    # sample_every=1 records every batch's span tree (the default engine
    # tracer keeps 1-in-16 plus outliers and error paths)
    pipe = GNNServeEngine(store, max_batch=args.batch, mode="subgraph",
                          pipeline_depth=2,
                          tracer=SpanTracer(sample_every=1))
    pipe.warmup("cora", "gcn")
    qp = pipe.submit_many("cora", "gcn", nodes)
    pipe.run_until_drained()
    snap = pipe.snapshot()
    bd = snap["batch_breakdown"]
    print(f"  [pipelined d=2] {snap['qps']:.1f} QPS | overlap ratio "
          f"{snap['overlap_ratio']:.2f} | extract p50 "
          f"{bd['extract']['p50_ms']:.2f}ms / compute p50 "
          f"{bd['compute']['p50_ms']:.2f}ms")
    serial_logits = {q.qid: q.logits for q in engine.finished}
    exact = all(np.array_equal(qp[i].logits, serial_logits[i])
                for i in range(len(qp)))
    assert exact, "pipelined loop diverged from the serial loop!"
    print("  pipelined answers are bit-exact vs the serial loop")
    pipe.close()

    # 5. cached full-graph fast path + invalidation -------------------------
    engine2 = GNNServeEngine(store, max_batch=args.batch, mode="full")
    engine2.submit_many("cora", "gcn", nodes)
    engine2.run_until_drained()
    _report("full-cache", engine2.snapshot())

    x2 = d.x.copy()
    x2[: d.n_nodes // 10] = 0.0                  # feature update
    store.update_features("cora", x2)
    q = engine2.submit_many("cora", "gcn", nodes[:8])
    engine2.run_until_drained()
    snap = engine2.snapshot()
    print(f"  feature update -> invalidations {snap['invalidations']}, "
          f"8 queries re-served from the recomputed cache "
          f"(preds: {[qq.pred for qq in q]})")

    # 6. sanity: served == direct forward -----------------------------------
    direct = gnn.gcn_forward_bitgnn(
        sess.qparams, jnp.asarray(x2), sess._adj_full["adj"],
        sess._adj_full["bin"], scheme=sess.plan.scheme,
        trinary_mode=sess.plan.trinary_mode)
    want = np.argmax(np.asarray(direct)[[qq.node for qq in q]], axis=-1)
    got = np.asarray([qq.pred for qq in q])
    assert (got == want).all(), "served predictions diverged from direct!"
    print("served predictions match the direct *_forward_bitgnn outputs")

    # 7. multi-tenant admission: quotas, shedding, weighted scheduling -------
    admission = AdmissionController(policies={
        "gold": TenantPolicy(weight=4),
        "hog": TenantPolicy(rate_qps=50.0, burst=args.batch,
                            max_queue_depth=2 * args.batch, weight=1),
    })
    mt = GNNServeEngine(store, max_batch=args.batch, mode="full",
                        admission=admission)
    mt.warmup("cora", "gcn")
    for i in range(0, nodes.size, args.batch):
        # the hog floods 10x its share; rejects come back TYPED (throttle
        # with a retry hint, or shed at the queue-depth bound) — they never
        # raise into the serving tick
        hogged = mt.submit_many("cora", "gcn",
                                rng.integers(0, d.n_nodes, 10 * args.batch),
                                tenant="hog")
        mt.submit_many("cora", "gcn", nodes[i:i + args.batch],
                       tenant="gold")
        mt.tick()
        del hogged
    mt.run_until_drained()
    tsnap = mt.snapshot()["tenants"]
    for name in ("gold", "hog"):
        t = tsnap[name]
        print(f"  [tenant {name}] accepted {t['accepted']} | throttled "
              f"{t['throttled']} | shed {t['shed']} (reject-rate "
              f"{t['reject_rate']:.2f}) | served {t['queries']} @ "
              f"{t['qps']:.1f} QPS | p99 {t['latency']['p99_ms']:.2f}ms")
    assert tsnap["gold"]["queries"] == nodes.size, "gold tenant starved!"
    assert tsnap["hog"]["reject_rate"] > 0, "hog was never limited!"
    print("  gold tenant fully served; hog throttled/shed per policy")

    # 8. observability: span traces, watchdogs, exporters --------------------
    trs = pipe.tracer.batch_traces()
    wd = pipe.snapshot()["watchdogs"]
    print(f"  [trace] {len(trs)} batch span trees recorded "
          f"({pipe.tracer.batches_seen} batches seen) | steady recompiles "
          f"{wd['recompile']['steady_recompiles']} | unexpected transfers "
          f"{wd['transfer']['host_sync_in_launch']}")
    t = trs[0]
    print(f"    e.g. trace {t.trace_id}: {len(t.queries)} queries, "
          + ", ".join(f"{s.name} {s.duration_s*1e3:.2f}ms"
                      for s in t.spans))
    write_chrome_trace(pipe.tracer, "/tmp/serve_gnn_trace.json")
    print("    Chrome trace -> /tmp/serve_gnn_trace.json "
          "(load in chrome://tracing or ui.perfetto.dev)")
    prom = prometheus_text(pipe.snapshot(), pipe.tracer)
    print("    Prometheus snapshot (first lines):")
    for line in prom.splitlines()[:4]:
        print(f"      {line}")

    # 9. cost accounting + SLOs: budgets, burn alerts, admission feedback ----
    cost = CostEstimator()
    csr = store.graphs["cora"].csr
    degs = np.asarray(csr.indptr[1:]) - np.asarray(csr.indptr[:-1])
    hubs = np.argsort(degs)[-max(32, args.batch):]
    hub_units = float(np.mean([cost.estimate("cora", int(n), csr).units
                               for n in hubs[-8:]]))
    ce = GNNServeEngine(
        store, max_batch=args.batch, mode="subgraph",
        admission=AdmissionController(policies={
            "gold": TenantPolicy(weight=4),
            # the whale tenant's QPS quota is GENEROUS — only its
            # cost-unit budget (~3 hub queries/s) binds
            "ml-batch": TenantPolicy(rate_qps=500.0, burst=500,
                                     max_queue_depth=2 * args.batch,
                                     cost_rate=3.0 * hub_units,
                                     cost_burst=3.0 * hub_units),
        }),
        cost=cost,
        slo=SLOTracker({
            "ml-batch": SLOPolicy(availability=0.99, window_s=4.0,
                                  short_window_s=0.5, burn_alert=2.0),
            "gold": SLOPolicy(availability=0.999, window_s=4.0),
        }))
    ce.warmup("cora", "gcn")
    for i in range(0, nodes.size, args.batch):
        # hub-band whales: nominally under the QPS limit, way over budget
        ce.submit_many("cora", "gcn", rng.choice(hubs, 2 * args.batch),
                       tenant="ml-batch")
        ce.submit_many("cora", "gcn", nodes[i:i + args.batch],
                       tenant="gold")
        ce.tick()
        ce.tick()
        ce.tick()
    ce.run_until_drained()
    csnap = ce.snapshot()
    for name in ("gold", "ml-batch"):
        t = csnap["tenants"][name]
        s = csnap["slo"]["tenants"][name]
        print(f"  [cost {name}] admitted {t['cost_units']:.0f} units | "
              f"cost-throttled {t['cost_throttled']} | attributed "
              f"{t['attributed_cost_s']*1e3:.1f}ms of service | burn "
              f"{s['burn_long']:.1f} | alerts {s['alerts']} | depth-scale "
              f"{s['depth_scale']:.2f}")
    burns = [w for w in ce.tracer.warning_events() if w.name == "slo_burn"]
    assert csnap["tenants"]["ml-batch"]["cost_throttled"] > 0, \
        "whale tenant was never held to its cost budget!"
    assert burns, "no slo_burn alert fired!"
    assert csnap["tenants"]["gold"]["queries"] == nodes.size
    print(f"  whale tenant held to cost budget ({len(burns)} burn alert(s) "
          f"fired, depth autotuned "
          f"x{csnap['slo']['tenants']['ml-batch']['depth_scale']:.2f}); "
          f"gold tenant fully served")
    print(f"  calibration: {csnap['cost']['batches_observed']} batches, "
          f"units/s {csnap['cost']['units_per_second']:.0f}")


if __name__ == "__main__":
    main()
