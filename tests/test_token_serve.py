"""Token serving tier acceptance: transformer and SSM token sessions are
BITWISE equal to a direct ``jit(decode_step)`` loop with zero steady-state
recompiles, and the shared serving machinery (admission, cost attribution,
span tracing, family-labelled metrics, TTFT stamps) is populated for token
tenants. Plus the deprecated ``repro.serve.engine`` shim's surface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.serve import (CostEstimator, SLOPolicy, SLOTracker,
                         TokenServeEngine, TokenSession, TokenStore,
                         prometheus_text)

jax.config.update("jax_platform_name", "cpu")

ARCHS = {"transformer": "stablelm-1.6b", "ssm": "rwkv6-3b"}


def _cfg(name):
    return reduced_config(get_config(name)).resolve_for_mesh(tp=1)


def direct_reference(cfg, params, prompt, max_new):
    """Ground truth: python loop of jit(decode_step) with argmax feedback —
    the exact program the serving tier must reproduce bitwise."""
    step = jax.jit(lambda p, c, t, pos: transformer.decode_step(
        p, cfg, c, t, pos))
    total = prompt.size + max_new
    cache = transformer.init_cache(
        cfg, 1, max(64, int(2 ** np.ceil(np.log2(total)))))
    out, prev = [], None
    for t in range(prompt.size + max_new - 1):
        tok = prompt[t] if t < prompt.size else prev
        lg, cache = step(params, cache, jnp.asarray([[tok]], jnp.int32), t)
        prev = int(np.argmax(np.asarray(lg[0, 0, :cfg.vocab])))
        if t >= prompt.size - 1:
            out.append(prev)
    return np.asarray(out[:max_new], np.int32)


def _engine(name, **kw):
    cfg = _cfg(ARCHS[name])
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    store = TokenStore(max_batch=3, max_len=128, chunk=4,
                       warm_len=10, warm_new=8)
    store.register_model("lm", cfg, params)
    return cfg, params, TokenServeEngine(store, **kw)


@pytest.mark.parametrize("kind", sorted(ARCHS))
def test_served_bitexact_zero_recompiles_ttft(kind):
    """The acceptance bar: varied prompt lengths and decode budgets across
    micro-batches serve bit-exact vs the direct loop, with ZERO recompiles
    after warmup and a first-token timestamp on every query."""
    cfg, params, eng = _engine(kind, pipeline_depth=1)
    assert eng.warmup("lm") >= 1
    c0 = eng.compile_count
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, ln).astype(np.int32)
               for ln in (2, 5, 10, 1, 7, 3)]
    news = [3, 8, 2, 6, 1, 5]
    qs = [eng.submit("lm", p, max_new=mn) for p, mn in zip(prompts, news)]
    eng.run_until_drained()
    eng.close()
    assert all(q.done for q in qs)
    assert eng.compile_count == c0
    snap = eng.snapshot()
    assert snap["watchdogs"]["recompile"]["steady_recompiles"] == 0
    for q, p, mn in zip(qs, prompts, news):
        assert np.array_equal(q.tokens, direct_reference(cfg, params, p, mn))
        assert q.ttft_s > 0.0
        assert q.t_first_token <= q.t_done


def test_admission_cost_tracing_populated_for_token_tenants():
    """Token tenants flow through the same admission / cost-attribution /
    span-tracing plumbing as GNN tenants, namespaced by model family."""
    cfg, params, eng = _engine(
        "transformer", cost=CostEstimator(),
        slo=SLOTracker({"acme": SLOPolicy(), "blue": SLOPolicy()}))
    eng.warmup("lm")
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit("lm", rng.integers(0, cfg.vocab, 4).astype(np.int32),
                   max_new=3, tenant="acme" if i % 2 else "blue")
    eng.run_until_drained()
    eng.close()
    snap = eng.snapshot()
    assert snap["family"] == "transformer"
    for tenant in ("acme", "blue"):
        t = snap["tenants"][tenant]
        assert t["accepted"] == 3
        assert t["cost_units"] > 0.0
    assert snap["cost"]["queries_estimated"] >= 6
    assert snap["trace"]["batches_seen"] >= 1
    assert "slo" in snap
    text = prometheus_text(snap)
    assert 'family="transformer"' in text


def test_eos_truncates_stream_inclusive():
    cfg = _cfg(ARCHS["transformer"])
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 5).astype(np.int32)
    plain = TokenSession("a", cfg, params, max_batch=2, max_len=64, chunk=4)
    want = plain.run([prompt], [8])[0]
    eos = int(want[2])
    first = int(np.nonzero(want == eos)[0][0])
    stopped = TokenSession("b", cfg, params, max_batch=2, max_len=64,
                           chunk=4, eos_id=eos)
    got = stopped.run([prompt], [8])[0]
    assert np.array_equal(got, want[:first + 1])


def test_param_update_through_store_reaches_engine():
    """Hot-swapping a registered model's params invalidates its session and
    subsequent queries serve under the new weights."""
    cfg, params, eng = _engine("transformer")
    eng.warmup("lm")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    q1 = eng.submit("lm", prompt, max_new=4)
    eng.run_until_drained()
    params2 = transformer.init_params(jax.random.PRNGKey(9), cfg)
    eng.store.update_params("lm", params2)
    q2 = eng.submit("lm", prompt, max_new=4)
    eng.run_until_drained()
    eng.close()
    assert np.array_equal(q1.tokens,
                          direct_reference(cfg, params, prompt, 4))
    assert np.array_equal(q2.tokens,
                          direct_reference(cfg, params2, prompt, 4))
    assert eng.snapshot()["invalidations"] == 1


def test_deprecated_engine_shim_serves_via_token_session():
    """The legacy ``repro.serve.engine`` surface still works (launch/serve
    depends on it) — warning on construction, token-session results."""
    from repro.serve.engine import Request, ServeEngine

    cfg = _cfg(ARCHS["transformer"])
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.warns(DeprecationWarning):
        eng = ServeEngine(cfg, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, ln).astype(np.int32)
               for ln in (3, 6, 4)]
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
    done = eng.run_until_done()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    for r in sorted(done, key=lambda r: r.rid):
        want = direct_reference(cfg, params, prompts[r.rid], 5)
        assert r.out_tokens == want.tolist()
