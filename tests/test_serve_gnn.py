"""Serving subsystem tests: served predictions bit-exact vs. the direct
``*_forward_bitgnn`` calls for all three families, bucket-padding invariance,
cache invalidation on feature update, artifact save/restore."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import frdc
from repro.core.bspmm import bspmm
from repro.graphs import sampling
from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import GNNServeEngine, GraphStore

jax.config.update("jax_platform_name", "cpu")

HIDDEN = 16
BATCH = 8


@pytest.fixture(scope="module")
def data():
    return make_dataset("cora", seed=0, scale=0.1)


@pytest.fixture(scope="module")
def store(data):
    st = GraphStore(max_batch=BATCH)
    st.register_graph("g", data)
    key = jax.random.PRNGKey(0)
    f, c = data.x.shape[1], data.n_classes
    st.register_model("gcn", "gcn", gnn.init_gcn(key, f, HIDDEN, c))
    st.register_model("sage", "sage", gnn.init_sage(key, f, HIDDEN, c))
    st.register_model("saint", "saint", gnn.init_saint(key, f, HIDDEN, c))
    return st


def _direct(store, data, model):
    """The reference: the plain full-graph *_forward_bitgnn call."""
    x = jnp.asarray(data.x)
    sess = store.session("g", model)
    if model == "gcn":
        out = gnn.gcn_forward_bitgnn(
            sess.qparams, x, data.adjacency("gcn"), data.adjacency("binary"),
            scheme=sess.plan.scheme, trinary_mode=sess.plan.trinary_mode)
    elif model == "sage":
        out = gnn.sage_forward_bitgnn(sess.qparams, x,
                                      data.adjacency("mean"))
    else:
        out = gnn.saint_forward_bitgnn(sess.qparams, x,
                                       data.adjacency("binary"))
    return np.asarray(out)


@pytest.mark.parametrize("model", ["gcn", "sage", "saint"])
def test_served_matches_direct_forward(store, data, model):
    """Micro-batched subgraph serving must reproduce the direct full-graph
    forward: identical predictions, logits equal to fp-reassociation noise."""
    ref = _direct(store, data, model)
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph")
    nodes = np.random.default_rng(1).integers(0, data.n_nodes, size=3 * BATCH)
    queries = engine.submit_many("g", model, nodes)
    engine.run_until_drained()
    assert all(q.done for q in queries)
    got = np.stack([q.logits for q in queries])
    want = ref[nodes]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.array([q.pred for q in queries]),
                                  np.argmax(want, axis=-1))


@pytest.mark.parametrize("model", ["gcn", "sage", "saint"])
def test_full_cache_path_matches_direct(store, data, model):
    """The cached full-graph path runs the direct forward once per feature
    version: predictions exactly equal, logits equal up to jit-vs-eager
    fusion rounding; repeat queries replay the identical cached array."""
    ref = _direct(store, data, model)
    engine = GNNServeEngine(store, max_batch=BATCH, mode="full")
    nodes = np.arange(0, data.n_nodes, 7)[:2 * BATCH]
    queries = engine.submit_many("g", model, nodes)
    engine.run_until_drained()
    got = np.stack([q.logits for q in queries])
    np.testing.assert_allclose(got, ref[nodes], rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.argmax(got, -1),
                                  np.argmax(ref[nodes], -1))
    assert engine.metrics.full_cache_hits == len(queries)
    again = engine.submit_many("g", model, nodes)
    engine.run_until_drained()
    np.testing.assert_array_equal(np.stack([q.logits for q in again]), got)


def test_bucket_padding_never_changes_results(data):
    """pad_frdc is exact: decoded matrix and BSpMM outputs are unchanged."""
    m = data.adjacency("gcn")
    pad = frdc.pad_frdc(m, m.n_rows + 37, n_groups=m.n_groups + 11)
    dense, dense_pad = np.asarray(frdc.to_dense(m)), \
        np.asarray(frdc.to_dense(pad))
    np.testing.assert_array_equal(dense_pad[:m.n_rows, :m.n_cols], dense)
    assert not dense_pad[m.n_rows:].any()
    assert not dense_pad[:, m.n_cols:].any()
    x = jax.random.normal(jax.random.PRNGKey(2), (m.n_cols, HIDDEN))
    x_pad = jnp.zeros((pad.n_cols, HIDDEN)).at[:m.n_cols].set(x)
    a = np.asarray(bspmm(m, x, "FBF"))
    b = np.asarray(bspmm(pad, x_pad, "FBF"))
    np.testing.assert_array_equal(b[:m.n_rows], a)


def test_batch_composition_invariance(store, data):
    """The same node served alone and inside a full batch (different shape
    buckets and neighbor subgraphs) must yield the same prediction."""
    ref = _direct(store, data, "gcn")
    sess = store.session("g", "gcn")
    node = int(np.argmax(np.bincount(data.edges[0])))   # a hub node
    alone = sess.serve_subgraph(np.array([node]))[0]
    rng = np.random.default_rng(3)
    batch = np.concatenate([[node], rng.integers(0, data.n_nodes, BATCH - 1)])
    grouped = sess.serve_subgraph(batch)[0]
    np.testing.assert_allclose(alone, grouped, rtol=1e-4, atol=1e-4)
    assert np.argmax(alone) == np.argmax(grouped) == np.argmax(ref[node])


def test_khop_closure_property(data):
    """Every node within k-1 hops of a seed keeps its FULL neighborhood."""
    csr = sampling.to_csr(data.edges, data.n_nodes)
    seeds = np.array([1, 2, 3])
    sub_nodes, sub_edges, seed_pos = sampling.khop_subgraph(csr, seeds, 2)
    np.testing.assert_array_equal(sub_nodes[seed_pos], seeds)
    in_sub = np.zeros(data.n_nodes, bool)
    in_sub[sub_nodes] = True
    deg_sub = np.bincount(sub_edges[0], minlength=sub_nodes.size)
    for s in seeds:                       # distance 0 <= k-1: full rows
        pos = int(np.searchsorted(sub_nodes, s))
        nbrs = csr.neighbors(int(s))
        assert in_sub[nbrs].all()
        assert deg_sub[pos] == nbrs.size


def test_feature_update_invalidates_sessions(data):
    """update_features bumps the version; both serve paths recalibrate and
    answer from the NEW features, matching a fresh direct forward."""
    st = GraphStore(max_batch=BATCH)
    d2 = make_dataset("cora", seed=0, scale=0.1)
    st.register_graph("g", d2)
    st.register_model("gcn", "gcn",
                      gnn.init_gcn(jax.random.PRNGKey(0), d2.x.shape[1],
                                   HIDDEN, d2.n_classes))
    engine = GNNServeEngine(st, max_batch=BATCH, mode="full")
    nodes = np.arange(BATCH)
    engine.submit_many("g", "gcn", nodes)
    engine.run_until_drained()
    before = np.stack([q.logits for q in engine.finished])

    x2 = d2.x.copy()
    x2[: d2.n_nodes // 5] = 0.0
    st.update_features("g", x2)
    sess = st.session("g", "gcn")
    ref2 = np.asarray(gnn.gcn_forward_bitgnn(
        sess.qparams, jnp.asarray(x2), d2.adjacency("gcn"),
        d2.adjacency("binary"), scheme=sess.plan.scheme,
        trinary_mode=sess.plan.trinary_mode))

    qs = engine.submit_many("g", "gcn", nodes)
    engine.run_until_drained()
    after = np.stack([q.logits for q in qs])
    np.testing.assert_allclose(after, ref2[nodes], rtol=1e-5, atol=1e-5)
    assert not np.allclose(after, before, rtol=1e-3, atol=1e-3)
    assert sess.invalidations == 1

    # subgraph path also serves from the new features
    sub = sess.serve_subgraph(nodes[:4])
    np.testing.assert_allclose(sub, ref2[nodes[:4]], rtol=1e-4, atol=1e-4)

    with pytest.raises(ValueError):
        st.update_features("g", x2[:, :10])   # feature width is fixed


def test_incremental_update_matches_full_recompute(data):
    """Incremental mode patches ONLY the k-hop out-neighborhood (reverse-edge
    closure) of the changed rows under frozen BN stats — and the patched
    cache equals a full recompute with the same frozen stats."""
    st = GraphStore(max_batch=BATCH, incremental=True)
    d2 = make_dataset("cora", seed=0, scale=0.1)
    st.register_graph("g", d2)
    st.register_model("gcn", "gcn",
                      gnn.init_gcn(jax.random.PRNGKey(0), d2.x.shape[1],
                                   HIDDEN, d2.n_classes))
    sess = st.session("g", "gcn")
    before = sess.full_logits().copy()
    bn0 = sess.bn

    changed = np.array([3, 17, 40])
    x2 = d2.x.copy()
    x2[changed] += 1.0
    st.update_features("g", x2)
    inc = sess.full_logits()
    assert sess.incremental_refreshes == 1
    # BN calibration stayed frozen (that is the incremental-mode contract)
    for a, b in zip(bn0, sess.bn):
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))

    # the oracle: a FULL recompute with the same frozen stats
    ref = np.asarray(sess._jit_full_frozen(jnp.asarray(x2), bn0))
    affected = sampling.khop_nodes(sess.graph.csr_rev, changed, 2)
    unaffected = np.setdiff1d(np.arange(d2.n_nodes), affected)
    assert 0 < affected.size < d2.n_nodes
    np.testing.assert_array_equal(inc[unaffected], ref[unaffected])
    np.testing.assert_array_equal(inc[unaffected], before[unaffected])
    np.testing.assert_allclose(inc[affected], ref[affected],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.argmax(inc, -1), np.argmax(ref, -1))

    # a second, larger update takes the frozen-stats full-pass patch branch
    x3 = x2.copy()
    x3[: d2.n_nodes // 2] -= 0.5
    st.update_features("g", x3)
    inc3 = sess.full_logits()
    ref3 = np.asarray(sess._jit_full_frozen(jnp.asarray(x3), bn0))
    np.testing.assert_allclose(inc3, ref3, rtol=1e-5, atol=1e-5)
    assert sess.incremental_refreshes == 2


def test_serve_with_pallas_kernels_flag(data):
    """use_pallas routes the bucketed forward's BSpMM through the Pallas
    kernels (interpret mode on CPU under force_kernels; silent fallback to
    the reference path otherwise) — answers must not change."""
    from repro.kernels import ops
    tiny = make_dataset("cora", seed=0, scale=0.03)
    key = jax.random.PRNGKey(0)
    params = gnn.init_gcn(key, tiny.x.shape[1], 8, tiny.n_classes)
    nodes = np.arange(4)

    st_ref = GraphStore(max_batch=4)
    st_ref.register_graph("t", tiny)
    st_ref.register_model("gcn", "gcn", params)
    ref = st_ref.session("t", "gcn").serve_subgraph(nodes)

    # off-TPU without force_kernels the flag is a documented no-op
    st_fb = GraphStore(max_batch=4, use_pallas=True)
    st_fb.register_graph("t", tiny)
    st_fb.register_model("gcn", "gcn", params)
    np.testing.assert_array_equal(
        st_fb.session("t", "gcn").serve_subgraph(nodes), ref)

    # force_kernels actually exercises the kernels (bucket-padded FRDC)
    ops.force_kernels(True)
    try:
        st_k = GraphStore(max_batch=4, use_pallas=True)
        st_k.register_graph("t", tiny)
        st_k.register_model("gcn", "gcn", params)
        got = st_k.session("t", "gcn").serve_subgraph(nodes)
    finally:
        ops.force_kernels(False)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.argmax(got, -1), np.argmax(ref, -1))


def test_session_artifact_roundtrip(tmp_path, data):
    """save/load through the checkpointer reproduces plan + outputs; a
    feature change invalidates the artifact (fingerprint mismatch)."""
    params = gnn.init_gcn(jax.random.PRNGKey(0), data.x.shape[1], HIDDEN,
                          data.n_classes)
    st1 = GraphStore(cache_dir=str(tmp_path), max_batch=BATCH)
    st1.register_graph("g", make_dataset("cora", seed=0, scale=0.1))
    st1.register_model("gcn", "gcn", params)
    s1 = st1.session("g", "gcn", tune=True, tune_repeats=1)
    assert s1.plan.family == "gcn" and s1.plan.scheme in ("full", "bin")
    assert np.isfinite(s1.plan.tuned_latency_s)

    st2 = GraphStore(cache_dir=str(tmp_path), max_batch=BATCH)
    st2.register_graph("g", make_dataset("cora", seed=0, scale=0.1))
    st2.register_model("gcn", "gcn", params)
    s2 = st2.session("g", "gcn")          # restored, not re-tuned
    p1, p2 = s1.plan.to_json(), s2.plan.to_json()
    d1, d2 = p1.pop("output_delta"), p2.pop("output_delta")
    assert p1 == p2
    assert (d1 == d2) or (np.isnan(d1) and np.isnan(d2))
    np.testing.assert_array_equal(s1.full_logits(), s2.full_logits())

    # different features -> stale artifact rejected -> fresh compile
    st3 = GraphStore(cache_dir=str(tmp_path), max_batch=BATCH)
    d3 = make_dataset("cora", seed=0, scale=0.1)
    d3.x[:5] = 1.0
    st3.register_graph("g", d3)
    st3.register_model("gcn", "gcn", params)
    from repro.serve.gnn_session import CompiledGraphSession
    assert CompiledGraphSession.load(tmp_path / "g__gcn",
                                     st3.graphs["g"],
                                     st3.models["gcn"]) is None


def test_zero_steady_state_recompiles(store, data):
    """After warmup the jit cache-miss counter must not move."""
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph")
    engine.warmup("g", "sage")
    c0 = engine.compile_count
    rng = np.random.default_rng(5)
    for _ in range(6):
        engine.submit_many("g", "sage",
                           rng.integers(0, data.n_nodes,
                                        rng.integers(1, BATCH + 1)))
        engine.tick()
    engine.run_until_drained()
    assert engine.compile_count == c0
    snap = engine.snapshot()
    assert snap["queries"] >= 6 and snap["qps"] > 0
    assert snap["latency"]["p99_ms"] >= snap["latency"]["p50_ms"]
