"""Multi-tenant admission control + weighted fair scheduling tests.

Controller-level tests drive :class:`AdmissionController` with injected
clocks and synthetic queues (fully deterministic); engine-level tests check
the guarantees end-to-end: rate-limit enforcement at ``submit()``, shedding
under overload, proportional drain by weight, starvation-freedom via the
staleness bound, and — the serving invariant — tenant-tagged answers
bit-exact vs the tenant-less engine on the replayed ``batch_log``.
"""
import time
from collections import deque

import numpy as np
import jax
import pytest

from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import (AdmissionController, GNNServeEngine, GraphStore,
                         ShardedServeEngine, TenantPolicy)
from repro.serve.admission import ACCEPT, SHED, THROTTLE

jax.config.update("jax_platform_name", "cpu")

HIDDEN = 16
BATCH = 8


@pytest.fixture(scope="module")
def data():
    return make_dataset("cora", seed=0, scale=0.1)


@pytest.fixture(scope="module")
def store(data):
    st = GraphStore(max_batch=BATCH)
    st.register_graph("g", data)
    key = jax.random.PRNGKey(0)
    st.register_model("gcn", "gcn", gnn.init_gcn(key, data.x.shape[1],
                                                 HIDDEN, data.n_classes))
    return st


# ------------------------------------------------------------ controller ---

def test_policy_validation():
    with pytest.raises(ValueError):
        TenantPolicy(rate_qps=0.0)
    with pytest.raises(ValueError):
        TenantPolicy(weight=0)
    with pytest.raises(ValueError):
        TenantPolicy(weight=1.5)
    with pytest.raises(ValueError):
        TenantPolicy(burst=0.5)
    with pytest.raises(ValueError):
        TenantPolicy(max_queue_depth=0)
    # defaults: unlimited rate, one second of burst at finite rates
    assert TenantPolicy().bucket_capacity == float("inf")
    assert TenantPolicy(rate_qps=40.0).bucket_capacity == 40.0
    assert TenantPolicy(rate_qps=0.25).bucket_capacity == 1.0


def test_token_bucket_rate_limit_deterministic():
    """Rate-limit enforcement with an injected clock: ``burst`` tokens up
    front, then exactly ``rate_qps`` admissions per second, with a
    ``retry_after_s`` hint on every throttle."""
    ctl = AdmissionController(
        policies={"t": TenantPolicy(rate_qps=2.0, burst=2)})
    assert ctl.admit("t", now=0.0).accepted
    assert ctl.admit("t", now=0.0).accepted
    d = ctl.admit("t", now=0.0)
    assert d.action == THROTTLE and not d.accepted
    assert d.retry_after_s == pytest.approx(0.5)
    # half a second refills exactly one token at 2 qps
    assert ctl.admit("t", now=0.5).accepted
    assert ctl.admit("t", now=0.5).action == THROTTLE
    # an unknown tenant falls back to the (unlimited) default policy
    for _ in range(100):
        assert ctl.admit("other", now=0.0).accepted


def test_depth_shed_checked_before_rate():
    """A shed (overload) submission must not also burn a rate token."""
    ctl = AdmissionController(
        policies={"t": TenantPolicy(rate_qps=1.0, burst=1,
                                    max_queue_depth=1)})
    assert ctl.admit("t", now=0.0).accepted
    ctl.on_enqueued("t")
    d = ctl.admit("t", now=0.0)
    assert d.action == SHED                      # depth, not rate
    ctl.on_served("t", 1)                        # queue drains
    assert ctl.backlog("t") == 0
    d = ctl.admit("t", now=0.0)
    assert d.action == THROTTLE                  # bucket empty, depth free


class _Q:
    def __init__(self, t):
        self.t_submit = t


def _fill(ctl, queues, key, tenant, times):
    dq = queues.setdefault(key, deque())
    for t in times:
        dq.append(_Q(t))
        if len(dq) == 1:
            ctl.push_head(key, tenant, t)


def test_weighted_pick_proportional():
    """Start-time fair queueing: a weight-3 tenant drains 3x faster than a
    weight-1 tenant under continuous backlog (exact virtual-time math,
    staleness pinned out of the way)."""
    ctl = AdmissionController(
        policies={"a": TenantPolicy(weight=3), "b": TenantPolicy(weight=1)},
        staleness_bound_s=1e9)
    queues = {}
    ka, kb = ("g", "m", "a"), ("g", "m", "b")
    _fill(ctl, queues, ka, "a", [i * 0.2 for i in range(20)])
    _fill(ctl, queues, kb, "b", [0.1 + i * 0.2 for i in range(20)])
    served = []
    for _ in range(12):
        key = ctl.pick(queues, now=4.0)
        queues[key].popleft()
        ctl.on_served(key[-1], 1)
        served.append(key[-1])
    assert served.count("a") == 9
    assert served.count("b") == 3


def test_staleness_override_starvation_free():
    """An overdue head preempts the virtual-time order: a weight-1 tenant
    whose last service left it with heavy virtual-time debt against a
    weight-100 firehose is still served once its head crosses the
    staleness bound (overdue heads drain globally FIFO)."""
    ctl = AdmissionController(
        policies={"hog": TenantPolicy(weight=100),
                  "meek": TenantPolicy(weight=1)},
        staleness_bound_s=10.0)
    queues = {}
    kh, km = ("g", "m", "hog"), ("g", "m", "meek")
    _fill(ctl, queues, kh, "hog", [0.02 * i for i in range(50)])
    _fill(ctl, queues, km, "meek", [0.01, 0.03])
    # the first meek service charges it a full 1/weight = 1.0 of virtual
    # time; the hog pays only 0.01 per query
    order = []
    for _ in range(8):
        key = ctl.pick(queues, now=0.2)
        queues[key].popleft()
        ctl.on_served(key[-1], 1)
        order.append(key[-1])
    assert order.count("meek") == 1               # its one fair early turn
    # by virtual time alone the hog would now hold the next ~90 turns;
    # once meek's remaining head is overdue it wins anyway, FIFO among
    # the (also overdue) hog heads because it is the oldest
    assert ctl.pick(queues, now=0.2) == kh        # nothing overdue yet
    assert ctl.pick(queues, now=20.0) == km       # staleness preempts
    queues[km].popleft()
    ctl.on_served("meek", 1)
    assert ctl.pick(queues, now=20.0) == kh


def test_tenant_state_pruned_when_quiescent():
    """High-cardinality tenant ids must not grow the controller without
    bound: drained heaps drop at peek time, and the periodic sweep removes
    refilled buckets / zero backlogs (exact equivalences) plus idle
    tenants' virtual-time tags (forgiving at most one batch/weight of
    residual debt — fair-queueing re-arrival semantics)."""
    ctl = AdmissionController(
        policies={"limited": TenantPolicy(rate_qps=100.0, burst=1)})
    queues = {}
    for i in range(50):
        tenant = f"u{i}"
        key = ("g", "m", tenant)
        assert ctl.admit(tenant, now=0.0).accepted
        ctl.on_enqueued(tenant)
        _fill(ctl, queues, key, tenant, [0.01 * i])
    for _ in range(50):                        # serve everything
        key = ctl.pick(queues, now=1.0)
        queues[key].popleft()
        ctl.on_served(key[-1], 1)
    assert ctl.pick(queues, now=1.0) is None   # drained -> heaps pruned
    assert not ctl._heaps
    # the sweep clears quiescent buckets/vtime/backlog (forced directly;
    # in production it runs every SWEEP_EVERY admits)
    assert ctl.admit("limited", now=10.0).accepted    # bucket now empty
    ctl._sweep(now=1000.0)                     # long idle: all refilled
    assert not ctl._buckets and not ctl._backlog and not ctl._vtime
    # pruning changed no decision: the limited tenant still gets exactly
    # one token per 10ms at 100 qps
    assert ctl.admit("limited", now=1000.0).accepted
    assert ctl.admit("limited", now=1000.0).action == THROTTLE


def test_requeue_restores_backlog():
    ctl = AdmissionController(
        policies={"t": TenantPolicy(max_queue_depth=2)})
    assert ctl.admit("t").accepted
    ctl.on_enqueued("t")
    ctl.on_served("t", 1)
    ctl.on_requeued("t", 1)
    assert ctl.backlog("t") == 1
    assert ctl.admit("t").accepted                 # depth 1 < 2
    ctl.on_enqueued("t")
    assert ctl.admit("t").action == SHED


# ---------------------------------------------------------------- engine ---

def test_engine_rate_limit_enforced(store, data):
    """Throttled submissions bounce back typed (never queued, never an
    exception in a tick) and the admitted ones are served normally."""
    admission = AdmissionController(
        policies={"lim": TenantPolicy(rate_qps=1e-3, burst=4)})
    engine = GNNServeEngine(store, max_batch=BATCH, mode="full",
                            admission=admission)
    engine.warmup("g", "gcn")
    qs = engine.submit_many("g", "gcn", np.arange(10), tenant="lim")
    accepted = [q for q in qs if not q.rejected]
    rejected = [q for q in qs if q.rejected]
    assert len(accepted) == 4                      # the burst capacity
    assert all(q.admission.action == THROTTLE for q in rejected)
    assert all(q.admission.retry_after_s > 0 for q in rejected)
    assert engine.pending == 4
    engine.run_until_drained()
    assert all(q.done for q in accepted)
    assert not any(q.done for q in rejected)
    snap = engine.snapshot()
    tm = snap["tenants"]["lim"]
    assert tm["accepted"] == 4 and tm["throttled"] == 6 and tm["shed"] == 0
    assert tm["queries"] == 4
    # rates stay consistent with their counters: throttles are not sheds
    assert tm["shed_rate"] == 0.0
    assert tm["throttle_rate"] == pytest.approx(0.6)
    assert tm["reject_rate"] == pytest.approx(0.6)


def test_engine_shed_under_overload(store, data):
    """Beyond ``max_queue_depth`` queued requests, submissions are shed —
    and admission recovers once the backlog drains."""
    admission = AdmissionController(
        policies={"t": TenantPolicy(max_queue_depth=6)})
    engine = GNNServeEngine(store, max_batch=BATCH, mode="full",
                            admission=admission)
    engine.warmup("g", "gcn")
    qs = engine.submit_many("g", "gcn", np.arange(10), tenant="t")
    assert [q.rejected for q in qs] == [False] * 6 + [True] * 4
    assert all(q.admission.action == SHED for q in qs[6:])
    engine.run_until_drained()
    q = engine.submit("g", "gcn", 0, tenant="t")   # backlog drained
    assert q.admission.action == ACCEPT
    engine.run_until_drained()
    assert q.done


def test_engine_priority_proportionality(store, data):
    """With both tenants continuously backlogged, served batches follow the
    3:1 weighted virtual-time schedule."""
    admission = AdmissionController(
        policies={"a": TenantPolicy(weight=3), "b": TenantPolicy(weight=1)},
        staleness_bound_s=600.0)
    engine = GNNServeEngine(store, max_batch=1, mode="full",
                            admission=admission)
    engine.warmup("g", "gcn")
    for i in range(10):                            # interleaved arrival
        engine.submit("g", "gcn", i, tenant="a")
        engine.submit("g", "gcn", i, tenant="b")
    engine.run_until_drained()
    order = [b[0].tenant for b in engine.batch_log]
    assert order[:12].count("a") == 9
    assert order[:12].count("b") == 3


def test_engine_starvation_freedom(store, data):
    """A request overdue past the staleness bound is served next even when
    its tenant's virtual time is far behind a high-weight competitor."""
    admission = AdmissionController(
        policies={"hog": TenantPolicy(weight=100),
                  "meek": TenantPolicy(weight=1)},
        staleness_bound_s=0.5)
    engine = GNNServeEngine(store, max_batch=2, mode="full",
                            admission=admission)
    engine.warmup("g", "gcn")
    for i in range(8):
        engine.submit("g", "gcn", i, tenant="hog")
    q_meek = engine.submit("g", "gcn", 0, tenant="meek")
    q_meek.t_submit -= 10.0                        # overdue beyond the bound
    engine.tick()
    assert engine.batch_log[-1][0].tenant == "meek"
    assert q_meek.done
    engine.run_until_drained()


def test_tenant_answers_bit_exact_vs_tenantless(store, data):
    """Tenant-tagged serving changes WHEN queries are served and how they
    co-batch, never what is computed: the admission-free engine replaying
    the tenanted engine's actual ``batch_log`` compositions produces
    bit-identical logits (and so does the raw single-host session)."""
    nodes = np.random.default_rng(11).integers(0, data.n_nodes,
                                               size=4 * BATCH)
    admission = AdmissionController(
        policies={"a": TenantPolicy(weight=2), "b": TenantPolicy(weight=1)},
        staleness_bound_s=600.0)
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph",
                            admission=admission)
    engine.warmup("g", "gcn")
    qs = []
    for i, n in enumerate(nodes):
        qs.append(engine.submit("g", "gcn", n,
                                tenant=("a" if i % 3 else "b")))
    engine.run_until_drained()
    assert all(q.done for q in qs)

    session = store.session("g", "gcn")
    replay = GNNServeEngine(store, max_batch=BATCH, mode="subgraph")
    replay.warmup("g", "gcn")
    for batch in engine.batch_log:
        assert len({q.tenant for q in batch}) == 1      # never mixed
        # the raw session on the same composition
        want = session.serve_subgraph(np.asarray([q.node for q in batch]))
        np.testing.assert_array_equal(
            np.stack([q.logits for q in batch]), want)
        # the admission-free ENGINE replaying the same composition
        rq = replay.submit_many("g", "gcn", [q.node for q in batch])
        replay.run_until_drained()
        np.testing.assert_array_equal(
            np.stack([q.logits for q in batch]),
            np.stack([r.logits for r in rq]))


def test_sharded_tenant_batches_single_owner_bit_exact(store, data):
    """Tenancy composes with the sharded engine: queues are keyed by
    (owner, tenant), so every served batch is single-owner AND
    single-tenant, and the replayed batch_log stays bit-exact vs the
    single-host session."""
    admission = AdmissionController(
        policies={"a": TenantPolicy(weight=2), "b": TenantPolicy(weight=1)},
        staleness_bound_s=600.0)
    engine = ShardedServeEngine(store, 2, max_batch=BATCH, mode="subgraph",
                                staleness_s=600.0, admission=admission)
    engine.warmup("g", "gcn")
    nodes = np.random.default_rng(13).integers(0, data.n_nodes,
                                               size=4 * BATCH)
    for i, n in enumerate(nodes):
        engine.submit("g", "gcn", n, tenant=("a" if i % 2 else "b"))
    engine.run_until_drained()
    sess = store.sharded_session("g", "gcn", 2)
    single = store.session("g", "gcn")
    assert engine.batch_log
    for batch in engine.batch_log:
        owners = sess.routing.owner(np.asarray([q.node for q in batch]))
        assert np.unique(owners).size == 1
        assert len({q.tenant for q in batch}) == 1
        want = single.serve_subgraph(np.asarray([q.node for q in batch]))
        np.testing.assert_array_equal(
            np.stack([q.logits for q in batch]), want)
    engine.close()


def test_overloaded_hog_is_limited_good_tenant_p99_holds(store, data):
    """The acceptance scenario: one tenant submits 10x over its rate limit;
    it is throttled/shed per policy while the well-behaved tenant's p99
    stays within 2x of its solo run (plus a small absolute floor — the
    full-cache service path is sub-millisecond, where scheduler noise
    dominates any ratio)."""
    rng = np.random.default_rng(17)
    good_nodes = rng.integers(0, data.n_nodes, size=6 * BATCH)

    def run(with_hog: bool):
        admission = AdmissionController(
            policies={
                "good": TenantPolicy(weight=4),
                # depth below burst so BOTH reject paths trigger: early
                # rounds shed at the depth bound while tokens remain,
                # later rounds throttle once the bucket is drained
                "hog": TenantPolicy(rate_qps=1e-3, burst=2 * BATCH,
                                    max_queue_depth=BATCH, weight=1),
            })
        engine = GNNServeEngine(store, max_batch=BATCH, mode="full",
                                admission=admission)
        engine.warmup("g", "gcn")
        hogged = 0
        for i in range(0, good_nodes.size, BATCH):
            if with_hog:                 # 10x the good tenant's volume
                for _ in range(10 * BATCH):
                    q = engine.submit("g", "gcn",
                                      int(rng.integers(0, data.n_nodes)),
                                      tenant="hog")
                    hogged += 0 if q.rejected else 1
            engine.submit_many("g", "gcn", good_nodes[i:i + BATCH],
                               tenant="good")
            engine.tick()
        engine.run_until_drained()
        if with_hog:
            # backlog drained, token bucket long empty: the hog's next
            # wave draws pure rate-limit throttles (with retry hints)
            for q in [engine.submit("g", "gcn", 0, tenant="hog")
                      for _ in range(BATCH)]:
                assert q.rejected and q.admission.retry_after_s > 0
        return engine.snapshot(), hogged

    solo, _ = run(False)
    mixed, hog_admitted = run(True)
    good = mixed["tenants"]["good"]
    hog = mixed["tenants"]["hog"]
    # the hog was limited: burst + depth bound what got through, the rest
    # came back typed (both reject kinds observed)
    assert hog["throttled"] > 0 and hog["shed"] > 0
    assert hog["reject_rate"] > 0.9
    assert hog_admitted == hog["accepted"] <= 3 * BATCH
    # every admitted good query answered, p99 within 2x of the solo run
    assert good["queries"] == good_nodes.size
    p99_solo = solo["tenants"]["good"]["latency"]["p99_ms"]
    p99_mixed = good["latency"]["p99_ms"]
    assert p99_mixed <= 2.0 * p99_solo + 50.0


def test_snapshot_reports_default_tenant(store, data):
    """Tenant-less traffic lands in the 'default' tenant's breakdown, so
    existing callers see their counters without opting into tenancy."""
    engine = GNNServeEngine(store, max_batch=BATCH, mode="full")
    engine.warmup("g", "gcn")
    engine.submit_many("g", "gcn", np.arange(BATCH))
    engine.run_until_drained()
    snap = engine.snapshot()
    tm = snap["tenants"]["default"]
    assert tm["accepted"] == BATCH and tm["queries"] == BATCH
    assert tm["throttled"] == 0 and tm["shed"] == 0
    assert tm["latency"]["count"] == BATCH
    assert tm["qps"] > 0
