"""Chunked SSM algorithms vs naive SEQUENTIAL oracles.

The chunked Mamba2/RWKV6 implementations (O(T/Q * Q^2) MXU form) must agree
with a literal per-timestep recurrence — the strongest correctness evidence
for the recurrence algebra (decay cumsums, inter/intra split, carry terms).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import ssm

jax.config.update("jax_platform_name", "cpu")


def _mamba_sequential(params, x, cfg):
    """Literal recurrence: S_t = exp(dt_t A) S_{t-1} + dt_t B_t x_t^T."""
    b, t, d = x.shape
    h = cfg.ssm_heads_padded or cfg.ssm_heads
    p_dim, n = cfg.ssm_head_dim, cfg.ssm_state
    from repro.models.layers import linear
    z = linear(params["wz"], x)
    xh = linear(params["wx"], x)
    xh, _ = ssm._causal_conv(xh, params["conv_w"])
    xh = jax.nn.silu(xh)
    bmat = linear(params["wB"], x).astype(jnp.float32)
    cmat = linear(params["wC"], x).astype(jnp.float32)
    dt = jax.nn.softplus(linear(params["wdt"], x).astype(jnp.float32)
                         + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    xs = xh.reshape(b, t, h, p_dim).astype(jnp.float32)

    s = np.zeros((b, h, p_dim, n), np.float32)
    ys = []
    for i in range(t):
        dec = np.exp(np.asarray(dt[:, i] * a))[..., None, None]
        contrib = np.einsum("bh,bn,bhp->bhpn", np.asarray(dt[:, i]),
                            np.asarray(bmat[:, i]), np.asarray(xs[:, i]))
        s = s * dec + contrib
        y = np.einsum("bn,bhpn->bhp", np.asarray(cmat[:, i]), s)
        ys.append(y)
    y = jnp.asarray(np.stack(ys, axis=1))
    y = y + np.asarray(params["D"])[None, None, :, None] * xs
    y = y.reshape(b, t, h * p_dim).astype(x.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6).astype(y.dtype)) * params["norm_scale"]
    return linear(params["wo"], y)


def _rwkv_wkv_sequential(r, k, v, logw, u):
    """Literal RWKV6 wkv: y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)."""
    b, t, h, hk = np.asarray(r).shape
    s = np.zeros((b, h, hk, hk), np.float64)
    ys = []
    rn, kn, vn = np.asarray(r, np.float64), np.asarray(k, np.float64), \
        np.asarray(v, np.float64)
    wn, un = np.exp(np.asarray(logw, np.float64)), np.asarray(u, np.float64)
    for i in range(t):
        kv = np.einsum("bhk,bhv->bhkv", kn[:, i], vn[:, i])
        y = np.einsum("bhk,bhkv->bhv", rn[:, i],
                      s + un[None, :, :, None] * kv)
        s = s * wn[:, i][..., None] + kv
        ys.append(y)
    return np.stack(ys, axis=1)


@pytest.mark.parametrize("t", [1, 7, 256, 300])
def test_mamba_chunked_matches_sequential(t):
    cfg = reduced_config(get_config("zamba2-1.2b")).resolve_for_mesh(tp=1)
    params = ssm.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, t, cfg.d_model),
                          jnp.float32) * 0.5
    got, _ = ssm.mamba_block(params, x, cfg, unroll=True)
    want = _mamba_sequential(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("t", [1, 5, 64, 100, 200])
def test_rwkv_wkv_chunked_matches_sequential(t):
    """Drive the inner wkv through the public block twice: chunked (unroll)
    vs a scratch-built sequential oracle on identical projections."""
    cfg = reduced_config(get_config("rwkv6-3b")).resolve_for_mesh(tp=1)
    params = ssm.init_rwkv(jax.random.PRNGKey(0), cfg, jnp.float32)
    # tame the decay lora so exp() ranges stay numerically comparable
    params["w0"] = -2.0 * jnp.ones_like(params["w0"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, t, cfg.d_model),
                          jnp.float32) * 0.3

    got, _ = ssm.rwkv_time_mix(params, x, cfg, unroll=True)

    # recompute projections exactly as the block does, then run the oracle
    from repro.models.layers import linear
    b = x.shape[0]
    h = cfg.ssm_heads_padded or (cfg.d_model // cfg.ssm_head_dim)
    hk = cfg.ssm_head_dim
    xr = ssm._token_shift(x, params["mu"][0])
    xk = ssm._token_shift(x, params["mu"][1])
    xv = ssm._token_shift(x, params["mu"][2])
    xw = ssm._token_shift(x, params["mu"][3])
    xg = ssm._token_shift(x, params["mu"][4])
    r = linear(params["wr"], xr).reshape(b, t, h, hk)
    k = linear(params["wk"], xk).reshape(b, t, h, hk)
    v = linear(params["wv"], xv).reshape(b, t, h, hk)
    g = jax.nn.silu(linear(params["wg"], xg))
    lora = jnp.tanh(xw @ params["wA"]) @ params["wB"]
    logw = -jnp.exp(jnp.clip(params["w0"] + lora, -8.0, 8.0))
    logw = jnp.maximum(logw, -ssm._CLAMP).reshape(b, t, h, hk)
    u = params["u"].reshape(h, hk)

    y = _rwkv_wkv_sequential(r, k, v, logw, u)
    y = jnp.asarray(y, jnp.float32)
    mu_ = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = ((y - mu_) * jax.lax.rsqrt(var + 1e-5)).reshape(b, t, h * hk)
    y = (y * params["ln_scale"]) * g
    want = linear(params["wo"], y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("name", ["zamba2-1.2b", "rwkv6-3b"])
@pytest.mark.parametrize("quant", [False, True])
def test_decode_chunk_bitexact_vs_stepwise(name, quant):
    """Model-level chunked decode == stepwise decode, BITWISE.

    ``transformer.decode_chunk`` (a lax.scan of the exact ``decode_step``
    body — the program the token serving tier launches per chunk) must
    reproduce a python loop of ``jit(decode_step)`` exactly: every logit
    AND every cache leaf (KV rows, SSM state, conv tail), for the mamba
    hybrid and the pure-rwkv stack, quantized and not. Any drift here
    would break the serving tier's bit-exactness guarantee."""
    from repro.models import transformer
    from repro.quant.binary_linear import quantize_params

    cfg = reduced_config(get_config(name)).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    if quant:
        params = quantize_params(params)
    b, t, cache_len = 2, 9, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab)

    step = jax.jit(lambda c, tok, pos: transformer.decode_step(
        params, cfg, c, tok, pos))
    cache_s = transformer.init_cache(cfg, b, cache_len)
    rows = []
    for i in range(t):
        lg, cache_s = step(cache_s, tokens[:, i:i + 1], jnp.int32(i))
        rows.append(np.asarray(lg[:, 0]))
    want = np.stack(rows, axis=1)

    cache_c = transformer.init_cache(cfg, b, cache_len)
    got, cache_c = transformer.decode_chunk(params, cfg, cache_c, tokens,
                                            jnp.int32(0))
    assert np.array_equal(np.asarray(got), want)

    leaves_s = jax.tree_util.tree_leaves(cache_s)
    leaves_c = jax.tree_util.tree_leaves(cache_c)
    assert len(leaves_s) == len(leaves_c)
    for ls, lc in zip(leaves_s, leaves_c):
        assert np.array_equal(np.asarray(ls), np.asarray(lc))


def test_mamba_decode_matches_chunked_prefix():
    """Decoding token-by-token reproduces the chunked forward's last output."""
    cfg = reduced_config(get_config("zamba2-1.2b")).resolve_for_mesh(tp=1)
    params = ssm.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    t = 12
    x = jax.random.normal(jax.random.PRNGKey(2), (1, t, cfg.d_model),
                          jnp.float32) * 0.5
    full, _ = ssm.mamba_block(params, x, cfg, unroll=True)
    cache = {"S": jnp.zeros((1, cfg.ssm_heads_padded or cfg.ssm_heads,
                             cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
             "conv": jnp.zeros((1, 3, (cfg.ssm_heads_padded or cfg.ssm_heads)
                                * cfg.ssm_head_dim), jnp.float32)}
    outs = []
    for i in range(t):
        y, cache = ssm.mamba_block(params, x[:, i:i + 1], cfg, unroll=True,
                                   cache=cache)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got[:, -1]),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)
