"""Unit tests for dry-run machinery that don't require the 512-device env:
input_specs shapes, probe-plan math, roofline term arithmetic."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config, shapes_for
from repro.configs.base import ShapeConfig
from repro.train.train_step import input_specs

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("arch", ["smollm-135m", "llava-next-34b",
                                  "seamless-m4t-medium", "rwkv6-3b",
                                  "zamba2-1.2b", "qwen2-moe-a2.7b"])
def test_input_specs_train(arch):
    cfg = get_config(arch).resolve_for_mesh(tp=16)
    shape = SHAPES["train_4k"]
    spec = input_specs(cfg, shape)
    assert spec["tokens"].shape[0] == shape.global_batch
    t_text = spec["tokens"].shape[1]
    if cfg.family == "vlm":
        assert "image_embeds" in spec
        assert t_text + cfg.frontend_len == shape.seq_len
    else:
        assert t_text == shape.seq_len
    if cfg.is_encdec:
        assert spec["frames"].shape == (shape.global_batch, cfg.frontend_len,
                                        cfg.frontend_dim)
    assert spec["labels"].shape == spec["tokens"].shape


@pytest.mark.parametrize("arch", ["minitron-8b", "rwkv6-3b", "zamba2-1.2b",
                                  "seamless-m4t-medium"])
def test_input_specs_decode_cache_abstract(arch):
    cfg = get_config(arch).resolve_for_mesh(tp=16)
    shape = SHAPES["decode_32k"]
    spec = input_specs(cfg, shape)
    assert spec["tokens"].shape == (shape.global_batch, 1)
    leaves = jax.tree.leaves(spec["cache"])
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    assert len(leaves) > 0


def test_shapes_for_skip_policy():
    assert "long_500k" in shapes_for("rwkv6-3b")
    assert "long_500k" in shapes_for("zamba2-1.2b")
    assert "long_500k" not in shapes_for("llava-next-34b")
    assert "long_500k" not in shapes_for("minitron-8b")
    # 32 live single-pod cells total (40 assigned minus 8 long_500k skips)
    total = sum(len(shapes_for(a)) for a in
                ["llava-next-34b", "minitron-8b", "starcoder2-3b",
                 "stablelm-1.6b", "smollm-135m", "zamba2-1.2b",
                 "qwen2-moe-a2.7b", "llama4-scout-17b-a16e",
                 "seamless-m4t-medium", "rwkv6-3b"])
    assert total == 32


def test_roofline_terms_math():
    from benchmarks.roofline import PEAK_FLOPS, HBM_BW, ICI_BW, analyze
    rec = dict(arch="smollm-135m", shape="train_4k", n_devices=256,
               flops_per_device=1e14, bytes_per_device=1e11,
               collective_bytes_per_device=5e10,
               model={"active_params": get_config("smollm-135m").param_count()},
               memory={"per_device_hbm_bytes": 1 << 30})
    a = analyze(rec)
    assert abs(a["terms"]["compute"] - 1e14 / PEAK_FLOPS) < 1e-9
    assert abs(a["terms"]["memory"] - 1e11 / HBM_BW) < 1e-9
    assert abs(a["terms"]["collective"] - 5e10 / ICI_BW) < 1e-9
    assert a["dominant"] == "collective"
    assert 0 < a["roofline_fraction"] < 1


def test_affine_probe_solve_exactness():
    """The 4-point (L,T) solve recovers an affine function exactly."""
    ba, bb, la, lb = 3.0, 0.5, 7.0, 0.25

    def f(l, t):
        return ba + bb * t + l * (la + lb * t)
    l1, l2, t1, t2 = 1, 2, 512, 1024
    f11, f12, f21, f22 = f(l1, t1), f(l1, t2), f(l2, t1), f(l2, t2)
    lb_ = (f22 - f21 - f12 + f11) / ((l2 - l1) * (t2 - t1))
    la_ = (f21 - f11) / (l2 - l1) - lb_ * t1
    bb_ = (f12 - f11) / (t2 - t1) - l1 * lb_
    ba_ = f11 - bb_ * t1 - l1 * (la_ + lb_ * t1)
    for lstar, tstar in [(32, 32768), (38 / 6, 524288)]:
        want = f(lstar, tstar)
        got = ba_ + bb_ * tstar + lstar * (la_ + lb_ * tstar)
        assert abs(got - want) / want < 1e-12
