"""Sharded serving subsystem tests.

The acceptance bar: ``ShardedServeEngine`` answers are BIT-EXACT against the
single-host ``CompiledGraphSession`` for the same queried micro-batches —
for all three families, at P=2 and P=4, including queries whose k-hop
neighborhoods span shard boundaries. Plus: routed k-hop extraction identical
to the single-host extractor, halo-exchange transport parity (host loopback
vs mesh collectives), distributed full pass vs single-host full pass,
zero steady-state recompiles per shard, and artifact roundtrip without
re-partitioning or re-tuning.
"""
import numpy as np
import jax
import pytest

from repro.graphs import sampling
from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import (CompiledGraphSession, GNNServeEngine, GraphStore,
                         ShardedServeEngine)
from repro.serve.sharded import (RoutingTable, ShardedCSR,
                                 ShardedGraphSession, gather_rows,
                                 build_mesh_plan, mesh_exchange)
from repro.serve.sharded import routing as routing_mod

jax.config.update("jax_platform_name", "cpu")

HIDDEN = 16
BATCH = 8
SHARD_COUNTS = (2, 4)


@pytest.fixture(scope="module")
def data():
    return make_dataset("cora", seed=0, scale=0.1)


@pytest.fixture(scope="module")
def store(data):
    st = GraphStore(max_batch=BATCH)
    st.register_graph("g", data)
    key = jax.random.PRNGKey(0)
    f, c = data.x.shape[1], data.n_classes
    st.register_model("gcn", "gcn", gnn.init_gcn(key, f, HIDDEN, c))
    st.register_model("sage", "sage", gnn.init_sage(key, f, HIDDEN, c))
    st.register_model("saint", "saint", gnn.init_saint(key, f, HIDDEN, c))
    return st


def _single_host_reference(single: CompiledGraphSession,
                           routing: RoutingTable, nodes: np.ndarray,
                           batch: int) -> np.ndarray:
    """Replay the sharded engine's batching (per-owner FIFO groups, chunks
    of ``batch``) against the single-host session — the bit-exact oracle."""
    owners = routing.owner(nodes)
    out = None
    for o in np.unique(owners):
        idx = np.nonzero(owners == o)[0]
        for i in range(0, idx.size, batch):
            chunk = idx[i:i + batch]
            logits = single.serve_subgraph(nodes[chunk])
            if out is None:
                out = np.zeros((nodes.size, logits.shape[1]), logits.dtype)
            out[chunk] = logits
    return out


# --------------------------------------------------------------- routing ----

@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_routed_khop_matches_single_host(data, n_shards):
    """Cross-shard frontier routing reproduces the single-host extractor
    bit-for-bit: node set, induced edge list (same order), seed positions."""
    from repro.graphs.partition import shard_node_bounds
    routing = RoutingTable(shard_node_bounds(data.edges[0], data.n_nodes,
                                             n_shards))
    scsr = ShardedCSR.from_edges(data.edges, routing)
    csr = sampling.to_csr(data.edges, data.n_nodes)
    rng = np.random.default_rng(0)
    for _ in range(5):
        seeds = rng.integers(0, data.n_nodes, size=BATCH)
        want = sampling.khop_subgraph(csr, np.unique(seeds), 2)
        got = routing_mod.khop_subgraph(scsr, np.unique(seeds), 2)
        for a, b in zip(got, want):
            np.testing.assert_array_equal(a, b)
    assert scsr.requests_by_shard.sum() > 0   # frontiers actually routed


def test_routing_table_owner_local(data):
    from repro.graphs.partition import shard_node_bounds
    routing = RoutingTable(shard_node_bounds(data.edges[0], data.n_nodes, 4))
    nodes = np.arange(data.n_nodes)
    owner = routing.owner(nodes)
    local = routing.local(nodes, owner)
    assert owner.min() == 0 and owner.max() == 3
    # owner/local invert exactly
    np.testing.assert_array_equal(routing.bounds[owner] + local, nodes)
    rt2 = RoutingTable.from_json(routing.to_json())
    np.testing.assert_array_equal(rt2.bounds, routing.bounds)


# ------------------------------------------------------------- bit-exact ----

@pytest.mark.parametrize("model", ["gcn", "sage", "saint"])
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_sharded_engine_bit_exact(store, data, model, n_shards):
    """ShardedServeEngine outputs EQUAL the single-host CompiledGraphSession
    outputs for the same served micro-batches — including nodes whose k-hop
    neighborhoods span shard boundaries. The oracle replays the engine's
    ACTUAL batch compositions (``batch_log``), so it holds under any batch
    formation policy (FIFO or halo-aware)."""
    single = store.session("g", model)
    engine = ShardedServeEngine(store, n_shards, max_batch=BATCH,
                                mode="subgraph")
    nodes = np.random.default_rng(1).integers(0, data.n_nodes, size=5 * BATCH)
    queries = engine.submit_many("g", model, nodes)
    engine.run_until_drained()
    assert all(q.done for q in queries)

    sess = store.sharded_session("g", model, n_shards)
    assert engine.batch_log and sum(len(b) for b in engine.batch_log) \
        == len(queries)
    for batch in engine.batch_log:
        # single-owner invariant of every served micro-batch
        owners = sess.routing.owner(np.asarray([q.node for q in batch]))
        assert np.unique(owners).size == 1
        want = single.serve_subgraph(np.asarray([q.node for q in batch]))
        np.testing.assert_array_equal(
            np.stack([q.logits for q in batch]), want)
        np.testing.assert_array_equal(
            np.asarray([q.pred for q in batch]), np.argmax(want, axis=-1))
    # the workload genuinely crossed shard boundaries: some query's k-hop
    # closure contains nodes owned by a different shard than its seed's
    crossed = False
    for seed in np.unique(nodes)[:3 * BATCH]:
        sub = sampling.khop_nodes(sess.graph.csr, np.array([seed]),
                                  sess.khop)
        if np.unique(sess.routing.owner(sub)).size > 1:
            crossed = True
            break
    assert crossed, "test graph too partitioned-friendly to exercise halo"
    assert sess.halo_stats.total_bytes > 0


@pytest.mark.parametrize("model", ["gcn", "sage", "saint"])
def test_sharded_full_pass_matches_single_host(store, data, model):
    """The distributed layer-wise pass (intra + halo partial aggregation,
    packed exchange on the binary layer) reproduces the single-host full
    pass to fp tolerance with identical predictions."""
    single = store.session("g", model)
    sess = store.sharded_session("g", model, 2)
    got, want = sess.full_logits(), single.full_logits()
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.argmax(got, -1), np.argmax(want, -1))
    tags = sess.halo_stats.bytes_by_tag
    assert any(t.startswith("layer1") for t in tags)
    assert any(t.startswith("layer2") for t in tags)
    if model == "gcn":   # binary aggregation exchanges PACKED words: 32x less
        assert tags["layer1/packed"] < tags["layer2/fp"]


def test_sharded_engine_full_cache_mode(store, data):
    """Full-cache mode answers from the per-shard caches the distributed
    pass filled — same predictions as the single-host cache."""
    single = store.session("g", "gcn")
    engine = ShardedServeEngine(store, 2, max_batch=BATCH, mode="full")
    nodes = np.arange(0, data.n_nodes, 11)[:2 * BATCH]
    qs = engine.submit_many("g", "gcn", nodes)
    engine.run_until_drained()
    got = np.stack([q.logits for q in qs])
    want = single.full_logits()[nodes]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.argmax(got, -1), np.argmax(want, -1))


# ------------------------------------------------------------------ halo ----

def test_gather_rows_and_byte_accounting():
    from repro.serve.sharded import HaloStats
    routing = RoutingTable(np.array([0, 8, 20, 32]))
    rng = np.random.default_rng(0)
    full = rng.standard_normal((32, 5)).astype(np.float32)
    blocks = [full[0:8], full[8:20], full[20:32]]
    nodes = np.array([31, 2, 9, 9, 19, 0])
    stats = HaloStats()
    out = gather_rows(blocks, routing, nodes, home=1, stats=stats)
    np.testing.assert_array_equal(out, full[nodes])
    # remote = rows NOT owned by shard 1 (ids outside [8, 20))
    remote = (nodes < 8) | (nodes >= 20)
    assert stats.total_bytes == int(remote.sum()) * 5 * 4
    # 1-D blocks (factorization vectors) work too
    vec = np.arange(32, dtype=np.float64)
    got = gather_rows([vec[0:8], vec[8:20], vec[20:32]], routing, nodes)
    np.testing.assert_array_equal(got, vec[nodes])


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_mesh_exchange_matches_host_gather(data, n_shards):
    """The shard_map/ppermute collective transport delivers exactly the rows
    the host loopback assembles. Needs >= n_shards devices — CPU CI forces
    them with XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    if len(jax.devices()) < n_shards:
        pytest.skip(f"needs {n_shards} devices, have {len(jax.devices())}")
    from repro.launch.mesh import make_shard_mesh
    from repro.serve.sharded import ShardPlanner
    plan = ShardPlanner(n_shards).plan(data, "gcn")
    mesh = make_shard_mesh(n_shards)
    assert mesh is not None
    rng = np.random.default_rng(0)
    blocks = [rng.standard_normal((p.n_local, 7)).astype(np.float32)
              for p in plan.parts]
    mplan = build_mesh_plan(plan.routing,
                            [p.halo_nodes for p in plan.parts])
    got = mesh_exchange(mesh, blocks, mplan)
    for p, g in zip(plan.parts, got):
        want = gather_rows(blocks, plan.routing, p.halo_nodes)
        np.testing.assert_array_equal(g, want)
    # packed payloads move through the same transport
    pblocks = [rng.integers(0, 2**32, size=(p.n_local, 3), dtype=np.uint32)
               for p in plan.parts]
    got_p = mesh_exchange(mesh, pblocks, mplan)
    for p, g in zip(plan.parts, got_p):
        want = gather_rows(pblocks, plan.routing, p.halo_nodes)
        np.testing.assert_array_equal(g, want)


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_mesh_session_matches_host_session(data, n_shards):
    """End-to-end: a session running its halo exchange over mesh collectives
    equals the host-transport session bitwise."""
    if len(jax.devices()) < n_shards:
        pytest.skip(f"needs {n_shards} devices, have {len(jax.devices())}")
    from repro.launch.mesh import make_shard_mesh
    key = jax.random.PRNGKey(0)
    params = gnn.init_gcn(key, data.x.shape[1], HIDDEN, data.n_classes)

    def build(mesh):
        st = GraphStore(max_batch=BATCH)
        st.register_graph("g", data)
        st.register_model("gcn", "gcn", params)
        return st.sharded_session("g", "gcn", n_shards, mesh=mesh)

    host = build(None)
    meshed = build(make_shard_mesh(n_shards))
    np.testing.assert_array_equal(meshed.full_logits(), host.full_logits())
    nodes = np.arange(BATCH)
    np.testing.assert_array_equal(meshed.serve_subgraph(nodes),
                                  host.serve_subgraph(nodes))


# ----------------------------------------------------------- steady state ---

def test_zero_steady_state_recompiles_per_shard(store, data):
    """After warmup no shard's jit cache-miss counter moves."""
    engine = ShardedServeEngine(store, 2, max_batch=BATCH, mode="subgraph")
    engine.warmup("g", "sage")
    per_shard0 = engine.compile_count_by_shard
    c0 = engine.compile_count
    assert c0 > 0
    rng = np.random.default_rng(5)
    for _ in range(6):
        engine.submit_many("g", "sage",
                           rng.integers(0, data.n_nodes,
                                        rng.integers(1, BATCH + 1)))
        engine.run_until_drained()
    assert engine.compile_count == c0
    assert engine.compile_count_by_shard == per_shard0
    snap = engine.snapshot()
    assert snap["n_shards"] == 2
    assert snap["halo_bytes"] > 0
    assert snap["queries"] >= 6 and snap["qps"] > 0


# -------------------------------------------------------------- artifacts ---

def test_sharded_artifact_roundtrip(tmp_path, data):
    """Per-shard FRDC + routing table serialize/restore through the
    checkpointer WITHOUT re-partitioning or re-tuning; the restored session
    serves bitwise-identical answers."""
    key = jax.random.PRNGKey(0)
    params = gnn.init_gcn(key, data.x.shape[1], HIDDEN, data.n_classes)

    st1 = GraphStore(cache_dir=str(tmp_path), max_batch=BATCH)
    st1.register_graph("g", make_dataset("cora", seed=0, scale=0.1))
    st1.register_model("gcn", "gcn", params)
    s1 = st1.sharded_session("g", "gcn", 2, tune=True, tune_repeats=1)
    assert np.isfinite(s1.plan.tuned_latency_s)
    nodes = np.arange(BATCH)
    a = s1.serve_subgraph(nodes)

    st2 = GraphStore(cache_dir=str(tmp_path), max_batch=BATCH)
    st2.register_graph("g", make_dataset("cora", seed=0, scale=0.1))
    st2.register_model("gcn", "gcn", params)
    # the artifact restores directly — no planner, no tuner
    restored = ShardedGraphSession.load(tmp_path / "g__gcn__P2",
                                        st2.graphs["g"], st2.models["gcn"])
    assert restored is not None
    p1, p2 = s1.plan.to_json(), restored.plan.to_json()
    assert p1 == p2 or (np.isnan(p1.pop("output_delta"))
                        and np.isnan(p2.pop("output_delta")) and p1 == p2)
    np.testing.assert_array_equal(restored.routing.bounds, s1.routing.bounds)
    for pa, pb in zip(s1.parts, restored.parts):
        np.testing.assert_array_equal(pa.halo_nodes, pb.halo_nodes)
        np.testing.assert_array_equal(pa.indices, pb.indices)
    np.testing.assert_array_equal(restored.serve_subgraph(nodes), a)
    np.testing.assert_array_equal(restored.full_logits(), s1.full_logits())

    # store-level restore path too
    s3 = st2.sharded_session("g", "gcn", 2)
    np.testing.assert_array_equal(s3.serve_subgraph(nodes), a)

    # stale features -> fingerprint mismatch -> no restore
    st4 = GraphStore(cache_dir=str(tmp_path), max_batch=BATCH)
    d4 = make_dataset("cora", seed=0, scale=0.1)
    d4.x[:5] = 1.0
    st4.register_graph("g", d4)
    st4.register_model("gcn", "gcn", params)
    assert ShardedGraphSession.load(tmp_path / "g__gcn__P2",
                                    st4.graphs["g"],
                                    st4.models["gcn"]) is None


def test_empty_shard_on_extreme_skew(data):
    """Edge-balanced cuts on a hub-dominated graph legally produce shards
    that own ZERO nodes; the distributed pass and serving must handle them
    (skip their phantom adjacencies, contribute empty row blocks)."""
    from repro.graphs.datasets import GraphData
    n = 24
    rng = np.random.default_rng(0)
    src = np.concatenate([np.zeros(200, np.int64),
                          rng.integers(0, n, 20)])
    dst = np.concatenate([rng.integers(1, n, 200),
                          rng.integers(0, n, 20)])
    keep = src != dst
    edges = np.stack([src[keep], dst[keep]]).astype(np.int64)
    hub = GraphData(name="hub",
                    x=rng.standard_normal((n, 12)).astype(np.float32),
                    y=rng.integers(0, 3, n).astype(np.int32),
                    edges=edges, n_classes=3,
                    train_mask=np.zeros(n, bool), val_mask=np.zeros(n, bool),
                    test_mask=np.zeros(n, bool))
    st = GraphStore(max_batch=4)
    st.register_graph("hub", hub)
    st.register_model("gcn", "gcn",
                      gnn.init_gcn(jax.random.PRNGKey(0), 12, 8, 3))
    sess = st.sharded_session("hub", "gcn", 4)
    assert any(p.n_local == 0 for p in sess.parts), \
        "scenario must actually produce an empty shard"
    single = st.session("hub", "gcn")
    got, want = sess.full_logits(), single.full_logits()
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    nodes = np.arange(4)
    np.testing.assert_array_equal(sess.serve_subgraph(nodes),
                                  single.serve_subgraph(nodes))


def test_sharded_feature_update_invalidates(data):
    """update_features bumps the version; the sharded session recalibrates,
    reruns the distributed pass, and matches the single-host session on the
    new features bitwise (same batch composition)."""
    st = GraphStore(max_batch=BATCH)
    d2 = make_dataset("cora", seed=0, scale=0.1)
    st.register_graph("g", d2)
    key = jax.random.PRNGKey(0)
    st.register_model("gcn", "gcn",
                      gnn.init_gcn(key, d2.x.shape[1], HIDDEN, d2.n_classes))
    single = st.session("g", "gcn")
    sess = st.sharded_session("g", "gcn", 2)
    nodes = np.arange(BATCH)
    before = sess.serve_subgraph(nodes)

    x2 = d2.x.copy()
    x2[: d2.n_nodes // 5] = 0.0
    st.update_features("g", x2)
    after = sess.serve_subgraph(nodes)
    assert sess.invalidations == 1
    assert not np.allclose(after, before, rtol=1e-3, atol=1e-3)
    want = _single_host_reference(single, sess.routing, nodes, BATCH)
    np.testing.assert_array_equal(after, want)
