"""Per-architecture smoke tests: reduced config, one forward + one decode
step (+ one grad step for a representative subset) on CPU. Full configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config, shapes_for
from repro.models import transformer

jax.config.update("jax_platform_name", "cpu")

B, T = 2, 32


def _batch_inputs(cfg, key, t=T):
    ks = jax.random.split(key, 3)
    kw = {}
    t_text = t
    if cfg.family == "vlm":
        n_img = cfg.frontend_len
        t_text = max(t - n_img, 4)
        kw["image_embeds"] = jax.random.normal(
            ks[1], (B, n_img, cfg.frontend_dim), jnp.float32)
    if cfg.is_encdec:
        kw["frames"] = jax.random.normal(
            ks[2], (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    tokens = jax.random.randint(ks[0], (B, t_text), 0, cfg.vocab)
    return tokens, kw


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(get_config(arch)).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens, kw = _batch_inputs(cfg, jax.random.PRNGKey(1))
    logits = transformer.forward(params, cfg, tokens, unroll=True, **kw)
    t_total = tokens.shape[1] + (cfg.frontend_len if cfg.family == "vlm" else 0)
    assert logits.shape == (B, t_total, cfg.vocab_padded or cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step_matches_forward(arch):
    """Prefill-by-decode must agree with the parallel forward (last logits)."""
    cfg = reduced_config(get_config(arch)).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    t = 8
    tokens, kw = _batch_inputs(cfg, jax.random.PRNGKey(1), t=t)
    if cfg.family == "vlm":
        pytest.skip("decode parity covered via text archs; vlm adds prefix")
    full = transformer.forward(params, cfg, tokens, unroll=True, **kw)

    cache = transformer.init_cache(cfg, B, max_len=t + 4,
                                   enc_len=cfg.frontend_len)
    if cfg.is_encdec:
        memory = transformer._encode(params, cfg, kw["frames"], q_chunk=0)
        cache["enc_memory"] = memory
    logits = None
    for i in range(t):
        logits, cache = transformer.decode_step(
            params, cfg, cache, tokens[:, i:i + 1], jnp.int32(i))
    np.testing.assert_allclose(
        np.asarray(logits[:, 0].astype(jnp.float32)),
        np.asarray(full[:, -1].astype(jnp.float32)), rtol=0.15, atol=0.15)


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen2-moe-a2.7b",
                                  "zamba2-1.2b", "rwkv6-3b",
                                  "seamless-m4t-medium"])
def test_train_grad_step(arch):
    """One loss+grad step: finite gradients for every block family."""
    cfg = reduced_config(get_config(arch)).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens, kw = _batch_inputs(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        logits = transformer.forward(p, cfg, tokens, unroll=True, **kw)
        tgt = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        t_total = logits.shape[1]
        tgt = jnp.pad(tgt, ((0, 0), (t_total - tgt.shape[1], 0)))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.mean(jnp.take_along_axis(logp, tgt[..., None], axis=-1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


def test_scan_path_matches_unrolled():
    cfg = reduced_config(get_config("smollm-135m")).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens, _ = _batch_inputs(cfg, jax.random.PRNGKey(1))
    a = transformer.forward(params, cfg, tokens, unroll=True)
    b = transformer.forward(params, cfg, tokens, unroll=False)
    # bf16 accumulation order differs between the scanned and unrolled
    # programs; logits range is O(1) so compare with absolute tolerance.
    np.testing.assert_allclose(np.asarray(a.astype(jnp.float32)),
                               np.asarray(b.astype(jnp.float32)),
                               rtol=0.25, atol=0.1)


def test_q_chunked_attention_matches():
    cfg = reduced_config(get_config("stablelm-1.6b")).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens, _ = _batch_inputs(cfg, jax.random.PRNGKey(1))
    a = transformer.forward(params, cfg, tokens, unroll=True, q_chunk=0)
    b = transformer.forward(params, cfg, tokens, unroll=True, q_chunk=8)
    np.testing.assert_allclose(np.asarray(a.astype(jnp.float32)),
                               np.asarray(b.astype(jnp.float32)),
                               rtol=2e-2, atol=2e-2)


def test_quantized_params_run():
    from repro.quant.binary_linear import quantize_params, quantized_param_bytes
    cfg = reduced_config(get_config("smollm-135m")).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_params(params)
    before = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    after = quantized_param_bytes(qparams)
    assert after < before * 0.6  # embeddings dominate the tiny config
    tokens, _ = _batch_inputs(cfg, jax.random.PRNGKey(1))
    logits = transformer.forward(qparams, cfg, tokens, unroll=True)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_resolve_for_mesh_padding_policy():
    cfg = get_config("smollm-135m").resolve_for_mesh(tp=16)
    assert cfg.n_heads_padded == 16          # 9 -> 16
    assert cfg.n_kv_heads_padded == 4        # 3 -> 4 (divides 16)
    assert cfg.kv_replication == 4
    assert cfg.vocab_padded % (16 * 128) == 0
    cfg2 = get_config("qwen2-moe-a2.7b").resolve_for_mesh(tp=16)
    assert cfg2.moe_experts_padded == 64     # 60 -> 64
    cfg3 = get_config("llava-next-34b").resolve_for_mesh(tp=16)
    assert cfg3.n_heads_padded == 64 and cfg3.n_kv_heads_padded == 8
    assert cfg3.kv_replication == 2


def test_param_counts_plausible():
    # smollm ~135M params (tied embeddings)
    cfg = get_config("smollm-135m")
    n = cfg.param_count()
    assert 0.10e9 < n < 0.18e9, n
    # minitron ~8B
    n = get_config("minitron-8b").param_count()
    assert 6e9 < n < 10e9, n
    # qwen2-moe total ~14B, active ~2.7B
    c = get_config("qwen2-moe-a2.7b")
    assert 10e9 < c.param_count() < 20e9, c.param_count()
    assert 1.5e9 < c.active_param_count() < 5e9, c.active_param_count()
