"""SPMD layer-executor tests.

The acceptance bar of the SPMD refactor: the ``executor="spmd"`` distributed
full pass (one shard_map program per layer, fused halo exchange) is
BIT-IDENTICAL to the host-orchestrated reference for gcn/sage/saint at P=2
and P=4 when fed the same BN constants — including a non-tile-multiple-rows
graph exercising the uniform padding — with exactly one jit trace per layer
program in steady state. Plus: distributed BN calibration (psum moments)
drift bound vs the single-host anchor, static-schedule halo byte accounting
under jit, artifact roundtrip of the ``spmd`` plan field (old sidecars
without it still load), engine integration, and a P=8 smoke for the CI
multi-device job.

SPMD cases need >= P devices — CPU CI forces them with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; under-provisioned
hosts skip those and still run the host-executor distributed-BN coverage.
"""
import json

import numpy as np
import jax
import pytest

from repro.graphs.datasets import GraphData, make_dataset
from repro.models import gnn
from repro.serve import GraphStore
from repro.serve.sharded import ShardedGraphSession, SpmdPlan

jax.config.update("jax_platform_name", "cpu")

HIDDEN = 16
BATCH = 8
SHARD_COUNTS = (2, 4)
FAMILIES = ("gcn", "sage", "saint")


def _needs_devices(p):
    if len(jax.devices()) < p:
        pytest.skip(f"needs {p} devices, have {len(jax.devices())}")


def _make_store(data, families=FAMILIES, **kw):
    st = GraphStore(max_batch=BATCH, **kw)
    st.register_graph("g", data)
    key = jax.random.PRNGKey(0)
    f, c = data.x.shape[1], data.n_classes
    inits = {"gcn": gnn.init_gcn, "sage": gnn.init_sage,
             "saint": gnn.init_saint}
    for fam in families:
        st.register_model(fam, fam, inits[fam](key, f, HIDDEN, c))
    return st


@pytest.fixture(scope="module")
def data():
    return make_dataset("cora", seed=0, scale=0.1)


@pytest.fixture(scope="module")
def store(data):
    return _make_store(data)


# ------------------------------------------------------------ bit-exact ----

@pytest.mark.parametrize("model", FAMILIES)
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_spmd_bit_exact_vs_host(store, data, model, n_shards):
    """SPMD full pass == host-orchestrated full pass BITWISE under shared
    (single-host anchor) BN constants, with exactly one compile per layer
    program."""
    _needs_devices(n_shards)
    host = store.sharded_session("g", model, n_shards)
    spmd = store.sharded_session("g", model, n_shards, executor="spmd")
    np.testing.assert_array_equal(spmd.full_logits(), host.full_logits())
    # same frozen calibration constants on both sides
    for (hm, hs), (sm, ss) in zip(host.bn, spmd.bn):
        np.testing.assert_array_equal(np.asarray(hm), np.asarray(sm))
        np.testing.assert_array_equal(np.asarray(hs), np.asarray(ss))
    assert spmd.executor_compile_count == len(spmd.program)


@pytest.mark.parametrize("n_shards", (2, 4))
def test_spmd_bit_exact_ragged_rows(n_shards):
    """Uniform padding: a graph whose node count is NOT a tile multiple and
    whose edge-balanced cuts give unequal per-shard row counts still matches
    bitwise (padded rows/columns never contaminate real ones)."""
    _needs_devices(n_shards)
    n = 117                                    # 117 % 4 == 1
    rng = np.random.default_rng(3)
    # skewed: a hub cluster concentrates edges -> ragged shard cuts
    src = np.concatenate([rng.integers(0, 10, 400),
                          rng.integers(0, n, 200)])
    dst = np.concatenate([rng.integers(0, n, 400),
                          rng.integers(0, n, 200)])
    keep = src != dst
    d = GraphData(name="ragged",
                  x=rng.standard_normal((n, 24)).astype(np.float32),
                  y=rng.integers(0, 4, n).astype(np.int32),
                  edges=np.stack([src[keep], dst[keep]]).astype(np.int64),
                  n_classes=4, train_mask=np.zeros(n, bool),
                  val_mask=np.zeros(n, bool), test_mask=np.zeros(n, bool))
    st = _make_store(d, families=("gcn", "sage"))
    for fam in ("gcn", "sage"):
        host = st.sharded_session("g", fam, n_shards)
        spmd = st.sharded_session("g", fam, n_shards, executor="spmd")
        locals_ = [p.n_local for p in host.parts]
        assert len(set(locals_)) > 1, "cuts should be ragged"
        np.testing.assert_array_equal(spmd.full_logits(),
                                      host.full_logits())


def test_spmd_zero_steady_state_recompiles(data):
    """Feature updates re-run the pass through the ALREADY-compiled layer
    programs: the executor trace counter must not move after the first
    pass (exactly one compile per layer-shape in steady state)."""
    _needs_devices(2)
    st = _make_store(make_dataset("cora", seed=0, scale=0.1),
                     families=("sage",))
    single = _make_store(make_dataset("cora", seed=0, scale=0.1),
                         families=("sage",))
    spmd = st.sharded_session("g", "sage", 2, executor="spmd")
    spmd.full_logits()
    c0 = spmd.executor_compile_count
    assert c0 == len(spmd.program)
    x2 = st.graphs["g"].data.x.copy()
    x2[:10] = 0.5
    st.update_features("g", x2)
    single.update_features("g", x2)
    got = spmd.full_logits()                    # recalibrate + new pass
    assert spmd.invalidations == 1
    assert spmd.executor_compile_count == c0    # zero new traces
    want = single.sharded_session("g", "sage", 2).full_logits()
    np.testing.assert_array_equal(got, want)


def test_spmd_p8_smoke(data):
    """CI multi-device smoke: P=8 SPMD parity on GCN."""
    _needs_devices(8)
    store_ = _make_store(data, families=("gcn",))
    host_sess = store_.sharded_session("g", "gcn", 8)
    spmd_sess = store_.sharded_session("g", "gcn", 8, executor="spmd")
    np.testing.assert_array_equal(spmd_sess.full_logits(),
                                  host_sess.full_logits())


# -------------------------------------------------------- distributed BN ----

@pytest.mark.parametrize("model", FAMILIES)
def test_distributed_bn_drift_bound(store, data, model):
    """bn_mode="distributed" (host executor — runs on ANY device count)
    serves with bounded drift vs the single-host calibration anchor:
    argmax agreement >= 99% and a small logits delta."""
    single = store.session("g", model).full_logits()
    dist = store.sharded_session("g", model, 2,
                                 bn_mode="distributed")
    got = dist.full_logits()
    agree = float((np.argmax(got, -1) == np.argmax(single, -1)).mean())
    assert agree >= 0.99
    scale = float(np.abs(single).max())
    assert float(np.abs(got - single).max()) <= 1e-3 * max(scale, 1.0)
    # calibration really came from the pass: per-site stats exist
    assert len(dist.bn) == len(
        [s for s in dist.program if s.bn_site is not None])


def test_distributed_bn_spmd_matches_host_formula(data):
    """SPMD psum moments agree with the host executor's summed partials to
    reduction-order tolerance, and serve the same predictions."""
    _needs_devices(2)
    st = _make_store(data, families=("sage",))
    h = st.sharded_session("g", "sage", 2, bn_mode="distributed")
    s = st.sharded_session("g", "sage", 2, executor="spmd",
                           bn_mode="distributed")
    for (hm, hs), (sm, ss) in zip(h.bn, s.bn):
        np.testing.assert_allclose(np.asarray(hm), np.asarray(sm),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(hs), np.asarray(ss),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.argmax(h.full_logits(), -1),
                                  np.argmax(s.full_logits(), -1))


# ---------------------------------------------------------- byte accounting -

def test_spmd_halo_bytes_static_schedule(data):
    """Jitted steady-state passes account the static schedule's bytes once
    per layer per pass — two passes double the counters while the compile
    counter stays put (the trace-time-recording bug this guards against
    would freeze the counters after the first trace)."""
    _needs_devices(2)
    st = _make_store(data, families=("gcn",))
    sess = st.sharded_session("g", "gcn", 2, executor="spmd")
    sess.full_logits()
    tags1 = dict(sess.halo_stats.bytes_by_tag)
    c1 = sess.executor_compile_count
    assert tags1["layer1/packed"] > 0 and tags1["layer2/fp"] > 0
    # packed exchange moves 32x fewer words than fp on the same schedule
    mp = sess.shard_plan.spmd_plan().mesh_plan
    w_packed = sess.program[0].payload_cols
    assert tags1["layer1/packed"] == mp.payload_bytes(w_packed, 4)
    sess.run_distributed_pass()                 # second frozen pass
    assert sess.executor_compile_count == c1    # no retrace...
    for t, b in tags1.items():                  # ...but bytes still counted
        assert sess.halo_stats.bytes_by_tag[t] == 2 * b


# --------------------------------------------------------------- artifacts --

def test_spmd_plan_artifact_roundtrip(tmp_path, data):
    """routing.json carries the ``spmd`` field; a restored session runs the
    SPMD executor without re-planning, and sidecars WITHOUT the field (old
    artifacts) still load by rebuilding the plan from the parts."""
    _needs_devices(2)
    st1 = _make_store(make_dataset("cora", seed=0, scale=0.1),
                      families=("gcn",), cache_dir=str(tmp_path))
    s1 = st1.sharded_session("g", "gcn", 2, executor="spmd")
    want = s1.full_logits()
    spmd1 = s1.shard_plan.spmd_plan()

    sidecar_path = tmp_path / "g__gcn__P2" / "routing.json"
    sidecar = json.loads(sidecar_path.read_text())
    assert "spmd" in sidecar
    rt = SpmdPlan.from_json(sidecar["spmd"])
    assert (rt.n_local_pad, rt.n_halo_pad) == (spmd1.n_local_pad,
                                               spmd1.n_halo_pad)
    assert rt.intra_groups == spmd1.intra_groups

    def _restore():
        st = _make_store(make_dataset("cora", seed=0, scale=0.1),
                         families=("gcn",))
        sess = ShardedGraphSession.load(tmp_path / "g__gcn__P2",
                                        st.graphs["g"], st.models["gcn"],
                                        executor="spmd")
        assert sess is not None
        return sess

    restored = _restore()
    assert restored.shard_plan.spmd.n_local_pad == spmd1.n_local_pad
    np.testing.assert_array_equal(restored.full_logits(), want)

    # OLD artifact: strip the spmd field -> still loads, plan rebuilt
    del sidecar["spmd"]
    sidecar_path.write_text(json.dumps(sidecar))
    old = _restore()
    assert old.shard_plan.spmd is None          # not restored...
    np.testing.assert_array_equal(old.full_logits(), want)
    assert old.shard_plan.spmd is not None      # ...rebuilt on demand


# ------------------------------------------------------------------ engine --

def test_engine_spmd_executor_bit_exact(data):
    """ShardedServeEngine(executor="spmd"): the routed serve path answers
    bitwise like the host-executor engine (the subgraph path is executor-
    independent; sync runs through the SPMD pass), and the snapshot reports
    the executor and its compile counter."""
    _needs_devices(2)
    from repro.serve import ShardedServeEngine
    st = _make_store(data, families=("gcn",))
    host_e = ShardedServeEngine(st, 2, max_batch=BATCH, mode="subgraph")
    spmd_e = ShardedServeEngine(st, 2, max_batch=BATCH, mode="subgraph",
                                executor="spmd")
    nodes = np.random.default_rng(7).integers(0, data.n_nodes,
                                              size=3 * BATCH)
    qa = host_e.submit_many("g", "gcn", nodes)
    host_e.run_until_drained()
    qb = spmd_e.submit_many("g", "gcn", nodes)
    spmd_e.run_until_drained()
    np.testing.assert_array_equal(np.stack([q.logits for q in qa]),
                                  np.stack([q.logits for q in qb]))
    snap = spmd_e.snapshot()
    assert snap["executor"] == "spmd"
    assert snap["executor_compiles"] > 0
