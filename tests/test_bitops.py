import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import bitops

jax.config.update("jax_platform_name", "cpu")


@given(st.integers(1, 130), st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(3, n))
    packed = bitops.pack_bits(bits)
    assert packed.dtype == jnp.uint32
    assert packed.shape == (3, (n + 31) // 32)
    out = bitops.unpack_bits(packed, n)
    np.testing.assert_array_equal(np.asarray(out), bits)


def test_pack_axis0():
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, size=(70, 5))
    packed = bitops.pack_bits(bits, axis=0)
    assert packed.shape == (3, 5)
    np.testing.assert_array_equal(np.asarray(bitops.unpack_bits(packed, 70, axis=0)), bits)


@given(st.integers(1, 200), st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_xnor_dot(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.choice([-1, 1], size=n)
    b = rng.choice([-1, 1], size=n)
    ap = bitops.pack_bits(a > 0)
    bp = bitops.pack_bits(b > 0)
    assert int(bitops.xnor_dot(ap, bp, n)) == int(np.dot(a, b))


@given(st.integers(1, 200), st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_trinary_dot_all_modes_agree(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, size=n)          # adjacency 0/1
    b = rng.choice([-1, 1], size=n)          # activation ±1
    expected = int(np.dot(a, b))
    ap = bitops.pack_bits(a)
    bp = bitops.pack_bits(b > 0)
    assert int(bitops.trinary_dot_s2(ap, bp)) == expected
    assert int(bitops.trinary_dot_s3(ap, bp)) == expected
    assert int(bitops.trinary_dot_s1(jnp.asarray(a), jnp.asarray(b))) == expected


def test_and_dot():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 2, size=100)
    b = rng.integers(0, 2, size=100)
    assert int(bitops.and_dot(bitops.pack_bits(a), bitops.pack_bits(b))) == int(a @ b)


def test_bit_transpose_32():
    rng = np.random.default_rng(2)
    m = rng.integers(0, 2, size=(32, 32))
    words = bitops.pack_bits(m)             # (32, 1) words: row k bits over f
    t = bitops.bit_transpose_32(words.reshape(32))
    mt = np.asarray(bitops.unpack_bits(t[:, None], 32))
    np.testing.assert_array_equal(mt, m.T)


def test_bit_transpose_batched():
    rng = np.random.default_rng(3)
    m = rng.integers(0, 2, size=(5, 32, 32))
    words = bitops.pack_bits(m)
    t = bitops.bit_transpose_32(words.squeeze(-1).reshape(5, 32))
    for i in range(5):
        np.testing.assert_array_equal(
            np.asarray(bitops.unpack_bits(t[i][:, None], 32)), m[i].T)


def test_bmm_xnor_words_matches_matmul():
    rng = np.random.default_rng(4)
    a = rng.choice([-1, 1], size=(7, 100))
    b = rng.choice([-1, 1], size=(9, 100))
    out = bitops.bmm_xnor_words(bitops.pack_bits(a > 0), bitops.pack_bits(b > 0), 100)
    np.testing.assert_array_equal(np.asarray(out), a @ b.T)


def test_unpack_pm1():
    x = np.array([1.5, -0.2, 0.0, -3.0])
    p = bitops.sign_bits(x)
    np.testing.assert_array_equal(np.asarray(bitops.unpack_pm1(p, 4)),
                                  [1.0, -1.0, 1.0, -1.0])
