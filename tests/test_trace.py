"""Serving telemetry tests: span tracing, watchdogs, exporters, the
perf-regression gate, and the metrics satellites.

The acceptance bar (ISSUE 6): a pipelined sharded serve (P=2) produces a
Chrome-trace JSON whose spans reconstruct per-batch extract/compute/
queue-wait within 1ms of the ``ServeMetrics`` stage sums; the recompile
watchdog fires on a forced novel shape and stays silent across 2 feature
updates in steady state; ``compare_bench.py`` exits nonzero on a synthetic
2x p99 regression and zero on identical inputs; tracing at the default
sampling stays within 5% of the untraced QPS.
"""
import copy
import json
import time

import numpy as np
import jax
import pytest

from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import (GNNServeEngine, GraphStore, LatencyStats,
                         ServeMetrics, ShardedServeEngine, SpanTracer,
                         chrome_trace, prometheus_text, write_chrome_trace)
from repro.serve.trace import STAGES, BatchTrace, TransferWatchdog

jax.config.update("jax_platform_name", "cpu")

HIDDEN = 16
BATCH = 8
PIPELINE_DEPTH = 2


@pytest.fixture(scope="module")
def data():
    return make_dataset("cora", seed=0, scale=0.1)


@pytest.fixture(scope="module")
def store(data):
    st = GraphStore(max_batch=BATCH)
    st.register_graph("g", data)
    st.register_model("gcn", "gcn",
                      gnn.init_gcn(jax.random.PRNGKey(0), data.x.shape[1],
                                   HIDDEN, data.n_classes))
    return st


def _serve(engine, data, n=64, seed=0):
    engine.warmup("g", "gcn")
    nodes = np.random.default_rng(seed).integers(0, data.n_nodes, size=n)
    qs = engine.submit_many("g", "gcn", nodes)
    engine.run_until_drained()
    assert all(q.done for q in qs)
    return qs


# ------------------------------------------------------------ acceptance ---

def test_sharded_p2_trace_reconstructs_metrics(store, data, tmp_path):
    """Pipelined sharded serve at P=2: the recorded span tree reconstructs
    the per-batch extract / attributed-compute / queue-wait stage sums
    within 1ms of what ``ServeMetrics`` accumulated, and the Chrome-trace
    export is a loadable span-per-track JSON."""
    engine = ShardedServeEngine(store, 2, max_batch=BATCH, mode="subgraph",
                                pipeline_depth=PIPELINE_DEPTH,
                                staleness_s=600.0,
                                tracer=SpanTracer(sample_every=1))
    _serve(engine, data)
    m = engine.metrics
    trs = engine.tracer.batch_traces()
    assert len(trs) == m.batches          # sample_every=1 records them all
    assert all(t.kept for t in trs)

    ext = sum(t.stage_s("extract") for t in trs)
    cmp_ = sum(t.stage_s("compute") for t in trs)   # attributed_s sums
    assert abs(ext - m.extract_s) < 1e-3
    assert abs(cmp_ - m.compute_s) < 1e-3
    # per-query queue waits are non-negative and end at the pick time
    for t in trs:
        for q in t.queries:
            assert q["queue_wait_s"] >= 0.0
        (qw,) = [s for s in t.spans if s.name == "queue_wait"]
        assert qw.t1 == t.t_start
    # every batch is tagged with its owning shard and tenant
    assert {t.shard for t in trs} <= {0, 1}
    assert {t.tenant for t in trs} == {"default"}
    # halo attribution from the static schedule rode along
    assert all("serve_x_bytes" in t.halo for t in trs)

    path = tmp_path / "trace.json"
    obj = write_chrome_trace(engine.tracer, str(path))
    loaded = json.loads(path.read_text())
    assert loaded == obj
    events = [e for e in loaded["traceEvents"] if e["ph"] == "X"]
    assert len(events) == sum(len(t.spans) for t in trs)
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in events)
    # one track (pid) per shard, one thread per pipeline stage
    meta = [e for e in loaded["traceEvents"] if e["ph"] == "M"]
    pnames = {e["args"]["name"] for e in meta
              if e["name"] == "process_name"}
    assert pnames == {"shard-0", "shard-1"}
    tnames = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
    assert set(STAGES) <= tnames
    engine.close()


def test_recompile_watchdog_silent_then_fires(data):
    """Steady state across 2 feature updates: zero watchdog events. A
    forced novel shape (bucket watermark doubled behind the engine's back):
    the watchdog fires with the offending shape key."""
    st = GraphStore(max_batch=BATCH)
    st.register_graph("g", data)
    st.register_model("gcn", "gcn",
                      gnn.init_gcn(jax.random.PRNGKey(0), data.x.shape[1],
                                   HIDDEN, data.n_classes))
    engine = ShardedServeEngine(st, 2, max_batch=BATCH, mode="subgraph",
                                pipeline_depth=PIPELINE_DEPTH,
                                staleness_s=600.0)
    _serve(engine, data)
    assert engine.recompile_watchdog.armed
    rng = np.random.default_rng(1)
    for i in (1, 2):                      # two steady-state feature updates
        st.update_features("g", data.x + np.float32(1e-3 * i))
        engine.submit_many("g", "gcn",
                           rng.integers(0, data.n_nodes, size=2 * BATCH))
        engine.run_until_drained()
    assert engine.recompile_watchdog.steady_recompiles == 0
    assert engine.tracer.warning_events() == []

    # force a novel launch shape: doubling the node watermark guarantees a
    # never-traced pow2 bucket on the next prepared batch
    sess = st.sharded_session("g", "gcn", 2)
    for core in sess.cores:
        core._n_water *= 2
    engine.submit_many("g", "gcn",
                       rng.integers(0, data.n_nodes, size=2 * BATCH))
    engine.run_until_drained()
    assert engine.recompile_watchdog.steady_recompiles > 0
    events = engine.tracer.warning_events()
    assert events and all(e.name == "recompile" for e in events)
    assert all("core" in e.attrs["label"] for e in events)
    assert all(e.attrs["shape"]["n_pad"] > 0 for e in events)
    engine.close()


def test_compare_bench_gate(tmp_path):
    """Identical inputs exit 0; a synthetic 2x p99 regression exits 1."""
    import sys
    sys.path.insert(0, str((__import__("pathlib").Path(__file__)
                            .resolve().parents[1])))
    from benchmarks.compare_bench import main

    base = dict(schema_version=2, families=dict(gcn=dict(subgraph=dict(
        qps=2500.0, steady_state_compiles=0,
        latency=dict(count=200, p50_ms=5.0, p99_ms=7.0)))))
    pb = tmp_path / "base.json"
    pb.write_text(json.dumps(base))
    assert main([str(pb), str(pb)]) == 0

    bad = copy.deepcopy(base)
    bad["families"]["gcn"]["subgraph"]["latency"]["p99_ms"] *= 2
    pc = tmp_path / "bad.json"
    pc.write_text(json.dumps(bad))
    assert main([str(pb), str(pc)]) == 1

    # warn band: 1.5x p99 warns but passes — unless --strict
    warn = copy.deepcopy(base)
    warn["families"]["gcn"]["subgraph"]["latency"]["p99_ms"] *= 1.5
    pw = tmp_path / "warn.json"
    pw.write_text(json.dumps(warn))
    assert main([str(pb), str(pw)]) == 0
    assert main([str(pb), str(pw), "--strict"]) == 1

    # zero-tolerance: any steady-state compile increase fails outright
    cmp_ = copy.deepcopy(base)
    cmp_["families"]["gcn"]["subgraph"]["steady_state_compiles"] = 1
    pz = tmp_path / "compiles.json"
    pz.write_text(json.dumps(cmp_))
    assert main([str(pb), str(pz)]) == 1


def test_trace_overhead_within_5pct(store, data):
    """Steady-state serve with tracing at the default sampling stays within
    5% of the untraced QPS. Runs are INTERLEAVED traced/untraced pairs and
    each side takes its best-of-5, so a noisy host window (the full suite
    running around this test) degrades both sides instead of one."""
    def qps_once(trace):
        engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph",
                                pipeline_depth=PIPELINE_DEPTH, trace=trace)
        _serve(engine, data, n=192, seed=3)
        q = engine.snapshot()["qps"]
        engine.close()
        return q

    qps_once(True)                        # common warm pass (jit, caches)
    qps_once(False)
    pairs = [(qps_once(True), qps_once(False)) for _ in range(5)]
    traced = max(t for t, _ in pairs)
    untraced = max(u for _, u in pairs)
    assert traced >= 0.95 * untraced, (traced, untraced)


# -------------------------------------------------------------- tracer -----

def _dummy_trace(tracer, key=("g", "m", "default"), total_s=0.01):
    t0 = time.perf_counter()
    tr = tracer.begin(key, key[-1], None, [], t0)
    if tr is not None:
        tr.t_end = t0 + total_s
    return tr


def test_ring_buffer_wraparound():
    tracer = SpanTracer(capacity=4, sample_every=1)
    for _ in range(10):
        tracer.commit(_dummy_trace(tracer))
    recs = tracer.records()
    assert len(recs) == 4                  # bounded
    assert tracer.batches_seen == 10
    assert tracer.batches_recorded == 10   # all were recorded, ring kept 4
    ids = [r.trace_id for r in recs]
    assert ids == sorted(ids) and ids[-1] == 9   # oldest-first, newest kept


def test_sampling_one_in_n():
    tracer = SpanTracer(sample_every=4)
    kept = sum(tracer.commit(_dummy_trace(tracer)) for _ in range(16))
    assert kept == 4                       # batches 0, 4, 8, 12


def test_outliers_always_recorded():
    tracer = SpanTracer(sample_every=10**9)
    for _ in range(64):                    # build the rolling p99 window
        tracer.commit(_dummy_trace(tracer, total_s=0.01))
    assert tracer.commit(_dummy_trace(tracer, total_s=10.0))
    assert tracer.outliers_recorded == 1
    assert tracer.batch_traces()[-1].kept == "outlier"


def test_error_requeue_always_sampled(store, data):
    """A compute failure commits the batch's trace on the error path even
    with sampling effectively off (reuses the PR 4 failure-injection
    hook)."""
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph",
                            pipeline_depth=PIPELINE_DEPTH,
                            tracer=SpanTracer(sample_every=10**9))
    engine.warmup("g", "gcn")
    session = engine._get_session(("g", "gcn"))
    real = session.launch_batch
    calls = {"n": 0}

    def flaky(*args):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient compute failure")
        return real(*args)

    session.launch_batch = flaky
    try:
        qs = engine.submit_many("g", "gcn", np.arange(BATCH))
        with pytest.raises(RuntimeError, match="transient"):
            engine.run_until_drained()
        errors = [t for t in engine.tracer.batch_traces() if t.error]
        assert len(errors) == 1
        assert errors[0].kept == "error"
        assert errors[0].requeued
        assert "transient compute failure" in errors[0].error
        engine.run_until_drained()         # retry succeeds
    finally:
        session.launch_batch = real
    assert all(q.done for q in qs)
    assert engine.tracer.errors_recorded == 1
    engine.close()


def test_transfer_watchdog_flags_host_sync(store, data):
    """A launch that returns concrete host arrays (a blocking
    device->host sync inside the dispatch) is counted and emitted as a
    structured warning; the clean engine path counts zero."""
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph")
    _serve(engine, data, n=2 * BATCH)
    assert engine.transfer_watchdog.host_sync_in_launch == 0
    assert engine.transfer_watchdog.device_in_extract == 0

    session = engine._get_session(("g", "gcn"))
    real = session.launch_batch
    session.launch_batch = lambda prep: [np.asarray(d)
                                         for d in real(prep)]
    try:
        engine.submit_many("g", "gcn", np.arange(BATCH))
        engine.run_until_drained()
    finally:
        session.launch_batch = real
    assert engine.transfer_watchdog.host_sync_in_launch > 0
    warns = [e for e in engine.tracer.warning_events()
             if e.name == "transfer"]
    assert warns and warns[0].attrs["kind"] == "host_sync_in_launch"
    engine.close()


def test_queries_carry_trace_context(store, data):
    """Served queries link back to the batch trace that answered them, and
    the trace records the scheduler's virtual-time tag at pick."""
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph",
                            tracer=SpanTracer(sample_every=1))
    qs = _serve(engine, data, n=4 * BATCH)
    ids = {t.trace_id for t in engine.tracer.batch_traces()}
    assert all(q.trace_id in ids for q in qs)
    vtimes = [t.vtime for t in engine.tracer.batch_traces()]
    assert vtimes == sorted(vtimes) and vtimes[-1] > 0   # advancing vtime
    engine.close()


# ------------------------------------------------------------ exporters ----

def test_prometheus_text(store, data):
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph")
    _serve(engine, data)
    txt = prometheus_text(engine.snapshot(), engine.tracer)
    assert txt.endswith("\n")
    # every series is namespaced by the engine's model family
    assert 'serve_queries_total{family="gnn"} 64' in txt
    assert 'serve_latency_ms{family="gnn",group="query",quantile="p99"}' \
        in txt
    assert 'serve_tenant_accepted_total{family="gnn",tenant="default"} 64' \
        in txt
    assert "serve_trace_batches_seen_total" in txt
    # every sample line parses as <name>{labels} <float>
    for line in txt.splitlines():
        if line.startswith("#"):
            continue
        name, val = line.rsplit(" ", 1)
        float(val)
    engine.close()


def test_chrome_trace_empty_and_warnings_only():
    tracer = SpanTracer()
    assert chrome_trace(tracer)["traceEvents"] == []
    tracer.warning("recompile", label="core", shape=dict(n_pad=64))
    obj = chrome_trace(tracer)
    inst = [e for e in obj["traceEvents"] if e["ph"] == "i"]
    assert len(inst) == 1 and inst[0]["name"] == "recompile"


# ------------------------------------------------------ metrics satellites --

def test_latency_stats_window_vs_count():
    ls = LatencyStats(max_samples=4)
    for i in range(10):
        ls.record(i * 1e-3)
    s = ls.summary()
    assert s["count"] == 10                # lifetime
    assert s["window"] == 4 == ls.window   # retained ring
    assert s["max_ms"] == pytest.approx(9.0)
    empty = LatencyStats().summary()
    assert empty["count"] == 0 and empty["window"] == 0


def test_serve_metrics_clock_restart_safe():
    """A second serve wave after stop_clock() must RESUME the clock: the
    banked first-wave time is kept, elapsed keeps growing, and qps is
    total queries over total serving time."""
    m = ServeMetrics()
    m.start_clock()
    time.sleep(0.02)
    m.stop_clock()
    wave1 = m.elapsed_s
    assert wave1 >= 0.02
    time.sleep(0.02)
    assert m.elapsed_s == wave1            # stopped clock holds
    m.start_clock()                        # second wave resumes
    time.sleep(0.02)
    m.stop_clock()
    assert m.elapsed_s >= wave1 + 0.02
    m.queries = 100
    assert m.qps == pytest.approx(100 / m.elapsed_s)
    # idempotent start while running (the engine calls it per submit)
    m2 = ServeMetrics()
    m2.start_clock()
    t0 = m2.started_at
    m2.start_clock()
    assert m2.started_at == t0


def test_engine_two_wave_qps_not_inflated(store, data):
    """Engine-level regression: serve, drain (stop_clock), pause, serve
    again — elapsed_s must cover both waves, so qps cannot be inflated by
    the frozen first-wave window."""
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph")
    _serve(engine, data, n=2 * BATCH)
    e1 = engine.metrics.elapsed_s
    time.sleep(0.05)                        # idle gap: must not count
    nodes = np.random.default_rng(7).integers(0, data.n_nodes,
                                              size=2 * BATCH)
    engine.submit_many("g", "gcn", nodes)
    engine.run_until_drained()
    e2 = engine.metrics.elapsed_s
    assert e2 > e1                          # second wave extended the clock
    assert e2 < e1 + 0.05                   # ... but not by the idle gap
    assert engine.metrics.queries == 4 * BATCH
    assert engine.metrics.qps == pytest.approx(4 * BATCH / e2)
    engine.close()


# ------------------------------------------------------------- watchdogs ---

def test_transfer_watchdog_unit():
    class G:
        def __init__(self, x):
            self.staged = type("S", (), {"x_pad": x})()

    class P:
        def __init__(self, xs):
            self.groups = [G(x) for x in xs]

    wd = TransferWatchdog(SpanTracer())
    wd.check_prepared(P([np.zeros((4, 4))]))
    assert wd.device_in_extract == 0
    import jax.numpy as jnp
    wd.check_prepared(P([jnp.zeros((4, 4))]))   # device-resident staged
    assert wd.device_in_extract == 1
    wd.check_launched([jnp.zeros((4,))])
    assert wd.host_sync_in_launch == 0
    wd.check_launched([np.zeros((4,))])         # host array out of launch
    assert wd.host_sync_in_launch == 1
    assert {e.attrs["kind"] for e in wd.tracer.warning_events()} == \
        {"device_in_extract", "host_sync_in_launch"}


def test_tracer_disabled_is_noop(store, data):
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph",
                            trace=False)
    _serve(engine, data, n=2 * BATCH)
    assert engine.tracer.records() == []
    assert engine.tracer.batches_seen == 0
    snap = engine.snapshot()
    assert snap["trace"]["enabled"] is False
    engine.close()
