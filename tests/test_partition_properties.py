"""Property tests for graph partitioning (graphs/partition.py + the shard
planner built on it): every node assigned to exactly one shard, edge-cut +
intra-shard edges conserve the input edge set, and planning is deterministic
under the seed (and invariant to edge-list permutation).

The structural properties run as plain deterministic tests (always);
randomized sweeps additionally run under hypothesis when it is installed.
"""
import numpy as np
import jax
import pytest

from repro.core import frdc
from repro.graphs import partition
from repro.graphs.datasets import make_dataset
from repro.serve.sharded import ShardPlanner

jax.config.update("jax_platform_name", "cpu")

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _random_graph(n, e, seed):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n, size=e)
    cols = rng.integers(0, n, size=e)
    return rows.astype(np.int64), cols.astype(np.int64)


def _edge_set(rows, cols):
    return set(zip(rows.tolist(), cols.tolist()))


def _plan_edge_set(plan, kind):
    """Reconstruct the global edge set a plan's intra + halo matrices hold."""
    edges = set()
    for p in plan.parts:
        dense = np.array(frdc.to_dense(p.intra[kind], apply_scales=False))
        if kind == "adj":        # drop the self-loops the GCN kind adds
            np.fill_diagonal(dense, 0.0)
        r, c = np.nonzero(dense[:p.n_local, :p.n_local])
        edges |= _edge_set(r + p.row_start, c + p.row_start)
        if p.n_halo:
            dh = np.asarray(frdc.to_dense(p.halo[kind], apply_scales=False))
            r, c = np.nonzero(dh[:p.n_local, :p.n_halo])
            edges |= _edge_set(r + p.row_start, p.halo_nodes[c])
    return edges


# ------------------------------------------------------ plain (always) ------

@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
def test_every_node_assigned_exactly_once(n_shards):
    rows, _ = _random_graph(130, 700, seed=1)
    bounds = partition.shard_node_bounds(rows, 130, n_shards)
    assert bounds[0] == 0 and bounds[-1] == 130
    assert (np.diff(bounds) >= 0).all()
    owner_count = np.zeros(130, np.int64)
    for s in range(n_shards):
        owner_count[bounds[s]:bounds[s + 1]] += 1
    np.testing.assert_array_equal(owner_count, 1)
    # interior boundaries are tile-row aligned
    assert all(b % frdc.TILE == 0 for b in bounds[:-1])


@pytest.mark.parametrize("family,kind", [("gcn", "bin"), ("sage", "mean"),
                                         ("saint", "sum")])
@pytest.mark.parametrize("n_shards", [2, 4])
def test_planner_conserves_edge_set(family, kind, n_shards):
    """Union of intra + halo edges (mapped back to global ids) == input."""
    data = make_dataset("cora", seed=0, scale=0.05)
    plan = ShardPlanner(n_shards).plan(data, family)
    want = _edge_set(data.edges[0], data.edges[1])
    assert _plan_edge_set(plan, kind) == want
    # every edge is intra XOR halo: totals add up exactly
    n_intra = sum(p.intra[kind].nnz for p in plan.parts)
    n_halo = sum(p.halo[kind].nnz for p in plan.parts)
    assert n_intra + n_halo == data.n_edges


def test_gcn_normalized_kind_conserves_with_self_loops():
    data = make_dataset("cora", seed=0, scale=0.05)
    plan = ShardPlanner(3).plan(data, "gcn")
    # "adj" kind = edges + one self-loop per node, all loops intra
    n_intra = sum(p.intra["adj"].nnz for p in plan.parts)
    n_halo = sum(p.halo["adj"].nnz for p in plan.parts)
    assert n_intra + n_halo == data.n_edges + data.n_nodes
    assert _plan_edge_set(plan, "adj") == _edge_set(data.edges[0],
                                                    data.edges[1])


def test_partition_rows_conserves_edges():
    rows, cols = _random_graph(97, 500, seed=3)
    shards = partition.partition_rows(rows, cols, 97, 3, kind="binary")
    got = set()
    for sh in shards:
        dense = np.asarray(frdc.to_dense(sh.adj, apply_scales=False))
        r, c = np.nonzero(dense[: sh.row_end - sh.row_start])
        got |= _edge_set(r + sh.row_start, c)
    assert got == _edge_set(rows, cols)


@pytest.mark.parametrize("n_shards", [2, 4])
def test_plan_deterministic_under_seed(n_shards):
    """Same seed -> identical plan; permuted edge order -> identical
    boundaries, halo sets and adjacency structure (FRDC bits)."""
    d1 = make_dataset("cora", seed=0, scale=0.05)
    d2 = make_dataset("cora", seed=0, scale=0.05)
    p1 = ShardPlanner(n_shards).plan(d1, "gcn")
    p2 = ShardPlanner(n_shards).plan(d2, "gcn")
    np.testing.assert_array_equal(p1.routing.bounds, p2.routing.bounds)
    for a, b in zip(p1.parts, p2.parts):
        np.testing.assert_array_equal(a.halo_nodes, b.halo_nodes)
        for k in a.intra:
            np.testing.assert_array_equal(np.asarray(a.intra[k].tiles),
                                          np.asarray(b.intra[k].tiles))
            np.testing.assert_array_equal(np.asarray(a.halo[k].col_idx),
                                          np.asarray(b.halo[k].col_idx))
        np.testing.assert_array_equal(a.indices, b.indices)

    # permutation invariance of the structure (CSR neighbor order may
    # legally differ; the adjacency MATRICES may not)
    d3 = make_dataset("cora", seed=0, scale=0.05)
    perm = np.random.default_rng(7).permutation(d3.n_edges)
    d3.edges = d3.edges[:, perm]
    p3 = ShardPlanner(n_shards).plan(d3, "gcn")
    np.testing.assert_array_equal(p3.routing.bounds, p1.routing.bounds)
    for a, b in zip(p1.parts, p3.parts):
        np.testing.assert_array_equal(a.halo_nodes, b.halo_nodes)
        for k in a.intra:
            np.testing.assert_array_equal(np.asarray(a.intra[k].tiles),
                                          np.asarray(b.intra[k].tiles))
            np.testing.assert_array_equal(np.asarray(a.halo[k].tiles),
                                          np.asarray(b.halo[k].tiles))


def test_different_seed_different_graph_still_conserves():
    for seed in (1, 2):
        rows, cols = _random_graph(64, 300, seed=seed)
        bounds = partition.shard_node_bounds(rows, 64, 2)
        b2 = partition.shard_node_bounds(rows, 64, 2)
        np.testing.assert_array_equal(bounds, b2)   # deterministic


# ------------------------------------------------- hypothesis (optional) ----

if HAVE_HYPOTHESIS:

    @given(st.integers(8, 120), st.integers(1, 6), st.integers(0, 2**31))
    @settings(max_examples=20, deadline=None)
    def test_bounds_partition_nodes_hyp(n, n_shards, seed):
        rows, _ = _random_graph(n, 4 * n, seed)
        bounds = partition.shard_node_bounds(rows, n, n_shards)
        assert bounds[0] == 0 and bounds[-1] == n
        assert (np.diff(bounds) >= 0).all()
        covered = np.concatenate(
            [np.arange(bounds[s], bounds[s + 1]) for s in range(n_shards)])
        np.testing.assert_array_equal(covered, np.arange(n))

    @given(st.integers(16, 80), st.integers(2, 4), st.integers(0, 2**31))
    @settings(max_examples=10, deadline=None)
    def test_split_conserves_edges_hyp(n, n_shards, seed):
        rows, cols = _random_graph(n, 3 * n, seed)
        bounds = partition.shard_node_bounds(rows, n, n_shards)
        total = 0
        for s in range(n_shards):
            lo, hi = bounds[s], bounds[s + 1]
            m = (rows >= lo) & (rows < hi)
            total += int(m.sum())
            cmask = (cols[m] >= lo) & (cols[m] < hi)
            # intra + halo of this shard == its row slice
            assert int(cmask.sum()) + int((~cmask).sum()) == int(m.sum())
        assert total == rows.size
