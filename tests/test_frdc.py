import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import bitops, frdc
from repro.core.binarize import BinTensor
from repro.core.bspmm import bspmm
from repro.core.bmm import quantize_act

jax.config.update("jax_platform_name", "cpu")


def random_graph(n, density, seed):
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < density).astype(np.float32)
    return a


@given(st.integers(1, 70), st.floats(0.01, 0.4), st.integers(0, 2**31))
@settings(max_examples=25, deadline=None)
def test_from_dense_roundtrip(n, density, seed):
    a = random_graph(n, density, seed)
    m = frdc.from_dense(a)
    np.testing.assert_array_equal(np.asarray(frdc.to_dense(m)), a)
    assert m.nnz == int(a.sum())


def test_coarsen_groups_concatenates_tiles():
    # one group: tile t has bit (i*4+j) -> word i bit (t*4+j)
    rng = np.random.default_rng(0)
    tiles = rng.integers(0, 2**16, size=(1, frdc.GROUP), dtype=np.uint16)
    words = np.asarray(frdc.coarsen_groups(jnp.asarray(tiles)))
    for i in range(4):
        for t in range(8):
            for j in range(4):
                expected = (int(tiles[0, t]) >> (i * 4 + j)) & 1
                got = (int(words[0, i]) >> (t * 4 + j)) & 1
                assert got == expected


@given(st.integers(2, 60), st.integers(1, 40), st.floats(0.02, 0.5),
       st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_bspmm_fbf_exact(n, f, density, seed):
    a = random_graph(n, density, seed)
    m = frdc.from_dense(a)
    rng = np.random.default_rng(seed + 1)
    x = rng.standard_normal((n, f)).astype(np.float32)
    out = bspmm(m, jnp.asarray(x), "FBF")
    np.testing.assert_allclose(np.asarray(out), a @ x, rtol=1e-5, atol=1e-5)


def test_bspmm_fbf_weighted_exact():
    n, f = 50, 17
    rng = np.random.default_rng(7)
    rr, cc = np.nonzero(random_graph(n, 0.15, 3))
    m = frdc.gcn_normalized(rr, cc, n)
    dense = np.asarray(frdc.to_dense(m))
    x = rng.standard_normal((n, f)).astype(np.float32)
    out = bspmm(m, jnp.asarray(x), "FBF")
    np.testing.assert_allclose(np.asarray(out), dense @ x, rtol=1e-5, atol=1e-5)


@given(st.integers(2, 60), st.integers(1, 64), st.floats(0.05, 0.5),
       st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_bspmm_bbf_counts_exact_unweighted(n, f, density, seed):
    """For an unweighted adjacency with unit act scales, BBF is EXACT."""
    a = random_graph(n, density, seed)
    m = frdc.from_dense(a)
    rng = np.random.default_rng(seed + 2)
    act = rng.choice([-1.0, 1.0], size=(n, f)).astype(np.float32)
    xt = BinTensor(packed=bitops.pack_bits(act > 0), scale=jnp.ones((n, 1)), n=f)
    out = bspmm(m, xt, "BBF")
    np.testing.assert_allclose(np.asarray(out), a @ act, rtol=1e-5, atol=1e-5)


@given(st.sampled_from(["s2_and_andnot", "s3_two_popc"]))
@settings(max_examples=2, deadline=None)
def test_bspmm_bbb_binarizes_counts(mode):
    n, f = 40, 33
    a = random_graph(n, 0.2, 11)
    m = frdc.from_dense(a)
    rng = np.random.default_rng(12)
    act = rng.choice([-1.0, 1.0], size=(n, f)).astype(np.float32)
    xt = BinTensor(packed=bitops.pack_bits(act > 0), scale=jnp.ones((n, 1)), n=f)
    out = bspmm(m, xt, "BBB", trinary_mode=mode)
    expected = (a @ act) >= 0
    got = np.asarray(bitops.unpack_bits(out.packed, f)) > 0
    np.testing.assert_array_equal(got, expected)


def test_bspmm_fbb_elides_row_scale():
    """FBB output bits must be unaffected by (positive) row scales."""
    n, f = 30, 20
    rng = np.random.default_rng(5)
    rr, cc = np.nonzero(random_graph(n, 0.2, 6))
    m = frdc.gcn_normalized(rr, cc, n)
    x = rng.standard_normal((n, f)).astype(np.float32)
    out = bspmm(m, jnp.asarray(x), "FBB")
    dense = np.asarray(frdc.to_dense(m))
    expected = (dense @ x) >= 0
    np.testing.assert_array_equal(
        np.asarray(bitops.unpack_bits(out.packed, f)) > 0, expected)


def test_stats_reports_space_saving():
    a = random_graph(200, 0.05, 9)
    m = frdc.from_dense(a)
    s = frdc.stats(m)
    assert s["nnz"] == int(a.sum())
    assert s["frdc_bytes"] > 0
    assert 0.0 <= s["pad_fraction"] < 1.0


def test_empty_graph():
    m = frdc.from_coo(np.array([], np.int64), np.array([], np.int64), 8, 8)
    x = jnp.ones((8, 4))
    out = bspmm(m, x, "FBF")
    np.testing.assert_allclose(np.asarray(out), np.zeros((8, 4)), atol=1e-6)
