"""Pipelined serving loop + halo-aware batch formation tests.

The acceptance bar of the pipelined engine: for the SAME submitted queries,
the double-buffered extract/compute pipeline produces BIT-IDENTICAL answers
to the serial loop — single-host for all three families, sharded at P=2/4 —
with zero steady-state recompiles across feature updates. Plus: the heap
queue pick preserves the linear scan's scheduling order, halo-aware
formation respects the staleness bound and the single-owner invariant, and
the Pallas BSpMM block-shape tunable rides through ``plan.json``.
"""
import time

import numpy as np
import jax
import pytest

from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import GNNServeEngine, GraphStore, ShardedServeEngine

jax.config.update("jax_platform_name", "cpu")

HIDDEN = 16
BATCH = 8
PIPELINE_DEPTH = 2


@pytest.fixture(scope="module")
def data():
    return make_dataset("cora", seed=0, scale=0.1)


@pytest.fixture(scope="module")
def store(data):
    st = GraphStore(max_batch=BATCH)
    st.register_graph("g", data)
    key = jax.random.PRNGKey(0)
    f, c = data.x.shape[1], data.n_classes
    st.register_model("gcn", "gcn", gnn.init_gcn(key, f, HIDDEN, c))
    st.register_model("sage", "sage", gnn.init_sage(key, f, HIDDEN, c))
    st.register_model("saint", "saint", gnn.init_saint(key, f, HIDDEN, c))
    return st


def _drain(engine, model, nodes):
    engine.warmup("g", model)
    queries = engine.submit_many("g", model, nodes)
    engine.run_until_drained()
    assert all(q.done for q in queries)
    return np.stack([q.logits for q in queries])


# ------------------------------------------------------------ bit-exact ----

@pytest.mark.parametrize("model", ["gcn", "sage", "saint"])
def test_pipelined_matches_serial_single_host(store, data, model):
    """pipeline_depth >= 1 overlaps extraction with the in-flight forward
    but must not change a single bit of any answer."""
    nodes = np.random.default_rng(1).integers(0, data.n_nodes, size=5 * BATCH)
    serial = _drain(GNNServeEngine(store, max_batch=BATCH, mode="subgraph"),
                    model, nodes)
    pipe_engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph",
                                 pipeline_depth=PIPELINE_DEPTH)
    piped = _drain(pipe_engine, model, nodes)
    np.testing.assert_array_equal(piped, serial)
    snap = pipe_engine.snapshot()
    assert snap["pipeline_depth"] == PIPELINE_DEPTH
    # both stages were timed for every served batch
    assert snap["batch_breakdown"]["extract"]["count"] == snap["batches"]
    assert snap["batch_breakdown"]["compute"]["count"] == snap["batches"]
    pipe_engine.close()


@pytest.mark.parametrize("model", ["gcn", "sage", "saint"])
@pytest.mark.parametrize("n_shards", (2, 4))
def test_pipelined_matches_serial_sharded(store, data, model, n_shards):
    """The sharded engine under pipelining (and halo-aware formation) is
    bit-exact vs the serial sharded engine AND vs the single-host session
    replaying its actual batch compositions. Staleness is pinned far above
    any plausible stall so both runs form identical (purely
    signature-driven) batches regardless of host timing."""
    nodes = np.random.default_rng(2).integers(0, data.n_nodes, size=5 * BATCH)
    serial = _drain(ShardedServeEngine(store, n_shards, max_batch=BATCH,
                                       mode="subgraph", staleness_s=600.0),
                    model, nodes)
    engine = ShardedServeEngine(store, n_shards, max_batch=BATCH,
                                mode="subgraph", staleness_s=600.0,
                                pipeline_depth=PIPELINE_DEPTH)
    piped = _drain(engine, model, nodes)
    np.testing.assert_array_equal(piped, serial)
    single = store.session("g", model)
    for batch in engine.batch_log:
        want = single.serve_subgraph(np.asarray([q.node for q in batch]))
        np.testing.assert_array_equal(np.stack([q.logits for q in batch]),
                                      want)
    engine.close()


def test_full_cache_mode_pipelined(store, data):
    """The full-cache path resolves in the extract stage; pipelining must
    reproduce the cached answers exactly."""
    nodes = np.arange(0, data.n_nodes, 5)[:3 * BATCH]
    serial = _drain(GNNServeEngine(store, max_batch=BATCH, mode="full"),
                    "gcn", nodes)
    piped = _drain(GNNServeEngine(store, max_batch=BATCH, mode="full",
                                  pipeline_depth=PIPELINE_DEPTH),
                   "gcn", nodes)
    np.testing.assert_array_equal(piped, serial)


# ---------------------------------------------------------- steady state ---

def test_zero_steady_state_recompiles_pipelined_across_updates(data):
    """Under pipelining, the jit cache-miss counter must not move in steady
    state — including across feature updates (recalibration reuses the
    already-traced full pass; serving reuses the warmed shape buckets)."""
    st = GraphStore(max_batch=BATCH)
    d2 = make_dataset("cora", seed=0, scale=0.1)
    st.register_graph("g", d2)
    st.register_model("gcn", "gcn",
                      gnn.init_gcn(jax.random.PRNGKey(0), d2.x.shape[1],
                                   HIDDEN, d2.n_classes))
    engine = GNNServeEngine(st, max_batch=BATCH, mode="subgraph",
                            pipeline_depth=PIPELINE_DEPTH)
    engine.warmup("g", "gcn")
    rng = np.random.default_rng(5)
    engine.submit_many("g", "gcn", rng.integers(0, d2.n_nodes, 3 * BATCH))
    engine.run_until_drained()
    c0 = engine.compile_count
    for round_ in range(2):
        x2 = d2.x.copy()
        x2[: d2.n_nodes // 7] = float(round_)
        st.update_features("g", x2)
        engine.submit_many("g", "gcn",
                           rng.integers(0, d2.n_nodes,
                                        rng.integers(1, 3 * BATCH)))
        engine.run_until_drained()
    assert engine.compile_count == c0
    sess = st.session("g", "gcn")
    assert sess.invalidations == 2
    engine.close()


def test_tick_drains_light_traffic(store, data):
    """A partially-filled pipeline must still complete via non-blocking
    tick() once the queue is empty — light traffic cannot strand launched
    batches behind the depth gate."""
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph",
                            pipeline_depth=PIPELINE_DEPTH)
    engine.warmup("g", "gcn")
    qs = engine.submit_many("g", "gcn", np.arange(BATCH))  # ONE batch
    served = 0
    for _ in range(1000):          # poll: completes once the device is done
        served += engine.tick()
        if served:
            break
        time.sleep(0.005)
    assert served == len(qs)
    assert all(q.done for q in qs)
    want = store.session("g", "gcn").serve_subgraph(np.arange(BATCH))
    np.testing.assert_array_equal(np.stack([q.logits for q in qs]), want)
    engine.close()


def test_prepared_batch_pins_calibration(data):
    """A batch staged before a feature update must compute with the
    calibration (and features) it was staged under, even if the session
    recalibrates before the launch — the pipelined-engine race the
    PreparedBatch.bn capture exists for."""
    st = GraphStore(max_batch=BATCH)
    d2 = make_dataset("cora", seed=0, scale=0.1)
    st.register_graph("g", d2)
    st.register_model("gcn", "gcn",
                      gnn.init_gcn(jax.random.PRNGKey(0), d2.x.shape[1],
                                   HIDDEN, d2.n_classes))
    sess = st.session("g", "gcn")
    seeds = np.arange(BATCH)
    want = sess.serve_subgraph(seeds)          # v0 features, v0 calibration

    prepared = sess.prepare_batch(seeds)       # staged under v0
    x2 = d2.x.copy()
    x2[: d2.n_nodes // 4] += 2.0
    st.update_features("g", x2)
    sess.sync()                                # session.bn now v1
    got = sess.finish_batch(prepared, sess.launch_batch(prepared))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("fail_in", ["launch_batch", "finish_batch"])
def test_metric_counters_retry_invariant(store, data, fail_in):
    """Injected launch/complete failures requeue the batch and retry it —
    the serve-path counters (``subgraph_queries`` / ``full_cache_hits``,
    hence ``cache_hit_rate``) must count the batch ONCE, in its single
    successful completion, not once per attempt (the old launch-stage
    counting double-counted retried batches)."""
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph",
                            pipeline_depth=PIPELINE_DEPTH)
    engine.warmup("g", "gcn")
    session = engine._get_session(("g", "gcn"))
    real = getattr(session, fail_in)
    calls = {"n": 0}

    def flaky(*args):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient compute failure")
        return real(*args)

    nodes = np.arange(BATCH)
    setattr(session, fail_in, flaky)
    try:
        qs = engine.submit_many("g", "gcn", nodes)
        with pytest.raises(RuntimeError, match="transient"):
            engine.run_until_drained()
        # the failed attempt must not have moved the serve-path counters
        assert engine.metrics.subgraph_queries == 0
        assert engine.metrics.queries == 0
        engine.run_until_drained()                 # retry succeeds
    finally:
        setattr(session, fail_in, real)
    assert all(q.done for q in qs)
    assert engine.metrics.subgraph_queries == len(qs)   # counted exactly once
    assert engine.metrics.full_cache_hits == 0
    assert engine.metrics.queries == len(qs)
    assert engine.metrics.cache_hit_rate == 0.0
    engine.close()


def test_extract_failure_requeues_and_recovers(store, data):
    """An extract-stage failure on the background worker must neither lose
    the popped queries nor wedge the pipeline: the error surfaces to the
    caller, the batch is requeued, and the next drain serves it."""
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph",
                            pipeline_depth=PIPELINE_DEPTH)
    engine.warmup("g", "gcn")
    session = engine._get_session(("g", "gcn"))
    real = session.prepare_batch
    calls = {"n": 0}

    def flaky(seeds):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient extract failure")
        return real(seeds)

    nodes = np.arange(BATCH)
    session.prepare_batch = flaky
    try:
        qs = engine.submit_many("g", "gcn", nodes)
        with pytest.raises(RuntimeError, match="transient"):
            engine.run_until_drained()
        assert engine.pending == len(qs)       # requeued, not lost
        engine.run_until_drained()             # pipeline not wedged: retry
    finally:
        session.prepare_batch = real
    assert all(q.done for q in qs)
    want = store.session("g", "gcn").serve_subgraph(nodes)
    np.testing.assert_array_equal(np.stack([q.logits for q in qs]), want)
    engine.close()


# ------------------------------------------------------------- scheduling --

class _LinearPickEngine(GNNServeEngine):
    """Reference scheduler: the pre-heap O(#queues) oldest-head scan."""

    def _pick_queue(self):
        best, best_t = None, float("inf")
        for key, dq in self._queues.items():
            if dq and dq[0].t_submit < best_t:
                best, best_t = key, dq[0].t_submit
        return best


def test_heap_pick_matches_linear_scan_order(store, data):
    """Regression: the incremental oldest-head heap serves queries in the
    same order as the linear scan it replaced."""
    rng = np.random.default_rng(7)
    plan = []
    for _ in range(40):
        plan.append((rng.choice(["gcn", "sage"]),
                     int(rng.integers(0, data.n_nodes))))

    def run(engine_cls):
        engine = engine_cls(store, max_batch=3, mode="full")
        order = []
        it = iter(plan)
        exhausted = False
        while not exhausted or engine.pending:
            for _ in range(2):     # interleave submission with serving
                nxt = next(it, None)
                if nxt is None:
                    exhausted = True
                    break
                engine.submit("g", nxt[0], nxt[1])
            engine.tick()
        engine.run_until_drained()
        for batch in engine.batch_log:
            order.append(tuple((q.graph, q.model, q.node) for q in batch))
        return order

    assert run(GNNServeEngine) == run(_LinearPickEngine)


# ----------------------------------------------------- halo-aware forming --

def _owner_nodes(sess, owner):
    lo, hi = sess.routing.shard_range(owner)
    return np.arange(lo, hi)


def test_halo_aware_groups_by_signature(store, data):
    """Within one owner queue, formation co-batches the seed sharing halo
    tiles with the head IN FRONT OF an earlier-submitted non-overlapping
    seed — and counts the shared tiles."""
    sess = store.sharded_session("g", "gcn", 2)
    nodes = _owner_nodes(sess, 0)
    sigs = {int(n): sess.seed_halo_tiles(int(n)) for n in nodes}
    head, buddy, loner = None, None, None
    for a in nodes:
        for b in nodes:
            if a != b and sigs[int(a)] & sigs[int(b)]:
                head, buddy = int(a), int(b)
                break
        if head is not None:
            break
    assert head is not None, "test graph has no overlapping signatures"
    for c in nodes:
        if int(c) not in (head, buddy) and not (sigs[int(c)] & sigs[head]):
            loner = int(c)
            break
    assert loner is not None

    engine = ShardedServeEngine(store, 2, max_batch=2, mode="subgraph",
                                staleness_s=60.0)
    engine.warmup("g", "gcn")
    saved0 = engine.halo_bytes_saved
    engine.submit("g", "gcn", head)
    engine.submit("g", "gcn", loner)     # FIFO-older than buddy
    engine.submit("g", "gcn", buddy)
    engine.run_until_drained()
    got = [[q.node for q in b] for b in engine.batch_log]
    assert got == [[head, buddy], [loner]]
    assert engine.halo_tiles_shared >= len(sigs[head] & sigs[buddy])
    assert engine.halo_bytes_saved > saved0
    # the reordered loner still came out bit-exact vs single host
    single = store.session("g", "gcn")
    for batch in engine.batch_log:
        want = single.serve_subgraph(np.asarray([q.node for q in batch]))
        np.testing.assert_array_equal(np.stack([q.logits for q in batch]),
                                      want)


def test_halo_aware_staleness_bound(store, data):
    """A request whose wait exceeds ``staleness_s`` preempts signature
    grouping: it is taken in FIFO order by the next batch formed from its
    queue, never skipped for better overlap."""
    sess = store.sharded_session("g", "gcn", 2)
    nodes = _owner_nodes(sess, 0)
    sigs = {int(n): sess.seed_halo_tiles(int(n)) for n in nodes}
    head, buddy, loner = None, None, None
    for a in nodes:
        for b in nodes:
            if a != b and sigs[int(a)] & sigs[int(b)]:
                head, buddy = int(a), int(b)
                break
        if head is not None:
            break
    for c in nodes:
        if int(c) not in (head, buddy) and not (sigs[int(c)] & sigs[head]):
            loner = int(c)
            break
    assert None not in (head, buddy, loner)

    engine = ShardedServeEngine(store, 2, max_batch=2, mode="subgraph",
                                staleness_s=0.5)
    engine.warmup("g", "gcn")
    q_head = engine.submit("g", "gcn", head)
    q_loner = engine.submit("g", "gcn", loner)
    engine.submit("g", "gcn", buddy)
    q_loner.t_submit -= 10.0             # overdue beyond the bound
    engine.run_until_drained()
    got = [[q.node for q in b] for b in engine.batch_log]
    assert got == [[head, loner], [buddy]]
    assert q_head.done and q_loner.done


def test_halo_aware_single_owner_and_fifo_fallback(store, data):
    """Every halo-aware batch is single-owner (queues are keyed by owning
    shard), and ``halo_aware=False`` restores the exact FIFO pop."""
    nodes = np.random.default_rng(3).integers(0, data.n_nodes, size=4 * BATCH)
    engine = ShardedServeEngine(store, 4, max_batch=BATCH, mode="subgraph")
    engine.warmup("g", "gcn")
    engine.submit_many("g", "gcn", nodes)
    engine.run_until_drained()
    sess = store.sharded_session("g", "gcn", 4)
    for batch in engine.batch_log:
        owners = sess.routing.owner(np.asarray([q.node for q in batch]))
        assert np.unique(owners).size == 1

    fifo = ShardedServeEngine(store, 4, max_batch=BATCH, mode="subgraph",
                              halo_aware=False)
    fifo.warmup("g", "gcn")
    qs = fifo.submit_many("g", "gcn", nodes)
    fifo.run_until_drained()
    assert fifo.halo_bytes_saved == 0
    # FIFO pop serves each owner queue in submission order
    by_owner = {}
    for q in qs:
        by_owner.setdefault(int(sess.routing.owner(
            np.asarray([q.node]))[0]), []).append(q.node)
    got_by_owner = {}
    for batch in fifo.batch_log:
        o = int(sess.routing.owner(np.asarray([batch[0].node]))[0])
        got_by_owner.setdefault(o, []).extend(q.node for q in batch)
    assert got_by_owner == by_owner


# --------------------------------------------------------- bspmm tunable ---

def test_bspmm_block_recorded_and_roundtrips(tmp_path, data):
    """The Pallas BSpMM block-shape tunable is recorded in plan.json, kept
    across artifact restore, forces a recompile when changed — and leaves
    answers unchanged (default-equivalent block, exercised through the
    kernels in interpret mode)."""
    from repro.kernels import ops
    from repro.serve.gnn_session import CompiledGraphSession
    tiny = make_dataset("cora", seed=0, scale=0.03)
    params = gnn.init_gcn(jax.random.PRNGKey(0), tiny.x.shape[1], 8,
                          tiny.n_classes)
    nodes = np.arange(4)

    st_ref = GraphStore(max_batch=4)
    st_ref.register_graph("t", tiny)
    st_ref.register_model("gcn", "gcn", params)
    ref = st_ref.session("t", "gcn").serve_subgraph(nodes)

    blk = (4, 64)           # tile-row height x feature-block pad
    ops.force_kernels(True)
    try:
        st1 = GraphStore(cache_dir=str(tmp_path), max_batch=4,
                         use_pallas=True, bspmm_block=blk)
        st1.register_graph("t", make_dataset("cora", seed=0, scale=0.03))
        st1.register_model("gcn", "gcn", params)
        s1 = st1.session("t", "gcn")
        assert s1.plan.bspmm_block == blk
        got = s1.serve_subgraph(nodes)
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.argmax(got, -1),
                                      np.argmax(ref, -1))

        # restore with the SAME block: plan (incl. the tunable) survives
        st2 = GraphStore(cache_dir=str(tmp_path), max_batch=4,
                         use_pallas=True, bspmm_block=blk)
        st2.register_graph("t", make_dataset("cora", seed=0, scale=0.03))
        st2.register_model("gcn", "gcn", params)
        s2 = st2.session("t", "gcn")
        assert s2.plan.bspmm_block == blk
        assert s2.plan.to_json()["bspmm_block"] == list(blk)
        np.testing.assert_array_equal(s2.serve_subgraph(nodes), got)

        # a different block shape is a trace-time choice: restore refuses
        assert CompiledGraphSession.load(
            tmp_path / "t__gcn", st2.graphs["t"], st2.models["gcn"],
            bspmm_block=(4, 128)) is None
        assert CompiledGraphSession.load(
            tmp_path / "t__gcn", st2.graphs["t"], st2.models["gcn"],
            bspmm_block=blk) is not None
    finally:
        ops.force_kernels(False)


def test_bspmm_block_validation():
    """Unsupported block shapes fail loudly at the kernel seam (no silent
    fallback): non-tile-multiple row counts and unaligned packed feature
    blocks. Multi-row blocks are legal since the 2D grid landed; the
    capability probe answers without raising and every rejection names the
    full legal block-shape space."""
    from repro.kernels import bspmm_kernel
    assert bspmm_kernel._resolve_block(None, 96, False) == 96
    assert bspmm_kernel._resolve_block((4, 64), 96, False) == 128
    assert bspmm_kernel._resolve_block((4, None), 96, False) == 96
    # packed paths keep their word-native width under a word-aligned block
    assert bspmm_kernel._resolve_block((4, 64), 96, True) == 96
    # multi-row output blocks are supported now (2D grid)
    assert bspmm_kernel._resolve_block((8, 64), 96, False) == 128
    assert bspmm_kernel.block_probe((16, None), 96, True) is None
    # the probe reports the violation AND the legal space in one message
    reason = bspmm_kernel.block_probe((6, 64), 96, False)
    assert reason is not None and "legal BSpMM block shapes" in reason
    with pytest.raises(ValueError):
        bspmm_kernel._resolve_block((6, 64), 96, False)
    with pytest.raises(ValueError):
        bspmm_kernel._resolve_block((4, 48), 96, True)
    with pytest.raises(ValueError):
        bspmm_kernel._resolve_block((4, 0), 96, False)


# -------------------------------------------------------------- plumbing ---

def test_extract_khop_prepared_object(data):
    """The sampling-layer extraction entry point returns the prepared-batch
    object with the same contents as the tuple API."""
    from repro.graphs import sampling
    csr = sampling.to_csr(data.edges, data.n_nodes)
    seeds = np.array([1, 5, 9])
    ex = sampling.extract_khop(csr, seeds, 2)
    want = sampling.khop_subgraph(csr, seeds, 2)
    np.testing.assert_array_equal(ex.sub_nodes, want[0])
    np.testing.assert_array_equal(ex.sub_edges, want[1])
    np.testing.assert_array_equal(ex.seed_pos, want[2])
    np.testing.assert_array_equal(ex.sub_nodes[ex.seed_pos], seeds)
