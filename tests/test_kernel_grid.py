"""2D BSpMM block grid + fused per-layer kernel + multi-bucket launch tests.

The three guarantees of the kernel-plan stack, checked end to end:

  * the 2D (rows, feats) block grid is BITWISE identical to the 1D
    flattened-group grid for every legal block shape (property sweep over
    rows/feats/n_feat/tile count; hypothesis widens the sweep when
    installed);
  * the fused per-layer path is one Pallas launch per layer and bitwise
    identical to the unfused serve path — verified through the replayed
    ``batch_log`` oracle, which compares jitted vs jitted (the fused
    guarantee; eager-vs-jit differs by XLA fusion rounding);
  * the multi-bucket co-launch dispatches several padded pow2 buckets as
    one jitted program per serve core — fewer dispatches per tick, same
    bits, visible in the span traces as shared coalesced launch windows.

Plus the persistence seams they ride on: the tuner cache file format and
``GraphStore`` seeding, the ``SessionPlan.fused`` artifact roundtrip, and
the ``repro.env`` XLA-flags helper.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitops, frdc
from repro.kernels import bspmm_kernel, fused_layer, ref
from repro.kernels import ops as kernel_ops
from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import GNNServeEngine, GraphStore
from repro.serve.trace import SpanTracer

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

jax.config.update("jax_platform_name", "cpu")

BATCH = 8
HIDDEN = 16


# ---------------------------------------------------------------- 2D grid ---

def _case(seed: int, n: int, f: int, rows: int, feats):
    """One property-sweep case: the 2D grid must match the 1D grid BITWISE
    (fp and counts) and the fp oracle to fp tolerance."""
    rng = np.random.default_rng(seed)
    adj = frdc.from_dense((rng.random((n, n)) < 0.2).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    got = bspmm_kernel.bspmm_fp(adj, x, block_shape=(rows, feats))
    base = bspmm_kernel.bspmm_fp(adj, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
    np.testing.assert_allclose(np.asarray(got[: adj.n_rows]),
                               np.asarray(ref.bspmm_fp_ref(adj, x))[
                                   : adj.n_rows],
                               rtol=1e-5, atol=1e-5)
    xp = bitops.pack_bits(rng.choice([-1.0, 1.0], size=(n, f)) > 0)
    # packed feature blocks must stay word-aligned (or the real width)
    bits_blk = (rows, None) if (feats is not None and feats % 32) \
        else (rows, feats)
    for binarize in (False, True):
        got_b = bspmm_kernel.bspmm_bits(adj, xp, f, binarize=binarize,
                                        block_shape=bits_blk)
        base_b = bspmm_kernel.bspmm_bits(adj, xp, f, binarize=binarize)
        np.testing.assert_array_equal(np.asarray(got_b), np.asarray(base_b))
        want_b = np.asarray(ref.bspmm_bits_ref(adj, xp, f,
                                               binarize=binarize))
        # the counts kernel carries the word-padded width; the oracle the
        # real one
        np.testing.assert_array_equal(
            np.asarray(got_b)[: adj.n_rows, : want_b.shape[1]],
            want_b[: adj.n_rows])


# (seed, n, f, rows, feats): tile counts 1..17, narrow/wide/ragged feature
# widths, single- and multi-row blocks, full-width and blocked features
GRID_SWEEP = [
    (0, 4, 32, 4, None),          # one tile row, minimal
    (1, 16, 32, 8, 32),           # rows > tile, exact feature block
    (2, 30, 64, 8, 32),           # ragged node count (pads to tile)
    (3, 33, 96, 12, 64),          # feats not dividing f (fp zero-pads)
    (4, 40, 24, 4, 24),           # f narrower than one word, real-width blk
    (5, 64, 128, 16, 64),         # many tile rows, wide block
    (6, 17, 40, 8, None),         # full-width multi-row
    (7, 68, 32, 32, 32),          # block rows > some row groups
]


@pytest.mark.parametrize("seed,n,f,rows,feats", GRID_SWEEP)
def test_grid_matches_single_block_and_reference(seed, n, f, rows, feats):
    _case(seed, n, f, rows, feats)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=hst.integers(0, 2**16),
           n=hst.integers(2, 70),
           f=hst.sampled_from([24, 32, 40, 64, 96, 128]),
           rows=hst.sampled_from([4, 8, 12, 16, 32]),
           feats=hst.sampled_from([None, 24, 32, 64, 128]))
    def test_grid_property_sweep(seed, n, f, rows, feats):
        _case(seed, n, f, rows, feats)


# ------------------------------------------------------------- fused path ---

@pytest.fixture(scope="module")
def data():
    return make_dataset("cora", seed=0, scale=0.1)


def _store(data, **kw):
    st = GraphStore(max_batch=BATCH, **kw)
    st.register_graph("g", data)
    key = jax.random.PRNGKey(0)
    f, c = data.x.shape[1], data.n_classes
    st.register_model("gcn", "gcn", gnn.init_gcn(key, f, HIDDEN, c))
    st.register_model("sage", "sage", gnn.init_sage(key, f, HIDDEN, c))
    st.register_model("saint", "saint", gnn.init_saint(key, f, HIDDEN, c))
    return st


@pytest.fixture(autouse=True)
def _kernels_on():
    kernel_ops.force_kernels(True)
    yield
    kernel_ops.force_kernels(False)


N_LAYERS = {"gcn": 2, "sage": 2, "saint": 3}


@pytest.mark.parametrize("model", ["gcn", "sage", "saint"])
def test_fused_serve_bitwise_and_one_launch_per_layer(data, model):
    """The fused session serves bitwise identically to the unfused one AND
    traces exactly ONE fused kernel launch per layer (the launches-per-layer
    regression: the unfused path costs several dispatches per layer)."""
    seeds = np.random.default_rng(0).integers(0, data.n_nodes, size=BATCH)
    want = _store(data, use_pallas=True).session("g", model) \
        .serve_subgraph(seeds)
    sess = _store(data, use_pallas=True, fused=True).session("g", model)
    assert sess.plan.fused and "|fused" in sess.plan.name()
    fused_layer.reset_counters()
    got = sess.serve_subgraph(seeds)
    assert fused_layer.KERNEL_CALLS["fused"] == N_LAYERS[model]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # steady state: a second serve of the same bucket traces nothing new
    sess.serve_subgraph(seeds)
    assert fused_layer.KERNEL_CALLS["fused"] == N_LAYERS[model]


@pytest.mark.parametrize("model", ["gcn", "sage"])
def test_fused_engine_replay_oracle(data, model):
    """Engine-served fused answers replay bitwise against the unfused
    session on the engine's ACTUAL batch compositions (the batch_log
    oracle — jitted fused vs jitted unfused)."""
    st = _store(data, use_pallas=True, fused=True)
    engine = GNNServeEngine(st, max_batch=BATCH, mode="subgraph")
    nodes = np.random.default_rng(1).integers(0, data.n_nodes,
                                              size=3 * BATCH)
    queries = engine.submit_many("g", model, nodes)
    engine.run_until_drained()
    assert all(q.done for q in queries)
    unfused = _store(data, use_pallas=True).session("g", model)
    assert engine.batch_log
    for batch in engine.batch_log:
        want = unfused.serve_subgraph(np.asarray([q.node for q in batch]))
        np.testing.assert_array_equal(
            np.stack([q.logits for q in batch]), want)


# ------------------------------------------------------ multi-bucket tick ---

def test_multi_bucket_tick_one_dispatch(data):
    """A multi-bucket pipeline tick co-launches every bucket it extracted
    as ONE device dispatch per serve core: the dispatch counter moves by 1
    where the serial engine moves by K, the co-launched batches' launch
    spans share one wall window tagged with the coalesced bucket count,
    and the answers replay bitwise against a serial session."""
    nodes = np.random.default_rng(2).integers(0, data.n_nodes,
                                              size=6 * BATCH)
    serial = GNNServeEngine(_store(data), max_batch=BATCH, mode="subgraph",
                            pipeline_depth=2)
    qs = serial.submit_many("g", "gcn", nodes)
    serial.run_until_drained()

    st = _store(data)
    engine = GNNServeEngine(st, max_batch=BATCH, mode="subgraph",
                            pipeline_depth=2, multi_bucket=True,
                            tracer=SpanTracer(sample_every=1))
    qm = engine.submit_many("g", "gcn", nodes)
    engine.run_until_drained()
    assert all(q.done for q in qm)
    n_batches = len(engine.batch_log)
    assert n_batches > 1
    # fewer dispatches than batches — the co-launch actually coalesced
    assert engine.dispatch_count < n_batches
    assert engine.dispatch_count < serial.dispatch_count
    assert serial.dispatch_count == len(serial.batch_log)
    # span evidence: coalesced launch spans share one dispatch window
    launches = [s for tr in engine.tracer.batch_traces() for s in tr.spans
                if s.name == "launch"]
    co = [s for s in launches if s.attrs.get("coalesced", 1) > 1]
    assert co, "no coalesced launch spans recorded"
    windows = {}
    for s in co:
        windows.setdefault((s.t0, s.t1), []).append(s)
    for (t0, t1), spans in windows.items():
        assert len(spans) == spans[0].attrs["coalesced"]
    # bit-exactness: replay the actual compositions against a fresh session
    oracle = _store(data).session("g", "gcn")
    for batch in engine.batch_log:
        want = oracle.serve_subgraph(np.asarray([q.node for q in batch]))
        np.testing.assert_array_equal(
            np.stack([q.logits for q in batch]), want)
    assert engine.snapshot()["multi_bucket"] is True


def test_launch_many_bitwise_vs_serial(data):
    """Core-level guarantee under every family: ``launch_many`` of K staged
    buckets returns bitwise what K serial ``launch`` calls return (the
    co-launched program is the serial bodies unrolled), and counts as ONE
    dispatch and at most one extra trace."""
    for model in ["gcn", "sage", "saint"]:
        sess = _store(data).session("g", model)
        rng = np.random.default_rng(3)
        b1 = sess.prepare_batch(rng.integers(0, data.n_nodes, size=BATCH))
        b2 = sess.prepare_batch(rng.integers(0, data.n_nodes, size=4))
        core = sess.core
        s1 = core.launch(b1.groups[0].staged, b1.bn)
        s2 = core.launch(b2.groups[0].staged, b2.bn)
        d0 = core.n_dispatches
        m1, m2 = core.launch_many([(b1.groups[0].staged, b1.bn),
                                   (b2.groups[0].staged, b2.bn)])
        assert core.n_dispatches == d0 + 1
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(m1))
        np.testing.assert_array_equal(np.asarray(s2), np.asarray(m2))


# ------------------------------------------------------------ tuner cache ---

def test_tuner_cache_roundtrip_and_lookup(tmp_path):
    from repro.serve.tuner_cache import SCHEMA, TunerCache, entry_key
    path = tmp_path / "cache.json"
    cache = TunerCache(path)
    stats = dict(n_nodes=100, n_edges=400, n_feat=32)
    cache.record(stats, (8, 64), 2e-3, fused=False, backend="cpu")
    cache.record(stats, None, 1e-3, fused=False, backend="cpu")
    cache.record(stats, (4, 32), 3e-3, fused=True, backend="cpu")
    # fastest wins per (stats, backend, fused); default block stays None
    reloaded = TunerCache(path)
    assert reloaded.lookup(stats, fused=False, backend="cpu") is None
    assert reloaded.lookup(stats, fused=True, backend="cpu") == (4, 32)
    # different stats or backend: no entry
    assert reloaded.lookup(dict(stats, n_nodes=101), backend="cpu") is None
    assert reloaded.lookup(stats, fused=False, backend="tpu") is None
    assert entry_key(stats, (8, 64), "cpu", False) in reloaded.entries
    # unknown schema is ignored, not migrated
    path.write_text('{"schema": 999, "entries": {"x": {}}}')
    assert TunerCache(path).entries == {}
    # corrupt file is ignored too
    path.write_text("not json")
    assert TunerCache(path).entries == {}


def test_graphstore_seeds_block_from_tuner_cache(tmp_path, data):
    """A store given a tuner cache seeds SessionPlan.bspmm_block from the
    fastest recorded block for the graph's stats fingerprint; an explicit
    store-level block override wins over the cache."""
    from repro.serve.tuner_cache import TunerCache, graph_stats
    path = tmp_path / "cache.json"
    cache = TunerCache(path)
    cache.record(graph_stats(data), (8, 64), 1e-3, fused=False,
                 backend=jax.default_backend())
    cache.record(graph_stats(data), (4, 32), 9e-3, fused=False,
                 backend=jax.default_backend())
    st = _store(data, use_pallas=True, tuner_cache=str(path))
    assert st.session("g", "gcn").plan.bspmm_block == (8, 64)
    # explicit override beats the cache
    st2 = _store(data, use_pallas=True, tuner_cache=str(path),
                 bspmm_block=(4, 32))
    assert st2.session("g", "gcn").plan.bspmm_block == (4, 32)
    # no cache entry for other stats: kernel-native default
    other = make_dataset("cora", seed=1, scale=0.05)
    st3 = GraphStore(max_batch=BATCH, use_pallas=True,
                     tuner_cache=str(path))
    st3.register_graph("g", other)
    st3.register_model("gcn", "gcn", gnn.init_gcn(
        jax.random.PRNGKey(0), other.x.shape[1], HIDDEN, other.n_classes))
    assert st3.session("g", "gcn").plan.bspmm_block is None


# -------------------------------------------------------- plan persistence --

def test_session_plan_fused_roundtrip(data, tmp_path):
    """SessionPlan.fused survives the artifact JSON roundtrip, shows in the
    plan name, and a store with a different fused flag REBUILDS instead of
    loading a mismatched artifact."""
    from repro.serve.session_core import SessionPlan
    p = SessionPlan("gcn", "bin", fused=True)
    p2 = SessionPlan.from_json(p.to_json())
    assert p2.fused and "|fused" in p2.name()
    assert not SessionPlan.from_json(
        SessionPlan("gcn", "bin").to_json()).fused

    st1 = _store(data, cache_dir=str(tmp_path), use_pallas=True, fused=True)
    assert st1.session("g", "gcn").plan.fused
    # same flag: loads; different flag: rebuilds with the requested flag
    st2 = _store(data, cache_dir=str(tmp_path), use_pallas=True, fused=True)
    assert st2.session("g", "gcn").plan.fused
    st3 = _store(data, cache_dir=str(tmp_path), use_pallas=True)
    assert not st3.session("g", "gcn").plan.fused


# ------------------------------------------------------------- env helper ---

def test_xla_tuned_env_helper():
    from repro import env
    # user flags win: untouched env
    e = {"XLA_FLAGS": "--user=1"}
    assert env.xla_tuned(e) is False
    assert e["XLA_FLAGS"] == "--user=1"
    # backend already initialized in this test process (jax imported above):
    # refuses with a warning rather than silently not taking effect
    jax.devices()
    with pytest.warns(RuntimeWarning):
        assert env.xla_tuned({}) is False
    # the flag set itself is the latency-hiding/async-collective trio
    joined = " ".join(env.XLA_TUNED_FLAGS)
    assert "latency_hiding_scheduler" in joined
    assert "async_collectives" in joined
