"""Replica-tier tests: fault injection, health protocol, bounded retry,
graceful drain, front-door failover, and live reshard.

The acceptance bars (chaos): kill a replica mid-wave at P=2 replicas x 2
shards -> every accepted query completes on a survivor, the survivors'
``batch_log`` replays bit-exact against the single-host session, and the
survivors take zero steady-state recompiles. Live reshard P=2 -> P=4 under
load -> zero dropped queries and bit-exact answers on both sides of the
swap.
"""
import time

import numpy as np
import jax
import pytest

from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import (AdmissionController, FaultInjector, FrontDoor,
                         GNNServeEngine, GraphStore, HealthMonitor,
                         HealthPolicy, InjectedFault, Resharder,
                         ShardedServeEngine, SpanTracer, TenantPolicy,
                         build_replica)
from repro.serve.sharded.planner import validate_reshard
from repro.serve.sharded.routing import RoutingTable

jax.config.update("jax_platform_name", "cpu")

HIDDEN = 16
BATCH = 8


@pytest.fixture(scope="module")
def data():
    return make_dataset("cora", seed=0, scale=0.05)


@pytest.fixture(scope="module")
def gcn_params(data):
    key = jax.random.PRNGKey(0)
    return gnn.init_gcn(key, data.x.shape[1], HIDDEN, data.n_classes)


@pytest.fixture(scope="module")
def models(gcn_params):
    return {"gcn": ("gcn", gcn_params)}


@pytest.fixture(scope="module")
def single_session(data, gcn_params):
    st = GraphStore(max_batch=BATCH)
    st.register_graph("g", data)
    st.register_model("gcn", "gcn", gcn_params)
    return st.session("g", "gcn")


def _replay_bit_exact(engine, single):
    """PR-4 replay oracle: every logged batch's composition re-served on
    the single-host session must reproduce the answers bit-for-bit."""
    assert engine.batch_log, "nothing served to replay"
    for batch in engine.batch_log:
        seeds = np.asarray([q.node for q in batch], np.int64)
        want = np.asarray(single.serve_subgraph(seeds))
        for i, q in enumerate(batch):
            np.testing.assert_array_equal(np.asarray(q.logits), want[i])


# --------------------------------------------------------- fault seam ------

def test_fault_injector_counted_and_cleared():
    f = FaultInjector(seed=0)
    f.fail_next("launch", 2)
    for _ in range(2):
        with pytest.raises(InjectedFault):
            f.check("launch")
    f.check("launch")                       # disarmed after n fires
    f.fail("extract", rate=1.0)
    with pytest.raises(InjectedFault):
        f.check("extract")
    f.clear("extract")
    f.check("extract")
    assert f.snapshot()["fired"] == {"launch": 2, "extract": 1}


def test_fault_injector_scoped_rules():
    f = FaultInjector(seed=0)
    f.fail_next("extract", 1, scope="r1")
    f.check("extract", scope="r0")          # other replica: untouched
    with pytest.raises(InjectedFault):
        f.check("extract", scope="r1")


def test_fault_injector_seeded_rates_reproducible():
    outcomes = []
    for _ in range(2):
        f = FaultInjector(seed=7)
        f.fail("complete", rate=0.5)
        row = []
        for _ in range(32):
            try:
                f.check("complete")
                row.append(0)
            except InjectedFault:
                row.append(1)
        outcomes.append(row)
    assert outcomes[0] == outcomes[1]
    assert 0 < sum(outcomes[0]) < 32


def test_fault_injector_kill_and_heartbeat_drop():
    f = FaultInjector(seed=0)
    f.kill("r1")
    assert f.is_killed("r1") and not f.is_killed("r0")
    f.revive("r1")
    assert not f.is_killed("r1")
    f.drop_heartbeats("r0", 2)
    assert f.take_heartbeat_drop("r0")
    assert f.take_heartbeat_drop("r0")
    assert not f.take_heartbeat_drop("r0")


def test_corrupt_artifact_truncates(tmp_path):
    p = tmp_path / "blob.bin"
    p.write_bytes(b"x" * 100)
    FaultInjector().corrupt_artifact(p)
    assert p.read_bytes() == b"x" * 50


# ----------------------------------------------------- health protocol ------

def test_health_deadline_and_recovery_hysteresis():
    hm = HealthMonitor(HealthPolicy(deadline_s=1.0, recovery_beats=2))
    hm.register("r0", now=0.0)
    assert hm.check(now=0.5) == []
    assert hm.check(now=2.0) == ["r0"]      # missed the deadline
    assert not hm.healthy("r0")
    assert hm.check(now=3.0) == []          # already down: not "newly"
    assert hm.beat("r0", ok=True, now=3.1) is None   # 1 good beat: not yet
    assert hm.beat("r0", ok=True, now=3.2) == "up"   # hysteresis satisfied
    assert hm.healthy("r0")


def test_health_fault_threshold():
    hm = HealthMonitor(HealthPolicy(fault_threshold=3))
    hm.register("r0", now=0.0)
    assert not hm.fault("r0", "boom", now=0.1)
    assert not hm.fault("r0", "boom", now=0.2)
    assert hm.fault("r0", "boom", now=0.3)   # threshold crossed
    assert not hm.healthy("r0")
    hm.register("r1", now=0.0)
    hm.fault("r1", "boom", now=0.1)
    hm.served("r1")                          # success resets the run
    assert not hm.fault("r1", "boom", now=0.2)
    assert not hm.fault("r1", "boom", now=0.3)


# ------------------------------------------- bounded retry / poison query ---

def _engine(data, gcn_params, **kw):
    st = GraphStore(max_batch=BATCH)
    st.register_graph("g", data)
    st.register_model("gcn", "gcn", gcn_params)
    return GNNServeEngine(st, mode="subgraph", **kw)


def test_transient_fault_retries_to_success(data, gcn_params,
                                            single_session):
    faults = FaultInjector(seed=0)
    eng = _engine(data, gcn_params, faults=faults, retry_backoff_s=0.001)
    eng.warmup("g", "gcn")
    faults.fail_next("extract", 1)
    qs = eng.submit_many("g", "gcn", np.arange(6))
    with pytest.raises(InjectedFault):
        eng.tick()                           # the injected failure surfaces
    eng.run_until_drained()
    assert all(q.done for q in qs)
    assert eng.metrics.requeues == 1 and eng.metrics.retry_shed == 0
    assert all(q.attempts == 1 for q in qs)
    _replay_bit_exact(eng, single_session)


def test_poison_query_typed_shed_after_max_retries(data, gcn_params):
    faults = FaultInjector(seed=0)
    eng = _engine(data, gcn_params, faults=faults, max_retries=3,
                  retry_backoff_s=0.001, retry_backoff_max_s=0.01)
    eng.warmup("g", "gcn")
    faults.fail("launch", rate=1.0)          # permanent: a poison batch
    qs = eng.submit_many("g", "gcn", np.arange(4))
    report = eng.drain(timeout_s=10.0)       # drain absorbs the failures
    assert all(q.failed for q in qs)
    for q in qs:
        assert q.failure.reason == "max_retries"
        assert q.failure.stage == "launch"
        assert q.failure.attempts == 4       # max_retries exceeded by one
        assert "InjectedFault" in q.failure.error
        assert q.settled and not q.done
    assert eng.metrics.retry_shed == 4
    assert report.failed == 4 and report.answered == 0
    # the engine is NOT wedged: clear the fault, serve again
    faults.clear()
    eng.resume_intake()
    q2 = eng.submit("g", "gcn", 0)
    eng.run_until_drained()
    assert q2.done
    ev = [w for w in eng.tracer.warning_events() if w.name == "retry_exhausted"]
    assert ev and ev[0].attrs["stage"] == "launch"


def test_backoff_does_not_starve_other_queues(data, gcn_params):
    """A poison tenant's backoff window must leave other tenants' queues
    servable in the meantime."""
    faults = FaultInjector(seed=0)
    adm = AdmissionController(policies={
        "bad": TenantPolicy(), "good": TenantPolicy()})
    eng = _engine(data, gcn_params, faults=faults, admission=adm,
                  max_retries=5, retry_backoff_s=0.2,
                  retry_backoff_max_s=0.5)
    eng.warmup("g", "gcn")
    faults.fail("extract", rate=1.0)
    bad = eng.submit("g", "gcn", 1, tenant="bad")
    with pytest.raises(InjectedFault):
        eng.tick()                           # bad's queue enters backoff
    faults.clear()
    good = eng.submit_many("g", "gcn", np.arange(4), tenant="good")
    eng.tick()                               # served DESPITE bad's backoff
    assert all(q.done for q in good)
    eng.run_until_drained()                  # backoff expires; bad recovers
    assert bad.done


# ------------------------------------------------------ graceful drain ------

def test_drain_answers_backlog(data, gcn_params):
    eng = _engine(data, gcn_params)
    eng.warmup("g", "gcn")
    qs = eng.submit_many("g", "gcn", np.arange(10))
    report = eng.drain(timeout_s=30.0)
    assert all(q.done for q in qs)
    assert report.answered == 10 and report.shed == 0
    assert not report.timed_out
    # intake is stopped: a post-drain submit is typed-shed
    late = eng.submit("g", "gcn", 0)
    assert late.rejected and "draining" in late.admission.reason
    eng.resume_intake()
    q = eng.submit("g", "gcn", 0)
    eng.run_until_drained()
    assert q.done


def test_drain_timeout_typed_sheds_queued(data, gcn_params):
    faults = FaultInjector(seed=0)
    eng = _engine(data, gcn_params, faults=faults, max_retries=1000,
                  retry_backoff_s=0.05, retry_backoff_max_s=0.2)
    eng.warmup("g", "gcn")
    faults.fail("extract", rate=1.0)         # nothing can be served
    qs = eng.submit_many("g", "gcn", np.arange(6))
    t0 = time.perf_counter()
    report = eng.drain(timeout_s=0.3)
    assert time.perf_counter() - t0 < 5.0    # terminates promptly
    assert report.timed_out and report.shed == 6 and report.answered == 0
    assert eng.metrics.drain_shed == 6
    for q in qs:
        assert q.settled and not q.done
        assert "drain timeout" in q.admission.reason
    assert eng.pending == 0
    ev = [w for w in eng.tracer.warning_events() if w.name == "drain"]
    assert ev and ev[-1].attrs["timed_out"]


# ----------------------------------------------------------- front door -----

def _tier(data, models, n_replicas=2, n_shards=2, spread="query",
          deadline_s=0.05, **engine_kw):
    faults = FaultInjector(seed=0)
    tracer = SpanTracer()
    reps = [build_replica(f"r{i}", data, models, n_shards=n_shards,
                          faults=faults, tracer=tracer, max_batch=BATCH,
                          mode="subgraph", retry_backoff_s=0.001,
                          **engine_kw)
            for i in range(n_replicas)]
    fd = FrontDoor(reps, faults=faults, tracer=tracer, spread=spread,
                   policy=HealthPolicy(deadline_s=deadline_s))
    for r in reps:
        r.engine.warmup("g", "gcn")
    return fd, reps, faults


def test_front_door_owns_admission(data, models):
    fd, reps, _ = _tier(data, models, n_shards=0)
    fd.admission.set_policy("t0", TenantPolicy(max_queue_depth=2))
    qs = [fd.submit("g", "gcn", i, tenant="t0") for i in range(5)]
    rejected = [q for q in qs if q.rejected]
    assert rejected, "front-door backlog cap never fired"
    assert all(q.inner is None for q in rejected)   # never reached a replica
    fd.run_until_drained()
    assert all(q.done for q in qs if not q.rejected)
    snap = fd.snapshot()["metrics"]["tenants"]["t0"]
    assert snap["shed"] == len(rejected)


def test_front_door_version_pinning(data, models):
    fd, reps, _ = _tier(data, models, n_shards=0)
    orig = data.x.copy()        # GraphData is shared module state: restore
    try:
        q0 = fd.submit("g", "gcn", 0)
        v0 = q0.pinned_version
        fd.run_until_drained()              # q0 answered pre-update
        # negated features: sign-binarized models see every bit flip
        fd.update_features("g", -data.x)
        q1 = fd.submit("g", "gcn", 0)
        assert q1.pinned_version == v0 + 1
        assert all(r.graph_version("g") == q1.pinned_version
                   for r in reps)
        fd.run_until_drained()
        assert q0.done and q1.done
        # q1 served post-update: answers must differ from the stale pass
        assert not np.array_equal(np.asarray(q0.logits),
                                  np.asarray(q1.logits))
    finally:
        fd.update_features("g", orig)


def test_front_door_tenant_spread_is_stable(data, models):
    fd, reps, _ = _tier(data, models, n_shards=0, spread="tenant")
    for tenant in ("alice", "bob", "carol"):
        qs = [fd.submit("g", "gcn", i, tenant=tenant) for i in range(4)]
        assert len({q.replica for q in qs}) == 1   # one replica per tenant
    fd.run_until_drained()


def test_chaos_kill_replica_mid_wave(data, models, single_session):
    """THE acceptance chaos test: P=2 replicas x 2 shards, kill r1 while a
    wave is in flight -> every accepted query completes on the survivor,
    the replayed batch_log is bit-exact, and the survivor takes zero
    steady-state recompiles."""
    fd, reps, faults = _tier(data, models, n_replicas=2, n_shards=2,
                             spread="query", deadline_s=0.05)
    survivor = reps[0].engine
    rng = np.random.default_rng(1)
    qs = fd.submit_many("g", "gcn", rng.integers(0, data.n_nodes, size=48))
    accepted = [q for q in qs if not q.rejected]
    assert {q.replica for q in accepted} == {"r0", "r1"}
    for _ in range(3):
        fd.tick()                            # both replicas mid-wave
    compiles_before = survivor.compile_count
    faults.kill("r1")
    time.sleep(0.06)                         # let the deadline lapse
    fd.run_until_drained(max_ticks=20_000)
    assert fd.pending == 0
    assert all(q.done for q in accepted), "accepted queries lost in chaos"
    assert fd.failovers == 1 and fd.failover_queries > 0
    moved = [q for q in accepted if q.failovers > 0]
    assert moved and all(q.replica == "r0" for q in moved)
    # bit-exact replay of everything both replicas actually served
    _replay_bit_exact(reps[0].engine, single_session)
    _replay_bit_exact(reps[1].engine, single_session)
    # zero steady-state recompiles on the survivor through the failover
    assert survivor.compile_count == compiles_before
    kinds = [w.name for w in fd.tracer.warning_events()]
    assert "replica_unhealthy" in kinds and "failover" in kinds


def test_replica_recovery_readmission(data, models):
    fd, reps, faults = _tier(data, models, n_replicas=2, n_shards=0,
                             spread="query", deadline_s=0.02)
    qs = fd.submit_many("g", "gcn", np.arange(8))
    faults.kill("r1")
    time.sleep(0.03)
    fd.run_until_drained(max_ticks=10_000)
    assert all(q.done for q in qs if not q.rejected)
    assert not fd.health.healthy("r1")
    faults.revive("r1")
    for _ in range(4):                       # recovery_beats good beats
        fd.tick()
    assert fd.health.healthy("r1")
    assert fd.readmissions == 1
    qs2 = fd.submit_many("g", "gcn", np.arange(16))
    fd.run_until_drained(max_ticks=10_000)
    assert all(q.done for q in qs2 if not q.rejected)
    assert {q.replica for q in qs2 if q.done} == {"r0", "r1"}
    assert "replica_recovered" in [w.name for w in fd.tracer.warning_events()]


# ---------------------------------------------------------- live reshard ----

def test_validate_reshard_rejects_bad_covers():
    ok_old = RoutingTable(np.array([0, 5, 10], np.int64))
    ok_new = RoutingTable(np.array([0, 2, 5, 8, 10], np.int64))
    validate_reshard(ok_old, ok_new, 10)
    with pytest.raises(ValueError, match="covers"):
        validate_reshard(ok_old, RoutingTable(np.array([0, 5, 9],
                                                       np.int64)), 10)
    with pytest.raises(ValueError, match="monotone"):
        validate_reshard(ok_old, RoutingTable(np.array([0, 7, 5, 10],
                                                       np.int64)), 10)


def test_live_reshard_under_load(data, models, single_session, tmp_path):
    """Reshard P=2 -> P=4 while queries are in flight: zero drops, both
    engines' batch logs bit-exact, and the swapped-in engine matches a
    freshly built P=4 stack bit-for-bit."""
    fd, reps, _ = _tier(data, models, n_replicas=1, n_shards=2,
                        spread="query", deadline_s=10.0)
    handle = reps[0]
    old_engine = handle.engine
    rng = np.random.default_rng(2)
    # steady window first: the reshard blip baseline
    warm = fd.submit_many("g", "gcn",
                          rng.integers(0, data.n_nodes, size=24))
    fd.run_until_drained(max_ticks=20_000)
    assert all(q.done for q in warm if not q.rejected)
    steady_p99 = float(np.percentile(
        [q.latency_s for q in warm if q.done], 99))
    pre = fd.submit_many("g", "gcn", rng.integers(0, data.n_nodes, size=24))
    for _ in range(2):
        fd.tick()                            # old engine mid-wave
    rs = Resharder(handle, "g", "gcn", 4, artifact_dir=tmp_path,
                   drain_timeout_s=30.0, tracer=fd.tracer)
    rs.prepare(block=False)                  # P' builds in the background
    while not rs.ready:
        fd.tick()                            # old engine keeps serving
    report = rs.swap()                       # old backlog drains on P=2
    assert report.from_shards == 2 and report.to_shards == 4
    assert report.drain.shed == 0            # zero dropped queries
    assert handle.engine is not old_engine
    assert handle.engine.n_shards == 4
    post = fd.submit_many("g", "gcn",
                          rng.integers(0, data.n_nodes, size=24))
    fd.run_until_drained(max_ticks=20_000)
    assert all(q.done for q in pre + post if not q.rejected)
    assert fd.pending == 0
    # p99 of the queries in flight across the swap stays inside the blip
    # bound: < max(5x steady p99, 1s noise floor at smoke scale)
    blip_p99 = float(np.percentile(
        [q.latency_s for q in pre + post if q.done], 99))
    assert blip_p99 < max(5.0 * steady_p99, 1.0)
    # bit-exactness on BOTH sides of the swap, and vs a fresh P=4 build
    _replay_bit_exact(old_engine, single_session)
    _replay_bit_exact(handle.engine, single_session)
    fresh = GraphStore(max_batch=BATCH)
    fresh.register_graph("g", data)
    fresh.register_model("gcn", "gcn", models["gcn"][1])
    fresh_p4 = fresh.sharded_session("g", "gcn", 4)
    for batch in handle.engine.batch_log:
        seeds = np.asarray([q.node for q in batch], np.int64)
        want = np.asarray(fresh_p4.serve_subgraph(seeds))
        for i, q in enumerate(batch):
            np.testing.assert_array_equal(np.asarray(q.logits), want[i])
    # the reshard artifacts round-tripped through the consistency gate
    assert (tmp_path / "g__gcn__P2" / "routing.json").exists()
    phases = [w.attrs.get("phase") for w in fd.tracer.warning_events()
              if w.name == "reshard"]
    assert phases == ["prepared", "swap_begin", "swap_end"]


def test_front_door_reshard_convenience(data, models):
    fd, reps, _ = _tier(data, models, n_replicas=1, n_shards=2,
                        spread="query", deadline_s=10.0)
    qs = fd.submit_many("g", "gcn", np.arange(12))
    report = fd.reshard("r0", "g", "gcn", 4)
    assert report.to_shards == 4 and report.drain.shed == 0
    fd.run_until_drained(max_ticks=10_000)
    assert all(q.done for q in qs if not q.rejected)
