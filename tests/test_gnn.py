"""GNN end-to-end behaviour: training converges, binary paths keep accuracy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frdc
from repro.graphs.datasets import make_dataset
from repro.graphs import partition, sampling
from repro.models import gnn

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny_cora():
    return make_dataset("cora", seed=0, scale=0.15)


@pytest.fixture(scope="module")
def trained_gcn_bigcn(tiny_cora):
    """STE-train the Bi-GCN (logical binarization) model — the paper's
    baseline recipe; BitGNN then executes the SAME model with packed bits."""
    d = tiny_cora
    adj = frdc.gcn_normalized(d.edges[0], d.edges[1], d.n_nodes)
    adj_dense = frdc.to_dense(adj)
    params = gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1], 32, d.n_classes)
    params, loss = gnn.train_node_classifier(
        gnn.gcn_forward_bigcn, params, (jnp.asarray(d.x), adj_dense),
        jnp.asarray(d.y), jnp.asarray(d.train_mask), epochs=300, lr=3e-2)
    return d, adj, adj_dense, params


def test_fp_gcn_learns(tiny_cora):
    d = tiny_cora
    adj = frdc.gcn_normalized(d.edges[0], d.edges[1], d.n_nodes)
    adj_dense = frdc.to_dense(adj)
    params = gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1], 32, d.n_classes)
    params, _ = gnn.train_node_classifier(
        gnn.gcn_forward_fp, params, (jnp.asarray(d.x), adj_dense),
        jnp.asarray(d.y), jnp.asarray(d.train_mask), epochs=120)
    logits = gnn.gcn_forward_fp(params, jnp.asarray(d.x), adj_dense)
    acc = gnn.accuracy(logits, jnp.asarray(d.y), jnp.asarray(d.test_mask))
    assert acc > 0.45, f"fp32 GCN failed to learn (acc={acc})"


def test_bitgnn_full_scheme_matches_bigcn_baseline(trained_gcn_bigcn):
    """Ours (full) must match the STE-trained Bi-GCN forward it executes."""
    d, adj, adj_dense, params = trained_gcn_bigcn
    x = jnp.asarray(d.x)
    ref_logits = gnn.gcn_forward_bigcn(params, x, adj_dense)
    y, m = jnp.asarray(d.y), jnp.asarray(d.test_mask)
    ref_acc = gnn.accuracy(ref_logits, y, m)
    assert ref_acc > 0.4, f"Bi-GCN STE training failed (acc={ref_acc})"
    q = gnn.quantize_gcn(params)
    adj_bin = frdc.from_coo(d.edges[0], d.edges[1], d.n_nodes, d.n_nodes)
    got = gnn.gcn_forward_bitgnn(q, x, adj, adj_bin, scheme="full")
    # identical math modulo fp reassociation -> logits match tightly
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits),
                               rtol=2e-2, atol=2e-2)
    # near-tie nodes can flip under the ~2% logit reassociation noise; on
    # this ~200-node test mask a handful of flips is 2-3% accuracy
    assert abs(gnn.accuracy(got, y, m) - ref_acc) < 0.04


def test_bitgnn_bin_scheme_accuracy_parity(tiny_cora):
    """STE-train the 'bin' scheme, then check the packed path's accuracy."""
    d = tiny_cora
    adj = frdc.gcn_normalized(d.edges[0], d.edges[1], d.n_nodes)
    adj_dense = frdc.to_dense(adj)
    adj_bin = frdc.from_coo(d.edges[0], d.edges[1], d.n_nodes, d.n_nodes)
    adj_hat_dense = frdc.to_dense(adj_bin)
    params = gnn.init_gcn(jax.random.PRNGKey(1), d.x.shape[1], 32, d.n_classes)
    params, _ = gnn.train_node_classifier(
        gnn.gcn_forward_ste_bin, params,
        (jnp.asarray(d.x), adj_hat_dense, adj_dense),
        jnp.asarray(d.y), jnp.asarray(d.train_mask), epochs=300, lr=3e-2)
    y, m = jnp.asarray(d.y), jnp.asarray(d.test_mask)
    ste_logits = gnn.gcn_forward_ste_bin(params, jnp.asarray(d.x),
                                         adj_hat_dense, adj_dense)
    ste_acc = gnn.accuracy(ste_logits, y, m)
    q = gnn.quantize_gcn(params)
    bit_logits = gnn.gcn_forward_bitgnn(q, jnp.asarray(d.x), adj, adj_bin,
                                        scheme="bin")
    bit_acc = gnn.accuracy(bit_logits, y, m)
    assert ste_acc > 0.35, f"STE training failed (acc={ste_acc})"
    # paper: binary aggregation loses <~2% vs its own training forward
    assert bit_acc >= ste_acc - 0.05, (ste_acc, bit_acc)


def test_sage_bitgnn_runs_and_learns(tiny_cora):
    d = tiny_cora
    adj_mean = frdc.mean_normalized(d.edges[0], d.edges[1], d.n_nodes)
    adj_mean_dense = frdc.to_dense(adj_mean)
    params = gnn.init_sage(jax.random.PRNGKey(2), d.x.shape[1], 32, d.n_classes)
    params, _ = gnn.train_node_classifier(
        gnn.sage_forward_bigcn, params, (jnp.asarray(d.x), adj_mean_dense),
        jnp.asarray(d.y), jnp.asarray(d.train_mask), epochs=300, lr=3e-2)
    y, m = jnp.asarray(d.y), jnp.asarray(d.test_mask)
    ref_acc = gnn.accuracy(gnn.sage_forward_bigcn(params, jnp.asarray(d.x),
                                                  adj_mean_dense), y, m)
    q = gnn.quantize_sage(params)
    got = gnn.sage_forward_bitgnn(q, jnp.asarray(d.x), adj_mean)
    got_acc = gnn.accuracy(got, y, m)
    assert ref_acc > 0.4
    assert got_acc >= ref_acc - 0.06, (ref_acc, got_acc)


def test_saint_forward_shapes(tiny_cora):
    d = tiny_cora
    adj_sum = frdc.from_coo(d.edges[0], d.edges[1], d.n_nodes, d.n_nodes)
    params = gnn.init_saint(jax.random.PRNGKey(3), d.x.shape[1], 32, d.n_classes)
    q = gnn.quantize_saint(params)
    out = gnn.saint_forward_bitgnn(q, jnp.asarray(d.x), adj_sum)
    assert out.shape == (d.n_nodes, d.n_classes)
    assert not bool(jnp.any(jnp.isnan(out)))


def test_sampling_sage():
    d = make_dataset("cora", seed=1, scale=0.1)
    batch = np.arange(16)
    nodes, edges = sampling.sage_sample(d, batch, fanouts=(5, 5), seed=0)
    assert np.all(np.isin(batch, nodes))
    if edges.size:
        assert edges.max() < nodes.size


def test_saint_sampler():
    d = make_dataset("cora", seed=1, scale=0.1)
    it = sampling.saint_node_sampler(d, budget=64, seed=0)
    nodes, edges = next(it)
    assert nodes.size <= 64
    if edges.size:
        assert edges.max() < nodes.size


def test_partition_rows_covers_graph():
    d = make_dataset("cora", seed=2, scale=0.1)
    shards = partition.partition_rows(d.edges[0], d.edges[1], d.n_nodes, 4,
                                      kind="gcn")
    assert len(shards) == 4
    assert shards[0].row_start == 0
    assert shards[-1].row_end == d.n_nodes or shards[-1].row_end >= d.n_nodes - 3
    # distributed spmm == global spmm
    full = frdc.gcn_normalized(d.edges[0], d.edges[1], d.n_nodes)
    x = np.random.default_rng(0).standard_normal((d.n_nodes, 8)).astype(np.float32)
    from repro.core.bspmm import bspmm
    want = np.asarray(bspmm(full, jnp.asarray(x), "FBF"))
    parts = []
    for s in shards:
        out = np.asarray(bspmm(s.adj, jnp.asarray(x), "FBF"))
        parts.append(out[: s.row_end - s.row_start])
    got = np.concatenate(parts)[: d.n_nodes]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
