"""ModelFamilyAdapter conformance: the contract every family behind the
serving core must honour, exercised against all three adapters (GNN /
transformer / SSM):

  * kind labels — the metrics/trace namespace each adapter claims;
  * bucket invariance — ``pad_operands`` water marks are monotone pow2,
    so staging order (not launch order) keys the jit cache;
  * zero steady-state recompiles — after warmup, varied batch shapes
    never re-trace;
  * prepared-batch pinning — an extracted batch finishes under the
    core/params/state it was staged for, across hot swaps;
  * injected launch failures flow through the engine's requeue/retry
    path and the queries still complete correctly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.graphs.datasets import make_dataset
from repro.models import gnn, transformer
from repro.serve import (FaultInjector, GNNAdapter, GNNServeEngine,
                         GraphStore, InjectedFault, SessionPlan,
                         TokenAdapter, TokenServeEngine, TokenSession,
                         TokenStore)

jax.config.update("jax_platform_name", "cpu")

TOKEN_ARCHS = {"transformer": "stablelm-1.6b", "ssm": "rwkv6-3b"}


def _token_cfg(name):
    return reduced_config(get_config(name)).resolve_for_mesh(tp=1)


def _token_session(name, **kw):
    cfg = _token_cfg(name)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 128)
    kw.setdefault("chunk", 4)
    kw.setdefault("warm_len", 6)
    kw.setdefault("warm_new", 4)
    return TokenSession("s", cfg, params, **kw)


@pytest.fixture(scope="module")
def gnn_store():
    data = make_dataset("cora", seed=0, scale=0.1)
    st = GraphStore(max_batch=4)
    st.register_graph("g", data)
    st.register_model("gcn", "gcn",
                      gnn.init_gcn(jax.random.PRNGKey(0),
                                   data.x.shape[1], 16, data.n_classes))
    return st


# --------------------------------------------------------------- kinds ----
def test_adapter_kind_labels():
    assert GNNAdapter(SessionPlan(family="gcn", scheme="bmm")).kind == "gnn"
    assert TokenAdapter(_token_cfg("stablelm-1.6b")).kind == "transformer"
    assert TokenAdapter(_token_cfg("rwkv6-3b")).kind == "ssm"
    # hybrids decode through the recurrent path -> namespaced as ssm
    assert TokenAdapter(_token_cfg("zamba2-1.2b")).kind == "ssm"
    with pytest.raises(ValueError):
        TokenAdapter(_token_cfg("seamless-m4t-medium"))


# ------------------------------------------------------ bucket shaping ----
@pytest.mark.parametrize("kind", sorted(TOKEN_ARCHS))
def test_token_bucket_water_monotone(kind):
    """Cache-length buckets only grow: a smaller batch after a large one
    reuses the established pow2 water (same jit key), and exceeding the
    session cap raises instead of silently truncating the decode."""
    s = _token_session(TOKEN_ARCHS[kind])
    core, adapter = s.core, s.adapter
    n1, _ = adapter.pad_operands(core, {}, 40)
    assert n1 == 64 and core._n_water == 64          # pow2, floor 64
    n2, _ = adapter.pad_operands(core, {}, 5)
    assert n2 == n1                                   # water holds
    n3, _ = adapter.pad_operands(core, {}, 65)
    assert n3 == 128 and core._n_water == 128         # monotone growth
    with pytest.raises(ValueError):
        adapter.pad_operands(core, {}, s.max_len + 1)


def test_gnn_bucket_water_monotone(gnn_store):
    """Same invariant on the GNN adapter: a small batch staged after a big
    one pads to the big batch's node bucket."""
    sess = gnn_store.session("g", "gcn")
    rng = np.random.default_rng(0)
    big = sess.prepare_batch(rng.integers(0, 100, size=4))
    n_big = big.groups[0].staged.x_pad.shape[0]
    small = sess.prepare_batch(rng.integers(0, 100, size=1))
    assert small.groups[0].staged.x_pad.shape[0] == n_big
    assert n_big == 2 ** int(np.log2(n_big))


# ------------------------------------------- zero steady-state recompiles --
@pytest.mark.parametrize("kind", sorted(TOKEN_ARCHS))
def test_token_zero_steady_state_recompiles(kind):
    """After warmup sets the cache-length water, batches of every size /
    prompt length / decode budget under it hit the one compiled program."""
    s = _token_session(TOKEN_ARCHS[kind], warm_len=10, warm_new=8)
    rng = np.random.default_rng(0)
    assert s.warmup(rng) >= 1
    c0 = s.compile_count
    for n, ln, mn in [(1, 3, 2), (2, 9, 7), (2, 1, 1), (1, 10, 8)]:
        prompts = [rng.integers(0, s.cfg.vocab, ln).astype(np.int32)
                   for _ in range(n)]
        outs = s.run(prompts, [mn] * n)
        assert all(o.size == mn for o in outs)
    assert s.compile_count == c0


def test_gnn_zero_steady_state_recompiles(gnn_store):
    sess = gnn_store.session("g", "gcn")
    rng = np.random.default_rng(1)
    sess.warmup(rng)
    c0 = sess.compile_count
    for n in (1, 4, 2):
        sess.serve_subgraph(rng.integers(0, 100, size=n))
    assert sess.compile_count == c0


# ------------------------------------------------------ prepared pinning --
@pytest.mark.parametrize("kind", sorted(TOKEN_ARCHS))
def test_token_prepared_batch_pins_params(kind):
    """An in-flight prepared batch finishes under the params it was staged
    for: ``update_params`` swaps the session's core, but the prepared
    groups keep the old core (and its packed weights) pinned."""
    s = _token_session(TOKEN_ARCHS[kind])
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, s.cfg.vocab, 5).astype(np.int32),
               rng.integers(0, s.cfg.vocab, 3).astype(np.int32)]
    mns = [4, 6]
    want = s.run(prompts, mns)
    prepared = s.prepare_batch(prompts, mns)    # staged under OLD params
    s.update_params(transformer.init_params(jax.random.PRNGKey(7), s.cfg))
    assert s.invalidations == 1
    got = s.finish_batch(prepared, s.launch_batch(prepared))
    for g, w in zip(got, want):
        assert np.array_equal(g, w)
    fresh = s.run(prompts, mns)                 # NEW params: streams differ
    assert any(not np.array_equal(f, w) for f, w in zip(fresh, want))


def test_gnn_prepared_batch_pins_features(gnn_store):
    """The GNN twin: staged features/calibration are pinned, so a feature
    update between stage and launch does not leak into the batch."""
    sess = gnn_store.session("g", "gcn")
    seeds = np.array([3, 17, 41])
    want = sess.serve_subgraph(seeds)
    prepared = sess.prepare_batch(seeds)
    # flip feature signs — the binarized forward quantizes inputs, so only
    # a sign change is guaranteed to alter the served logits
    x2 = -(gnn_store.graphs["g"].data.x + 1.0)
    gnn_store.update_features("g", x2)
    got = sess.finish_batch(prepared, sess.launch_batch(prepared))
    np.testing.assert_array_equal(got, want)
    after = sess.serve_subgraph(seeds)          # fresh stage sees new x
    assert not np.array_equal(after, want)


# -------------------------------------------------- failure -> requeue ----
def test_token_injected_launch_failure_requeues():
    """A launch-stage fault flows through the engine's requeue/retry path:
    the queries retry, complete, and the streams match a clean session."""
    cfg = _token_cfg("stablelm-1.6b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    store = TokenStore(max_batch=2, max_len=128, chunk=4,
                       warm_len=6, warm_new=4)
    store.register_model("lm", cfg, params)
    fi = FaultInjector(seed=0)
    eng = TokenServeEngine(store, faults=fi, retry_backoff_s=0.0)
    eng.warmup("lm")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 4).astype(np.int32)
               for _ in range(3)]
    fi.fail_next("launch", 1)
    qs = eng.submit_many("lm", prompts, max_new=3)
    # the failing tick requeues the batch at the front of its queue and
    # re-raises (never-lose-queries); the next drain retries and completes
    with pytest.raises(InjectedFault):
        eng.run_until_drained()
    eng.run_until_drained()
    eng.close()
    assert all(q.done for q in qs)
    assert max(q.attempts for q in qs) >= 1   # the faulted batch retried
    clean = TokenSession("ref", cfg, params, max_batch=2, max_len=128,
                         chunk=4)
    for q, p in zip(qs, prompts):
        assert np.array_equal(q.tokens, clean.run([p], [3])[0])


def test_gnn_injected_launch_failure_requeues(gnn_store):
    fi = FaultInjector(seed=0)
    eng = GNNServeEngine(gnn_store, mode="subgraph", faults=fi,
                         retry_backoff_s=0.0)
    want = gnn_store.session("g", "gcn").serve_subgraph(np.array([5, 9]))
    fi.fail_next("launch", 1)
    qs = eng.submit_many("g", "gcn", np.array([5, 9]))
    with pytest.raises(InjectedFault):
        eng.run_until_drained()
    eng.run_until_drained()
    eng.close()
    assert all(q.done for q in qs)
    assert max(q.attempts for q in qs) >= 1   # the faulted batch retried
    np.testing.assert_array_equal(np.stack([q.logits for q in qs]), want)
