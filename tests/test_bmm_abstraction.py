import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import abstraction, bitops, frdc
from repro.core.binarize import BinTensor, dequantize
from repro.core.bmm import (BMM_VARIANTS, bmm, bmm_reference_fp,
                            quantize_act, quantize_weight)

jax.config.update("jax_platform_name", "cpu")


@given(st.integers(1, 40), st.integers(1, 70), st.integers(1, 40),
       st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_bmm_bbf_matches_fp_oracle(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    out = bmm(quantize_act(jnp.asarray(x)), quantize_weight(jnp.asarray(w)), "BBF")
    expected = bmm_reference_fp(jnp.asarray(x), jnp.asarray(w), "BBF")
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(1, 40), st.integers(1, 70), st.integers(1, 40),
       st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_bmm_fbf_matches_fp_oracle(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    out = bmm(jnp.asarray(x), quantize_weight(jnp.asarray(w)), "FBF")
    expected = bmm_reference_fp(jnp.asarray(x), jnp.asarray(w), "FBF")
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-4, atol=1e-4)


def test_bmm_bff_matches():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 33)).astype(np.float32)
    w = rng.standard_normal((33, 9)).astype(np.float32)
    out = bmm(quantize_act(jnp.asarray(x)), jnp.asarray(w), "BFF")
    expected = bmm_reference_fp(jnp.asarray(x), jnp.asarray(w), "BFF")
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("variant", ["FBB", "BBB", "BFB", "FFB"])
def test_binary_output_variants_sign_correct(variant):
    rng = np.random.default_rng(1)
    x = rng.standard_normal((10, 64)).astype(np.float32)
    w = rng.standard_normal((64, 16)).astype(np.float32)
    xin = quantize_act(jnp.asarray(x)) if variant[0] == "B" else jnp.asarray(x)
    win = quantize_weight(jnp.asarray(w)) if variant[1] == "B" else jnp.asarray(w)
    out = bmm(xin, win, variant)
    assert isinstance(out, BinTensor)
    if variant[:2] == "BB":
        # integer-exact oracle: fp matmul of ±scale values hits FMA rounding
        # residue at exact ties (acc==0), where sign() is ill-conditioned.
        expected_full = (np.where(x >= 0, 1, -1) @ np.where(w >= 0, 1, -1)
                         ).astype(np.float32)
    else:
        expected_full = np.asarray(
            bmm_reference_fp(jnp.asarray(x), jnp.asarray(w), variant))
    got_bits = np.asarray(bitops.unpack_bits(out.packed, out.n)) > 0
    np.testing.assert_array_equal(got_bits, expected_full >= 0)


def test_bbb_elides_scales_bitwise_identical():
    """BBB output bits must be identical with or without operand scales."""
    rng = np.random.default_rng(2)
    x = rng.standard_normal((6, 96)).astype(np.float32)
    w = rng.standard_normal((96, 32)).astype(np.float32)
    xa = quantize_act(jnp.asarray(x))
    xa_noscale = BinTensor(xa.packed, jnp.ones_like(xa.scale), xa.n)
    wt = quantize_weight(jnp.asarray(w))
    wt_noscale = BinTensor(wt.packed, jnp.ones_like(wt.scale), wt.n)
    a = bmm(xa, wt, "BBB")
    b = bmm(xa_noscale, wt_noscale, "BBB")
    np.testing.assert_array_equal(np.asarray(a.packed), np.asarray(b.packed))


def test_check_chain_accepts_legal_rejects_illegal():
    abstraction.check_chain("BMM.FBB", "BSpMM.BBB")
    abstraction.check_chain("BMM.BBF", "BSpMM.FBF")
    with pytest.raises(TypeError):
        abstraction.check_chain("BMM.FBF", "BSpMM.BBB")
    with pytest.raises(TypeError):
        abstraction.check_chain("BMM.FBB", "BSpMM.FBF")


def test_registry_complete():
    names = set(abstraction.REGISTRY)
    for v in BMM_VARIANTS:
        assert f"BMM.{v}" in names
    for v in ("FBF", "FBB", "BBF", "BBB"):
        assert f"BSpMM.{v}" in names
    assert "ADD.FFF" in names and "ADD.BBF" in names
    assert "CONCAT.FFF" in names and "CONCAT.BBB" in names


def test_mmspmm_high_level_block():
    rng = np.random.default_rng(3)
    n, f, h = 24, 48, 32
    x = rng.standard_normal((n, f)).astype(np.float32)
    w = rng.standard_normal((f, h)).astype(np.float32)
    adj = frdc.from_dense((rng.random((n, n)) < 0.2).astype(np.float32))
    block = abstraction.MMSpMM("BMM.FBB", "BSpMM.BBB")
    out = block(jnp.asarray(x), quantize_weight(jnp.asarray(w)), adj)
    assert isinstance(out, BinTensor)
    assert out.shape == (n, h)

    block2 = abstraction.MMSpMM("BMM.FBF", "BSpMM.FBF")
    out2 = block2(jnp.asarray(x), quantize_weight(jnp.asarray(w)), adj)
    assert out2.shape == (n, h)


def test_concat_bbb():
    rng = np.random.default_rng(4)
    a = rng.standard_normal((5, 32)).astype(np.float32)
    b = rng.standard_normal((5, 40)).astype(np.float32)
    ta, tb = quantize_act(jnp.asarray(a)), quantize_act(jnp.asarray(b))
    out = abstraction.op("CONCAT.BBB").fn(ta, tb)
    bits = np.asarray(bitops.unpack_bits(out.packed, out.n))
    expected = np.concatenate([a >= 0, b >= 0], axis=-1)
    np.testing.assert_array_equal(bits > 0, expected)
