"""Cost-model + SLO tests: submit-time cost prediction (topology-only,
deterministic), the cost-budget admission lane, cost-weighted fair queueing,
error-budget burn alerts + depth autotune on an injected clock, whale-aware
sharded batch formation, the Prometheus cost/SLO series, and — above all —
the bit-exactness oracle: a cost-aware engine may reorder service but must
answer every query identically to the cost-unaware path."""
import json
import threading

import jax
import numpy as np
import pytest

from repro.graphs import sampling
from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import (AdmissionController, CostEstimator, GNNServeEngine,
                         GraphStore, SLOPolicy, SLOTracker, ShardedServeEngine,
                         SpanTracer, TenantPolicy, prometheus_text,
                         spearman_rho)

jax.config.update("jax_platform_name", "cpu")

HIDDEN = 16
BATCH = 8


@pytest.fixture(scope="module")
def data():
    return make_dataset("cora", seed=0, scale=0.1)


@pytest.fixture(scope="module")
def store(data):
    st = GraphStore(max_batch=BATCH)
    st.register_graph("g", data)
    key = jax.random.PRNGKey(0)
    f, c = data.x.shape[1], data.n_classes
    st.register_model("gcn", "gcn", gnn.init_gcn(key, f, HIDDEN, c))
    return st


def _degrees(csr):
    return np.asarray(csr.indptr[1:]) - np.asarray(csr.indptr[:-1])


# ------------------------------------------------------------ spearman_rho --

def test_spearman_rho_basic():
    assert spearman_rho([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
    assert spearman_rho([1, 2, 3, 4], [4, 3, 2, 1]) == pytest.approx(-1.0)
    # monotone in rank but not linear in value: still exactly 1
    assert spearman_rho([1, 2, 3], [1, 100, 10000]) == pytest.approx(1.0)


def test_spearman_rho_ties_and_degenerate():
    # average ranks for ties: a tied pair must not flip the sign
    assert spearman_rho([1, 2, 2, 4], [1, 2, 3, 4]) > 0.9
    assert np.isnan(spearman_rho([1, 2], [1, 2]))          # < 3 points
    assert np.isnan(spearman_rho([5, 5, 5], [1, 2, 3]))    # constant series
    with pytest.raises(ValueError):
        spearman_rho([1, 2, 3], [1, 2])


# ----------------------------------------------- estimator edge cases (sat) --

def test_full_cache_cost_is_o1(store):
    """A full-cache hit is O(1) no matter how hubby the node is."""
    est = CostEstimator()
    csr = store.graphs["g"].csr
    hub = int(np.argmax(_degrees(csr)))
    e = est.estimate("g", hub, csr, full_cache=True)
    assert e.full_cache
    assert e.units == CostEstimator.FULL_CACHE_UNITS
    assert e.units <= est.estimate("g", hub, csr).units


def test_isolated_node_minimal_cost():
    """An isolated node's closure is itself: the cheapest possible query."""
    edges = np.array([[0, 1, 1, 2], [1, 0, 2, 1]], np.int64)
    csr = sampling.to_csr(edges, 4)                 # node 3 has no edges
    est = CostEstimator()
    iso = est.estimate("tiny", 3, csr)
    assert iso.closure_nodes == 1 and iso.closure_edges == 0
    for n in (0, 1, 2):
        assert est.estimate("tiny", n, csr).units >= iso.units


def test_hub_node_cost_dominates(store):
    """The max-degree hub at full k costs at least any leaf, and more hops
    never cost less."""
    est = CostEstimator()
    csr = store.graphs["g"].csr
    degs = _degrees(csr)
    hub, leaf = int(np.argmax(degs)), int(np.argmin(degs))
    hub_e = est.estimate("g", hub, csr, khop=2)
    assert hub_e.units >= est.estimate("g", leaf, csr, khop=2).units
    assert hub_e.units >= est.estimate("g", hub, csr, khop=1).units


def test_cost_deterministic_across_feature_updates(store, data):
    """Estimates are pure functions of topology: updating features must not
    move a single field of the prediction."""
    est = CostEstimator()
    csr = store.graphs["g"].csr
    nodes = np.random.default_rng(0).integers(0, data.n_nodes, size=16)
    before = [est.estimate("g", int(n), csr) for n in nodes]
    store.update_features("g", data.x + 1.0)
    after = [est.estimate("g", int(n), csr) for n in nodes]
    assert before == after
    store.update_features("g", data.x)              # restore for other tests


def test_estimate_halo_rows_and_attribution():
    edges = np.array([[0, 1], [1, 0]], np.int64)
    csr = sampling.to_csr(edges, 2)
    est = CostEstimator()
    plain = est.estimate("h", 0, csr)
    halo = est.estimate("h", 0, csr, halo_rows=8, row_bytes=64)
    assert halo.halo_bytes == 8 * 64
    assert halo.units > plain.units
    shares = est.attribute([1.0, 3.0], 4.0)
    assert shares == pytest.approx([1.0, 3.0])
    assert est.attribute([0.0, 0.0], 4.0) == pytest.approx([2.0, 2.0])


def test_whale_threshold():
    est = CostEstimator(whale_units=100.0)
    from repro.serve import CostEstimate
    assert est.is_whale(CostEstimate(units=100.0))
    assert not est.is_whale(CostEstimate(units=99.0))
    assert not est.is_whale(None)


def test_calibration_rank_correlation():
    est = CostEstimator()
    for u, s in [(10, 0.01), (20, 0.02), (40, 0.04), (80, 0.08)]:
        est.observe_batch(u, s, n_pad=64)
    assert est.rank_correlation() == pytest.approx(1.0)
    assert est.units_per_second(64) == pytest.approx(1000.0)
    snap = est.snapshot()
    assert snap["batches_observed"] == 4
    assert snap["rank_correlation"] == pytest.approx(1.0)


# ----------------------------------------------------- cost-budget admission --

def test_cost_bucket_throttles_on_cost_not_qps():
    adm = AdmissionController(policies=dict(
        t=TenantPolicy(cost_rate=10.0, cost_burst=20.0)))
    # 20 units of burst: two 10-unit queries pass, the third is cost-limited
    assert adm.admit("t", now=0.0, cost=10.0).accepted
    assert adm.admit("t", now=0.0, cost=10.0).accepted
    d = adm.admit("t", now=0.0, cost=10.0)
    assert not d.accepted and d.cost_limited
    # the budget refills at cost_rate units/s
    assert adm.admit("t", now=1.0, cost=10.0).accepted


def test_cost_charge_clamped_to_capacity():
    """A single whale above the whole bucket capacity must still be
    admissible from a full bucket — the charge clamps, it doesn't starve."""
    adm = AdmissionController(policies=dict(
        t=TenantPolicy(cost_rate=10.0, cost_burst=16.0)))
    assert adm.admit("t", now=0.0, cost=1000.0).accepted
    assert not adm.admit("t", now=0.0, cost=1.0).accepted


def test_depth_scale_feedback():
    adm = AdmissionController(policies=dict(
        t=TenantPolicy(max_queue_depth=64)))
    assert adm.effective_depth("t") == 64
    adm.set_depth_scale("t", 0.25)
    assert adm.effective_depth("t") == 16
    adm.set_depth_scale("t", 1.0)
    assert adm.effective_depth("t") == 64


def test_cost_weighted_fair_queueing_vtime():
    """on_served(cost=...) advances virtual time by cost/weight: after one
    expensive batch the tenant must wait behind a cheap equal-weight peer."""
    from collections import deque

    class _Q:                                       # duck-typed queue head
        def __init__(self, t):
            self.t_submit = t

    adm = AdmissionController(policies=dict(a=TenantPolicy(),
                                            b=TenantPolicy()))
    queues = {("g", "m", "a"): deque([_Q(0.0)]),
              ("g", "m", "b"): deque([_Q(0.0)])}
    adm.push_head(("g", "m", "a"), "a", 0.0)
    adm.push_head(("g", "m", "b"), "b", 0.0)
    first = adm.pick(queues, now=0.0)
    assert first is not None
    tenant = adm.last_pick["tenant"]
    # whoever went first gets charged a WHALE; the other a pittance
    adm.on_served(tenant, 1, cost=1000.0)
    other = "b" if tenant == "a" else "a"
    key = ("g", "m", tenant)
    queues[key].popleft()
    queues[key].append(_Q(0.1))
    adm.push_head(key, tenant, 0.1)
    assert adm.pick(queues, now=0.2) == ("g", "m", other)
    assert adm.last_pick["tenant"] == other


# ------------------------------------------------------- SLO burn tracking --

def test_burn_alert_fires_on_multi_window_breach():
    tracer = SpanTracer()
    slo = SLOTracker(dict(t=SLOPolicy(availability=0.9, window_s=10.0,
                                      short_window_s=1.0, burn_alert=2.0)),
                     tracer=tracer)
    # 50% bad over both windows: burn = 0.5 / 0.1 = 5 >= 2
    for i in range(20):
        slo.observe("t", now=9.0 + 0.05 * i, rejected=(i % 2 == 0))
    fired = slo.check(now=10.0)
    assert len(fired) == 1 and fired[0]["tenant"] == "t"
    assert fired[0]["burn_long"] >= 2.0 and fired[0]["burn_short"] >= 2.0
    events = [w for w in tracer.warning_events() if w.name == "slo_burn"]
    assert len(events) == 1
    # cooldown: an immediate re-check must not re-fire
    assert slo.check(now=10.01) == []
    # ... but after the cooldown (one short window) it may
    slo.observe("t", now=11.0, rejected=True)
    assert len(slo.check(now=11.0)) == 1


def test_burn_alert_needs_both_windows():
    """A long-ago burst that left the short window must NOT page."""
    slo = SLOTracker(dict(t=SLOPolicy(availability=0.9, window_s=10.0,
                                      short_window_s=1.0, burn_alert=2.0)))
    for i in range(10):
        slo.observe("t", now=0.1 * i, rejected=True)
    for i in range(10):
        slo.observe("t", now=8.0 + 0.1 * i, rejected=False)
    assert slo.check(now=9.0) == []


def test_autotune_shrinks_then_relaxes_depth():
    adm = AdmissionController(policies=dict(
        t=TenantPolicy(max_queue_depth=64)))
    slo = SLOTracker(dict(t=SLOPolicy(availability=0.9, window_s=10.0,
                                      short_window_s=1.0, burn_alert=2.0,
                                      min_depth_scale=0.25)))
    for i in range(20):
        slo.observe("t", now=9.0 + 0.05 * i, rejected=True)
    slo.check(now=10.0, admission=adm)
    assert adm.effective_depth("t") == 32            # one x0.5 shrink
    snap = slo.snapshot(now=10.0)["tenants"]["t"]
    assert snap["depth_shrinks"] == 1
    assert snap["depth_scale"] == pytest.approx(0.5)
    # a healthy stretch relaxes the scale back up
    for i in range(40):
        slo.observe("t", now=25.0 + 0.1 * i, rejected=False)
    slo.check(now=30.0, admission=adm)
    assert adm.effective_depth("t") > 32


def test_latency_slower_than_target_burns():
    slo = SLOTracker(dict(t=SLOPolicy(target_p99_ms=10.0,
                                      availability=0.9, window_s=10.0)))
    slo.observe("t", now=1.0, latency_s=0.005)       # fast: good
    slo.observe("t", now=1.0, latency_s=0.500)       # slow: burns
    snap = slo.snapshot(now=1.0)["tenants"]["t"]
    assert snap["good"] == 1 and snap["bad"] == 1


# --------------------------------------------------- engine: bit-exactness --

def _serve_costed(store, data, cost, slo, seed=1):
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph",
                            cost=cost, slo=slo)
    engine.tracer.sample_every = 1
    nodes = np.random.default_rng(seed).integers(0, data.n_nodes,
                                                 size=4 * BATCH)
    queries = engine.submit_many("g", "gcn", nodes)
    engine.run_until_drained()
    return engine, queries


def test_cost_aware_engine_bit_exact(store, data):
    """The closed-loop cost/SLO machinery may reorder service but must not
    perturb a single logit: replay the cost-aware engine's actual batch
    compositions through the raw session and compare bit-for-bit."""
    engine, queries = _serve_costed(
        store, data, CostEstimator(),
        SLOTracker(dict(default=SLOPolicy(availability=0.99))))
    assert all(q.done for q in queries)
    sess = store.session("g", "gcn")
    for batch in engine.batch_log:
        seeds = np.asarray([q.node for q in batch], np.int64)
        prepared = sess.prepare_batch(seeds)
        logits = sess.finish_batch(prepared, sess.launch_batch(prepared))
        got = np.stack([q.logits for q in batch])
        np.testing.assert_array_equal(np.asarray(logits), got)
    engine.close()


def test_engine_cost_attribution_and_snapshot(store, data):
    cost = CostEstimator()
    engine, queries = _serve_costed(
        store, data, cost,
        SLOTracker(dict(default=SLOPolicy(availability=0.99))))
    snap = engine.snapshot()
    assert snap["cost"]["queries_estimated"] == len(queries)
    assert snap["cost"]["batches_observed"] == len(engine.batch_log)
    tm = snap["tenants"]["default"]
    assert tm["cost_units"] > 0
    assert tm["attributed_cost_s"] > 0
    # measured seconds are conserved across the attribution split
    total_measured = sum(t.cost["measured_s"]
                         for t in engine.tracer.batch_traces()
                         if t.cost)
    assert tm["attributed_cost_s"] <= total_measured * 1.01 \
        + 1e-9
    assert "slo" in snap and "default" in snap["slo"]["tenants"]
    engine.close()


def test_engine_without_cost_unchanged(store, data):
    """cost=None/slo=None is the exact pre-cost engine: no cost leaves in
    the snapshot, no per-query estimates."""
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph")
    qs = engine.submit_many("g", "gcn", np.arange(BATCH))
    engine.run_until_drained()
    snap = engine.snapshot()
    assert "cost" not in snap and "slo" not in snap
    assert all(q.cost is None for q in qs)
    engine.close()


# ------------------------------------------------- sharded whale avoidance --

def test_sharded_no_two_whales_cobatched(store, data):
    """With a cost model wired, halo-aware formation never greedily packs
    two predicted whales into one batch."""
    cost = CostEstimator()
    # staleness_s high: the overdue override deliberately TAKES whales (an
    # overdue request is never skipped), which is not what this test pins
    engine = ShardedServeEngine(store, 2, max_batch=BATCH, mode="subgraph",
                                cost=cost, staleness_s=30.0)
    # threshold from the ENGINE's own estimates (halo rows included), so
    # is_whale agrees between formation and the assertions below
    units = np.array([engine._estimate_cost("g", "gcn", int(n)).units
                      for n in range(data.n_nodes)])
    threshold = float(np.percentile(units, 90))
    cost.whale_units = threshold
    rng = np.random.default_rng(2)
    whales = np.nonzero(units >= threshold)[0]
    minnows = np.nonzero(units < threshold)[0]
    nodes = np.concatenate([rng.choice(whales, size=2 * BATCH),
                            rng.choice(minnows, size=2 * BATCH)])
    rng.shuffle(nodes)
    queries = engine.submit_many("g", "gcn", nodes)
    engine.run_until_drained()
    assert all(q.done for q in queries)
    for batch in engine.batch_log:
        n_whales = sum(1 for q in batch if cost.is_whale(q.cost))
        assert n_whales <= 1
    # the stream above forces at least one early batch close
    assert engine.whale_splits > 0
    assert engine.snapshot()["whale_splits"] == engine.whale_splits
    engine.close()


# ------------------------------------------------------- prometheus export --

def test_prometheus_help_type_headers_and_cost_series(store, data):
    cost = CostEstimator()
    engine, _ = _serve_costed(
        store, data, cost,
        SLOTracker(dict(default=SLOPolicy(availability=0.99))))
    text = prometheus_text(engine.snapshot(), engine.tracer)
    engine.close()
    lines = text.splitlines()
    seen_header = set()
    seen_sample = set()
    for ln in lines:
        if ln.startswith("# HELP ") or ln.startswith("# TYPE "):
            name = ln.split()[2]
            # headers precede every sample of their metric, exactly once
            assert name not in seen_sample
            if ln.startswith("# TYPE "):
                assert ln.split()[3] in ("counter", "gauge")
                assert name not in seen_header
                seen_header.add(name)
        elif ln and not ln.startswith("#"):
            seen_sample.add(ln.split("{")[0].split(" ")[0])
    assert seen_sample and seen_header >= seen_sample
    for series in ("serve_tenant_cost_units_total",
                   "serve_tenant_cost_attributed_seconds_total",
                   "serve_cost_rank_correlation",
                   "serve_slo_burn_rate",
                   "serve_slo_budget_remaining"):
        assert any(ln.startswith(series) for ln in lines), series


# --------------------------------------------------- compare_bench gating --

def test_compare_bench_graceful_missing_baseline(tmp_path, capsys):
    import sys
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.compare_bench import main

    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps(dict(schema_version=3)))
    assert main([str(tmp_path / "missing.json"), str(cur)]) == 0
    assert "WARN" in capsys.readouterr().out
    # unreadable (invalid JSON) baseline: same graceful path
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert main([str(bad), str(cur)]) == 0
    # a MISSING CURRENT file is a plain failure, not a traceback
    assert main([str(cur), str(tmp_path / "missing.json")]) == 1


def test_compare_bench_gates_cost_rho_drift(tmp_path):
    import sys
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.compare_bench import main

    base = dict(schema_version=3, slo=dict(cost_spearman_rho=1.0))
    pb = tmp_path / "base.json"
    pb.write_text(json.dumps(base))
    drifted = dict(schema_version=3, slo=dict(cost_spearman_rho=0.4))
    pc = tmp_path / "drift.json"
    pc.write_text(json.dumps(drifted))
    assert main([str(pb), str(pc)]) == 1            # 2.5x worse: hard fail
    ok = dict(schema_version=3, slo=dict(cost_spearman_rho=0.9))
    pk = tmp_path / "ok.json"
    pk.write_text(json.dumps(ok))
    assert main([str(pb), str(pk)]) == 0
    # sub-floor baselines are too noisy to gate on
    noisy = dict(schema_version=3, slo=dict(cost_spearman_rho=0.3))
    pn = tmp_path / "noisy.json"
    pn.write_text(json.dumps(noisy))
    assert main([str(pn), str(pc)]) == 0


# ------------------------------------------------- tracer under concurrency --

def test_tracer_snapshot_safe_under_concurrent_writers():
    """Hammer commit/warning from threads while snapshotting: no torn
    reads, no exceptions, monotone unique trace ids."""
    tracer = SpanTracer(capacity=64, sample_every=1)
    stop = threading.Event()
    errors = []

    class _Query:
        def __init__(self, qid):
            self.qid, self.node, self.t_submit = qid, qid, 0.0
            self.trace_id = None

    def writer():
        import time as _time
        try:
            qid = 0
            while not stop.is_set():
                t = _time.perf_counter()
                tr = tracer.begin(("g", "m", "default"), "default", None,
                                  [_Query(qid)], t)
                tr.span("extract", t, t + 1e-4)
                tr.span("compute", t + 1e-4, t + 2e-4)
                tracer.commit(tr)
                tracer.warning("w", k=1)
                qid += 1
        except Exception as e:          # pragma: no cover
            errors.append(e)

    def reader():
        try:
            while not stop.is_set():
                recs = tracer.records()
                ids = [t.trace_id for t in recs]
                assert len(ids) == len(set(ids))
                tracer.warning_events()
        except Exception as e:          # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer) for _ in range(3)] \
        + [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    import time
    time.sleep(0.3)
    stop.set()
    for t in threads:
        t.join()
    assert not errors
    # counters stayed coherent under the races
    assert tracer.batches_recorded <= tracer.batches_seen
    assert len(tracer.records()) <= tracer.capacity


def test_tracer_consistent_with_pipelined_engine(store, data):
    """Regression: with pipeline_depth > 1 the extract thread commits traces
    while the main thread snapshots — records() must never tear."""
    engine = GNNServeEngine(store, max_batch=BATCH, mode="subgraph",
                            pipeline_depth=2, cost=CostEstimator())
    engine.tracer.sample_every = 1
    nodes = np.random.default_rng(3).integers(0, data.n_nodes,
                                              size=6 * BATCH)
    errors = []
    stop = threading.Event()

    def snapshotter():
        try:
            while not stop.is_set():
                for tr in engine.tracer.records():
                    d = tr.to_json()
                    assert d["trace_id"] == tr.trace_id
        except Exception as e:          # pragma: no cover
            errors.append(e)

    th = threading.Thread(target=snapshotter)
    th.start()
    queries = engine.submit_many("g", "gcn", nodes)
    engine.run_until_drained()
    stop.set()
    th.join()
    assert not errors
    assert all(q.done for q in queries)
    traces = engine.tracer.batch_traces()
    assert traces and all(t.cost for t in traces)
    engine.close()
