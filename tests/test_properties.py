"""Property-based tests (hypothesis) for the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import abstraction, bitops, frdc
from repro.core.binarize import BinTensor, binarize_matrix, dequantize
from repro.core.bmm import bmm, quantize_act, quantize_weight
from repro.core.bspmm import bspmm
from repro.quant import grad_compress as gc

jax.config.update("jax_platform_name", "cpu")


# --- invariant: packing is an isomorphism on {0,1}^n -----------------------

@given(st.integers(1, 257), st.integers(1, 5), st.integers(0, 2**31))
@settings(max_examples=25, deadline=None)
def test_pack_preserves_popcount(n, rows, seed):
    bits = np.random.default_rng(seed).integers(0, 2, size=(rows, n))
    packed = bitops.pack_bits(bits)
    total = int(jnp.sum(bitops.popcount(packed)))
    assert total == int(bits.sum())


# --- invariant: dequantize(binarize(x)) preserves signs and row scale ------

@given(st.integers(1, 40), st.integers(1, 100), st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_binarize_dequantize_signs(m, n, seed):
    x = np.random.default_rng(seed).standard_normal((m, n)).astype(np.float32)
    t = binarize_matrix(jnp.asarray(x), scale="row")
    back = np.asarray(dequantize(t))
    assert np.all(np.sign(back) == np.where(x >= 0, 1, -1))
    np.testing.assert_allclose(np.abs(back)[:, 0],
                               np.mean(np.abs(x), axis=1), rtol=1e-5)


# --- invariant: the SCL-before-BIN elision is EXACT (paper §3.1.2) ---------

@given(st.integers(1, 30), st.integers(1, 60), st.integers(0, 2**31),
       st.floats(0.01, 100.0))
@settings(max_examples=20, deadline=None)
def test_positive_scale_elision_exact(m, n, seed, scale):
    x = np.random.default_rng(seed).standard_normal((m, n)).astype(np.float32)
    a = bitops.sign_bits(jnp.asarray(x))
    b = bitops.sign_bits(jnp.asarray(x) * scale)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- invariant: trinary schemes agree on any (adjacency, activation) pair --

@given(st.integers(1, 120), st.integers(0, 2**31))
@settings(max_examples=25, deadline=None)
def test_trinary_equivalence(n, seed):
    rng = np.random.default_rng(seed)
    a = bitops.pack_bits(rng.integers(0, 2, size=(1, n)))
    b = bitops.pack_bits(rng.integers(0, 2, size=(1, n)))
    s2 = np.asarray(bitops.trinary_dot_s2(a, b))
    s3 = np.asarray(bitops.trinary_dot_s3(a, b))
    np.testing.assert_array_equal(s2, s3)


# --- invariant: FRDC decode o encode == identity on sparsity patterns ------

@given(st.integers(1, 50), st.integers(1, 50), st.floats(0.0, 0.5),
       st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_frdc_roundtrip_rect(rows, cols, density, seed):
    a = (np.random.default_rng(seed).random((rows, cols)) < density
         ).astype(np.float32)
    m = frdc.from_dense(a)
    np.testing.assert_array_equal(np.asarray(frdc.to_dense(m)), a)


# --- invariant: BSpMM.FBF is linear in its dense operand -------------------

@given(st.integers(4, 40), st.integers(1, 24), st.integers(0, 2**31),
       st.floats(-3.0, 3.0))
@settings(max_examples=15, deadline=None)
def test_bspmm_linearity(n, f, seed, alpha):
    rng = np.random.default_rng(seed)
    adj = frdc.from_dense((rng.random((n, n)) < 0.3).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    lhs = bspmm(adj, x + alpha * y, "FBF")
    rhs = bspmm(adj, x, "FBF") + alpha * bspmm(adj, y, "FBF")
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=2e-3, atol=2e-3)


# --- invariant: type-checked chains never mix precisions -------------------

@given(st.sampled_from(list(abstraction.MMSPMM_PAIRINGS)))
@settings(max_examples=6, deadline=None)
def test_all_registered_pairings_typecheck(pair):
    abstraction.check_chain(*pair)


# --- invariant: EF compression error stays bounded (no drift) --------------

@given(st.integers(0, 2**31), st.integers(10, 60))
@settings(max_examples=10, deadline=None)
def test_ef_residual_bounded(seed, steps):
    rng = np.random.default_rng(seed)
    err = jnp.zeros(32)
    for _ in range(steps):
        g = jnp.asarray(rng.standard_normal(32), jnp.float32)
        _, err = gc.compress_leaf(g, err)
    # EF residual is bounded by ~2*max|g| per coordinate, never diverges
    assert float(jnp.max(jnp.abs(err))) < 10.0


# --- invariant: quantized LM linear == sign(W)*scale matmul ----------------

@given(st.integers(1, 8), st.integers(1, 70), st.integers(1, 20),
       st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_quantized_linear_matches_dequant(b, din, dout, seed):
    from repro.models.layers import linear
    from repro.quant.binary_linear import dequantize_linear, quantize_linear
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((din, dout)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, din)), jnp.float32)
    q = quantize_linear(w)
    got = linear(q, x)
    w_eff = dequantize_linear(q, din, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w_eff),
                               rtol=1e-3, atol=1e-3)
