"""Checkpoint-load robustness: truncated/corrupt serving artifacts raise a
typed ``ArtifactError`` naming the damaged file (and field), while MISSING
artifacts keep the silent recompile path (load returns None). Byte-level
truncation is driven through the chaos seam's ``corrupt_artifact``.
"""
import json
from pathlib import Path

import numpy as np
import jax
import pytest

from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import ArtifactError, FaultInjector, GraphStore
from repro.serve import session_core

jax.config.update("jax_platform_name", "cpu")

BATCH = 8


@pytest.fixture(scope="module")
def data():
    return make_dataset("cora", seed=0, scale=0.05)


@pytest.fixture(scope="module")
def gcn_params(data):
    key = jax.random.PRNGKey(0)
    return gnn.init_gcn(key, data.x.shape[1], 16, data.n_classes)


def _store(data, gcn_params, cache_dir):
    st = GraphStore(cache_dir=str(cache_dir), max_batch=BATCH)
    st.register_graph("g", data)
    st.register_model("gcn", "gcn", gcn_params)
    return st


def _saved_single(data, gcn_params, cache_dir) -> Path:
    st = _store(data, gcn_params, cache_dir)
    st.session("g", "gcn")
    d = cache_dir / "g__gcn"
    assert (d / "plan.json").exists()
    return d

def _saved_sharded(data, gcn_params, cache_dir) -> Path:
    st = _store(data, gcn_params, cache_dir)
    st.sharded_session("g", "gcn", 2)
    d = cache_dir / "g__gcn__P2"
    assert (d / "routing.json").exists()
    return d


# ------------------------------------------------------- sidecar loader -----

def test_load_sidecar_missing_file_is_none(tmp_path):
    assert session_core.load_sidecar(tmp_path / "nope.json") is None


def test_load_sidecar_truncated_raises_typed(tmp_path):
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(dict(plan={}, fingerprint={})))
    FaultInjector().corrupt_artifact(p, keep_bytes=10)
    with pytest.raises(ArtifactError) as ei:
        session_core.load_sidecar(p, required=("plan",))
    assert str(p) in str(ei.value)


def test_load_sidecar_missing_field_names_it(tmp_path):
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(dict(plan={})))
    with pytest.raises(ArtifactError) as ei:
        session_core.load_sidecar(p, required=("plan", "fingerprint"))
    assert ei.value.field == "fingerprint"
    assert "fingerprint" in str(ei.value)


def test_load_sidecar_non_object_raises(tmp_path):
    p = tmp_path / "plan.json"
    p.write_text(json.dumps([1, 2, 3]))
    with pytest.raises(ArtifactError):
        session_core.load_sidecar(p)


# ------------------------------------------------- single-host artifacts ----

def test_truncated_plan_json_raises_typed(data, gcn_params, tmp_path):
    d = _saved_single(data, gcn_params, tmp_path)
    FaultInjector().corrupt_artifact(d / "plan.json", keep_bytes=20)
    fresh = _store(data, gcn_params, tmp_path)
    with pytest.raises(ArtifactError) as ei:
        fresh.session("g", "gcn")
    assert "plan.json" in str(ei.value)


def test_truncated_weight_npz_raises_typed(data, gcn_params, tmp_path):
    d = _saved_single(data, gcn_params, tmp_path)
    npz = next(d.glob("step_*/shard_0.npz"))
    FaultInjector().corrupt_artifact(npz)
    fresh = _store(data, gcn_params, tmp_path)
    with pytest.raises(ArtifactError) as ei:
        fresh.session("g", "gcn")
    assert "shard_0.npz" in str(ei.value)
    assert ei.value.field == "leaves"


def test_truncated_manifest_raises_typed(data, gcn_params, tmp_path):
    d = _saved_single(data, gcn_params, tmp_path)
    manifest = next(d.glob("step_*/manifest.json"))
    FaultInjector().corrupt_artifact(manifest, keep_bytes=5)
    fresh = _store(data, gcn_params, tmp_path)
    with pytest.raises(ArtifactError) as ei:
        fresh.session("g", "gcn")
    assert "manifest.json" in str(ei.value)


def test_missing_npz_named_by_manifest_raises(data, gcn_params, tmp_path):
    d = _saved_single(data, gcn_params, tmp_path)
    npz = next(d.glob("step_*/shard_0.npz"))
    npz.unlink()
    fresh = _store(data, gcn_params, tmp_path)
    with pytest.raises(ArtifactError) as ei:
        fresh.session("g", "gcn")
    assert "shard_0.npz" in str(ei.value)


def test_missing_artifacts_still_recompile(data, gcn_params, tmp_path):
    """No artifacts at all stays the silent rebuild path (None, not an
    error) — robustness must not break cold starts."""
    st = _store(data, gcn_params, tmp_path / "empty")
    sess = st.session("g", "gcn")
    assert sess is not None


def test_intact_roundtrip_unaffected(data, gcn_params, tmp_path):
    """The typed loader changes nothing for healthy artifacts: a second
    store restores without recompiling and serves identically."""
    _saved_single(data, gcn_params, tmp_path)
    fresh = _store(data, gcn_params, tmp_path)
    sess = fresh.session("g", "gcn")
    assert sess.compile_count == 0        # restored, not rebuilt
    st0 = _store(data, gcn_params, tmp_path / "other")
    want = st0.session("g", "gcn").serve_subgraph(np.arange(4))
    np.testing.assert_array_equal(sess.serve_subgraph(np.arange(4)), want)


# ---------------------------------------------------- sharded artifacts -----

def test_truncated_routing_json_raises_typed(data, gcn_params, tmp_path):
    d = _saved_sharded(data, gcn_params, tmp_path)
    FaultInjector().corrupt_artifact(d / "routing.json", keep_bytes=30)
    fresh = _store(data, gcn_params, tmp_path)
    with pytest.raises(ArtifactError) as ei:
        fresh.sharded_session("g", "gcn", 2)
    assert "routing.json" in str(ei.value)


def test_corrupt_routing_field_names_field(data, gcn_params, tmp_path):
    d = _saved_sharded(data, gcn_params, tmp_path)
    sidecar = json.loads((d / "routing.json").read_text())
    sidecar["routing"] = {"wrong": 1}      # structurally broken table
    (d / "routing.json").write_text(json.dumps(sidecar))
    fresh = _store(data, gcn_params, tmp_path)
    with pytest.raises(ArtifactError) as ei:
        fresh.sharded_session("g", "gcn", 2)
    assert ei.value.field == "routing"


def test_truncated_shard_checkpoint_raises_typed(data, gcn_params,
                                                 tmp_path):
    d = _saved_sharded(data, gcn_params, tmp_path)
    npz = next(d.glob("step_*/shard_0.npz"))
    FaultInjector().corrupt_artifact(npz)
    fresh = _store(data, gcn_params, tmp_path)
    with pytest.raises(ArtifactError) as ei:
        fresh.sharded_session("g", "gcn", 2)
    assert "shard_0.npz" in str(ei.value)
