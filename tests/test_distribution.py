"""Distribution substrate tests: sharding rules, checkpoint/restore with
resharding, fault-tolerant training with injected failures, straggler-
tolerant loader, 1-bit gradient compression, serve engine."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced_config
from repro.distributed import sharding
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.optim.optimizer import AdamW, cosine_schedule
from repro.quant import grad_compress as gc

jax.config.update("jax_platform_name", "cpu")


def test_param_pspec_rules():
    cfg = reduced_config(get_config("minitron-8b")).resolve_for_mesh(tp=1)
    ap = jax.eval_shape(lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    mesh = make_host_mesh()
    shs = sharding.param_shardings(ap, mesh, fsdp=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(shs)
    by_key = {}
    for path, s in flat:
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        by_key.setdefault(key, s.spec)
    assert by_key["table"] == P("model", "data")
    assert by_key["wq"] == P("data", "model")
    assert by_key["wo"] == P("model", "data")
    assert by_key["scale"] in (P(None), P(None,))  # norm scales replicated


def test_quantized_param_specs_transpose():
    from repro.quant.binary_linear import quantize_params
    cfg = reduced_config(get_config("smollm-135m")).resolve_for_mesh(tp=1)
    ap = jax.eval_shape(lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    qp = jax.eval_shape(quantize_params, ap)
    mesh = make_host_mesh()
    shs = sharding.param_shardings(qp, mesh, fsdp=False)
    flat, _ = jax.tree_util.tree_flatten_with_path(shs)
    for path, s in flat:
        key = path[-1].key if hasattr(path[-1], "key") else ""
        parent = None
        for e in reversed(path[:-1]):
            if hasattr(e, "key"):
                parent = e.key
                break
        if key == "packed" and parent == "wq":
            # fp wq is P(None,"model") -> packed (out,in/32) = P("model",None)
            assert s.spec == P("model", None), s.spec
            return
    pytest.fail("no quantized wq found")


def test_hlo_collective_parser():
    from repro.distributed.hlo_analysis import analyze_collectives
    fake = """
  %ag = bf16[64,128]{1,0} all-gather(bf16[4,128]{1,0} %x), dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%sum
  ROOT %rs = (f32[8,16]{1,0}, f32[8]{0}) reduce-scatter(%a, %b), dimensions={0}
"""
    st = analyze_collectives(fake)
    assert st.bytes_by_op["all-gather"] == 64 * 128 * 2
    assert st.bytes_by_op["all-reduce"] == 256 * 4
    assert st.bytes_by_op["reduce-scatter"] == 8 * 16 * 4 + 8 * 4
    assert st.wire_bytes == (64 * 128 * 2) + 2 * (256 * 4) + (8 * 16 * 4 + 8 * 4)


def test_grad_compress_error_feedback_converges():
    """EF compression: quantization error is re-injected, so the RUNNING SUM
    of compressed grads tracks the running sum of true grads."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.standard_normal(64), jnp.float32)
              for _ in range(50)]
    err = jnp.zeros(64)
    acc_c = jnp.zeros(64)
    acc_t = jnp.zeros(64)
    for g in g_true:
        gh, err = gc.compress_leaf(g, err)
        acc_c += gh
        acc_t += g
    # residual bounded by one step's quantization error, not accumulating
    resid = float(jnp.max(jnp.abs(acc_c - acc_t)))
    assert resid < 3.0, resid


def test_allreduce_1bit_shard_map():
    mesh = make_host_mesh()
    g = jnp.asarray(np.random.default_rng(1).standard_normal(128), jnp.float32)
    out = gc.allreduce_1bit(g, mesh, axis="data")
    # single replica on CPU: mean of 1 replica == its own sign*scale
    scale = float(jnp.mean(jnp.abs(g)))
    np.testing.assert_allclose(np.asarray(out),
                               np.where(np.asarray(g) >= 0, scale, -scale),
                               rtol=1e-5)


def test_checkpoint_save_restore_roundtrip(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(tmp_path, keep=2)
    state = {"w": jnp.arange(8.0), "opt": {"mu": jnp.ones((3, 3))}}
    ck.save(10, state, blocking=True)
    ck.save(20, jax.tree.map(lambda x: x * 2, state), blocking=True)
    assert ck.latest_step() == 20
    restored = ck.restore(None, state)
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.arange(8.0) * 2)
    # keep=2 garbage collection
    ck.save(30, state, blocking=True)
    ck.save(40, state, blocking=True)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000040"


def _tiny_trainer(tmp_path, fail_at=-1, total=12):
    from repro.data.pipeline import PrefetchLoader, SyntheticLM
    from repro.train.trainer import (FailureInjector, Trainer, TrainerConfig)
    from repro.train.train_step import make_train_step
    cfg = reduced_config(get_config("smollm-135m")).resolve_for_mesh(tp=1)
    opt = AdamW(lr=3e-3)
    step = make_train_step(cfg, opt, unroll=True)
    loader = PrefetchLoader(SyntheticLM(cfg.vocab, 16), batch=4, seed=0)

    def init_state():
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        return params, opt.init(params), ()

    return Trainer(cfg, step, init_state, loader, str(tmp_path),
                   TrainerConfig(total_steps=total, ckpt_every=4,
                                 log_every=4),
                   failer=FailureInjector(fail_at) if fail_at >= 0 else None)


def test_trainer_loss_decreases(tmp_path):
    tr = _tiny_trainer(tmp_path, total=40)
    out = tr.run()
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5]) - 0.1
    tr.loader.close()


def test_trainer_failure_injection_and_restart(tmp_path):
    from repro.train.trainer import run_with_restarts
    calls = {"n": 0}

    def make():
        calls["n"] += 1
        return _tiny_trainer(tmp_path, fail_at=9 if calls["n"] == 1 else -1,
                             total=12)

    out = run_with_restarts(make, max_failures=2)
    assert out["restarts"] == 1
    # restarted from step 8 checkpoint -> ran only steps 8..12 the 2nd time
    assert out["steps"] <= 6


def test_loader_straggler_substitution():
    from repro.data.pipeline import PrefetchLoader, SyntheticLM

    class SlowLM(SyntheticLM):
        def __init__(self):
            super().__init__(vocab=64, seq_len=8)
            self.calls = 0

        def sample(self, rng, batch):
            import time
            self.calls += 1
            if self.calls > 1:
                time.sleep(3600)  # simulated dead input shard
            return super().sample(rng, batch)

    loader = PrefetchLoader(SlowLM(), batch=2, timeout_s=0.3)
    b1 = loader.next_batch()
    b2 = loader.next_batch()   # worker is stuck -> backup batch
    assert loader.straggler_misses >= 1
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    loader._stop.set()


def test_serve_engine_end_to_end():
    from repro.serve.engine import Request, ServeEngine
    cfg = reduced_config(get_config("smollm-135m")).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=rng.integers(0, cfg.vocab, 5),
                           max_new_tokens=4))
    done = eng.run_until_done()
    assert len(done) == 3
    for req in done:
        assert len(req.out_tokens) == 4
        assert all(0 <= t < cfg.vocab for t in req.out_tokens)


def test_elastic_restore_resharding(tmp_path):
    """Restore a checkpoint onto a different mesh layout (elastic scaling)."""
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(tmp_path)
    w = jnp.arange(64.0).reshape(8, 8)
    ck.save(1, {"w": w}, blocking=True)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored = ck.restore(None, {"w": w}, shardings=sh)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(w))
    assert restored["w"].sharding.spec == P("data", None)
