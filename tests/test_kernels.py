"""Kernel-vs-oracle sweeps (interpret mode on CPU; same code targets TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops, frdc
from repro.kernels import bmm_kernel, bspmm_kernel, pack_kernel, ref

jax.config.update("jax_platform_name", "cpu")


def _rand_packed(rng, rows, nbits):
    raw = rng.choice([-1.0, 1.0], size=(rows, nbits))
    return bitops.pack_bits(raw > 0), raw


@pytest.mark.parametrize("m,n,k", [
    (8, 32, 32), (16, 64, 96), (3, 33, 65), (130, 40, 256), (1, 1, 7),
])
def test_bmm_xnor_kernel_matches_ref(m, n, k):
    rng = np.random.default_rng(m * 1000 + n * 10 + k)
    ap, _ = _rand_packed(rng, m, k)
    bp, _ = _rand_packed(rng, n, k)
    got = bmm_kernel.bmm_xnor(ap, bp, k, block_m=32, block_n=32)
    want = ref.bmm_xnor_ref(ap, bp, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,n,k", [(8, 64, 32), (5, 96, 128), (9, 40, 64)])
def test_bmm_xnor_binarize_fused(m, n, k):
    rng = np.random.default_rng(m + n + k)
    ap, _ = _rand_packed(rng, m, k)
    bp, _ = _rand_packed(rng, n, k)
    got = bmm_kernel.bmm_xnor(ap, bp, k, binarize=True, block_m=32, block_n=32)
    want = ref.bmm_xnor_bin_ref(ap, bp, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,f", [(8, 32), (3, 100), (65, 256), (1, 31)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_binarize_pack_kernel(m, f, dtype):
    rng = np.random.default_rng(m * f)
    x = jnp.asarray(rng.standard_normal((m, f)), dtype)
    got = pack_kernel.binarize_pack(x, block_m=32, block_f=64)
    want = ref.binarize_pack_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _graph(rng, n, density):
    return (rng.random((n, n)) < density).astype(np.float32)


@pytest.mark.parametrize("n,f,density", [
    (16, 32, 0.3), (40, 64, 0.1), (33, 96, 0.25), (64, 32, 0.05),
])
def test_bspmm_bits_kernel_binarized(n, f, density):
    rng = np.random.default_rng(n * f)
    adj = frdc.from_dense(_graph(rng, n, density))
    act = rng.choice([-1.0, 1.0], size=(n, f))
    xp = bitops.pack_bits(act > 0)
    got = bspmm_kernel.bspmm_bits(adj, xp, f, binarize=True)
    want = ref.bspmm_bits_ref(adj, xp, f, binarize=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mode", ["s2_and_andnot", "s3_two_popc"])
def test_bspmm_bits_kernel_counts(mode):
    rng = np.random.default_rng(7)
    n, f = 24, 64
    adj = frdc.from_dense(_graph(rng, n, 0.2))
    act = rng.choice([-1.0, 1.0], size=(n, f))
    xp = bitops.pack_bits(act > 0)
    got = bspmm_kernel.bspmm_bits(adj, xp, f, binarize=False,
                                  trinary_mode=mode)
    want = ref.bspmm_bits_ref(adj, xp, f, binarize=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,f,density", [(16, 32, 0.3), (41, 128, 0.15)])
def test_bspmm_fp_kernel(n, f, density):
    rng = np.random.default_rng(n + f)
    adj = frdc.from_dense(_graph(rng, n, density))
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    got = bspmm_kernel.bspmm_fp(adj, x)
    want = ref.bspmm_fp_ref(adj, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_bspmm_empty_rows_prefill():
    """Rows with no edges: counts path gives 0, binarized path gives sign(0)=+1."""
    n, f = 16, 32
    a = np.zeros((n, n), np.float32)
    a[0, 3] = 1.0   # only tile-row 0 has a group
    adj = frdc.from_dense(a)
    rng = np.random.default_rng(0)
    act = rng.choice([-1.0, 1.0], size=(n, f))
    xp = bitops.pack_bits(act > 0)
    counts = bspmm_kernel.bspmm_bits(adj, xp, f, binarize=False)
    np.testing.assert_array_equal(np.asarray(counts[4:]), 0)
    bits = bspmm_kernel.bspmm_bits(adj, xp, f, binarize=True)
    np.testing.assert_array_equal(np.asarray(bits[4:]), 0xFFFFFFFF)


def test_bspmm_dma_start_wait_descriptors_pair():
    """Step-② DMA regression: every started HBM->VMEM gather must be waited
    on with the SAME descriptor (source slice included). Both kernels build
    start and wait through the shared ``_gather_copy`` helper; record its
    calls per kernel trace and check the wait half mirrors the start half
    slot for slot — a wait reconstructed from a different source slice
    (e.g. the old constant ``x_hbm[0:TILE]``) would bypass the helper and
    break the pairing."""
    calls = []
    real = bspmm_kernel._gather_copy

    def spy(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t):
        calls.append(t)
        return real(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t)

    rng = np.random.default_rng(5)
    adj = frdc.from_dense(_graph(rng, 24, 0.2))
    x = jnp.asarray(rng.standard_normal((24, 32)), jnp.float32)
    act = rng.choice([-1.0, 1.0], size=(24, 32))
    xp = bitops.pack_bits(act > 0)
    bspmm_kernel._gather_copy = spy
    try:
        got_fp = bspmm_kernel.bspmm_fp(adj, x)
        got_bits = bspmm_kernel.bspmm_bits(adj, xp, 32, binarize=False)
    finally:
        bspmm_kernel._gather_copy = real
    # each kernel-body trace issues GROUP starts then GROUP waits over the
    # same slot sequence — start/wait pairs match by construction
    assert calls and len(calls) % (2 * frdc.GROUP) == 0
    for i in range(0, len(calls), 2 * frdc.GROUP):
        window = calls[i:i + 2 * frdc.GROUP]
        assert window[:frdc.GROUP] == window[frdc.GROUP:] \
            == list(range(frdc.GROUP))
    # and the kernels still agree with the oracles through the spy
    np.testing.assert_allclose(np.asarray(got_fp),
                               np.asarray(ref.bspmm_fp_ref(adj, x)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(got_bits),
        np.asarray(ref.bspmm_bits_ref(adj, xp, 32, binarize=False)))


def test_bspmm_bits_block_validates_real_feature_width():
    """``bspmm_bits`` used to validate ``block_shape`` against the padded
    word width ``wf * WORD``: a block equal to the caller's REAL (narrower)
    ``n_feat`` bounced off the word-alignment check even though the packed
    kernel's word-native storage covers it exactly. Validation now sees the
    actual feature width."""
    n, f = 16, 24                    # wf = 1 word; wf * WORD = 32 > f
    rng = np.random.default_rng(9)
    adj = frdc.from_dense(_graph(rng, n, 0.25))
    act = rng.choice([-1.0, 1.0], size=(n, f))
    xp = bitops.pack_bits(act > 0)
    want = bspmm_kernel.bspmm_bits(adj, xp, f, binarize=False)
    # a block matching the real feature width is legal (used to raise) and
    # changes nothing
    got = bspmm_kernel.bspmm_bits(adj, xp, f, binarize=False,
                                  block_shape=(4, f))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_bin = bspmm_kernel.bspmm_bits(adj, xp, f, binarize=True,
                                      block_shape=(4, f))
    np.testing.assert_array_equal(
        np.asarray(got_bin),
        np.asarray(bspmm_kernel.bspmm_bits(adj, xp, f, binarize=True)))
    # genuinely unsupported widths still fail loudly
    with pytest.raises(ValueError):
        bspmm_kernel.bspmm_bits(adj, xp, f, binarize=False,
                                block_shape=(4, 48))
    assert bspmm_kernel._resolve_block((4, 24), 24, True) == 24
    with pytest.raises(ValueError):
        bspmm_kernel._resolve_block((4, 24), 32, True)


def test_bspmm_kernel_bucket_padded_frdc():
    """pad_frdc bucket padding appends all-zero groups mapped to tile-row 0
    WITHOUT a first-of-row reset. The kernel's flush schedule must neither
    let a pad group close row 0 with a stale accumulator nor hide row 0's
    real last group behind the pads (both bugs existed): padded and
    unpadded results must agree, including the row-0-only corner."""
    # corner: the ONLY real group is in tile-row 0, pads follow in row 0
    m = frdc.pad_frdc(frdc.from_coo([0], [0], 1, 1), 64, n_groups=16)
    x = jnp.ones((64, 5), jnp.float32)
    got = np.asarray(bspmm_kernel.bspmm_fp(m, x))[:1]
    np.testing.assert_array_equal(got, [[1.0] * 5])

    rng = np.random.default_rng(3)
    a = (rng.random((30, 30)) < 0.2).astype(np.float32)
    adj = frdc.from_dense(a)
    xf = jnp.asarray(rng.standard_normal((30, 32)), jnp.float32)
    want_fp = np.asarray(bspmm_kernel.bspmm_fp(adj, xf))[:30]
    padded = frdc.pad_frdc(adj, 64, n_groups=adj.n_groups + 7)
    xf_pad = jnp.zeros((64, 32)).at[:30].set(xf)
    got_fp = np.asarray(bspmm_kernel.bspmm_fp(padded, xf_pad))[:30]
    np.testing.assert_allclose(got_fp, want_fp, rtol=1e-5, atol=1e-5)

    act = rng.choice([-1.0, 1.0], size=(30, 32))
    xp = bitops.pack_bits(act > 0)
    want_c = np.asarray(bspmm_kernel.bspmm_bits(adj, xp, 32,
                                                binarize=False))[:30]
    xp_pad = jnp.zeros((64, 1), jnp.uint32).at[:30].set(xp)
    got_c = np.asarray(bspmm_kernel.bspmm_bits(padded, xp_pad, 32,
                                               binarize=False))[:30]
    np.testing.assert_array_equal(got_c, want_c)
