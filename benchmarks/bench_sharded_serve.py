"""Sharded serving benchmark: QPS + latency percentiles of the partitioned
engine (ShardPlanner -> ShardedGraphSession -> ShardedServeEngine) against
the single-host baseline, plus the halo traffic the distributed pass and the
routed subgraph path moved — per layer, packed vs fp.

Shards are simulated on one host (the shard boundary, routing and halo
mechanics are identical; only the transport latency is not real), so the QPS
columns measure the ORCHESTRATION overhead of sharding, and the halo-bytes
columns the communication volume a real deployment would pay — the number
the paper's bit-packing shrinks 32x on the binary-aggregation layer.

Two additional sections per family x P:

  * ``full_pass_latency`` — host-orchestrated vs SPMD executor wall time of
    one distributed full pass (``--executor`` picks which executor the
    ENGINE benches use; the comparison always runs both when the host can
    expose P devices — forced via ``ensure_host_devices`` when this module
    runs standalone — and records SPMD/host bit-equality);
  * ``bn_calibration_drift`` — distributed BN calibration (psum moments
    from the pass itself) vs the single-host anchor: max |logit delta| and
    argmax agreement;
  * ``pipeline`` — the double-buffered extract/compute engine with
    halo-aware batch formation vs the strict-FIFO serial engine on the
    identical query stream: overlap ratio, per-stage breakdown, and the
    MEASURED ``serve/x`` halo bytes saved by co-batching seeds that share
    halo tiles (``--pipeline`` additionally switches the main engine
    benches to the pipelined loop).

Emits CSV rows like every other section plus
``results/BENCH_sharded_serve.json``.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import (AdmissionController, GNNServeEngine, GraphStore,
                         ShardedServeEngine, SpanTracer, TenantPolicy,
                         write_chrome_trace)

from .common import csv_row

RESULTS = Path(__file__).resolve().parents[1] / "results"

# bump when the emitted JSON layout changes (compare_bench.py warns on
# cross-version diffs). v3: sharded snapshots carry ``whale_splits`` (and
# cost/SLO leaves when a CostEstimator/SLOTracker is wired). v4: the
# ``kernels`` section (multi-bucket co-launch dispatch reduction per shard
# count + the fused sharded path's bit-exactness).
SCHEMA_VERSION = 4

FAMILY_INITS = {
    "gcn": gnn.init_gcn, "sage": gnn.init_sage, "saint": gnn.init_saint,
}
SHARD_COUNTS = (2, 4)


def _time_full_pass(sess, repeats: int) -> float:
    sess.run_distributed_pass()                       # warm the programs
    t0 = time.perf_counter()
    for _ in range(repeats):
        sess.run_distributed_pass()
    return (time.perf_counter() - t0) / repeats


def _executor_compare(store, fam: str, p: int, spmd_ok: bool,
                      repeats: int) -> dict:
    """Host-vs-SPMD full-pass latency + SPMD bit-equality check."""
    host = store.sharded_session("bench", fam, p)
    out = dict(host_full_pass_s=_time_full_pass(host, repeats),
               spmd_full_pass_s=None, spmd_bit_exact=None,
               spmd_layer_compiles=None)
    if spmd_ok:
        spmd = store.sharded_session("bench", fam, p, executor="spmd")
        out["spmd_full_pass_s"] = _time_full_pass(spmd, repeats)
        out["spmd_bit_exact"] = bool(np.array_equal(spmd.full_logits(),
                                                    host.full_logits()))
        out["spmd_layer_compiles"] = spmd.executor_compile_count
    return out


def _bn_drift(store, fam: str, p: int, executor: str) -> dict:
    """Distributed-BN serving drift vs the single-host calibration."""
    anchor = store.session("bench", fam).full_logits()
    dist = store.sharded_session("bench", fam, p, executor=executor,
                                 bn_mode="distributed")
    got = dist.full_logits()
    return dict(
        executor=executor,
        max_abs_logit_delta=float(np.abs(got - anchor).max()),
        argmax_agreement=float((np.argmax(got, -1)
                                == np.argmax(anchor, -1)).mean()))


def _serve_wave(engine, graph: str, model: str, nodes: np.ndarray,
                batch: int) -> None:
    for i in range(0, nodes.size, batch):
        engine.submit_many(graph, model, nodes[i:i + batch])
        engine.tick()
    engine.run_until_drained()


def _bench_engine(engine, fam: str, nodes: np.ndarray, batch: int) -> dict:
    warm = engine.warmup("bench", fam)
    c0 = engine.compile_count
    _serve_wave(engine, "bench", fam, nodes, batch)
    snap = engine.snapshot()
    snap["warmup_compiles"] = warm
    snap["steady_state_compiles"] = engine.compile_count - c0
    engine.close()
    return snap


PIPELINE_DEPTH = 2


def _pipeline_compare(store, fam: str, p: int, executor: str,
                      nodes: np.ndarray, batch: int,
                      trace_path=None) -> dict:
    """Pipelined + halo-aware engine vs the strict-FIFO serial engine on
    the identical query stream (submitted up-front so batch formation has a
    real queue to group over): overlap ratio, stage breakdown, and the
    MEASURED ``serve/x`` halo bytes each run actually gathered — the delta
    is what halo-aware co-batching saved. With ``trace_path``, the
    pipelined run records EVERY batch span (sample_every=1) and exports a
    Perfetto-loadable Chrome trace there."""
    sess = store.sharded_session("bench", fam, p, executor=executor)

    def run_one(halo_aware: bool, depth: int, tracer=None):
        engine = ShardedServeEngine(store, p, max_batch=batch,
                                    mode="subgraph", executor=executor,
                                    halo_aware=halo_aware,
                                    pipeline_depth=depth, tracer=tracer)
        engine.warmup("bench", fam)
        c0 = engine.compile_count
        b0 = sess.halo_stats.bytes_by_tag.get("serve/x", 0)
        engine.submit_many("bench", fam, nodes)
        engine.run_until_drained()
        moved = sess.halo_stats.bytes_by_tag.get("serve/x", 0) - b0
        snap = engine.snapshot()
        snap["steady_state_compiles"] = engine.compile_count - c0
        engine.close()
        return snap, moved

    tracer = SpanTracer(sample_every=1) if trace_path is not None else None
    fifo_snap, fifo_bytes = run_one(False, 0)
    aware_snap, aware_bytes = run_one(True, PIPELINE_DEPTH, tracer=tracer)
    if tracer is not None:
        write_chrome_trace(tracer, str(trace_path))
        csv_row(f"sharded_serve/{fam}/P{p}/trace", 0.0,
                f"spans={len(tracer.batch_traces())};wrote={trace_path}")
    return dict(
        pipeline_depth=PIPELINE_DEPTH,
        overlap_ratio=aware_snap["overlap_ratio"],
        batch_breakdown=aware_snap["batch_breakdown"],
        qps_fifo_serial=fifo_snap["qps"],
        qps_pipelined=aware_snap["qps"],
        serve_x_bytes_fifo=fifo_bytes,
        serve_x_bytes_halo_aware=aware_bytes,
        halo_bytes_saved_measured=fifo_bytes - aware_bytes,
        halo_bytes_saved_est=aware_snap["halo_bytes_saved"],
        halo_tiles_shared=aware_snap["halo_tiles_shared"],
        steady_state_compiles=aware_snap["steady_state_compiles"],
    )


def _bench_tenants_sharded(store, fam: str, p: int, executor: str,
                           n_nodes: int, batch: int,
                           n_queries: int) -> dict:
    """Weighted two-tenant wave through the sharded engine: tenancy keys
    ride inside the (owner, tenant) queues, so every served batch stays
    single-owner AND single-tenant; records the per-tenant breakdown and
    the served ratio against the 4:1 weights."""
    admission = AdmissionController(
        policies={"gold": TenantPolicy(weight=4),
                  "base": TenantPolicy(weight=1)})
    engine = ShardedServeEngine(store, p, max_batch=batch, mode="subgraph",
                                executor=executor, admission=admission)
    engine.warmup("bench", fam)
    rng = np.random.default_rng(3)
    nodes = rng.integers(0, n_nodes, size=n_queries)
    for i, n in enumerate(nodes):
        engine.submit("bench", fam, n,
                      tenant=("gold" if i % 2 else "base"))
    engine.run_until_drained()
    snap = engine.snapshot()
    mixed = sum(len({q.tenant for q in b}) != 1 for b in engine.batch_log)
    engine.close()
    return dict(n_shards=p, weights=dict(gold=4, base=1),
                tenants=snap["tenants"], tenant_mixed_batches=mixed)


def _multi_bucket_compare(store, fam: str, p: int, executor: str,
                          nodes: np.ndarray, batch: int) -> dict:
    """Serial vs multi-bucket co-launch through the sharded engine: with
    coalescing on, each pump tick dispatches every core's share of the
    formed batches as ONE ``launch_many`` program per core, so the dispatch
    count drops below one-per-batch. Every answer is replayed through a
    single-host session (the ``batch_log`` oracle) — co-launching and
    sharding together must stay bit-identical to the unsharded forward."""
    oracle = store.session("bench", fam)
    # sharded queues alternate owner shards, so a pump tick only holds >= 2
    # batches of the SAME core once the pipeline is ~2 batches deep per
    # shard — scale the depth with the shard count
    depth = 2 * p

    def one(multi: bool, measured: bool = True) -> tuple:
        if measured:        # warm the co-launch composition traces first
            one(multi, measured=False)
        engine = ShardedServeEngine(store, p, max_batch=batch,
                                    mode="subgraph", executor=executor,
                                    pipeline_depth=depth,
                                    multi_bucket=multi)
        engine.warmup("bench", fam)
        d0 = engine.dispatch_count
        engine.submit_many("bench", fam, nodes)
        engine.run_until_drained()
        snap = engine.snapshot()
        n_batches = len(engine.batch_log)
        disp = engine.dispatch_count - d0
        replay = measured and all(
            np.array_equal(
                np.stack([q.logits for q in b]),
                np.asarray(oracle.serve_subgraph(
                    np.asarray([q.node for q in b], np.int64))))
            for b in engine.batch_log)
        engine.close()
        return snap, disp, n_batches, replay

    s_snap, s_disp, s_nb, s_ok = one(False)
    m_snap, m_disp, m_nb, m_ok = one(True)
    return dict(
        n_shards=p, pipeline_depth=depth,
        n_batches_serial=s_nb, n_batches_multi=m_nb,
        serial_dispatches=s_disp, coalesced_dispatches=m_disp,
        dispatch_reduction=s_disp / max(m_disp, 1),
        qps_serial=s_snap["qps"], qps_multi=m_snap["qps"],
        replay_bit_exact=bool(s_ok and m_ok),
    )


def _fused_sharded_bit_exact(d, fam: str, p: int, batch: int,
                             hidden: int) -> bool:
    """Serve one batch through a FUSED sharded session (kernels forced on,
    interpret mode off-TPU) and compare bitwise against the UNFUSED sharded
    forward — the fused-path half of the sharded bit-exactness acceptance
    (fusing a layer must never change its arithmetic), recorded where the
    gate can see it. The oracle is the sharded unfused path: sharded serving
    itself sits one fp-reassociation ulp from the single-host forward (the
    intra+halo aggregation split), fused or not."""
    from repro.kernels import ops as kernel_ops

    def build(fused: bool) -> GraphStore:
        st = GraphStore(max_batch=batch, use_pallas=True, fused=fused)
        st.register_graph("bench", d)
        st.register_model(fam, fam,
                          FAMILY_INITS[fam](jax.random.PRNGKey(0),
                                            d.x.shape[1], hidden,
                                            d.n_classes))
        return st

    seeds = np.random.default_rng(5).integers(0, d.n_nodes, size=batch)
    kernel_ops.force_kernels(True)
    try:
        want = np.asarray(
            build(False).sharded_session("bench", fam, p)
            .serve_subgraph(seeds))
        got = np.asarray(
            build(True).sharded_session("bench", fam, p)
            .serve_subgraph(seeds))
    finally:
        kernel_ops.force_kernels(False)
    return bool(np.array_equal(got, want))


def run(full: bool = False, executor: str = "host",
        pipeline: bool = False) -> dict:
    # the SPMD comparison needs P host devices; only effective when jax has
    # not initialized a backend yet (standalone runs) — otherwise the SPMD
    # columns degrade to None and the host columns still emit. The CPU pin
    # must precede ensure_host_devices (it initializes the backend).
    jax.config.update("jax_platform_name", "cpu")
    from repro.launch.mesh import ensure_host_devices
    spmd_ok = ensure_host_devices(max(SHARD_COUNTS))
    if executor == "spmd" and not spmd_ok:
        print("# bench_sharded_serve: --executor spmd needs "
              f"{max(SHARD_COUNTS)} devices, have {len(jax.devices())}; "
              "falling back to host for the engine benches")
        executor = "host"
    scale = 1.0 if full else 0.15
    n_queries = 600 if full else 120
    batch = 32 if full else 16
    hidden = 64 if full else 32
    pass_repeats = 5 if full else 2

    d = make_dataset("cora", seed=0, scale=scale)
    store = GraphStore(max_batch=batch)
    store.register_graph("bench", d)
    key = jax.random.PRNGKey(0)
    for fam, init in FAMILY_INITS.items():
        store.register_model(fam, fam, init(key, d.x.shape[1], hidden,
                                            d.n_classes))

    engine_depth = PIPELINE_DEPTH if pipeline else 0
    summary: dict = dict(schema_version=SCHEMA_VERSION, dataset="cora",
                         scale=scale, n_nodes=d.n_nodes,
                         n_edges=d.n_edges, n_queries=n_queries,
                         batch=batch, shard_counts=list(SHARD_COUNTS),
                         engine_executor=executor, spmd_available=spmd_ok,
                         engine_pipeline_depth=engine_depth,
                         families={})
    rng = np.random.default_rng(0)
    nodes = rng.integers(0, d.n_nodes, size=n_queries)

    for fam in FAMILY_INITS:
        fam_out: dict = {}
        single = _bench_engine(
            GNNServeEngine(store, max_batch=batch, mode="subgraph",
                           pipeline_depth=engine_depth),
            fam, nodes, batch)
        fam_out["single"] = single
        csv_row(f"sharded_serve/{fam}/single",
                1e6 / max(single["qps"], 1e-9),
                f"qps={single['qps']:.1f};"
                f"p50_ms={single['latency']['p50_ms']:.2f};"
                f"p99_ms={single['latency']['p99_ms']:.2f}")
        for p in SHARD_COUNTS:
            engine = ShardedServeEngine(store, p, max_batch=batch,
                                        mode="subgraph", executor=executor,
                                        pipeline_depth=engine_depth)
            snap = _bench_engine(engine, fam, nodes, batch)
            sess = store.sharded_session("bench", fam, p,
                                         executor=executor)
            snap["plan_stats"] = sess.shard_plan.stats()
            # the distributed full pass ran once per calibration: its tags
            # are the per-layer halo volume of full-graph inference
            snap["full_pass_halo_bytes"] = {
                t: b for t, b in sess.halo_stats.bytes_by_tag.items()
                if t.startswith("layer")}
            snap["full_pass_latency"] = _executor_compare(
                store, fam, p, spmd_ok, pass_repeats)
            snap["bn_calibration_drift"] = _bn_drift(
                store, fam, p, "spmd" if spmd_ok else "host")
            # the gcn P=2 pipelined run also exports a Chrome trace of every
            # batch's span tree (the CI workflow uploads it as an artifact)
            trace_path = (RESULTS / "TRACE_sharded_serve.json"
                          if fam == "gcn" and p == 2 else None)
            if trace_path is not None:
                RESULTS.mkdir(parents=True, exist_ok=True)
            snap["pipeline"] = _pipeline_compare(store, fam, p, executor,
                                                 nodes, batch,
                                                 trace_path=trace_path)
            fam_out[f"P{p}"] = snap
            pipe = snap["pipeline"]
            csv_row(f"sharded_serve/{fam}/P{p}/pipeline",
                    1e6 / max(pipe["qps_pipelined"], 1e-9),
                    f"qps={pipe['qps_pipelined']:.1f};"
                    f"overlap={pipe['overlap_ratio']:.2f};"
                    f"serve_x_fifo={pipe['serve_x_bytes_fifo']};"
                    f"serve_x_halo_aware="
                    f"{pipe['serve_x_bytes_halo_aware']};"
                    f"halo_saved={pipe['halo_bytes_saved_measured']};"
                    f"steady_compiles={pipe['steady_state_compiles']}")
            halo = ";".join(f"{t.replace('/', '_')}={b}"
                            for t, b in
                            sorted(snap["full_pass_halo_bytes"].items()))
            lat = snap["full_pass_latency"]
            spmd_s = lat["spmd_full_pass_s"]
            drift = snap["bn_calibration_drift"]
            csv_row(f"sharded_serve/{fam}/P{p}",
                    1e6 / max(snap["qps"], 1e-9),
                    f"qps={snap['qps']:.1f};"
                    f"p50_ms={snap['latency']['p50_ms']:.2f};"
                    f"p99_ms={snap['latency']['p99_ms']:.2f};"
                    f"halo_bytes={snap['halo_bytes']};{halo};"
                    f"steady_compiles={snap['steady_state_compiles']};"
                    f"host_pass_ms={lat['host_full_pass_s']*1e3:.2f};"
                    f"spmd_pass_ms="
                    f"{'n/a' if spmd_s is None else f'{spmd_s*1e3:.2f}'};"
                    f"spmd_bit_exact={lat['spmd_bit_exact']};"
                    f"bn_drift_max={drift['max_abs_logit_delta']:.2e};"
                    f"bn_argmax_agree={drift['argmax_agreement']:.4f}")
        summary["families"][fam] = fam_out

    summary["tenants"] = _bench_tenants_sharded(
        store, "gcn", SHARD_COUNTS[0], executor, d.n_nodes, batch,
        n_queries)
    ten = summary["tenants"]
    csv_row("sharded_serve/tenants", 0.0,
            f"gold_qps={ten['tenants']['gold']['qps']:.1f};"
            f"base_qps={ten['tenants']['base']['qps']:.1f};"
            f"mixed_batches={ten['tenant_mixed_batches']}")

    # multi-bucket co-launch per shard count + the fused sharded path's
    # bitwise identity with the unfused single-host forward
    summary["kernels"] = {
        f"P{p}": _multi_bucket_compare(store, "gcn", p, executor, nodes,
                                       batch)
        for p in SHARD_COUNTS}
    summary["kernels"]["fused_sharded_bit_exact"] = _fused_sharded_bit_exact(
        d, "gcn", SHARD_COUNTS[0], batch, hidden)
    for p in SHARD_COUNTS:
        mb = summary["kernels"][f"P{p}"]
        csv_row(f"sharded_serve/kernels/P{p}/multi_bucket", 0.0,
                f"batches={mb['n_batches_multi']};"
                f"serial_dispatches={mb['serial_dispatches']};"
                f"coalesced_dispatches={mb['coalesced_dispatches']};"
                f"dispatch_reduction={mb['dispatch_reduction']:.2f}x;"
                f"replay_bit_exact={mb['replay_bit_exact']}")
    csv_row("sharded_serve/kernels/fused", 0.0,
            f"fused_sharded_bit_exact="
            f"{summary['kernels']['fused_sharded_bit_exact']}")

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_sharded_serve.json"
    out.write_text(json.dumps(summary, indent=2))
    csv_row("sharded_serve/summary", 0.0, f"wrote={out}")
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--executor", choices=("host", "spmd"), default="host",
                    help="executor the sharded ENGINE benches run with; "
                    "the host-vs-SPMD full-pass comparison always emits")
    ap.add_argument("--pipeline", action="store_true",
                    help="run the engine benches with the double-buffered "
                    "extract/compute pipeline (depth "
                    f"{PIPELINE_DEPTH}); the pipelined-vs-FIFO comparison "
                    "section always emits")
    args = ap.parse_args()
    run(full=args.full, executor=args.executor, pipeline=args.pipeline)
