"""Replica-tier benchmark: availability and latency of the fault-tolerant
front door under steady state, injected chaos, and a live reshard.

Three sections, all emitted every run (``--chaos`` additionally ENFORCES
the chaos/reshard bounds in-process and exits non-zero on violation — the
CI smoke mode):

  * ``steady``  — P=2 replicas x 2 shards serving a clean wave: tier QPS,
    end-to-end p50/p99, availability (answered / accepted), zero dropped
    queries, zero steady-state recompiles.
  * ``chaos``   — the same tier with one replica killed mid-wave: every
    accepted query must complete on the survivor (availability 1.0,
    ``dropped_queries`` 0), the survivors' batch logs must replay bit-exact
    against the single-host session, and the survivor must take zero
    steady-state recompiles through the failover.
  * ``reshard`` — one replica live-resharded P=2 -> P=4 under load:
    ``blip_p99_ms`` (end-to-end p99 of the queries in flight across the
    swap window) vs the steady p99, the bound ``blip_p99_ms <
    max(5 x steady p99, 1s)``, and zero dropped queries.

Leaves feed ``compare_bench.py``: ``availability`` is higher-is-better,
``dropped_queries`` is zero-tolerance, ``p50_ms``/``p99_ms``/``qps`` use
the standard bands. Emits ``results/BENCH_replica.json``.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import (FaultInjector, FrontDoor, GraphStore,
                         HealthPolicy, Resharder, SpanTracer,
                         build_replica)

from .common import csv_row

RESULTS = Path(__file__).resolve().parents[1] / "results"

# bump when the emitted JSON layout changes
SCHEMA_VERSION = 1

BATCH = 8
HIDDEN = 16
BLIP_RATIO_BOUND = 5.0       # reshard p99 blip < 5x steady p99 ...
BLIP_FLOOR_S = 1.0           # ... with a smoke-scale noise floor


def _tier(data, params, n_replicas=2, n_shards=2, deadline_s=0.05):
    faults = FaultInjector(seed=0)
    tracer = SpanTracer()
    models = {"gcn": ("gcn", params)}
    reps = [build_replica(f"r{i}", data, models, n_shards=n_shards,
                          faults=faults, tracer=tracer, max_batch=BATCH,
                          mode="subgraph", retry_backoff_s=0.001)
            for i in range(n_replicas)]
    fd = FrontDoor(reps, faults=faults, tracer=tracer, spread="query",
                   policy=HealthPolicy(deadline_s=deadline_s))
    for r in reps:
        r.engine.warmup("g", "gcn")
    return fd, reps, faults


def _single_session(data, params):
    st = GraphStore(max_batch=BATCH)
    st.register_graph("g", data)
    st.register_model("gcn", "gcn", params)
    return st.session("g", "gcn")


def _replay_bit_exact(engine, single) -> bool:
    for batch in engine.batch_log:
        seeds = np.asarray([q.node for q in batch], np.int64)
        want = np.asarray(single.serve_subgraph(seeds))
        for i, q in enumerate(batch):
            if not np.array_equal(np.asarray(q.logits), want[i]):
                return False
    return True


def _wave_stats(fd, qs) -> dict:
    accepted = [q for q in qs if not q.rejected]
    answered = [q for q in accepted if q.done]
    dropped = len(accepted) - len(answered)
    lat = np.asarray([q.latency_s for q in answered]) * 1e3 \
        if answered else np.asarray([0.0])
    m = fd.metrics
    return dict(
        accepted=len(accepted), answered=len(answered),
        dropped_queries=dropped,
        availability=len(answered) / max(len(accepted), 1),
        qps=m.qps, p50_ms=float(np.percentile(lat, 50)),
        p99_ms=float(np.percentile(lat, 99)))


def _bench_steady(data, params, n_queries: int) -> dict:
    fd, reps, _ = _tier(data, params)
    c0 = sum(r.engine.compile_count for r in reps)
    rng = np.random.default_rng(0)
    qs = fd.submit_many("g", "gcn",
                        rng.integers(0, data.n_nodes, size=n_queries))
    fd.run_until_drained(max_ticks=200_000)
    out = _wave_stats(fd, qs)
    out["steady_state_compiles"] = \
        sum(r.engine.compile_count for r in reps) - c0
    for r in reps:
        r.engine.close()
    return out


def _bench_chaos(data, params, n_queries: int, single) -> dict:
    fd, reps, faults = _tier(data, params, deadline_s=0.05)
    survivor = reps[0].engine
    rng = np.random.default_rng(1)
    qs = fd.submit_many("g", "gcn",
                        rng.integers(0, data.n_nodes, size=n_queries))
    for _ in range(3):
        fd.tick()
    c0 = survivor.compile_count
    faults.kill("r1")
    time.sleep(0.06)
    fd.run_until_drained(max_ticks=200_000)
    out = _wave_stats(fd, qs)
    out["failovers"] = fd.failovers
    out["failover_queries"] = fd.failover_queries
    out["replay_bit_exact"] = all(
        _replay_bit_exact(r.engine, single) for r in reps)
    out["steady_state_compiles"] = survivor.compile_count - c0
    for r in reps:
        r.engine.close()
    return out


def _bench_reshard(data, params, n_queries: int, single) -> dict:
    fd, reps, _ = _tier(data, params, n_replicas=1, deadline_s=10.0)
    handle = reps[0]
    rng = np.random.default_rng(2)
    # steady window on P=2 first: the blip baseline
    warm = fd.submit_many("g", "gcn",
                          rng.integers(0, data.n_nodes, size=n_queries))
    fd.run_until_drained(max_ticks=200_000)
    steady = _wave_stats(fd, warm)
    # queries in flight ACROSS the swap window feel the blip
    blip_qs = fd.submit_many("g", "gcn",
                             rng.integers(0, data.n_nodes,
                                          size=n_queries // 2))
    for _ in range(2):
        fd.tick()
    rs = Resharder(handle, "g", "gcn", 4, drain_timeout_s=60.0,
                   tracer=fd.tracer)
    rs.prepare(block=False)      # P' builds in the background ...
    while not rs.ready:
        fd.tick()                # ... while the old engine keeps serving
    report = rs.swap()
    post = fd.submit_many("g", "gcn",
                          rng.integers(0, data.n_nodes,
                                       size=n_queries // 2))
    fd.run_until_drained(max_ticks=200_000)
    answered = [q for q in blip_qs + post if q.done]
    accepted = [q for q in blip_qs + post if not q.rejected]
    lat = np.asarray([q.latency_s for q in answered]) * 1e3 \
        if answered else np.asarray([0.0])
    blip_p99 = float(np.percentile(lat, 99))
    out = dict(
        steady_p50_ms=steady["p50_ms"], steady_p99_ms=steady["p99_ms"],
        blip_p99_ms=blip_p99,
        blip_ratio=blip_p99 / max(steady["p99_ms"], 1e-9),
        blip_bound_ms=max(BLIP_RATIO_BOUND * steady["p99_ms"],
                          BLIP_FLOOR_S * 1e3),
        dropped_queries=(len(accepted) - len(answered)
                         + report.drain.shed),
        availability=len(answered) / max(len(accepted), 1),
        from_shards=report.from_shards, to_shards=report.to_shards,
        prepare_s=report.prepare_s, swap_s=report.swap_s,
        drain=report.drain.to_json(),
        replay_bit_exact=_replay_bit_exact(handle.engine, single))
    out["blip_bounded"] = out["blip_p99_ms"] < out["blip_bound_ms"]
    handle.engine.close()
    return out


def run(full: bool = False, chaos: bool = False) -> dict:
    jax.config.update("jax_platform_name", "cpu")
    scale = 0.3 if full else 0.05
    n_queries = 256 if full else 48
    data = make_dataset("cora", seed=0, scale=scale)
    params = gnn.init_gcn(jax.random.PRNGKey(0), data.x.shape[1], HIDDEN,
                          data.n_classes)
    single = _single_session(data, params)

    summary = dict(schema_version=SCHEMA_VERSION,
                   config=dict(full=full, n_queries=n_queries,
                               scale=scale))
    summary["steady"] = _bench_steady(data, params, n_queries)
    s = summary["steady"]
    csv_row("replica/steady", 1e6 / max(s["qps"], 1e-9),
            f"qps={s['qps']:.1f};p50_ms={s['p50_ms']:.2f};"
            f"p99_ms={s['p99_ms']:.2f};availability={s['availability']};"
            f"dropped={s['dropped_queries']};"
            f"steady_compiles={s['steady_state_compiles']}")

    summary["chaos"] = _bench_chaos(data, params, n_queries, single)
    c = summary["chaos"]
    csv_row("replica/chaos", 0.0,
            f"availability={c['availability']};"
            f"dropped={c['dropped_queries']};failovers={c['failovers']};"
            f"moved={c['failover_queries']};"
            f"replay_bit_exact={c['replay_bit_exact']};"
            f"survivor_steady_compiles={c['steady_state_compiles']}")

    summary["reshard"] = _bench_reshard(data, params, n_queries, single)
    r = summary["reshard"]
    csv_row("replica/reshard", 0.0,
            f"blip_p99_ms={r['blip_p99_ms']:.2f};"
            f"steady_p99_ms={r['steady_p99_ms']:.2f};"
            f"blip_bounded={r['blip_bounded']};"
            f"dropped={r['dropped_queries']};"
            f"prepare_s={r['prepare_s']:.2f};swap_s={r['swap_s']:.2f};"
            f"replay_bit_exact={r['replay_bit_exact']}")

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_replica.json"
    out.write_text(json.dumps(summary, indent=2))
    csv_row("replica/summary", 0.0, f"wrote={out}")

    if chaos:
        # CI smoke mode: the availability/bit-exactness/blip bounds are
        # hard failures here, independent of the compare_bench gate
        problems = []
        if c["availability"] < 1.0 or c["dropped_queries"]:
            problems.append(f"chaos lost queries: {c}")
        if not c["replay_bit_exact"]:
            problems.append("chaos replay not bit-exact")
        if c["steady_state_compiles"]:
            problems.append(
                f"survivor recompiled {c['steady_state_compiles']}x")
        if r["dropped_queries"]:
            problems.append(f"reshard dropped {r['dropped_queries']}")
        if not r["blip_bounded"]:
            problems.append(
                f"reshard blip {r['blip_p99_ms']:.1f}ms over bound "
                f"{r['blip_bound_ms']:.1f}ms")
        if not r["replay_bit_exact"]:
            problems.append("reshard replay not bit-exact")
        for p in problems:
            print(f"CHAOS-FAIL {p}")
        if problems:
            raise SystemExit(1)
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--chaos", action="store_true",
                    help="enforce the chaos/reshard availability + blip "
                         "bounds (exit 1 on violation) — the CI smoke")
    args = ap.parse_args()
    run(full=args.full, chaos=args.chaos)
