"""Shared benchmark utilities: timing, CSV output, dataset prep."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "size"))


def csv_row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
