"""Tables 3-5 'Peak Mem' columns: exact representation sizes — fp32 CSR vs
FRDC bit-blocks, fp32 vs packed activations/weights (hardware-independent)."""
from __future__ import annotations

import numpy as np

from repro.core import frdc
from repro.graphs.datasets import DATASET_STATS, make_dataset

from .common import csv_row


def run(full: bool = False) -> None:
    scales = {"cora": 1.0, "pubmed": 1.0 if full else 0.3,
              "citeseer": 1.0, "flickr": 1.0 if full else 0.05,
              "reddit": 1.0 if full else 0.002}
    for name, scale in scales.items():
        d = make_dataset(name, seed=0, scale=scale)
        m = frdc.from_coo(d.edges[0], d.edges[1], d.n_nodes, d.n_nodes)
        st = frdc.stats(m)
        n, f = d.x.shape
        fp = st["csr_fp32_bytes"] + n * f * 4
        ours_full = st["frdc_bytes"] + n * f * 4
        ours_bin = st["frdc_bytes"] + n * ((f + 31) // 32) * 4
        csv_row(f"memory/{name}/fp32", 0.0, f"bytes={fp}")
        csv_row(f"memory/{name}/ours_full", 0.0,
                f"bytes={ours_full};saving={fp/ours_full:.2f}x")
        csv_row(f"memory/{name}/ours_bin", 0.0,
                f"bytes={ours_bin};saving={fp/ours_bin:.2f}x;"
                f"adj_vs_csr={st['vs_csr']:.2f}x;scale={scale}")
