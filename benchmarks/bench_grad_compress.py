"""Beyond-paper: 1-bit EF gradient compression — wire bytes of the packed
all-gather vs an fp32 all-reduce, measured from compiled HLO."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.hlo_analysis import analyze_collectives
from repro.launch.mesh import make_host_mesh
from repro.quant import grad_compress as gc

from .common import csv_row


def run(full: bool = False) -> None:
    n = 1 << 20 if full else 1 << 16
    mesh = make_host_mesh()
    g = jnp.zeros((n,), jnp.float32)

    def fp32_allreduce(x):
        return jax.shard_map(
            lambda v: jax.lax.pmean(v, "data"), mesh=mesh,
            in_specs=P(None), out_specs=P(None), check_vma=False)(x)

    c_fp = jax.jit(fp32_allreduce).lower(g).compile()
    c_1b = jax.jit(lambda x: gc.allreduce_1bit(x, mesh)).lower(g).compile()
    b_fp = analyze_collectives(c_fp.as_text()).wire_bytes
    b_1b = analyze_collectives(c_1b.as_text()).wire_bytes
    csv_row("grad_compress/fp32_allreduce", 0.0, f"wire_bytes={b_fp}")
    csv_row("grad_compress/onebit_allgather", 0.0,
            f"wire_bytes={b_1b};reduction={b_fp/max(b_1b,1):.1f}x")
