"""Paper Tables 3/4/5: end-to-end GNN inference latency + memory across the
five execution backends (FP32 scatter / FP32 tensor / Bi-GCN / Ours(full) /
Ours(bin)) on stat-matched synthetic graphs.

CPU caveat (recorded in EXPERIMENTS.md): this box has no GPU/TPU, so wall
times show CPU ratios, not the paper's GPU ratios; the MEMORY columns are
exact (bit-representation sizes are hardware-independent) and the kernels'
bit-manipulation structure is identical to the TPU target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops, frdc
from repro.core.binarize import BinTensor
from repro.graphs.datasets import make_dataset
from repro.models import gnn

from .common import csv_row, time_fn, tree_bytes


def _memory_bytes(d, model_params, mode: str) -> int:
    """Peak-memory proxy: graph + features + weights (paper's Peak Mem)."""
    n, f = d.x.shape
    w_bytes = tree_bytes(model_params)
    if mode == "fp32":
        adj = d.n_edges * 8 + (n + 1) * 4           # CSR fp32
        feat = n * f * 4
        return adj + feat + w_bytes
    m = frdc.from_coo(d.edges[0], d.edges[1], n, n)
    adj = m.nbytes()
    if mode == "full":                               # bin weights, fp agg
        feat = n * f * 4
        return adj + feat + w_bytes // 32 + n * 4
    feat = n * ((f + 31) // 32) * 4                  # packed activations
    return adj + feat + w_bytes // 32 + n * 4


def bench_gcn(dataset: str, scale: float, hidden: int = 64) -> None:
    d = make_dataset(dataset, seed=0, scale=scale)
    x = jnp.asarray(d.x)
    adj = frdc.gcn_normalized(d.edges[0], d.edges[1], d.n_nodes)
    adj_bin = frdc.from_coo(d.edges[0], d.edges[1], d.n_nodes, d.n_nodes)
    adj_dense = frdc.to_dense(adj)
    edges = jnp.asarray(np.concatenate(
        [d.edges, np.stack([np.arange(d.n_nodes)] * 2)], axis=1))
    params = gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1], hidden,
                          d.n_classes)
    q = gnn.quantize_gcn(params)

    norm = 1.0 / jnp.sqrt(jnp.bincount(edges[0], length=d.n_nodes) + 1.0)

    @jax.jit
    def fp32_scatter(x):
        p = params
        h = x @ p.w1
        h = gnn.aggregate_scatter(edges, h * norm[:, None], d.n_nodes) \
            * norm[:, None]
        h = jax.nn.relu(h)
        h2 = h @ p.w2
        return gnn.aggregate_scatter(edges, h2 * norm[:, None], d.n_nodes) \
            * norm[:, None]

    @jax.jit
    def fp32_tensor(x):
        return gnn.gcn_forward_fp(params, x, adj_dense)

    @jax.jit
    def bigcn(x):
        return gnn.gcn_forward_bigcn(params, x, adj_dense)

    @jax.jit
    def ours_full(x):
        return gnn.gcn_forward_bitgnn(q, x, adj, adj_bin, scheme="full")

    @jax.jit
    def ours_bin(x):
        return gnn.gcn_forward_bitgnn(q, x, adj, adj_bin, scheme="bin")

    rows = [
        ("FP32(S)", fp32_scatter, "fp32"),
        ("FP32(T)", fp32_tensor, "fp32"),
        ("Bi-GCN", bigcn, "fp32"),
        ("Ours(full)", ours_full, "full"),
        ("Ours(bin)", ours_bin, "bin"),
    ]
    base = None
    for name, fn, mode in rows:
        t = time_fn(fn, x, repeats=3, warmup=1)
        base = base or t
        mem = _memory_bytes(d, params, mode)
        csv_row(f"table3/gcn/{dataset}/{name}", t * 1e6,
                f"mem_mb={mem/1e6:.2f};speedup={base/t:.2f}x")


def bench_sage(dataset: str, scale: float, hidden: int = 64) -> None:
    d = make_dataset(dataset, seed=0, scale=scale)
    x = jnp.asarray(d.x)
    adj_mean = frdc.mean_normalized(d.edges[0], d.edges[1], d.n_nodes)
    adj_mean_dense = frdc.to_dense(adj_mean)
    params = gnn.init_sage(jax.random.PRNGKey(1), d.x.shape[1], hidden,
                           d.n_classes)
    q = gnn.quantize_sage(params)

    @jax.jit
    def fp32_tensor(x):
        return gnn.sage_forward_fp(params, x, adj_mean_dense)

    @jax.jit
    def bigcn(x):
        return gnn.sage_forward_bigcn(params, x, adj_mean_dense)

    @jax.jit
    def ours(x):
        return gnn.sage_forward_bitgnn(q, x, adj_mean)

    rows = [("FP32(T)", fp32_tensor, "fp32"),
            ("Bi-GCN", bigcn, "fp32"),
            ("Ours(bin)", ours, "bin")]
    base = None
    for name, fn, mode in rows:
        t = time_fn(fn, x, repeats=3, warmup=1)
        base = base or t
        mem = _memory_bytes(d, params, mode)
        csv_row(f"table4/sage/{dataset}/{name}", t * 1e6,
                f"mem_mb={mem/1e6:.2f};speedup={base/t:.2f}x")


def bench_saint(dataset: str, scale: float, hidden: int = 64) -> None:
    d = make_dataset(dataset, seed=0, scale=scale)
    x = jnp.asarray(d.x)
    adj_sum = frdc.from_coo(d.edges[0], d.edges[1], d.n_nodes, d.n_nodes)
    adj_dense = frdc.to_dense(adj_sum)
    params = gnn.init_saint(jax.random.PRNGKey(2), d.x.shape[1], hidden,
                            d.n_classes)
    q = gnn.quantize_saint(params)

    @jax.jit
    def fp32_tensor(x):
        return gnn.saint_forward_fp(params, x, adj_dense)

    @jax.jit
    def ours(x):
        return gnn.saint_forward_bitgnn(q, x, adj_sum)

    rows = [("FP32(T)", fp32_tensor, "fp32"),
            ("Ours(bin)", ours, "bin")]
    base = None
    for name, fn, mode in rows:
        t = time_fn(fn, x, repeats=3, warmup=1)
        base = base or t
        mem = _memory_bytes(d, params, mode)
        csv_row(f"table5/saint/{dataset}/{name}", t * 1e6,
                f"mem_mb={mem/1e6:.2f};speedup={base/t:.2f}x")


def run(full: bool = False) -> None:
    bench_gcn("cora", 1.0 if full else 0.5)
    bench_gcn("pubmed", 1.0 if full else 0.15)
    bench_gcn("citeseer", 1.0 if full else 0.5)
    bench_sage("flickr", 1.0 if full else 0.02)
    bench_saint("flickr", 1.0 if full else 0.02)
