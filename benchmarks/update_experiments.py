"""Regenerate the data-driven sections of EXPERIMENTS.md from results/."""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def roofline_section() -> str:
    from .roofline import load_all, markdown_table
    rows = load_all("single")
    return markdown_table(rows) if rows else "_no dry-run results yet_"


def multipod_section() -> str:
    res = sorted((ROOT / "results" / "dryrun").glob("*__multi.json"))
    if not res:
        return "_no multi-pod results yet_"
    lines = ["| arch | shape | compile s | HBM/dev GB | coll GB/dev (scanned prog) |",
             "|---|---|---|---|---|"]
    for p in res:
        r = json.loads(p.read_text())
        coll = sum(b for b, _ in
                   r.get("collectives_scanned_program", {}).values())
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} "
            f"| {r['memory']['per_device_hbm_bytes']/1e9:.2f} "
            f"| {coll/1e9:.2f} |")
    lines.append(f"\nAll {len(res)} multi-pod (2,16,16)=512-chip cells "
                 "lowered AND compiled successfully — the `pod` axis shards.")
    return "\n".join(lines)


def perf_section() -> str:
    perf = sorted((ROOT / "results" / "perf").glob("*.json")) \
        if (ROOT / "results" / "perf").exists() else []
    if not perf:
        return "_hillclimb results pending_"
    from .roofline import analyze
    lines = ["| variant | hypothesis | compute s | memory s | collective s "
             "| dominant | frac |", "|---|---|---|---|---|---|---|"]
    for p in perf:
        r = json.loads(p.read_text())
        a = analyze(r)
        t = a["terms"]
        hyp = r.get("hypothesis", "")[:80]
        lines.append(
            f"| {r.get('variant', p.stem)} | {hyp} | {t['compute']:.4f} "
            f"| {t['memory']:.4f} | {t['collective']:.4f} "
            f"| **{a['dominant']}** | {a['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def main():
    path = ROOT / "EXPERIMENTS.md"
    text = path.read_text()
    for marker, gen in [("<!-- ROOFLINE_TABLE -->", roofline_section),
                        ("<!-- MULTIPOD_TABLE -->", multipod_section),
                        ("<!-- PERF_LOG -->", perf_section)]:
        block = f"{marker}\n{gen()}\n<!-- /{marker[5:-4].strip()} -->"
        if marker in text:
            # replace marker (and any previously generated block after it)
            start = text.index(marker)
            end_tag = f"<!-- /{marker[5:-4].strip()} -->"
            end = text.find(end_tag)
            if end >= 0:
                end += len(end_tag)
            else:
                end = start + len(marker)
            text = text[:start] + block + text[end:]
    path.write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
