"""Serving benchmark: QPS + latency percentiles of the GNN serving subsystem
(GraphStore -> CompiledGraphSession -> GNNServeEngine) on a stat-matched
synthetic Table-2 graph, for all three model families and both serve paths
(micro-batched k-hop subgraph vs. cached full-graph inference).

Queries arrive in waves (submit one micro-batch worth, then tick) so the
reported latency is end-to-end batch service time, not closed-loop queueing
over the whole run. Emits CSV rows like every other section plus a
``results/BENCH_serve_gnn.json`` summary — the start of the serving-side
perf trajectory (kernels are tracked by the other sections).
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import (AdmissionController, GNNServeEngine, GraphStore,
                         TenantPolicy)

from .common import csv_row

RESULTS = Path(__file__).resolve().parents[1] / "results"

# bump when the emitted JSON layout changes (compare_bench.py warns on
# cross-version diffs)
SCHEMA_VERSION = 2

FAMILY_INITS = {
    "gcn": gnn.init_gcn, "sage": gnn.init_sage, "saint": gnn.init_saint,
}


def _serve_wave(engine: GNNServeEngine, graph: str, model: str,
                nodes: np.ndarray, batch: int) -> None:
    for i in range(0, nodes.size, batch):
        engine.submit_many(graph, model, nodes[i:i + batch])
        engine.tick()
    engine.run_until_drained()


def _bench_mode(store: GraphStore, family: str, mode: str, n_queries: int,
                n_nodes: int, batch: int, seed: int = 0,
                pipeline_depth: int = 0) -> dict:
    engine = GNNServeEngine(store, max_batch=batch, mode=mode,
                            pipeline_depth=pipeline_depth)
    warm_compiles = engine.warmup("bench", family)
    c0 = engine.compile_count
    nodes = np.random.default_rng(seed).integers(0, n_nodes, size=n_queries)
    _serve_wave(engine, "bench", family, nodes, batch)
    snap = engine.snapshot()
    snap["warmup_compiles"] = warm_compiles
    snap["steady_state_compiles"] = engine.compile_count - c0
    engine.close()
    return snap


def _bench_tenants(store: GraphStore, family: str, n_nodes: int,
                   batch: int, n_good: int, seed: int = 0) -> dict:
    """Two-tenant overload scenario: ``hog`` submits 10x the well-behaved
    ``good`` tenant's volume against a rate limit + queue-depth bound, so
    most of its traffic comes back typed (shed at the depth bound while
    tokens remain, throttled once the bucket drains). Records the admission
    outcomes, the weighted fairness of what WAS admitted, and the good
    tenant's p99 against its own solo run — the acceptance gauge is
    ``good_p99_within_2x_solo``."""
    rng = np.random.default_rng(seed)
    good_nodes = rng.integers(0, n_nodes, size=n_good)
    policies = dict(
        good=TenantPolicy(weight=8),
        hog=TenantPolicy(rate_qps=5.0, burst=batch,
                         max_queue_depth=batch, weight=1),
    )

    def one_run(with_hog: bool) -> dict:
        engine = GNNServeEngine(
            store, max_batch=batch, mode="subgraph",
            admission=AdmissionController(policies=dict(policies)))
        engine.warmup("bench", family)
        for i in range(0, good_nodes.size, batch):
            engine.submit_many("bench", family, good_nodes[i:i + batch],
                               tenant="good")
            if with_hog:                 # 10x the good tenant's volume
                hog_nodes = rng.integers(0, n_nodes, size=10 * batch)
                engine.submit_many("bench", family, hog_nodes, tenant="hog")
            # two service slots per arrival wave: the engine has the
            # capacity to absorb the hog's ADMITTED trickle, so the good
            # tenant's p99 reflects scheduling, not an undersized server
            engine.tick()
            engine.tick()
        engine.run_until_drained()
        snap = engine.snapshot()
        engine.close()
        return snap

    solo = one_run(False)
    mixed = one_run(True)
    good, hog = mixed["tenants"]["good"], mixed["tenants"]["hog"]
    p99_solo = solo["tenants"]["good"]["latency"]["p99_ms"]
    p99_mixed = good["latency"]["p99_ms"]
    def _fin(v):                       # inf -> null (strict-JSON safe)
        return None if v is None or np.isinf(v) else v

    return dict(
        family=family,
        policy={t: dict(rate_qps=_fin(p.rate_qps),
                        burst=_fin(p.bucket_capacity),
                        weight=p.weight, max_queue_depth=p.max_queue_depth)
                for t, p in policies.items()},
        good_solo=solo["tenants"]["good"],
        good_mixed=good,
        hog_mixed=hog,
        hog_shed_rate=hog["shed_rate"],
        hog_reject_rate=hog["reject_rate"],
        fairness_served_ratio=(good["queries"] / max(hog["queries"], 1)),
        good_p99_solo_ms=p99_solo,
        good_p99_mixed_ms=p99_mixed,
        good_p99_ratio=p99_mixed / max(p99_solo, 1e-9),
        good_p99_within_2x_solo=bool(p99_mixed <= 2.0 * p99_solo),
    )


def _tenants_row(section: dict, suffix: str = "") -> None:
    """THE csv emitter of the tenants section — shared by ``run()`` and the
    standalone ``--tenants`` entry so the row never drifts between them."""
    csv_row("serve_gnn/tenants",
            section["good_p99_mixed_ms"] * 1e3,
            f"good_p99_solo_ms={section['good_p99_solo_ms']:.2f};"
            f"good_p99_mixed_ms={section['good_p99_mixed_ms']:.2f};"
            f"p99_ratio={section['good_p99_ratio']:.2f};"
            f"within_2x={section['good_p99_within_2x_solo']};"
            f"hog_reject_rate={section['hog_reject_rate']:.2f};"
            f"hog_shed_rate={section['hog_shed_rate']:.2f};"
            f"hog_accepted={section['hog_mixed']['accepted']}"
            f"{suffix}")


def _merge_results(section: str, payload: dict) -> Path:
    """Write ``payload`` under ``section`` of BENCH_serve_gnn.json, keeping
    whatever other sections a previous (possibly fuller) run recorded."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_serve_gnn.json"
    summary = json.loads(out.read_text()) if out.exists() else {}
    summary.setdefault("schema_version", SCHEMA_VERSION)
    summary[section] = payload
    out.write_text(json.dumps(summary, indent=2))
    return out


def run_tenants(full: bool = False) -> dict:
    """Standalone ``--tenants`` entry: the overload scenario only, merged
    into the existing results JSON."""
    jax.config.update("jax_platform_name", "cpu")
    scale = 1.0 if full else 0.15
    batch = 32 if full else 16
    hidden = 64 if full else 32
    n_good = 320 if full else 96

    d = make_dataset("cora", seed=0, scale=scale)
    store = GraphStore(max_batch=batch)
    store.register_graph("bench", d)
    store.register_model("gcn", "gcn",
                         gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1],
                                      hidden, d.n_classes))
    section = _bench_tenants(store, "gcn", d.n_nodes, batch, n_good)
    out = _merge_results("tenants", section)
    _tenants_row(section, suffix=f";wrote={out}")
    return section


def run(full: bool = False) -> dict:
    jax.config.update("jax_platform_name", "cpu")
    scale = 1.0 if full else 0.15
    n_queries = 1000 if full else 200
    batch = 32 if full else 16
    hidden = 64 if full else 32

    d = make_dataset("cora", seed=0, scale=scale)
    store = GraphStore(max_batch=batch)
    store.register_graph("bench", d)
    key = jax.random.PRNGKey(0)
    for fam, init in FAMILY_INITS.items():
        store.register_model(fam, fam, init(key, d.x.shape[1], hidden,
                                            d.n_classes))

    summary: dict = dict(schema_version=SCHEMA_VERSION, dataset="cora",
                         scale=scale, n_nodes=d.n_nodes,
                         n_edges=d.n_edges, n_queries=n_queries,
                         batch=batch, families={})
    for fam in FAMILY_INITS:
        sess = store.session("bench", fam, tune=(fam == "gcn"),
                             tune_repeats=1)
        fam_out = dict(plan=sess.plan.name(),
                       tuned_latency_ms=sess.plan.tuned_latency_s * 1e3)
        for mode in ("subgraph", "full"):
            snap = _bench_mode(store, fam, mode, n_queries, d.n_nodes, batch)
            fam_out[mode] = snap
            lat = snap["latency"]
            csv_row(f"serve_gnn/{fam}/{mode}",
                    1e6 / max(snap["qps"], 1e-9),
                    f"qps={snap['qps']:.1f};p50_ms={lat['p50_ms']:.2f};"
                    f"p99_ms={lat['p99_ms']:.2f};"
                    f"hit_rate={snap['cache_hit_rate']:.2f};"
                    f"steady_compiles={snap['steady_state_compiles']}")
        # the pipelined subgraph loop: extraction of batch i+1 overlaps the
        # in-flight forward of batch i (bit-exact vs the serial rows above)
        snap = _bench_mode(store, fam, "subgraph", n_queries, d.n_nodes,
                           batch, pipeline_depth=2)
        fam_out["subgraph_pipelined"] = snap
        bd = snap["batch_breakdown"]
        csv_row(f"serve_gnn/{fam}/subgraph_pipelined",
                1e6 / max(snap["qps"], 1e-9),
                f"qps={snap['qps']:.1f};"
                f"overlap={snap['overlap_ratio']:.2f};"
                f"extract_p50_ms={bd['extract']['p50_ms']:.2f};"
                f"compute_p50_ms={bd['compute']['p50_ms']:.2f};"
                f"steady_compiles={snap['steady_state_compiles']}")
        summary["families"][fam] = fam_out

    # the multi-tenant overload scenario (fairness + shed-rate + the good
    # tenant's p99-vs-solo acceptance gauge)
    summary["tenants"] = _bench_tenants(
        store, "gcn", d.n_nodes, batch,
        n_good=(320 if full else 96))
    _tenants_row(summary["tenants"])

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_serve_gnn.json"
    out.write_text(json.dumps(summary, indent=2))
    csv_row("serve_gnn/summary", 0.0, f"wrote={out}")
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tenants", action="store_true",
                    help="run only the multi-tenant overload scenario and "
                    "merge it into results/BENCH_serve_gnn.json")
    args = ap.parse_args()
    if args.tenants:
        run_tenants(full=args.full)
    else:
        run(full=args.full)
