"""Serving benchmark: QPS + latency percentiles of the GNN serving subsystem
(GraphStore -> CompiledGraphSession -> GNNServeEngine) on a stat-matched
synthetic Table-2 graph, for all three model families and both serve paths
(micro-batched k-hop subgraph vs. cached full-graph inference).

Queries arrive in waves (submit one micro-batch worth, then tick) so the
reported latency is end-to-end batch service time, not closed-loop queueing
over the whole run. Emits CSV rows like every other section plus a
``results/BENCH_serve_gnn.json`` summary — the start of the serving-side
perf trajectory (kernels are tracked by the other sections).
"""
from __future__ import annotations

import gc
import json
from pathlib import Path

import jax
import numpy as np

from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import (AdmissionController, CostEstimator, GNNServeEngine,
                         GraphStore, SLOPolicy, SLOTracker, TenantPolicy,
                         prometheus_text, spearman_rho)

from .common import csv_row

RESULTS = Path(__file__).resolve().parents[1] / "results"

# bump when the emitted JSON layout changes (compare_bench.py warns on
# cross-version diffs). v3: cost-model + SLO leaves (the ``slo`` section,
# ``cost_spearman_rho``). v4: the ``kernels`` section (fused-vs-unfused
# launch footprint + per-layer latency, multi-bucket dispatch reduction).
SCHEMA_VERSION = 4

FAMILY_INITS = {
    "gcn": gnn.init_gcn, "sage": gnn.init_sage, "saint": gnn.init_saint,
}
N_LAYERS = {"gcn": 2, "sage": 2, "saint": 3}


def _serve_wave(engine: GNNServeEngine, graph: str, model: str,
                nodes: np.ndarray, batch: int) -> None:
    for i in range(0, nodes.size, batch):
        engine.submit_many(graph, model, nodes[i:i + batch])
        engine.tick()
    engine.run_until_drained()


def _bench_mode(store: GraphStore, family: str, mode: str, n_queries: int,
                n_nodes: int, batch: int, seed: int = 0,
                pipeline_depth: int = 0) -> dict:
    engine = GNNServeEngine(store, max_batch=batch, mode=mode,
                            pipeline_depth=pipeline_depth)
    warm_compiles = engine.warmup("bench", family)
    c0 = engine.compile_count
    nodes = np.random.default_rng(seed).integers(0, n_nodes, size=n_queries)
    # a collector pass landing inside a sub-ms full-cache wave dominates its
    # p99, and WHICH wave it lands in shifts with the process's unrelated
    # allocation history — pause the collector for the measured waves (same
    # idiom as the SLO section's calibration loop)
    gc.collect()
    gc_was = gc.isenabled()
    gc.disable()
    try:
        _serve_wave(engine, "bench", family, nodes, batch)
    finally:
        if gc_was:
            gc.enable()
    snap = engine.snapshot()
    snap["warmup_compiles"] = warm_compiles
    snap["steady_state_compiles"] = engine.compile_count - c0
    engine.close()
    return snap


def _bench_tenants(store: GraphStore, family: str, n_nodes: int,
                   batch: int, n_good: int, seed: int = 0) -> dict:
    """Two-tenant overload scenario: ``hog`` submits 10x the well-behaved
    ``good`` tenant's volume against a rate limit + queue-depth bound, so
    most of its traffic comes back typed (shed at the depth bound while
    tokens remain, throttled once the bucket drains). Records the admission
    outcomes, the weighted fairness of what WAS admitted, and the good
    tenant's p99 against its own solo run — the acceptance gauge is
    ``good_p99_within_2x_solo``."""
    rng = np.random.default_rng(seed)
    good_nodes = rng.integers(0, n_nodes, size=n_good)
    policies = dict(
        good=TenantPolicy(weight=8),
        hog=TenantPolicy(rate_qps=5.0, burst=batch,
                         max_queue_depth=batch, weight=1),
    )

    def one_run(with_hog: bool) -> dict:
        engine = GNNServeEngine(
            store, max_batch=batch, mode="subgraph",
            admission=AdmissionController(policies=dict(policies)))
        engine.warmup("bench", family)
        for i in range(0, good_nodes.size, batch):
            engine.submit_many("bench", family, good_nodes[i:i + batch],
                               tenant="good")
            if with_hog:                 # 10x the good tenant's volume
                hog_nodes = rng.integers(0, n_nodes, size=10 * batch)
                engine.submit_many("bench", family, hog_nodes, tenant="hog")
            # two service slots per arrival wave: the engine has the
            # capacity to absorb the hog's ADMITTED trickle, so the good
            # tenant's p99 reflects scheduling, not an undersized server
            engine.tick()
            engine.tick()
        engine.run_until_drained()
        snap = engine.snapshot()
        engine.close()
        return snap

    solo = one_run(False)
    mixed = one_run(True)
    good, hog = mixed["tenants"]["good"], mixed["tenants"]["hog"]
    p99_solo = solo["tenants"]["good"]["latency"]["p99_ms"]
    p99_mixed = good["latency"]["p99_ms"]
    def _fin(v):                       # inf -> null (strict-JSON safe)
        return None if v is None or np.isinf(v) else v

    return dict(
        family=family,
        policy={t: dict(rate_qps=_fin(p.rate_qps),
                        burst=_fin(p.bucket_capacity),
                        weight=p.weight, max_queue_depth=p.max_queue_depth)
                for t, p in policies.items()},
        good_solo=solo["tenants"]["good"],
        good_mixed=good,
        hog_mixed=hog,
        hog_shed_rate=hog["shed_rate"],
        hog_reject_rate=hog["reject_rate"],
        fairness_served_ratio=(good["queries"] / max(hog["queries"], 1)),
        good_p99_solo_ms=p99_solo,
        good_p99_mixed_ms=p99_mixed,
        good_p99_ratio=p99_mixed / max(p99_solo, 1e-9),
        good_p99_within_2x_solo=bool(p99_mixed <= 2.0 * p99_solo),
    )


def _degree_bands(store: GraphStore, graph: str, n_bands: int = 4):
    """Node-id bands stratified by degree (ascending): the calibration
    stream serves degree-homogeneous waves so per-batch predicted cost
    actually VARIES — a uniformly random stream averages every batch to the
    same cost and leaves rank correlation nothing to rank."""
    csr = store.graphs[graph].csr
    degs = np.asarray(csr.indptr[1:]) - np.asarray(csr.indptr[:-1])
    order = np.argsort(degs, kind="stable")
    return np.array_split(order, n_bands)


def _replay_bit_exact(store: GraphStore, graph: str, family: str,
                      engine: GNNServeEngine) -> bool:
    """The batch_log oracle: replay the cost-aware engine's actual served
    batch compositions straight through the raw session — cost-weighted
    scheduling may REORDER service, but every answer must be bit-identical
    to the cost-unaware compute path."""
    sess = store.session(graph, family)
    for batch in engine.batch_log:
        seeds = np.asarray([q.node for q in batch], np.int64)
        prepared = sess.prepare_batch(seeds)
        logits = sess.finish_batch(prepared, sess.launch_batch(prepared))
        got = np.stack([q.logits for q in batch])
        if not np.array_equal(np.asarray(logits), got):
            return False
    return True


def _kernel_path_stats(store: GraphStore, family: str,
                       seeds: np.ndarray, repeats: int) -> tuple:
    """Serve one bucketed batch through ``store``'s kernel path and measure
    its launch footprint: the traced-program equation/pallas counts of the
    ACTUAL jitted forward (via ``ops.launch_stats`` on the staged operands),
    the fused trace-time kernel counter, and a best-of-``repeats`` forward
    latency. Returns (stats dict, logits) — logits so the caller can assert
    fused/unfused bitwise identity."""
    import time

    import jax.numpy as jnp

    from repro.kernels import fused_layer
    from repro.kernels import ops as kernel_ops

    sess = store.session("bench", family)
    fused_layer.reset_counters()
    logits = np.asarray(sess.serve_subgraph(seeds))       # warmup + trace
    fused_calls = fused_layer.KERNEL_CALLS["fused"]
    prepared = sess.prepare_batch(np.asarray(seeds, np.int64))
    g = prepared.groups[0]
    tr = kernel_ops.launch_stats(
        g.core._serve_one, jnp.asarray(g.staged.x_pad), prepared.bn,
        g.staged.adjs, jnp.asarray(g.staged.pos_pad))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        np.asarray(sess.serve_subgraph(seeds))
        best = min(best, time.perf_counter() - t0)
    n_layers = N_LAYERS[family]
    stats = dict(
        plan=sess.plan.name(),
        pallas_launches=tr["pallas_calls"],
        traced_ops=tr["eqns"],
        ops_per_layer=tr["eqns"] / n_layers,
        fused_kernel_calls=fused_calls,
        latency_ms=best * 1e3,
        layer_latency_ms=best * 1e3 / n_layers,
    )
    return stats, logits


def _bench_kernels(d, batch: int, hidden: int, repeats: int = 3) -> dict:
    """Fused-vs-unfused kernel path comparison, per family: the traced
    launch footprint (the fused path collapses each layer's whole op chain
    into ONE ``pallas_call``), per-layer latency both ways, and the bitwise
    identity of their outputs. Forces the kernels on (CPU runs them in
    interpret mode — latency here tracks regressions of the kernel path
    itself, the launch counts are backend-independent trace facts)."""
    from repro.kernels import ops as kernel_ops

    seeds = np.random.default_rng(7).integers(0, d.n_nodes, size=batch)
    out: dict = dict(note="interpret-mode kernels (CPU); launch counts are "
                          "trace-time facts, latencies gate the kernel "
                          "path's own regressions", families={})
    kernel_ops.force_kernels(True)
    try:
        for fam in FAMILY_INITS:
            per: dict = {}
            logits = {}
            for tag, fused in (("unfused", False), ("fused", True)):
                store = GraphStore(max_batch=batch, use_pallas=True,
                                   fused=fused)
                store.register_graph("bench", d)
                store.register_model(
                    fam, fam, FAMILY_INITS[fam](jax.random.PRNGKey(0),
                                                d.x.shape[1], hidden,
                                                d.n_classes))
                per[tag], logits[tag] = _kernel_path_stats(
                    store, fam, seeds, repeats)
            n_layers = N_LAYERS[fam]
            per["n_layers"] = n_layers
            per["launches_per_layer_fused"] = (
                per["fused"]["fused_kernel_calls"] / n_layers)
            per["op_reduction"] = (per["unfused"]["ops_per_layer"]
                                   / max(per["fused"]["ops_per_layer"], 1e-9))
            per["bit_exact"] = bool(
                np.array_equal(logits["fused"], logits["unfused"]))
            out["families"][fam] = per
    finally:
        kernel_ops.force_kernels(False)
    return out


def _bench_multi_bucket(store: GraphStore, family: str, n_nodes: int,
                        batch: int, n_queries: int, depth: int = 3,
                        seed: int = 2) -> dict:
    """Serial vs multi-bucket co-launch on the identical query stream: the
    coalesced engine serves several padded buckets per pipeline tick as ONE
    device dispatch (``ServeCore.launch_many``), so its dispatch count
    drops below one-per-batch while every answer stays bit-identical to
    the serial path (the replayed ``batch_log`` oracle)."""
    nodes = np.random.default_rng(seed).integers(0, n_nodes, size=n_queries)

    def one(multi: bool, measured: bool = True) -> tuple:
        if measured:
            # warm pass on a throwaway engine: the co-launch compositions'
            # ``_serve_many`` traces live on the store's ServeCores, so the
            # measured pass below runs pure steady state for BOTH paths
            one(multi, measured=False)
        engine = GNNServeEngine(store, max_batch=batch, mode="subgraph",
                                pipeline_depth=depth, multi_bucket=multi)
        engine.warmup("bench", family)
        # the store's sessions (and their dispatch counters) outlive each
        # engine — count only THIS run's steady-state dispatches
        d0 = engine.dispatch_count
        engine.submit_many("bench", family, nodes)
        engine.run_until_drained()
        snap = engine.snapshot()
        n_batches = len(engine.batch_log)
        disp = engine.dispatch_count - d0
        replay = (_replay_bit_exact(store, "bench", family, engine)
                  if measured else True)
        engine.close()
        return snap, disp, n_batches, replay

    s_snap, s_disp, s_nb, s_ok = one(False)
    m_snap, m_disp, m_nb, m_ok = one(True)
    return dict(
        family=family, pipeline_depth=depth,
        n_batches_serial=s_nb, n_batches_multi=m_nb,
        serial_dispatches=s_disp, coalesced_dispatches=m_disp,
        dispatch_reduction=s_disp / max(m_disp, 1),
        qps_serial=s_snap["qps"], qps_multi=m_snap["qps"],
        replay_bit_exact=bool(s_ok and m_ok),
    )


def _kernels_rows(section: dict, suffix: str = "") -> None:
    """THE csv emitters of the kernels section (shared by ``run()`` and
    ``--kernels``)."""
    for fam, per in section["families"].items():
        csv_row(f"serve_gnn/kernels/{fam}",
                per["fused"]["latency_ms"] * 1e3,
                f"ops_per_layer_unfused={per['unfused']['ops_per_layer']:.1f};"
                f"ops_per_layer_fused={per['fused']['ops_per_layer']:.1f};"
                f"launches_per_layer_fused="
                f"{per['launches_per_layer_fused']:.2f};"
                f"op_reduction={per['op_reduction']:.1f}x;"
                f"layer_ms_unfused={per['unfused']['layer_latency_ms']:.2f};"
                f"layer_ms_fused={per['fused']['layer_latency_ms']:.2f};"
                f"bit_exact={per['bit_exact']}")
    mb = section["multi_bucket"]
    csv_row("serve_gnn/kernels/multi_bucket", 0.0,
            f"batches={mb['n_batches_multi']};"
            f"serial_dispatches={mb['serial_dispatches']};"
            f"coalesced_dispatches={mb['coalesced_dispatches']};"
            f"dispatch_reduction={mb['dispatch_reduction']:.2f}x;"
            f"replay_bit_exact={mb['replay_bit_exact']}"
            f"{suffix}")


def _bench_slo(store: GraphStore, family: str, n_nodes: int, batch: int,
               n_good: int, seed: int = 0) -> dict:
    """Closed-loop cost/SLO scenario, two parts.

    **Calibration**: a single-tenant serial engine serves a graded cost
    sweep — a leaf anchor plus hub-band batches in pow2 sizes up to a full
    whale batch — so predicted per-batch units and measured service seconds
    both spread. Each fixed composition is served ``reps`` times
    (interleaved) and the gate ranks per-composition BEST-OF times (min,
    like ``timeit`` — scheduler/GC spikes only ever add time) so host
    timing noise can't shuffle adjacent ranks; the raw every-batch rho
    stays as ``rho_raw``. The gate is the Spearman rank correlation of
    the best-of times (``cost_spearman_rho``).

    **Overload**: tenant ``hub`` submits hub-band nodes at a QPS it is
    nominally ALLOWED — but its predicted cost-unit flow exceeds its
    ``cost_rate`` budget, so admission throttles it on cost
    (``hub_cost_throttled``). Its rejections burn its error budget, the
    multi-window burn alert fires into the span tracer (and the Prometheus
    export), and the SLO autotuner shrinks its effective queue depth. The
    well-behaved ``good`` tenant's p99 must stay within 2x its solo run,
    and the replayed ``batch_log`` oracle must stay bit-exact."""
    rng = np.random.default_rng(seed)
    bands = _degree_bands(store, "bench")
    csr = store.graphs["bench"].csr

    # --- calibration: a graded cost sweep through a costed engine --------
    cal_cost = CostEstimator()
    engine = GNNServeEngine(store, max_batch=batch, mode="subgraph")
    engine.warmup("bench", family)
    leaf_band, hub_band = bands[0], bands[-1]
    comps = [rng.choice(leaf_band, size=min(2, leaf_band.size),
                        replace=False).astype(np.int64)]
    s = 2
    while s <= batch:
        comps.append(rng.choice(hub_band, size=min(s, hub_band.size),
                                replace=False).astype(np.int64))
        s *= 2
    # prime the bucket high-water with one whale batch, then two throwaway
    # cycles (estimator detached) so steady-state timing is what gets ranked
    engine.submit_many("bench", family,
                       rng.choice(hub_band, size=min(batch, hub_band.size),
                                  replace=False))
    engine.tick()
    for _ in range(2):
        for nodes in comps:
            engine.submit_many("bench", family, nodes)
            engine.tick()
    engine.run_until_drained()
    engine.cost = cal_cost
    reps = 9
    gc_was = gc.isenabled()
    gc.disable()
    try:
        for _ in range(reps):
            for nodes in comps:
                engine.submit_many("bench", family, nodes)
                engine.tick()
        engine.run_until_drained()
    finally:
        if gc_was:
            gc.enable()
    pred, meas = cal_cost.predicted_vs_measured()
    # best-of-reps (min, like timeit): scheduler/GC spikes only ever ADD
    # time, so the fastest rep is the faithful per-composition cost
    pred_med = np.median(pred.reshape(reps, len(comps)), axis=0)
    meas_med = np.min(meas.reshape(reps, len(comps)), axis=0)
    rho = spearman_rho(pred_med, meas_med)
    raw = cal_cost.rank_correlation()
    calibration = dict(batches_observed=cal_cost.batches_observed,
                       compositions=[int(c.size) for c in comps],
                       reps=reps,
                       rho=(None if rho != rho else float(rho)),
                       rho_raw=(None if raw != raw else float(raw)),
                       estimator=cal_cost.snapshot())
    engine.close()

    # --- overload: cost-budgeted hub tenant vs a well-behaved tenant ----
    hub_band = bands[-1]
    probe = CostEstimator()
    hub_units = float(np.mean([
        probe.estimate("bench", n, csr, khop=2).units
        for n in rng.choice(hub_band, size=min(16, hub_band.size),
                            replace=False)]))
    good_nodes = rng.integers(0, n_nodes, size=n_good)
    policies = dict(
        good=TenantPolicy(weight=8),
        # generous QPS (never binds) — the COST budget is what holds:
        # ~3 hub-scale queries of burst, ~3 hub queries/s sustained
        hub=TenantPolicy(rate_qps=500.0, burst=500,
                         max_queue_depth=2 * batch, weight=1,
                         cost_rate=3.0 * hub_units,
                         cost_burst=3.0 * hub_units),
    )
    slo_policies = dict(
        hub=SLOPolicy(availability=0.99, window_s=4.0, short_window_s=0.5,
                      burn_alert=2.0),
        good=SLOPolicy(availability=0.999, window_s=4.0),
    )

    def one_run(with_hub: bool) -> tuple:
        eng = GNNServeEngine(
            store, max_batch=batch, mode="subgraph",
            admission=AdmissionController(policies=dict(policies)),
            cost=CostEstimator(),
            slo=SLOTracker(dict(slo_policies)))
        eng.warmup("bench", family)
        for i in range(0, good_nodes.size, batch):
            eng.submit_many("bench", family, good_nodes[i:i + batch],
                            tenant="good")
            if with_hub:          # hub-band whales, 2x the good volume
                hub_nodes = rng.choice(hub_band,
                                       size=min(2 * batch, hub_band.size),
                                       replace=False)
                eng.submit_many("bench", family, hub_nodes, tenant="hub")
            # three service slots per arrival wave: capacity for the good
            # batch plus the hub's cost-admitted trickle, so good-tenant
            # p99 reflects scheduling rather than an undersized server
            eng.tick()
            eng.tick()
            eng.tick()
        eng.run_until_drained()
        snap = eng.snapshot()
        return eng, snap

    solo_eng, solo = one_run(False)
    solo_eng.close()
    eng, mixed = one_run(True)
    good, hub = mixed["tenants"]["good"], mixed["tenants"]["hub"]
    p99_solo = solo["tenants"]["good"]["latency"]["p99_ms"]
    p99_mixed = good["latency"]["p99_ms"]
    slo_hub = mixed["slo"]["tenants"]["hub"]
    burn_warnings = [w for w in eng.tracer.warning_events()
                     if w.name == "slo_burn"]
    prom = prometheus_text(mixed, eng.tracer)
    replay_ok = _replay_bit_exact(store, "bench", family, eng)
    eng.close()

    return dict(
        family=family,
        cost_spearman_rho=calibration["rho"],
        calibration=calibration,
        policy=dict(hub_cost_rate=policies["hub"].cost_rate,
                    hub_probe_units=hub_units),
        good_solo=solo["tenants"]["good"],
        good_mixed=good,
        hub_mixed=hub,
        hub_cost_throttled=hub["cost_throttled"],
        hub_held_to_cost_budget=bool(hub["cost_throttled"] > 0),
        hub_slo=slo_hub,
        burn_alerts_fired=len(burn_warnings),
        burn_alert_in_trace=bool(burn_warnings),
        burn_alert_in_prometheus=(
            'serve_slo_alerts_total{tenant="hub"}' in prom
            and slo_hub["alerts"] > 0),
        depth_autotuned=bool(slo_hub["depth_shrinks"] > 0),
        good_p99_solo_ms=p99_solo,
        good_p99_mixed_ms=p99_mixed,
        good_p99_ratio=p99_mixed / max(p99_solo, 1e-9),
        good_p99_within_2x_solo=bool(p99_mixed <= 2.0 * p99_solo),
        replay_bit_exact=replay_ok,
    )


def _slo_row(section: dict, suffix: str = "") -> None:
    """THE csv emitter of the slo section (shared by ``run()`` and
    ``--slo``)."""
    rho = section["cost_spearman_rho"]
    csv_row("serve_gnn/slo",
            section["good_p99_mixed_ms"] * 1e3,
            f"rho={-1.0 if rho is None else rho:.3f};"
            f"hub_cost_throttled={section['hub_cost_throttled']};"
            f"burn_alerts={section['burn_alerts_fired']};"
            f"depth_autotuned={section['depth_autotuned']};"
            f"p99_ratio={section['good_p99_ratio']:.2f};"
            f"within_2x={section['good_p99_within_2x_solo']};"
            f"replay_bit_exact={section['replay_bit_exact']}"
            f"{suffix}")


def _tenants_row(section: dict, suffix: str = "") -> None:
    """THE csv emitter of the tenants section — shared by ``run()`` and the
    standalone ``--tenants`` entry so the row never drifts between them."""
    csv_row("serve_gnn/tenants",
            section["good_p99_mixed_ms"] * 1e3,
            f"good_p99_solo_ms={section['good_p99_solo_ms']:.2f};"
            f"good_p99_mixed_ms={section['good_p99_mixed_ms']:.2f};"
            f"p99_ratio={section['good_p99_ratio']:.2f};"
            f"within_2x={section['good_p99_within_2x_solo']};"
            f"hog_reject_rate={section['hog_reject_rate']:.2f};"
            f"hog_shed_rate={section['hog_shed_rate']:.2f};"
            f"hog_accepted={section['hog_mixed']['accepted']}"
            f"{suffix}")


def _merge_results(section: str, payload: dict) -> Path:
    """Write ``payload`` under ``section`` of BENCH_serve_gnn.json, keeping
    whatever other sections a previous (possibly fuller) run recorded."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_serve_gnn.json"
    summary = json.loads(out.read_text()) if out.exists() else {}
    summary.setdefault("schema_version", SCHEMA_VERSION)
    summary[section] = payload
    out.write_text(json.dumps(summary, indent=2))
    return out


def run_slo(full: bool = False) -> dict:
    """Standalone ``--slo`` entry: cost calibration + the cost-budget/SLO
    overload scenario only, merged into the existing results JSON."""
    jax.config.update("jax_platform_name", "cpu")
    scale = 1.0 if full else 0.15
    batch = 32 if full else 16
    hidden = 64 if full else 32
    n_good = 320 if full else 96

    d = make_dataset("cora", seed=0, scale=scale)
    store = GraphStore(max_batch=batch)
    store.register_graph("bench", d)
    store.register_model("gcn", "gcn",
                         gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1],
                                      hidden, d.n_classes))
    section = _bench_slo(store, "gcn", d.n_nodes, batch, n_good)
    out = _merge_results("slo", section)
    _slo_row(section, suffix=f";wrote={out}")
    return section


def run_tenants(full: bool = False) -> dict:
    """Standalone ``--tenants`` entry: the overload scenario only, merged
    into the existing results JSON."""
    jax.config.update("jax_platform_name", "cpu")
    scale = 1.0 if full else 0.15
    batch = 32 if full else 16
    hidden = 64 if full else 32
    n_good = 320 if full else 96

    d = make_dataset("cora", seed=0, scale=scale)
    store = GraphStore(max_batch=batch)
    store.register_graph("bench", d)
    store.register_model("gcn", "gcn",
                         gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1],
                                      hidden, d.n_classes))
    section = _bench_tenants(store, "gcn", d.n_nodes, batch, n_good)
    out = _merge_results("tenants", section)
    _tenants_row(section, suffix=f";wrote={out}")
    return section


def run_kernels(full: bool = False) -> dict:
    """Standalone ``--kernels`` entry: the fused-vs-unfused launch footprint
    and the multi-bucket co-launch comparison only, merged into the
    existing results JSON."""
    jax.config.update("jax_platform_name", "cpu")
    scale = 1.0 if full else 0.15
    batch = 32 if full else 16
    hidden = 64 if full else 32

    d = make_dataset("cora", seed=0, scale=scale)
    section = _bench_kernels(d, batch, hidden)
    store = GraphStore(max_batch=batch)
    store.register_graph("bench", d)
    store.register_model("gcn", "gcn",
                         gnn.init_gcn(jax.random.PRNGKey(0), d.x.shape[1],
                                      hidden, d.n_classes))
    section["multi_bucket"] = _bench_multi_bucket(
        store, "gcn", d.n_nodes, batch, n_queries=6 * batch)
    out = _merge_results("kernels", section)
    _kernels_rows(section, suffix=f";wrote={out}")
    return section


def run(full: bool = False) -> dict:
    jax.config.update("jax_platform_name", "cpu")
    scale = 1.0 if full else 0.15
    n_queries = 1000 if full else 200
    batch = 32 if full else 16
    hidden = 64 if full else 32

    d = make_dataset("cora", seed=0, scale=scale)
    store = GraphStore(max_batch=batch)
    store.register_graph("bench", d)
    key = jax.random.PRNGKey(0)
    for fam, init in FAMILY_INITS.items():
        store.register_model(fam, fam, init(key, d.x.shape[1], hidden,
                                            d.n_classes))

    summary: dict = dict(schema_version=SCHEMA_VERSION, dataset="cora",
                         scale=scale, n_nodes=d.n_nodes,
                         n_edges=d.n_edges, n_queries=n_queries,
                         batch=batch, families={})
    for fam in FAMILY_INITS:
        sess = store.session("bench", fam, tune=(fam == "gcn"),
                             tune_repeats=1)
        fam_out = dict(plan=sess.plan.name(),
                       tuned_latency_ms=sess.plan.tuned_latency_s * 1e3)
        for mode in ("subgraph", "full"):
            snap = _bench_mode(store, fam, mode, n_queries, d.n_nodes, batch)
            fam_out[mode] = snap
            lat = snap["latency"]
            csv_row(f"serve_gnn/{fam}/{mode}",
                    1e6 / max(snap["qps"], 1e-9),
                    f"qps={snap['qps']:.1f};p50_ms={lat['p50_ms']:.2f};"
                    f"p99_ms={lat['p99_ms']:.2f};"
                    f"hit_rate={snap['cache_hit_rate']:.2f};"
                    f"steady_compiles={snap['steady_state_compiles']}")
        # the pipelined subgraph loop: extraction of batch i+1 overlaps the
        # in-flight forward of batch i (bit-exact vs the serial rows above)
        snap = _bench_mode(store, fam, "subgraph", n_queries, d.n_nodes,
                           batch, pipeline_depth=2)
        fam_out["subgraph_pipelined"] = snap
        bd = snap["batch_breakdown"]
        csv_row(f"serve_gnn/{fam}/subgraph_pipelined",
                1e6 / max(snap["qps"], 1e-9),
                f"qps={snap['qps']:.1f};"
                f"overlap={snap['overlap_ratio']:.2f};"
                f"extract_p50_ms={bd['extract']['p50_ms']:.2f};"
                f"compute_p50_ms={bd['compute']['p50_ms']:.2f};"
                f"steady_compiles={snap['steady_state_compiles']}")
        summary["families"][fam] = fam_out

    # the multi-tenant overload scenario (fairness + shed-rate + the good
    # tenant's p99-vs-solo acceptance gauge)
    summary["tenants"] = _bench_tenants(
        store, "gcn", d.n_nodes, batch,
        n_good=(320 if full else 96))
    _tenants_row(summary["tenants"])

    # cost calibration + the cost-budget/SLO closed-loop overload scenario
    summary["slo"] = _bench_slo(store, "gcn", d.n_nodes, batch,
                                n_good=(320 if full else 96))
    _slo_row(summary["slo"])

    # fused-vs-unfused kernel launch footprint + multi-bucket co-launch
    summary["kernels"] = _bench_kernels(d, batch, hidden)
    summary["kernels"]["multi_bucket"] = _bench_multi_bucket(
        store, "gcn", d.n_nodes, batch, n_queries=6 * batch)
    _kernels_rows(summary["kernels"])

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_serve_gnn.json"
    out.write_text(json.dumps(summary, indent=2))
    csv_row("serve_gnn/summary", 0.0, f"wrote={out}")
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tenants", action="store_true",
                    help="run only the multi-tenant overload scenario and "
                    "merge it into results/BENCH_serve_gnn.json")
    ap.add_argument("--slo", action="store_true",
                    help="run only the cost/SLO closed-loop scenario and "
                    "merge it into results/BENCH_serve_gnn.json")
    ap.add_argument("--kernels", action="store_true",
                    help="run only the fused-vs-unfused launch footprint + "
                    "multi-bucket co-launch comparison and merge it into "
                    "results/BENCH_serve_gnn.json")
    args = ap.parse_args()
    if args.tenants:
        run_tenants(full=args.full)
    elif args.slo:
        run_slo(full=args.full)
    elif args.kernels:
        run_kernels(full=args.full)
    else:
        run(full=args.full)
