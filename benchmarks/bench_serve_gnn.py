"""Serving benchmark: QPS + latency percentiles of the GNN serving subsystem
(GraphStore -> CompiledGraphSession -> GNNServeEngine) on a stat-matched
synthetic Table-2 graph, for all three model families and both serve paths
(micro-batched k-hop subgraph vs. cached full-graph inference).

Queries arrive in waves (submit one micro-batch worth, then tick) so the
reported latency is end-to-end batch service time, not closed-loop queueing
over the whole run. Emits CSV rows like every other section plus a
``results/BENCH_serve_gnn.json`` summary — the start of the serving-side
perf trajectory (kernels are tracked by the other sections).
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from repro.graphs.datasets import make_dataset
from repro.models import gnn
from repro.serve import GNNServeEngine, GraphStore

from .common import csv_row

RESULTS = Path(__file__).resolve().parents[1] / "results"

FAMILY_INITS = {
    "gcn": gnn.init_gcn, "sage": gnn.init_sage, "saint": gnn.init_saint,
}


def _serve_wave(engine: GNNServeEngine, graph: str, model: str,
                nodes: np.ndarray, batch: int) -> None:
    for i in range(0, nodes.size, batch):
        engine.submit_many(graph, model, nodes[i:i + batch])
        engine.tick()
    engine.run_until_drained()


def _bench_mode(store: GraphStore, family: str, mode: str, n_queries: int,
                n_nodes: int, batch: int, seed: int = 0,
                pipeline_depth: int = 0) -> dict:
    engine = GNNServeEngine(store, max_batch=batch, mode=mode,
                            pipeline_depth=pipeline_depth)
    warm_compiles = engine.warmup("bench", family)
    c0 = engine.compile_count
    nodes = np.random.default_rng(seed).integers(0, n_nodes, size=n_queries)
    _serve_wave(engine, "bench", family, nodes, batch)
    snap = engine.snapshot()
    snap["warmup_compiles"] = warm_compiles
    snap["steady_state_compiles"] = engine.compile_count - c0
    engine.close()
    return snap


def run(full: bool = False) -> dict:
    jax.config.update("jax_platform_name", "cpu")
    scale = 1.0 if full else 0.15
    n_queries = 1000 if full else 200
    batch = 32 if full else 16
    hidden = 64 if full else 32

    d = make_dataset("cora", seed=0, scale=scale)
    store = GraphStore(max_batch=batch)
    store.register_graph("bench", d)
    key = jax.random.PRNGKey(0)
    for fam, init in FAMILY_INITS.items():
        store.register_model(fam, fam, init(key, d.x.shape[1], hidden,
                                            d.n_classes))

    summary: dict = dict(dataset="cora", scale=scale, n_nodes=d.n_nodes,
                         n_edges=d.n_edges, n_queries=n_queries,
                         batch=batch, families={})
    for fam in FAMILY_INITS:
        sess = store.session("bench", fam, tune=(fam == "gcn"),
                             tune_repeats=1)
        fam_out = dict(plan=sess.plan.name(),
                       tuned_latency_ms=sess.plan.tuned_latency_s * 1e3)
        for mode in ("subgraph", "full"):
            snap = _bench_mode(store, fam, mode, n_queries, d.n_nodes, batch)
            fam_out[mode] = snap
            lat = snap["latency"]
            csv_row(f"serve_gnn/{fam}/{mode}",
                    1e6 / max(snap["qps"], 1e-9),
                    f"qps={snap['qps']:.1f};p50_ms={lat['p50_ms']:.2f};"
                    f"p99_ms={lat['p99_ms']:.2f};"
                    f"hit_rate={snap['cache_hit_rate']:.2f};"
                    f"steady_compiles={snap['steady_state_compiles']}")
        # the pipelined subgraph loop: extraction of batch i+1 overlaps the
        # in-flight forward of batch i (bit-exact vs the serial rows above)
        snap = _bench_mode(store, fam, "subgraph", n_queries, d.n_nodes,
                           batch, pipeline_depth=2)
        fam_out["subgraph_pipelined"] = snap
        bd = snap["batch_breakdown"]
        csv_row(f"serve_gnn/{fam}/subgraph_pipelined",
                1e6 / max(snap["qps"], 1e-9),
                f"qps={snap['qps']:.1f};"
                f"overlap={snap['overlap_ratio']:.2f};"
                f"extract_p50_ms={bd['extract']['p50_ms']:.2f};"
                f"compute_p50_ms={bd['compute']['p50_ms']:.2f};"
                f"steady_compiles={snap['steady_state_compiles']}")
        summary["families"][fam] = fam_out

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_serve_gnn.json"
    out.write_text(json.dumps(summary, indent=2))
    csv_row("serve_gnn/summary", 0.0, f"wrote={out}")
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(full=ap.parse_args().full)
