#!/usr/bin/env python
"""Perf-regression gate over two ``results/BENCH_*.json`` files.

Walks baseline and current JSON jointly and compares every metric leaf it
knows about under tolerance bands:

  * **higher-is-better** — ``qps`` / ``qps_pipelined`` / ``qps_fifo_serial``
    / ``halo_bytes_saved_measured`` / ``overlap_ratio`` /
    ``cost_spearman_rho`` (cost-model calibration drift) /
    ``op_reduction`` (the fused kernels' traced-op collapse) /
    ``dispatch_reduction`` (multi-bucket co-launch): a drop beyond the
    warn band is a warning, beyond the hard band a failure.
  * **lower-is-better** — ``p50_ms`` / ``p99_ms`` / ``ttft_p50_ms`` /
    ``ttft_p99_ms`` (token serving time-to-first-token) / ``halo_bytes`` /
    ``serve_x_bytes_halo_aware`` / ``ops_per_layer`` /
    ``layer_latency_ms``: a growth beyond the bands likewise.
  * **zero-tolerance** — ``steady_state_compiles`` (the
    zero-steady-state-recompiles invariant) and
    ``launches_per_layer_fused`` (a fused layer IS one Pallas launch):
    any INCREASE over the baseline is an immediate failure; no band
    applies.

Default bands: warn at >= 1.3x, hard-fail at >= 2.0x (``--warn-ratio`` /
``--hard-ratio``; ``--strict`` promotes warnings to failures). Exit code 0
when nothing regressed beyond the hard band, 1 otherwise — wire it into CI
right after regenerating a bench result:

    python benchmarks/compare_bench.py results/BENCH_serve_gnn.json \
        /tmp/BENCH_serve_gnn.json

Timing leaves on smoke-scale runs are noisy, so microscopic baselines are
skipped (latency < 0.05 ms, qps <= 0, overlap < 0.1, byte counts < 4096) —
the gate targets order-of-magnitude regressions (a hidden recompile, a lost
overlap, a halo blowup), not scheduler jitter. Per-stage ``batch_breakdown``
latencies are worst-of-a-handful-of-batches statistics at smoke scale and
swing several-x between identical runs, so they get a higher floor (5 ms)
than the end-to-end query percentiles. A ``schema_version``
mismatch between the two files is reported as a warning, never a failure.

A MISSING or unreadable baseline is a warning and exit 0 (first run of a
new bench has nothing to gate against); a missing current file is a plain
failure message and exit 1 — neither ever tracebacks.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Tuple

HIGHER_BETTER = {"qps", "qps_pipelined", "qps_fifo_serial",
                 "halo_bytes_saved_measured", "overlap_ratio",
                 "cost_spearman_rho", "op_reduction", "dispatch_reduction",
                 "availability"}
LOWER_BETTER = {"p50_ms", "p99_ms", "ttft_p50_ms", "ttft_p99_ms",
                "halo_bytes", "serve_x_bytes_halo_aware",
                "ops_per_layer", "layer_latency_ms"}
ZERO_TOLERANCE = {"steady_state_compiles", "launches_per_layer_fused",
                  "dropped_queries"}

# baseline floors below which a leaf is too noisy to gate on
MIN_LATENCY_MS = 0.05
MIN_STAGE_LATENCY_MS = 5.0
MIN_OVERLAP = 0.1
MIN_BYTES = 4096
MIN_RHO = 0.5


def _comparable(key: str, base: float, path: str = "") -> bool:
    if key == "layer_latency_ms":
        return base >= MIN_LATENCY_MS
    if key in ("p50_ms", "p99_ms", "ttft_p50_ms", "ttft_p99_ms"):
        # per-stage breakdowns are max-of-a-handful-of-batches at smoke
        # scale — only gate them once they are macroscopic
        if "batch_breakdown" in path:
            return base >= MIN_STAGE_LATENCY_MS
        return base >= MIN_LATENCY_MS
    if key.startswith("qps"):
        return base > 0
    if key == "overlap_ratio":
        return base >= MIN_OVERLAP
    if key == "cost_spearman_rho":
        return base >= MIN_RHO
    if key in ("halo_bytes", "serve_x_bytes_halo_aware",
               "halo_bytes_saved_measured"):
        return base >= MIN_BYTES
    return True


def compare(baseline: dict, current: dict, warn_ratio: float = 1.3,
            hard_ratio: float = 2.0
            ) -> Tuple[List[str], List[str], List[str]]:
    """Joint walk; returns (failures, warnings, notes)."""
    failures: List[str] = []
    warnings: List[str] = []
    notes: List[str] = []

    bv = baseline.get("schema_version")
    cv = current.get("schema_version")
    if bv != cv:
        warnings.append(f"schema_version mismatch: baseline={bv} "
                        f"current={cv} (comparing anyway)")

    def walk(b, c, path: str) -> None:
        if isinstance(b, dict) and isinstance(c, dict):
            for k in b:
                if k in c:
                    walk(b[k], c[k], f"{path}/{k}")
                elif k in HIGHER_BETTER | LOWER_BETTER | ZERO_TOLERANCE:
                    notes.append(f"{path}/{k}: missing from current")
            return
        key = path.rsplit("/", 1)[-1]
        if not isinstance(b, (int, float)) or not isinstance(c, (int, float)) \
                or isinstance(b, bool) or isinstance(c, bool):
            return
        if key in ZERO_TOLERANCE:
            if c > b:
                failures.append(f"{path}: {key} increased {b:g} -> {c:g} "
                                f"(zero-tolerance)")
            return
        if key in HIGHER_BETTER:
            if not _comparable(key, float(b), path):
                return
            if c <= 0:
                failures.append(f"{path}: dropped to {c:g} from {b:g}")
                return
            ratio = float(b) / float(c)          # >1 means current is worse
        elif key in LOWER_BETTER:
            if not _comparable(key, float(b), path):
                return
            if b <= 0:
                return
            ratio = float(c) / float(b)
        else:
            return
        if ratio >= hard_ratio:
            failures.append(f"{path}: {b:g} -> {c:g} "
                            f"({ratio:.2f}x worse, hard band {hard_ratio}x)")
        elif ratio >= warn_ratio:
            warnings.append(f"{path}: {b:g} -> {c:g} "
                            f"({ratio:.2f}x worse, warn band {warn_ratio}x)")

    walk(baseline, current, "")
    return failures, warnings, notes


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff two BENCH_*.json files under tolerance bands; "
                    "exit 1 on regression beyond the hard band.")
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    ap.add_argument("current", help="freshly generated BENCH_*.json")
    ap.add_argument("--warn-ratio", type=float, default=1.3,
                    help="warn when a metric is >= this factor worse "
                         "(default 1.3)")
    ap.add_argument("--hard-ratio", type=float, default=2.0,
                    help="fail when a metric is >= this factor worse "
                         "(default 2.0)")
    ap.add_argument("--strict", action="store_true",
                    help="promote warnings to failures")
    args = ap.parse_args(argv)
    if args.warn_ratio > args.hard_ratio:
        ap.error(f"--warn-ratio {args.warn_ratio} exceeds "
                 f"--hard-ratio {args.hard_ratio}")

    def _load(path: str):
        try:
            with open(path) as f:
                return json.load(f)
        except (OSError, ValueError) as e:
            return e

    baseline = _load(args.baseline)
    if isinstance(baseline, Exception):
        print(f"WARN  baseline {args.baseline} unavailable ({baseline}) — "
              f"first run of a new bench has nothing to gate against")
        print(f"OK: 0 failure(s), 1 warning(s) "
              f"[no baseline vs {args.current}]")
        return 0
    current = _load(args.current)
    if isinstance(current, Exception):
        print(f"FAIL  current {args.current} unavailable ({current})")
        print(f"REGRESSED: 1 failure(s), 0 warning(s) "
              f"[{args.baseline} vs missing current]")
        return 1
    failures, warnings, notes = compare(
        baseline, current, warn_ratio=args.warn_ratio,
        hard_ratio=args.hard_ratio)
    if args.strict:
        failures, warnings = failures + warnings, []

    for msg in notes:
        print(f"NOTE  {msg}")
    for msg in warnings:
        print(f"WARN  {msg}")
    for msg in failures:
        print(f"FAIL  {msg}")
    verdict = "REGRESSED" if failures else "OK"
    print(f"{verdict}: {len(failures)} failure(s), {len(warnings)} "
          f"warning(s) [{args.baseline} vs {args.current}]")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
