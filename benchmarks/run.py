"""Benchmark harness entry point: one section per paper table/figure.

``python -m benchmarks.run [--full]`` prints ``name,us_per_call,derived``
CSV. --full uses paper-scale datasets (slow on CPU); default is scaled."""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--xla-tuned", action="store_true",
                    help="set the XLA latency-hiding/async-collective flags "
                         "before backend init (no-op if XLA_FLAGS is set)")
    args = ap.parse_args()

    if args.xla_tuned:
        # must run before the section imports below pull in jax — XLA only
        # reads the flags at backend init
        from repro.env import xla_tuned
        xla_tuned()

    from . import (bench_fig4, bench_gnn_tables, bench_grad_compress,
                   bench_memory, bench_replica, bench_serve_gnn,
                   bench_serve_llm, bench_sharded_serve)
    sections = [
        ("gnn_tables", bench_gnn_tables.run),     # Tables 3, 4, 5
        ("memory", bench_memory.run),             # Peak-Mem columns
        ("fig4", bench_fig4.run),                 # kernel profile proxy
        ("grad_compress", bench_grad_compress.run),
        ("serve_gnn", bench_serve_gnn.run),       # serving QPS/latency
        ("serve_llm", bench_serve_llm.run),       # token serving tier
        ("sharded_serve", bench_sharded_serve.run),  # partitioned serving
        ("replica", bench_replica.run),           # fault-tolerant tier
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        try:
            fn(full=args.full)
        except Exception:
            failures += 1
            traceback.print_exc()
    # roofline summary (reads results/dryrun if present)
    try:
        from . import roofline
        rows = roofline.load_all("single")
        for r in rows:
            rec = r["rec"]
            print(f"roofline/{rec['arch']}/{rec['shape']},0.0,"
                  f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f}")
    except Exception:
        traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
