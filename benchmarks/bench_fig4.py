"""Paper Figure 4 proxy: BSpMM kernel profile vs the dense/tensor baseline.

Without NSight on this box, we report the structural counters that DRIVE the
paper's profile deltas: bytes moved per edge, words touched per output, and
popcount-op counts — plus wall time of the jnp word-level path and the Pallas
kernel (interpret mode; the kernel is the TPU artifact)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops, frdc
from repro.core.binarize import BinTensor
from repro.core.bspmm import bspmm
from repro.kernels import bspmm_kernel

from .common import csv_row, time_fn


def _pair(n, density, f, seed):
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < density).astype(np.float32)
    act = rng.choice([-1.0, 1.0], size=(n, f)).astype(np.float32)
    return a, act


def run(full: bool = False) -> None:
    cases = [("matpair_sparse", 512 if not full else 4096, 0.01, 128),
             ("matpair_denser", 256 if not full else 2048, 0.08, 128)]
    for name, n, density, f in cases:
        a, act = _pair(n, density, f, seed=1)
        adj = frdc.from_dense(a)
        st = frdc.stats(adj)
        xp = bitops.pack_bits(act > 0)
        xt = BinTensor(packed=xp, scale=jnp.ones((n, 1)), n=f)
        ad = jnp.asarray(a)
        xd = jnp.asarray(act)

        t_dense = time_fn(jax.jit(lambda X: ad @ X), xd, repeats=3)
        # BinTensor/FRDCMatrix carry static int fields: close over them
        # rather than passing as jit args.
        t_words = time_fn(jax.jit(lambda p: bspmm(
            adj, BinTensor(packed=p, scale=xt.scale, n=f), "BBF")),
            xt.packed, repeats=3)
        t_kernel = time_fn(
            lambda x: bspmm_kernel.bspmm_bits(adj, x, f, binarize=False),
            xp, repeats=1, warmup=1)

        fp_bytes_per_edge = 8.0                       # CSR value+index
        bit_bytes_per_edge = st["frdc_bytes"] / max(st["nnz"], 1)
        csv_row(f"fig4/{name}/dense_fp32", t_dense * 1e6,
                f"bytes_per_edge={fp_bytes_per_edge:.2f}")
        csv_row(f"fig4/{name}/bspmm_words", t_words * 1e6,
                f"bytes_per_edge={bit_bytes_per_edge:.2f};"
                f"pad_frac={st['pad_fraction']:.2f}")
        csv_row(f"fig4/{name}/bspmm_pallas_interp", t_kernel * 1e6,
                f"groups={st['n_groups']};"
                f"popc_per_out_word=2")
