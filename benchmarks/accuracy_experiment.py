"""Accuracy-parity experiment (paper Tables 3-5 accuracy columns): STE-train
fp32 / Bi-GCN / binary-aggregation GCNs on stat-matched synthetic graphs and
run the packed BitGNN inference paths. Prints a markdown table."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import frdc
from repro.graphs.datasets import make_dataset
from repro.models import gnn


def run_one(dataset: str, scale: float, hidden: int = 32, seeds=(0, 1, 2)):
    rows = {}
    for seed in seeds:
        d = make_dataset(dataset, seed=seed, scale=scale)
        adj = frdc.gcn_normalized(d.edges[0], d.edges[1], d.n_nodes)
        adj_bin = frdc.from_coo(d.edges[0], d.edges[1], d.n_nodes, d.n_nodes)
        adj_dense = frdc.to_dense(adj)
        adj_hat_dense = frdc.to_dense(adj_bin)
        x = jnp.asarray(d.x)
        y, m = jnp.asarray(d.y), jnp.asarray(d.test_mask)
        tm = jnp.asarray(d.train_mask)
        key = jax.random.PRNGKey(seed)

        p0 = gnn.init_gcn(key, d.x.shape[1], hidden, d.n_classes)
        p_fp, _ = gnn.train_node_classifier(
            gnn.gcn_forward_fp, p0, (x, adj_dense), y, tm, epochs=150)
        rows.setdefault("FP32", []).append(gnn.accuracy(
            gnn.gcn_forward_fp(p_fp, x, adj_dense), y, m))

        p_bi, _ = gnn.train_node_classifier(
            gnn.gcn_forward_bigcn, p0, (x, adj_dense), y, tm,
            epochs=300, lr=3e-2)
        rows.setdefault("Bi-GCN", []).append(gnn.accuracy(
            gnn.gcn_forward_bigcn(p_bi, x, adj_dense), y, m))
        q = gnn.quantize_gcn(p_bi)
        rows.setdefault("Ours(full)", []).append(gnn.accuracy(
            gnn.gcn_forward_bitgnn(q, x, adj, adj_bin, scheme="full"), y, m))

        p_bin, _ = gnn.train_node_classifier(
            gnn.gcn_forward_ste_bin, p0, (x, adj_hat_dense, adj_dense),
            y, tm, epochs=300, lr=3e-2)
        qb = gnn.quantize_gcn(p_bin)
        rows.setdefault("Ours(bin)", []).append(gnn.accuracy(
            gnn.gcn_forward_bitgnn(qb, x, adj, adj_bin, scheme="bin"), y, m))
    return {k: (float(np.mean(v)), float(np.std(v))) for k, v in rows.items()}


def main():
    print("| dataset | FP32 | Bi-GCN | Ours(full) | Ours(bin) |")
    print("|---|---|---|---|---|")
    for name, scale in [("cora", 0.3), ("citeseer", 0.3), ("pubmed", 0.08)]:
        r = run_one(name, scale)
        cells = " | ".join(f"{r[k][0]*100:.1f}±{r[k][1]*100:.1f}"
                           for k in ("FP32", "Bi-GCN", "Ours(full)",
                                     "Ours(bin)"))
        print(f"| {name} | {cells} |")


if __name__ == "__main__":
    main()
