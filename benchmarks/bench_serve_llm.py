"""Token-serving benchmark: QPS / latency percentiles / time-to-first-token
of the token serving tier (TokenStore -> TokenSession -> TokenServeEngine)
for the binary transformer and the RWKV SSM stack.

Queries arrive in waves (one micro-batch worth, then tick) like the GNN
serve bench, so latency is end-to-end batch service time. Two recorded
gates ride along: ``steady_state_compiles`` (the zero-recompile invariant
after warmup, zero-tolerance in ``compare_bench``) and ``bit_exact`` (a
sample of served streams replayed through the direct ``jit(decode_step)``
loop). Emits CSV rows plus ``results/BENCH_serve_llm.json``.
"""
from __future__ import annotations

import gc
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import transformer
from repro.serve import TokenServeEngine, TokenStore

from .common import csv_row

RESULTS = Path(__file__).resolve().parents[1] / "results"

# bump when the emitted JSON layout changes (compare_bench.py warns on
# cross-version diffs)
SCHEMA_VERSION = 1

ARCHS = {"transformer": "stablelm-1.6b", "ssm": "rwkv6-3b"}


def _direct_reference(cfg, params, prompt, max_new):
    """The oracle: python loop of jit(decode_step) with argmax feedback."""
    step = jax.jit(lambda p, c, t, pos: transformer.decode_step(
        p, cfg, c, t, pos))
    total = prompt.size + max_new
    cache = transformer.init_cache(
        cfg, 1, max(64, int(2 ** np.ceil(np.log2(total)))))
    out, prev = [], None
    for t in range(prompt.size + max_new - 1):
        tok = prompt[t] if t < prompt.size else prev
        lg, cache = step(params, cache, jnp.asarray([[tok]], jnp.int32), t)
        prev = int(np.argmax(np.asarray(lg[0, 0, :cfg.vocab])))
        if t >= prompt.size - 1:
            out.append(prev)
    return np.asarray(out[:max_new], np.int32)


def _pct_ms(vals, q):
    return float(np.percentile(np.asarray(vals), q) * 1e3) if vals else 0.0


def _bench_family(kind: str, n_queries: int, batch: int, max_new: int,
                  chunk: int, pipeline_depth: int = 1, seed: int = 0,
                  oracle_samples: int = 4) -> dict:
    cfg = reduced_config(get_config(ARCHS[kind])).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    store = TokenStore(max_batch=batch, max_len=256, chunk=chunk,
                       warm_len=12, warm_new=max_new)
    store.register_model("lm", cfg, params)
    eng = TokenServeEngine(store, pipeline_depth=pipeline_depth)
    warm_compiles = eng.warmup("lm")
    c0 = eng.compile_count

    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab,
                            int(rng.integers(3, 12))).astype(np.int32)
               for _ in range(n_queries)]
    queries = []
    gc.collect()
    gc_was = gc.isenabled()
    gc.disable()
    t0 = time.perf_counter()
    try:
        for i in range(0, n_queries, batch):
            queries += eng.submit_many("lm", prompts[i:i + batch],
                                       max_new=max_new)
            eng.tick()
        eng.run_until_drained()
    finally:
        if gc_was:
            gc.enable()
    wall_s = time.perf_counter() - t0
    snap = eng.snapshot()
    steady_compiles = eng.compile_count - c0
    eng.close()

    answered = [q for q in queries if q.done]
    tokens_out = int(sum(q.tokens.size for q in answered))
    ttfts = [q.ttft_s for q in answered if q.ttft_s > 0]
    sample = answered[:: max(1, len(answered) // max(oracle_samples, 1))]
    sample = sample[:oracle_samples]
    bit_exact = all(
        np.array_equal(q.tokens,
                       _direct_reference(cfg, params, q.prompt, max_new))
        for q in sample)
    lat = snap["latency"]
    return dict(
        arch=ARCHS[kind], n_queries=n_queries, batch=batch,
        max_new=max_new, chunk=chunk, pipeline_depth=pipeline_depth,
        qps=snap["qps"],
        tokens_per_s=tokens_out / max(wall_s, 1e-9),
        tokens_generated=tokens_out,
        latency=lat,
        ttft_p50_ms=_pct_ms(ttfts, 50),
        ttft_p99_ms=_pct_ms(ttfts, 99),
        warmup_compiles=warm_compiles,
        steady_state_compiles=steady_compiles,
        dropped_queries=n_queries - len(answered),
        bit_exact=bool(bit_exact),
        oracle_samples=len(sample),
        family_label=snap["family"],
    )


def run(full: bool = False) -> dict:
    jax.config.update("jax_platform_name", "cpu")
    n_queries = 64 if full else 24
    batch = 8 if full else 4
    max_new = 16 if full else 8
    chunk = 8 if full else 4

    summary: dict = dict(schema_version=SCHEMA_VERSION,
                         n_queries=n_queries, batch=batch,
                         max_new=max_new, chunk=chunk, families={})
    for kind in sorted(ARCHS):
        sec = _bench_family(kind, n_queries, batch, max_new, chunk)
        summary["families"][kind] = sec
        lat = sec["latency"]
        csv_row(f"serve_llm/{kind}",
                1e6 / max(sec["qps"], 1e-9),
                f"qps={sec['qps']:.1f};tok_s={sec['tokens_per_s']:.0f};"
                f"p50_ms={lat['p50_ms']:.2f};p99_ms={lat['p99_ms']:.2f};"
                f"ttft_p50_ms={sec['ttft_p50_ms']:.2f};"
                f"steady_compiles={sec['steady_state_compiles']};"
                f"dropped={sec['dropped_queries']};"
                f"bit_exact={sec['bit_exact']}")

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_serve_llm.json"
    out.write_text(json.dumps(summary, indent=2))
    csv_row("serve_llm/summary", 0.0, f"wrote={out}")
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(full=args.full)
