"""Roofline analysis (deliverable g): three terms per (arch x shape) from the
dry-run's compiled artifacts.

    compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
    collective = wire_bytes_per_device / ICI_bw           (50 GB/s/link x 2
                 links usable per torus axis on v5e; we charge 1 link —
                 conservative)

plus MODEL_FLOPS (6ND train / 2ND inference, N_active for MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs_total.
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def model_flops_per_step(rec: dict) -> float:
    """Analytic MODEL_FLOPS for the whole step, all devices."""
    from repro.configs import SHAPES, get_config
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_active = rec["model"]["active_params"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1          # one new token per request
    return 2.0 * n_active * tokens


def analyze(rec: dict) -> dict:
    n_dev = rec["n_devices"]
    t_compute = rec["flops_per_device"] / PEAK_FLOPS
    t_memory = rec["bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_step(rec)
    hlo_total = rec["flops_per_device"] * n_dev
    useful = mf / hlo_total if hlo_total else float("nan")
    bound = max(terms.values())
    # roofline fraction: useful model flops per second at the bound time,
    # relative to the cluster's peak.
    frac = (mf / bound) / (n_dev * PEAK_FLOPS) if bound else float("nan")
    return dict(rec=rec, terms=terms, dominant=dominant,
                model_flops=mf, useful_ratio=useful,
                step_time_bound_s=bound, roofline_fraction=frac)


def load_all(mesh: str = "single"):
    out = []
    for p in sorted(RESULTS.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("mesh") == mesh:
            out.append(analyze(rec))
    return out


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | roofline frac | HBM/dev GB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        rec = r["rec"]
        mem_gb = rec["memory"]["per_device_hbm_bytes"] / 1e9
        lines.append(
            f"| {rec['arch']} | {rec['shape']} "
            f"| {r['terms']['compute']:.4f} | {r['terms']['memory']:.4f} "
            f"| {r['terms']['collective']:.4f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} "
            f"| {mem_gb:.2f} |")
    return hdr + "\n".join(lines)


def main() -> None:
    rows = load_all("single")
    print(markdown_table(rows))
    print()
    multi = load_all("multi")
    print(f"multi-pod cells compiled: {len(multi)}")


if __name__ == "__main__":
    main()
