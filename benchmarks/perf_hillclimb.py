"""§Perf hillclimbing driver: re-runs a dry-run cell with an optimization
variant and reports the three roofline terms vs the recorded baseline.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb --cell A1
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results"

# hypothesis -> change catalogue; each entry re-runs one cell with overrides.
VARIANTS = {
    # --- Cell A: qwen2-moe train_4k (most collective-bound) ---------------
    "A-base": dict(arch="qwen2-moe-a2.7b", shape="train_4k", cfg={}),
    "A1-grouped-dispatch": dict(
        arch="qwen2-moe-a2.7b", shape="train_4k",
        cfg=dict(moe_groups=16),
        hypothesis="global dispatch all-gathers the (1M, d) token buffer per "
                   "MoE layer; per-dp-shard dispatch keeps routing local so "
                   "collective bytes drop ~dp x on the dispatch path"),
    "A2-grouped-no-seqshard": dict(
        arch="qwen2-moe-a2.7b", shape="train_4k",
        cfg=dict(moe_groups=16), opts=dict(seq_shard=False),
        hypothesis="after A1, the per-layer boundary reshard (seq-parallel "
                   "all-gather + reduce-scatter) remains; d=2048 activations "
                   "fit per-device WITHOUT sequence sharding (268MB/boundary "
                   "x24 under remat) -> drop it, removing 2 collectives/"
                   "layer/pass at slightly higher activation memory"),
    "A3-shardmap-moe": dict(
        arch="qwen2-moe-a2.7b", shape="train_4k",
        cfg=dict(moe_groups=-1),
        hypothesis="A1 REFUTED: XLA cannot prove the grouped scatter is "
                   "shard-local and gathers the dispatch buffers anyway "
                   "(all-gather 55->219GB). Make locality EXPLICIT with "
                   "shard_map: local routing + local experts + one (nl,d) "
                   "psum/layer. Napkin: 268MB x2 x24 layers x3 passes "
                   "~ 38GB/dev ~ 0.8s collective (vs 208s baseline)"),
    # --- Cell B: llava-34b decode_32k (memory-bound, worst-ish fraction) --
    "B-base": dict(arch="llava-next-34b", shape="decode_32k", cfg={}),
    "B1-int8-kv": dict(
        arch="llava-next-34b", shape="decode_32k",
        cfg=dict(kv_cache_quant="int8"),
        hypothesis="decode reads the whole KV cache per token; int8+scales "
                   "halves cache bytes -> memory term ~2x down"),
    "B2-gqa-norepeat": dict(
        arch="llava-next-34b", shape="decode_32k",
        cfg={}, gqa_no_repeat=True,
        hypothesis="jnp.repeat expands KV 4x (64 q / 16 kv-compute heads) "
                   "before the dots; grouped einsum reads the cache once -> "
                   "attention bytes ~4x down on the cache-read path"),
    "B3-int8-norepeat": dict(
        arch="llava-next-34b", shape="decode_32k",
        cfg=dict(kv_cache_quant="int8"), gqa_no_repeat=True,
        hypothesis="compose B1+B2: int8 halves stored-cache bytes, grouped "
                   "einsum removes the 4x read amplification — predict "
                   "memory term ~0.26s -> <0.1s"),
    # --- Cell C: rwkv6 long_500k (paper technique: binary weights) --------
    "C-base": dict(arch="rwkv6-3b", shape="long_500k", cfg={}),
    "C1-bitgnn": dict(
        arch="rwkv6-3b", shape="long_500k", quant="bitgnn",
        hypothesis="attention-free decode at B=1 is weight-read-bound; "
                   "BitGNN packed projections cut the dominant memory "
                   "term toward 16x (uint32 bits + unpack temp traffic)"),
    # --- transfer check: does A3 generalize to the other MoE arch? --------
    "A4-llama4-shardmap": dict(
        arch="llama4-scout-17b-a16e", shape="train_4k",
        cfg=dict(moe_groups=-1),
        hypothesis="A3's explicit-SPMD dispatch is arch-independent; "
                   "llama4-scout (16e top-1, 5120d) baseline coll=279.0s "
                   "should drop by a similar ~25x factor"),
    "C2-bitgnn-replicated": dict(
        arch="rwkv6-3b", shape="long_500k", quant="bitgnn",
        quant_replicate=True,
        hypothesis="C1 was REFUTED: word-sharded packed weights force an "
                   "all-gather to reassemble the contraction dim, and the "
                   "in-graph unpack writes the full bf16 temp anyway. "
                   "Packed weights are 32x smaller -> REPLICATE them "
                   "(22MB/chip): the collective regression disappears; the "
                   "unpack temp remains (kernel-level fusion — our Pallas "
                   "bmm_xnor — is the real fix on TPU, which XLA-CPU "
                   "accounting cannot show)"),
}


def run_variant(name: str) -> dict:
    from repro.launch.dryrun import run_cell
    from repro.models import layers
    from repro.distributed import sharding as shd
    v = VARIANTS[name]
    overrides = dict(v.get("opts", {}))
    layers.GQA_NO_REPEAT = bool(v.get("gqa_no_repeat", False))
    shd.QUANT_REPLICATE = bool(v.get("quant_replicate", False))
    result = run_cell(v["arch"], v["shape"], "single",
                      quant=v.get("quant", "none"),
                      probe=True,
                      opt_overrides=overrides or None,
                      cfg_overrides=v.get("cfg") or None)
    layers.GQA_NO_REPEAT = False
    shd.QUANT_REPLICATE = False
    result["variant"] = name
    result["hypothesis"] = v.get("hypothesis", "(baseline)")
    out = RESULTS / "perf" / f"{name}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2))
    return result


def summarize(names):
    from .roofline import analyze
    rows = []
    for n in names:
        p = RESULTS / "perf" / f"{n}.json"
        if not p.exists():
            continue
        r = json.loads(p.read_text())
        a = analyze(r)
        rows.append((n, a))
    print(f"{'variant':26s} {'compute':>9s} {'memory':>9s} {'coll':>9s} "
          f"{'dominant':>10s} {'frac':>7s}")
    for n, a in rows:
        t = a["terms"]
        print(f"{n:26s} {t['compute']:9.4f} {t['memory']:9.4f} "
              f"{t['collective']:9.4f} {a['dominant']:>10s} "
              f"{a['roofline_fraction']:7.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="variant name or prefix (A/B/C runs all of a cell)")
    args = ap.parse_args()
    names = [n for n in VARIANTS if n.startswith(args.cell)]
    for n in names:
        if not (RESULTS / "perf" / f"{n}.json").exists():
            print(f"[run] {n}: {VARIANTS[n].get('hypothesis', 'baseline')}")
            run_variant(n)
    summarize(names)


if __name__ == "__main__":
    main()
