"""§Perf hillclimbing driver: re-runs a dry-run cell with an optimization
variant and reports the three roofline terms vs the recorded baseline.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb --cell A1

``--bspmm`` instead sweeps the Pallas BSpMM (rows, feats) block-shape grid
(plus the kernel-native default and optionally the fused per-layer path) on
a real served forward and RECORDS every measurement into the persistent
tuner cache (``results/tuner_cache.json`` by default) that
``GraphStore(tuner_cache=...)`` seeds ``SessionPlan.bspmm_block`` from:

    PYTHONPATH=src python -m benchmarks.perf_hillclimb --bspmm --fused
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results"

# hypothesis -> change catalogue; each entry re-runs one cell with overrides.
VARIANTS = {
    # --- Cell A: qwen2-moe train_4k (most collective-bound) ---------------
    "A-base": dict(arch="qwen2-moe-a2.7b", shape="train_4k", cfg={}),
    "A1-grouped-dispatch": dict(
        arch="qwen2-moe-a2.7b", shape="train_4k",
        cfg=dict(moe_groups=16),
        hypothesis="global dispatch all-gathers the (1M, d) token buffer per "
                   "MoE layer; per-dp-shard dispatch keeps routing local so "
                   "collective bytes drop ~dp x on the dispatch path"),
    "A2-grouped-no-seqshard": dict(
        arch="qwen2-moe-a2.7b", shape="train_4k",
        cfg=dict(moe_groups=16), opts=dict(seq_shard=False),
        hypothesis="after A1, the per-layer boundary reshard (seq-parallel "
                   "all-gather + reduce-scatter) remains; d=2048 activations "
                   "fit per-device WITHOUT sequence sharding (268MB/boundary "
                   "x24 under remat) -> drop it, removing 2 collectives/"
                   "layer/pass at slightly higher activation memory"),
    "A3-shardmap-moe": dict(
        arch="qwen2-moe-a2.7b", shape="train_4k",
        cfg=dict(moe_groups=-1),
        hypothesis="A1 REFUTED: XLA cannot prove the grouped scatter is "
                   "shard-local and gathers the dispatch buffers anyway "
                   "(all-gather 55->219GB). Make locality EXPLICIT with "
                   "shard_map: local routing + local experts + one (nl,d) "
                   "psum/layer. Napkin: 268MB x2 x24 layers x3 passes "
                   "~ 38GB/dev ~ 0.8s collective (vs 208s baseline)"),
    # --- Cell B: llava-34b decode_32k (memory-bound, worst-ish fraction) --
    "B-base": dict(arch="llava-next-34b", shape="decode_32k", cfg={}),
    "B1-int8-kv": dict(
        arch="llava-next-34b", shape="decode_32k",
        cfg=dict(kv_cache_quant="int8"),
        hypothesis="decode reads the whole KV cache per token; int8+scales "
                   "halves cache bytes -> memory term ~2x down"),
    "B2-gqa-norepeat": dict(
        arch="llava-next-34b", shape="decode_32k",
        cfg={}, gqa_no_repeat=True,
        hypothesis="jnp.repeat expands KV 4x (64 q / 16 kv-compute heads) "
                   "before the dots; grouped einsum reads the cache once -> "
                   "attention bytes ~4x down on the cache-read path"),
    "B3-int8-norepeat": dict(
        arch="llava-next-34b", shape="decode_32k",
        cfg=dict(kv_cache_quant="int8"), gqa_no_repeat=True,
        hypothesis="compose B1+B2: int8 halves stored-cache bytes, grouped "
                   "einsum removes the 4x read amplification — predict "
                   "memory term ~0.26s -> <0.1s"),
    # --- Cell C: rwkv6 long_500k (paper technique: binary weights) --------
    "C-base": dict(arch="rwkv6-3b", shape="long_500k", cfg={}),
    "C1-bitgnn": dict(
        arch="rwkv6-3b", shape="long_500k", quant="bitgnn",
        hypothesis="attention-free decode at B=1 is weight-read-bound; "
                   "BitGNN packed projections cut the dominant memory "
                   "term toward 16x (uint32 bits + unpack temp traffic)"),
    # --- transfer check: does A3 generalize to the other MoE arch? --------
    "A4-llama4-shardmap": dict(
        arch="llama4-scout-17b-a16e", shape="train_4k",
        cfg=dict(moe_groups=-1),
        hypothesis="A3's explicit-SPMD dispatch is arch-independent; "
                   "llama4-scout (16e top-1, 5120d) baseline coll=279.0s "
                   "should drop by a similar ~25x factor"),
    "C2-bitgnn-replicated": dict(
        arch="rwkv6-3b", shape="long_500k", quant="bitgnn",
        quant_replicate=True,
        hypothesis="C1 was REFUTED: word-sharded packed weights force an "
                   "all-gather to reassemble the contraction dim, and the "
                   "in-graph unpack writes the full bf16 temp anyway. "
                   "Packed weights are 32x smaller -> REPLICATE them "
                   "(22MB/chip): the collective regression disappears; the "
                   "unpack temp remains (kernel-level fusion — our Pallas "
                   "bmm_xnor — is the real fix on TPU, which XLA-CPU "
                   "accounting cannot show)"),
}


def run_variant(name: str) -> dict:
    from repro.launch.dryrun import run_cell
    from repro.models import layers
    from repro.distributed import sharding as shd
    v = VARIANTS[name]
    overrides = dict(v.get("opts", {}))
    layers.GQA_NO_REPEAT = bool(v.get("gqa_no_repeat", False))
    shd.QUANT_REPLICATE = bool(v.get("quant_replicate", False))
    result = run_cell(v["arch"], v["shape"], "single",
                      quant=v.get("quant", "none"),
                      probe=True,
                      opt_overrides=overrides or None,
                      cfg_overrides=v.get("cfg") or None)
    layers.GQA_NO_REPEAT = False
    shd.QUANT_REPLICATE = False
    result["variant"] = name
    result["hypothesis"] = v.get("hypothesis", "(baseline)")
    out = RESULTS / "perf" / f"{name}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=2))
    return result


def summarize(names):
    from .roofline import analyze
    rows = []
    for n in names:
        p = RESULTS / "perf" / f"{n}.json"
        if not p.exists():
            continue
        r = json.loads(p.read_text())
        a = analyze(r)
        rows.append((n, a))
    print(f"{'variant':26s} {'compute':>9s} {'memory':>9s} {'coll':>9s} "
          f"{'dominant':>10s} {'frac':>7s}")
    for n, a in rows:
        t = a["terms"]
        print(f"{n:26s} {t['compute']:9.4f} {t['memory']:9.4f} "
              f"{t['collective']:9.4f} {a['dominant']:>10s} "
              f"{a['roofline_fraction']:7.4f}")


def bspmm_block_candidates(n_feat: int):
    """The (rows, feats) sweep space: the kernel-native default (None)
    plus every legal 2D-grid shape from small candidate row/feat tilings
    (legality via the kernel's own capability probe, so the sweep and the
    kernel cannot disagree about the space)."""
    from repro.kernels.bspmm_kernel import block_probe
    cands = [None]
    for rows in (4, 8, 16, 32):
        for feats in (None, 32, 64, 128):
            blk = (rows, feats)
            # probe both the packed and fp paths — a serve forward runs both
            if (block_probe(blk, n_feat, True) is None
                    and block_probe(blk, n_feat, False) is None):
                cands.append(blk)
    return cands


def sweep_bspmm(dataset: str = "cora", scale: float = 0.1,
                family: str = "gcn", fused: bool = False,
                cache_path=None, repeats: int = 3, batch: int = 8) -> dict:
    """Time a served subgraph forward per block-shape candidate (and per
    fused flag when ``fused``) and record every measurement into the
    persistent tuner cache. Returns {tag: latency_s} for the report."""
    import numpy as np
    import jax
    from repro.graphs.datasets import make_dataset
    from repro.kernels import ops as kernel_ops
    from repro.models import gnn
    from repro.serve.gnn_session import GraphStore
    from repro.serve.tuner_cache import TunerCache, graph_stats

    cache = TunerCache(cache_path or RESULTS / "tuner_cache.json")
    data = make_dataset(dataset, seed=0, scale=scale)
    stats = graph_stats(data)
    seeds = np.random.default_rng(0).integers(0, data.n_nodes, size=batch)
    kernel_ops.force_kernels(True)
    timings = {}
    try:
        for use_fused in ([False, True] if fused else [False]):
            for blk in bspmm_block_candidates(int(data.x.shape[1])):
                st = GraphStore(max_batch=batch, use_pallas=True,
                                bspmm_block=blk, fused=use_fused)
                st.register_graph("g", data)
                key = jax.random.PRNGKey(0)
                f, c = data.x.shape[1], data.n_classes
                init = {"gcn": gnn.init_gcn, "sage": gnn.init_sage,
                        "saint": gnn.init_saint}[family]
                st.register_model(family, family, init(key, f, 16, c))
                sess = st.session("g", family)
                sess.serve_subgraph(seeds)          # warmup/compile
                best = float("inf")
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    np.asarray(sess.serve_subgraph(seeds))
                    best = min(best, time.perf_counter() - t0)
                k = cache.record(stats, blk, best, fused=use_fused)
                timings[k] = best
                print(f"[bspmm] {k}: {best * 1e3:.3f} ms")
    finally:
        kernel_ops.force_kernels(False)
    pick = cache.lookup(stats, fused=fused)
    print(f"[bspmm] fastest block for fused={fused}: {pick} "
          f"(cache: {cache.path})")
    return timings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None,
                    help="variant name or prefix (A/B/C runs all of a cell)")
    ap.add_argument("--bspmm", action="store_true",
                    help="sweep the Pallas BSpMM block-shape space and "
                         "record results into the tuner cache")
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--family", default="gcn",
                    choices=["gcn", "sage", "saint"])
    ap.add_argument("--fused", action="store_true",
                    help="also sweep the fused per-layer kernel path")
    ap.add_argument("--cache", default=None,
                    help="tuner cache path (default results/tuner_cache.json)")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    if args.bspmm:
        sweep_bspmm(args.dataset, args.scale, args.family, args.fused,
                    cache_path=args.cache, repeats=args.repeats)
        return
    if not args.cell:
        ap.error("one of --cell or --bspmm is required")
    names = [n for n in VARIANTS if n.startswith(args.cell)]
    for n in names:
        if not (RESULTS / "perf" / f"{n}.json").exists():
            print(f"[run] {n}: {VARIANTS[n].get('hypothesis', 'baseline')}")
            run_variant(n)
    summarize(names)


if __name__ == "__main__":
    main()
