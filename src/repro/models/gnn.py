"""GNN models: GCN / GraphSAGE / GraphSAINT — fp32, Bi-GCN baseline, and
BitGNN binary inference paths (paper §2.1, §4.1).

Three execution paths per model:
  * ``*_fp``      — full-precision reference (PyG-equivalent semantics);
  * ``*_bigcn``   — the Bi-GCN baseline: *logically* binarized (sign() and
    scales applied, values stored fp32, fp32 matmuls) — the paper's
    state-of-the-art comparison that shows NO speed/memory gain;
  * ``*_bitgnn``  — BitGNN packed-bit inference through the two-level
    abstraction (schemes: "full" = full-precision aggregation, "bin" =
    binary aggregation; Table 3's "Ours (full)" / "Ours (bin)").

Training uses straight-through estimators so the binarized inference paths
can be validated for ACCURACY PARITY against their own training forward.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import abstraction, bitops, frdc
from repro.core.binarize import BinTensor, straight_through_sign
from repro.core.bmm import bmm, quantize_act, quantize_weight
from repro.core.bspmm import bspmm
from repro.optim.optimizer import AdamW


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

class GCNParams(NamedTuple):
    w1: jax.Array
    w2: jax.Array


class SAGEParams(NamedTuple):
    w1_self: jax.Array
    w1_agg: jax.Array
    w2_self: jax.Array
    w2_agg: jax.Array


class SAINTParams(NamedTuple):
    w1_self: jax.Array
    w1_agg: jax.Array
    w2_self: jax.Array
    w2_agg: jax.Array
    w_fc: jax.Array


def _glorot(key, shape):
    lim = float(np.sqrt(6.0 / (shape[0] + shape[1])))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def init_gcn(key, n_feat: int, hidden: int, n_classes: int) -> GCNParams:
    k1, k2 = jax.random.split(key)
    return GCNParams(_glorot(k1, (n_feat, hidden)), _glorot(k2, (hidden, n_classes)))


def init_sage(key, n_feat: int, hidden: int, n_classes: int) -> SAGEParams:
    ks = jax.random.split(key, 4)
    return SAGEParams(_glorot(ks[0], (n_feat, hidden)),
                      _glorot(ks[1], (n_feat, hidden)),
                      _glorot(ks[2], (hidden, n_classes)),
                      _glorot(ks[3], (hidden, n_classes)))


def init_saint(key, n_feat: int, hidden: int, n_classes: int) -> SAINTParams:
    ks = jax.random.split(key, 5)
    return SAINTParams(_glorot(ks[0], (n_feat, hidden)),
                       _glorot(ks[1], (n_feat, hidden)),
                       _glorot(ks[2], (hidden, hidden)),
                       _glorot(ks[3], (hidden, hidden)),
                       _glorot(ks[4], (hidden, n_classes)))


# ---------------------------------------------------------------------------
# Aggregation backends (the FP32 (S) / FP32 (T) rows of Tables 3-5)
# ---------------------------------------------------------------------------

def aggregate_scatter(edges: jax.Array, x: jax.Array, n: int,
                      norm: Optional[jax.Array] = None) -> jax.Array:
    """PyG scatter-gather semantics: per-edge gather + scatter-add."""
    src, dst = edges
    msgs = x[src]
    if norm is not None:
        msgs = msgs * norm[:, None]
    return jax.ops.segment_sum(msgs, dst, num_segments=n)


def aggregate_dense(adj_dense: jax.Array, x: jax.Array) -> jax.Array:
    """PyG SpMM-tensor semantics (dense matmul stand-in on CPU)."""
    return adj_dense @ x


# ---------------------------------------------------------------------------
# STE binarization helpers (training-time)
# ---------------------------------------------------------------------------

def _ste_binarize_w(w: jax.Array) -> jax.Array:
    scale = jnp.mean(jnp.abs(w), axis=0, keepdims=True)
    return straight_through_sign(w) * scale


def _ste_binarize_x(x: jax.Array) -> jax.Array:
    scale = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)
    return straight_through_sign(x) * scale


def bn_stats(x: jax.Array, eps: float = 1e-5) -> tuple:
    """Per-feature (mu, sd) over the node axis — the only cross-node statistic
    in any bitgnn forward. Serving freezes these on the FULL graph so a k-hop
    subgraph forward reproduces the full-graph computation node-for-node."""
    mu = jnp.mean(x, axis=0, keepdims=True)
    sd = jnp.std(x, axis=0, keepdims=True) + eps
    return mu, sd


def batch_norm(x: jax.Array, eps: float = 1e-5,
               stats: Optional[tuple] = None) -> jax.Array:
    """Per-feature standardization — the BN stage that precedes every BIN in
    Bi-GCN (paper Fig. 1). Without it, sign() of nonnegative inputs (sparse
    bag-of-words features, post-ReLU activations) collapses to all +1.

    ``stats``: optional frozen (mu, sd) — inference-mode BN for serving."""
    if stats is None:
        stats = bn_stats(x, eps)
    mu, sd = stats
    return (x - mu) / sd


class _BNTap:
    """Sequences the BN sites of a forward: replays frozen per-site stats
    (serving) or computes-and-records them from the batch (calibration)."""

    def __init__(self, frozen: Optional[tuple]):
        self.frozen = frozen
        self.collected: list = []
        self._i = 0

    def __call__(self, x: jax.Array) -> jax.Array:
        if self.frozen is not None:
            s = self.frozen[self._i]
            self._i += 1
        else:
            s = bn_stats(x)
            self.collected.append(s)
        return batch_norm(x, stats=s)


# ---------------------------------------------------------------------------
# GCN forwards
# ---------------------------------------------------------------------------

def gcn_forward_fp(params: GCNParams, x, adj_dense):
    h = jax.nn.relu(adj_dense @ (x @ params.w1))
    return adj_dense @ (h @ params.w2)


def gcn_forward_bigcn(params: GCNParams, x, adj_dense):
    """Bi-GCN baseline: BN -> BIN -> BMM -> SCL -> SpMM per layer (Fig. 1),
    logically binarized: fp32 storage & compute."""
    h = _ste_binarize_x(batch_norm(x)) @ _ste_binarize_w(params.w1)
    h = jax.nn.relu(adj_dense @ h)
    h = _ste_binarize_x(batch_norm(h)) @ _ste_binarize_w(params.w2)
    return adj_dense @ h


def gcn_forward_ste_bin(params: GCNParams, x, adj_hat_dense, adj_dense):
    """Training forward matching the BitGNN "bin" scheme (binary aggregation
    with the unnormalized 0/1 adjacency in layer 1)."""
    h = batch_norm(x) @ _ste_binarize_w(params.w1)   # BN + MM.FB?
    s = straight_through_sign(h)                      # BIN (unit scale)
    agg = adj_hat_dense @ s                           # binary aggregation
    h1 = straight_through_sign(agg)                   # output BIN
    h2 = (h1 @ _ste_binarize_w(params.w2))            # MM.BB?
    return adj_dense @ h2                             # fp aggregation


class GCNQuant(NamedTuple):
    w1: BinTensor
    w2: BinTensor


def quantize_gcn(params: GCNParams) -> GCNQuant:
    return GCNQuant(quantize_weight(params.w1), quantize_weight(params.w2))


def gcn_bitgnn_layers(q: GCNQuant, scheme: str = "bin",
                      trinary_mode: str = "s3_two_popc") -> list:
    """Per-layer callables ``fn(bn_tap, h, mats)`` decomposing the GCN
    bitgnn forward. ``mats`` holds the adjacency operands ("adj" fp-scaled,
    "bin" the 0/1 layer-1 matrix). The monolithic forward composes these
    verbatim; the fused serving path wraps each in ONE Pallas kernel."""
    if scheme == "full":
        l1 = abstraction.MMSpMM("BMM.BBF", "BSpMM.FBF")
        l2 = abstraction.MMSpMM("BMM.BBF", "BSpMM.FBF")
        return [
            lambda bn, h, mats: jax.nn.relu(
                l1(quantize_act(bn(h)), q.w1, mats["adj"])),
            lambda bn, h, mats: l2(quantize_act(bn(h)), q.w2, mats["adj"]),
        ]
    if scheme != "bin":
        raise ValueError(scheme)
    l1 = abstraction.MMSpMM("BMM.FBB", "BSpMM.BBB")
    l2 = abstraction.MMSpMM("BMM.BBF", "BSpMM.FBF")
    return [
        lambda bn, h, mats: l1(bn(h), q.w1, mats["bin"],
                               trinary_mode=trinary_mode, out_scale=False),
        lambda bn, h, mats: l2(h, q.w2, mats["adj"]),
    ]


def _run_bitgnn_layers(layers: list, x, mats: dict,
                       bn_stats: Optional[tuple],
                       return_bn_stats: bool):
    bn = _BNTap(bn_stats)
    h = x
    for fn in layers:
        h = fn(bn, h, mats)
    if return_bn_stats:
        return h, tuple(bn.collected)
    return h


def gcn_forward_bitgnn(q: GCNQuant, x, adj: frdc.FRDCMatrix,
                       adj_bin: frdc.FRDCMatrix, scheme: str = "bin",
                       trinary_mode: str = "s3_two_popc",
                       bn_stats: Optional[tuple] = None,
                       return_bn_stats: bool = False):
    """BitGNN packed inference.

    scheme="full": BIN -> BMM.BBF -> BSpMM.FBF per layer (fp aggregation).
    scheme="bin":  layer1 BMM.FBB + BSpMM.BBB (binary aggregation over the
                   0/1 adjacency), layer2 BMM.BBF + BSpMM.FBF — exactly the
                   Table 3 "Ours (bin)" configuration.

    ``bn_stats``: frozen per-site (mu, sd) tuples (serving/inference mode);
    ``return_bn_stats=True`` additionally returns the stats computed from this
    batch (full-graph BN calibration for the serving subsystem).
    """
    return _run_bitgnn_layers(gcn_bitgnn_layers(q, scheme, trinary_mode),
                              x, {"adj": adj, "bin": adj_bin},
                              bn_stats, return_bn_stats)


# ---------------------------------------------------------------------------
# SAGE forwards (mean aggregator + self weight; paper §2.1 SAGEConv)
# ---------------------------------------------------------------------------

def sage_forward_fp(params: SAGEParams, x, adj_mean_dense):
    h = x @ params.w1_self + (adj_mean_dense @ x) @ params.w1_agg
    h = jax.nn.relu(h)
    return h @ params.w2_self + (adj_mean_dense @ h) @ params.w2_agg


def sage_forward_bigcn(params: SAGEParams, x, adj_mean_dense):
    xb = _ste_binarize_x(batch_norm(x))
    h = xb @ _ste_binarize_w(params.w1_self) \
        + (adj_mean_dense @ xb) @ _ste_binarize_w(params.w1_agg)
    h = jax.nn.relu(h)
    hb = _ste_binarize_x(batch_norm(h))
    return hb @ _ste_binarize_w(params.w2_self) \
        + (adj_mean_dense @ hb) @ _ste_binarize_w(params.w2_agg)


class SAGEQuant(NamedTuple):
    w1_self: BinTensor
    w1_agg: BinTensor
    w2_self: BinTensor
    w2_agg: BinTensor


def quantize_sage(params: SAGEParams) -> SAGEQuant:
    return SAGEQuant(*(quantize_weight(w) for w in params))


def _branch_add_layer(w_self: BinTensor, w_agg: BinTensor, relu: bool):
    """One SAGE/SAINT layer: BMM self + BSpMM(BMM agg), merged by ADD."""
    def fn(bn, h, mats):
        hq = quantize_act(bn(h))
        out = bmm(hq, w_self, "BBF") \
            + bspmm(mats["adj"], bmm(hq, w_agg, "BBF"), "FBF")
        return jax.nn.relu(out) if relu else out
    return fn


def sage_bitgnn_layers(q: SAGEQuant) -> list:
    return [_branch_add_layer(q.w1_self, q.w1_agg, True),
            _branch_add_layer(q.w2_self, q.w2_agg, False)]


def sage_forward_bitgnn(q: SAGEQuant, x, adj_mean: frdc.FRDCMatrix,
                        bn_stats: Optional[tuple] = None,
                        return_bn_stats: bool = False):
    """BitGNN SAGE: BMM for both branches + BSpMM.FBF mean aggregation,
    merged by ADD (paper Fig. 2 SAGE.bin). Aggregation is applied AFTER the
    transform — ``(A @ xb) @ W == A @ (xb @ W)`` — so the packed path is
    bit-exact with the Bi-GCN training forward while running the cheap
    (hidden-width) BSpMM."""
    return _run_bitgnn_layers(sage_bitgnn_layers(q), x, {"adj": adj_mean},
                              bn_stats, return_bn_stats)


# ---------------------------------------------------------------------------
# SAINT forwards (GraphConv sum aggregator x2 + FC; paper §2.1)
# ---------------------------------------------------------------------------

def saint_forward_fp(params: SAINTParams, x, adj_sum_dense):
    h = x @ params.w1_self + (adj_sum_dense @ x) @ params.w1_agg
    h = jax.nn.relu(h)
    h = h @ params.w2_self + (adj_sum_dense @ h) @ params.w2_agg
    h = jax.nn.relu(h)
    return h @ params.w_fc


class SAINTQuant(NamedTuple):
    w1_self: BinTensor
    w1_agg: BinTensor
    w2_self: BinTensor
    w2_agg: BinTensor
    w_fc: BinTensor


def quantize_saint(params: SAINTParams) -> SAINTQuant:
    return SAINTQuant(*(quantize_weight(w) for w in params))


def saint_bitgnn_layers(q: SAINTQuant) -> list:
    return [_branch_add_layer(q.w1_self, q.w1_agg, True),
            _branch_add_layer(q.w2_self, q.w2_agg, True),
            lambda bn, h, mats: bmm(quantize_act(bn(h)), q.w_fc, "BBF")]


def saint_forward_bitgnn(q: SAINTQuant, x, adj_sum: frdc.FRDCMatrix,
                         bn_stats: Optional[tuple] = None,
                         return_bn_stats: bool = False):
    return _run_bitgnn_layers(saint_bitgnn_layers(q), x, {"adj": adj_sum},
                              bn_stats, return_bn_stats)


def bitgnn_layers(family: str, q, scheme: str = "bin",
                  trinary_mode: str = "s3_two_popc") -> list:
    """Family dispatch for the per-layer decomposition (fused serving)."""
    if family == "gcn":
        return gcn_bitgnn_layers(q, scheme, trinary_mode)
    if family == "sage":
        return sage_bitgnn_layers(q)
    if family == "saint":
        return saint_bitgnn_layers(q)
    raise ValueError(f"unknown bitgnn family: {family!r}")


# ---------------------------------------------------------------------------
# Training (full-batch node classification) & evaluation
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, mask):
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.sum(nll * mask) / jnp.sum(mask)


def accuracy(logits, labels, mask) -> float:
    pred = jnp.argmax(logits, axis=-1)
    return float(jnp.sum((pred == labels) * mask) / jnp.sum(mask))


def train_node_classifier(forward: Callable, params, inputs: tuple,
                          y: jax.Array, train_mask: jax.Array,
                          epochs: int = 150, lr: float = 1e-2,
                          weight_decay: float = 5e-4):
    """Full-batch training of any forward(params, *inputs) model."""
    opt = AdamW(lr=lr, weight_decay=weight_decay)
    state = opt.init(params)
    mask = train_mask.astype(jnp.float32)

    @jax.jit
    def step(params, state):
        def loss_fn(p):
            return cross_entropy(forward(p, *inputs), y, mask)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    loss = jnp.inf
    for _ in range(epochs):
        params, state, loss = step(params, state)
    return params, float(loss)
