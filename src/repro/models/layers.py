"""Transformer building blocks: norms, RoPE, GQA attention (train / chunked
prefill / KV-cache decode), MLPs, embeddings — all sharding-friendly and
usable under ``jax.eval_shape`` for the dry-run.

BitGNN integration: ``linear()`` transparently consumes either a plain fp
weight or a bit-packed ``{"packed","scale"}`` dict produced by
``repro.quant.binary_linear`` (32x smaller weight storage; see DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
            * scale + bias)


def linear(w, x: jax.Array) -> jax.Array:
    """x @ W with optional BitGNN bit-packed weight.

    Packed form: {"packed": (out, in/32) uint32, "scale": (out,)}; bits are
    signs packed along the contraction axis (``quantize_linear``). The unpack
    runs in-graph (sign = 2*bit-1, times positive per-output scale).
    """
    if isinstance(w, dict) and "packed" in w:
        packed, scale = w["packed"], w["scale"]
        n_in = x.shape[-1]
        k = jnp.arange(32, dtype=jnp.uint32)
        bits = (packed[:, :, None] >> k) & jnp.uint32(1)          # (out,W,32)
        pm1 = (2.0 * bits.astype(x.dtype) - 1.0).reshape(packed.shape[0], -1)
        w_eff = (pm1[:, :n_in] * scale[:, None]).T                # (in, out)
        return x @ w_eff
    return x @ w


def _init(key, shape, in_axis_size, dtype):
    std = 1.0 / math.sqrt(in_axis_size)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); positions: (B, T) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs     # (B,T,half)
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype):
    """GQA projections with the TP padding policy applied.

    q heads: ``n_heads_padded``; kv heads physically materialized at
    ``max(n_kv_heads_padded, tp)`` (replication for tp > kv is explicit so
    each model shard owns its kv slice — Megatron GQA practice)."""
    d, hd = cfg.d_model, cfg.head_dim
    hq = cfg.n_heads_padded or cfg.n_heads
    kvc = kv_compute_heads(cfg)
    ks = jax.random.split(key, 4)
    return {
        "wq": _init(ks[0], (d, hq * hd), d, dtype),
        "wk": _init(ks[1], (d, kvc * hd), d, dtype),
        "wv": _init(ks[2], (d, kvc * hd), d, dtype),
        "wo": _init(ks[3], (hq * hd, d), hq * hd, dtype),
    }


def kv_compute_heads(cfg: ModelConfig) -> int:
    kvp = cfg.n_kv_heads_padded or cfg.n_kv_heads
    return max(kvp, cfg.tp) if cfg.tp > 1 else kvp


def _sdpa(q, k, v, causal: bool, q_offset, kv_len: Optional[jax.Array] = None):
    """(B,Tq,H,hd) x (B,S,H,hd): scores materialized per call (callers chunk)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    s = k.shape[1]
    kpos = jnp.arange(s)
    if causal:
        qpos = q_offset + jnp.arange(q.shape[1])
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None], scores, -1e9)
    if kv_len is not None:  # decode: mask cache tail beyond current length
        scores = jnp.where((kpos < kv_len)[None, None, None, :], scores, -1e9)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _sdpa_grouped(q, k, v, causal: bool, q_offset, kv_len=None):
    """GQA without materializing repeated K/V (§Perf B2): q is reshaped to
    (B,Tq,KV,G,hd) and contracted straight against the KV-head tensors — the
    cache is read ONCE instead of G times."""
    b, tq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q5 = q.reshape(b, tq, hkv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k) / math.sqrt(hd)
    s = k.shape[1]
    kpos = jnp.arange(s)
    if causal:
        qpos = q_offset + jnp.arange(tq)
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None, None], scores, -1e9)
    if kv_len is not None:
        scores = jnp.where((kpos < kv_len)[None, None, None, None, :],
                           scores, -1e9)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, tq, hq, hd)


GQA_NO_REPEAT = False   # flipped by §Perf variants (see perf_hillclimb)


def multi_head_attention(q, k, v, causal: bool = True, q_chunk: int = 0,
                         q_offset: int = 0, kv_len=None):
    """Exact attention, optionally Q-chunked so the (C, S) score block — not
    (T, S) — bounds live memory for 32k prefill (DESIGN.md §7). Chunks are an
    unrolled Python loop so the dry-run's cost analysis counts every FLOP."""
    hq, hkv = q.shape[2], k.shape[2]
    attn = _sdpa
    if hq != hkv:
        if GQA_NO_REPEAT:
            attn = _sdpa_grouped
        else:
            k = jnp.repeat(k, hq // hkv, axis=2)
            v = jnp.repeat(v, hq // hkv, axis=2)
    tq = q.shape[1]
    if not q_chunk or tq <= q_chunk:
        return attn(q, k, v, causal, q_offset, kv_len)
    outs = []
    for c0 in range(0, tq, q_chunk):
        c1 = min(c0 + q_chunk, tq)
        outs.append(attn(q[:, c0:c1], k, v, causal, q_offset + c0, kv_len))
    return jnp.concatenate(outs, axis=1)


def attention_block(params, x, positions, cfg: ModelConfig, causal=True,
                    q_chunk: int = 0, cache=None, cache_pos=None,
                    kv_override=None):
    """Full attention block: proj -> rope -> sdpa -> out-proj.

    cache: {"k","v"} (B, S, KVC, hd) ring buffers for decode; cache_pos is
    the write position (scalar). kv_override short-circuits projection for
    cross-attention (pre-computed encoder memory).
    Returns (out, new_cache).
    """
    b, t, d = x.shape
    hd = cfg.head_dim
    hq = cfg.n_heads_padded or cfg.n_heads
    kvc = kv_compute_heads(cfg)
    q = linear(params["wq"], x).reshape(b, t, hq, hd)
    if kv_override is not None:
        k, v = kv_override
        q = rope(q, positions, cfg.rope_theta)
        new_cache = cache
        kv_len = None
    else:
        k = linear(params["wk"], x).reshape(b, t, kvc, hd)
        v = linear(params["wv"], x).reshape(b, t, kvc, hd)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if cache is not None and "k_scale" in cache:
            # int8 KV cache (§Perf): per-(position, head) symmetric scales;
            # the dequant multiply fuses into the attention dots.
            def quant(u):
                s = jnp.max(jnp.abs(u), axis=-1, keepdims=True) / 127.0 + 1e-8
                return jnp.round(u / s).astype(jnp.int8), s.astype(u.dtype)
            kq, ks = quant(k)
            vq, vs = quant(v)
            upd = lambda buf, val: jax.lax.dynamic_update_slice(
                buf, val, (0, cache_pos) + (0,) * (buf.ndim - 2))
            new_cache = {"k": upd(cache["k"], kq),
                         "v": upd(cache["v"], vq),
                         "k_scale": upd(cache["k_scale"], ks),
                         "v_scale": upd(cache["v_scale"], vs)}
            k = new_cache["k"].astype(x.dtype) * new_cache["k_scale"]
            v = new_cache["v"].astype(x.dtype) * new_cache["v_scale"]
            kv_len = cache_pos + t
        elif cache is not None:
            k = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
            new_cache = {"k": k, "v": v}
            kv_len = cache_pos + t
        else:
            new_cache = None
            kv_len = None
    out = multi_head_attention(q, k, v, causal=causal and kv_override is None,
                               q_chunk=q_chunk,
                               q_offset=0 if cache is None else cache_pos,
                               kv_len=kv_len)
    out = linear(params["wo"], out.reshape(b, t, hq * hd))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, act: str, dtype):
    k1, k2 = jax.random.split(key)
    if act == "swiglu":
        return {"wi": _init(k1, (d, 2 * ff), d, dtype),
                "wo": _init(k2, (ff, d), ff, dtype)}
    return {"wi": _init(k1, (d, ff), d, dtype),
            "wo": _init(k2, (ff, d), ff, dtype)}


def mlp_block(params, x, act: str):
    h = linear(params["wi"], x)
    if act == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(h)
    return linear(params["wo"], h)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig, dtype):
    v = cfg.vocab_padded or cfg.vocab
    table = _init(key, (v, cfg.d_model), cfg.d_model, dtype)
    return {"table": table}


def embed(params, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def lm_head(params, x: jax.Array, logical_vocab: int) -> jax.Array:
    logits = x @ params["table"].T
    v = logits.shape[-1]
    if v > logical_vocab:  # mask padding vocab out of the softmax
        neg = jnp.full((v - logical_vocab,), -1e9, logits.dtype)
        logits = logits.at[..., logical_vocab:].set(neg)
    return logits
