"""Model definitions: GNNs (paper) + the 10 assigned LM architectures."""
from . import gnn, layers, moe, ssm, transformer
