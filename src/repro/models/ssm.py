"""State-space blocks: Mamba2 (zamba2 hybrid) and RWKV6 "Finch" time-mix.

Both use chunked linear-recurrence algorithms: O(T/Q * Q^2) intra-chunk
matmuls (MXU-friendly) plus an O(1)-per-chunk carried state — the standard
TPU-native formulation (quadratic attention would be O(T^2); sequential scan
would serialize). The chunk loop is a Python loop when ``unroll`` (dry-run
FLOP counting) else ``lax.scan`` (training compile time).

Decode steps are O(1): a single state update per token — this is why the
``long_500k`` shape runs only for these families (DESIGN.md §5).

SSM states stay in fp32 (accumulator precision — binarizing them is
unboundedly lossy; see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import _init, linear

CHUNK = 256
_CONV_K = 4


# ---------------------------------------------------------------------------
# Mamba2 (SSD, n_groups=1)
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads_padded or cfg.ssm_heads
    p_dim = cfg.ssm_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wz": _init(ks[0], (d, h * p_dim), d, dtype),
        "wx": _init(ks[1], (d, h * p_dim), d, dtype),
        "wB": _init(ks[2], (d, n), d, dtype),
        "wC": _init(ks[3], (d, n), d, dtype),
        "wdt": _init(ks[4], (d, h), d, dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "conv_w": _init(ks[5], (_CONV_K, h * p_dim), _CONV_K, dtype),
        "norm_scale": jnp.ones((h * p_dim,), dtype),
        "wo": _init(ks[6], (h * p_dim, d), h * p_dim, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv (k=4) via shifted adds. x: (B, T, C); state:
    (B, K-1, C) tail of the previous segment. Returns (y, new_state)."""
    b, t, c = x.shape
    if state is None:
        state = jnp.zeros((b, _CONV_K - 1, c), x.dtype)
    ext = jnp.concatenate([state, x], axis=1)
    y = sum(ext[:, i:i + t] * w[i] for i in range(_CONV_K))
    return y, ext[:, -(_CONV_K - 1):]


def mamba_block(params, x, cfg: ModelConfig, unroll: bool,
                cache: Optional[dict] = None):
    """x: (B, T, d). cache (decode): {"S": (B,H,P,N) fp32, "conv": (B,3,HP)}.
    Returns (y, new_cache)."""
    b, t, d = x.shape
    h = cfg.ssm_heads_padded or cfg.ssm_heads
    p_dim, n = cfg.ssm_head_dim, cfg.ssm_state

    z = linear(params["wz"], x)
    xh = linear(params["wx"], x)
    conv_state = None if cache is None else cache["conv"]
    xh, new_conv = _causal_conv(xh, params["conv_w"], conv_state)
    xh = jax.nn.silu(xh)
    bmat = linear(params["wB"], x).astype(jnp.float32)      # (B,T,N)
    cmat = linear(params["wC"], x).astype(jnp.float32)      # (B,T,N)
    dt = jax.nn.softplus(linear(params["wdt"], x).astype(jnp.float32)
                         + params["dt_bias"])               # (B,T,H)
    a = -jnp.exp(params["A_log"])                            # (H,)
    da = dt * a                                              # (B,T,H) <= 0

    xs = xh.reshape(b, t, h, p_dim).astype(jnp.float32)
    s0 = (jnp.zeros((b, h, p_dim, n), jnp.float32) if cache is None
          else cache["S"])

    def chunk_step_clean(s, args):
        xq, bq, cq, dtq, daq = args
        q_ = xq.shape[1]
        lq = jnp.cumsum(daq, axis=1)
        cb = jnp.einsum("bin,bjn->bij", cq, bq)
        dec = jnp.exp(lq[:, :, None, :] - lq[:, None, :, :])
        mask = jnp.tril(jnp.ones((q_, q_), bool))
        w_ij = jnp.where(mask[None, :, :, None],
                         cb[:, :, :, None] * dec * dtq[:, None, :, :], 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w_ij, xq)
        cd = jnp.exp(lq)                                     # (B,Q,H)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cq, s, cd)
        lq_end = lq[:, -1:, :]
        contrib = jnp.einsum("bjh,bjn,bjhp->bhpn",
                             dtq * jnp.exp(lq_end - lq), bq, xq)
        s_new = s * jnp.exp(lq_end[:, 0])[..., None, None] + contrib
        return s_new, y_intra

    if cache is not None and t == 1:  # decode: exact single-step update
        da1 = da[:, 0]                                       # (B,H)
        dec = jnp.exp(da1)[..., None, None]
        contrib = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], bmat[:, 0], xs[:, 0])
        s_new = s0 * dec + contrib
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], s_new)
        y = y + params["D"][None, :, None] * xs[:, 0]
        y = y.reshape(b, 1, h * p_dim).astype(x.dtype)
        new_cache = {"S": s_new, "conv": new_conv}
    else:
        nq = -(-t // CHUNK)
        pad = nq * CHUNK - t
        def padq(v):
            return jnp.pad(v, ((0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 2))
        xq = padq(xs).reshape(b, nq, CHUNK, h, p_dim)
        bq = padq(bmat).reshape(b, nq, CHUNK, n)
        cq = padq(cmat).reshape(b, nq, CHUNK, n)
        dtq = padq(dt).reshape(b, nq, CHUNK, h)
        daq = padq(da).reshape(b, nq, CHUNK, h)

        def step(s, i):
            args = (xq[:, i], bq[:, i], cq[:, i], dtq[:, i], daq[:, i])
            s_new, y_intra = chunk_step_clean(s, args)
            cd = jnp.exp(jnp.cumsum(daq[:, i], axis=1))
            y_inter = jnp.einsum("bin,bhpn,bih->bihp", cq[:, i], s, cd)
            return s_new, y_intra + y_inter

        if unroll:
            ys, s = [], s0
            for i in range(nq):
                s, y_i = step(s, i)
                ys.append(y_i)
            y = jnp.concatenate(ys, axis=1)[:, :t]
        else:
            s, ys = jax.lax.scan(step, s0, jnp.arange(nq))
            y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nq * CHUNK, h, p_dim)[:, :t]
        y = y + params["D"][None, None, :, None] * xs[:, :t]
        y = y.reshape(b, t, h * p_dim).astype(x.dtype)
        new_cache = None if cache is None else {"S": s, "conv": new_conv}

    # gated RMSNorm + out proj (Mamba2 epilogue)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6).astype(y.dtype)) * params["norm_scale"]
    return linear(params["wo"], y), new_cache


# ---------------------------------------------------------------------------
# RWKV6 (Finch) — data-dependent per-channel decay, chunked GLA form
# ---------------------------------------------------------------------------

_LORA = 32
_CLAMP = 30.0


def init_rwkv(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    h = cfg.ssm_heads_padded or (d // cfg.ssm_head_dim)
    hk = cfg.ssm_head_dim
    dh = h * hk
    ks = jax.random.split(key, 12)
    return {
        "mu": 0.5 * jnp.ones((5, d), dtype),            # r,k,v,w,g token-shift
        "wr": _init(ks[0], (d, dh), d, dtype),
        "wk": _init(ks[1], (d, dh), d, dtype),
        "wv": _init(ks[2], (d, dh), d, dtype),
        "wg": _init(ks[3], (d, dh), d, dtype),
        "w0": -6.0 * jnp.ones((dh,), jnp.float32),      # base decay
        "wA": _init(ks[4], (d, _LORA), d, dtype),       # decay lora
        "wB": _init(ks[5], (_LORA, dh), _LORA, dtype),
        "u": jnp.zeros((dh,), jnp.float32),             # bonus
        "ln_scale": jnp.ones((dh,), dtype),
        "wo": _init(ks[6], (dh, d), dh, dtype),
        # channel mix
        "cm_mu": 0.5 * jnp.ones((2, d), dtype),
        "cm_wk": _init(ks[7], (d, cfg.d_ff), d, dtype),
        "cm_wv": _init(ks[8], (cfg.d_ff, d), cfg.d_ff, dtype),
        "cm_wr": _init(ks[9], (d, d), d, dtype),
    }


def _token_shift(x, mu, last: Optional[jax.Array] = None):
    """x + mu * (prev_token - x); ``last`` is the previous segment's tail."""
    b = x.shape[0]
    prev = jnp.concatenate(
        [jnp.zeros((b, 1, x.shape[-1]), x.dtype) if last is None
         else last[:, None, :], x[:, :-1]], axis=1)
    return x + mu * (prev - x)


def rwkv_time_mix(params, x, cfg: ModelConfig, unroll: bool,
                  cache: Optional[dict] = None):
    """x: (B,T,d) -> (B,T,d). cache: {"S": (B,H,K,V) fp32, "last": (B,d)}."""
    b, t, d = x.shape
    h = cfg.ssm_heads_padded or (d // cfg.ssm_head_dim)
    hk = cfg.ssm_head_dim
    last = None if cache is None else cache["last"]
    xr = _token_shift(x, params["mu"][0], last)
    xk = _token_shift(x, params["mu"][1], last)
    xv = _token_shift(x, params["mu"][2], last)
    xw = _token_shift(x, params["mu"][3], last)
    xg = _token_shift(x, params["mu"][4], last)

    r = linear(params["wr"], xr).reshape(b, t, h, hk).astype(jnp.float32)
    k = linear(params["wk"], xk).reshape(b, t, h, hk).astype(jnp.float32)
    v = linear(params["wv"], xv).reshape(b, t, h, hk).astype(jnp.float32)
    g = jax.nn.silu(linear(params["wg"], xg))

    lora = jnp.tanh(xw @ params["wA"]) @ params["wB"]       # (B,T,HK)
    logw = -jnp.exp(jnp.clip(params["w0"] + lora.astype(jnp.float32),
                             -8.0, 8.0))                    # < 0
    logw = jnp.maximum(logw, -_CLAMP).reshape(b, t, h, hk)
    u = params["u"].reshape(h, hk)

    s0 = (jnp.zeros((b, h, hk, hk), jnp.float32) if cache is None
          else cache["S"])

    if cache is not None and t == 1:
        r1, k1, v1, w1 = r[:, 0], k[:, 0], v[:, 0], jnp.exp(logw[:, 0])
        kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
        y = jnp.einsum("bhk,bhkv->bhv", r1, s0 + u[None, :, :, None] * kv)
        s_new = s0 * w1[..., None] + kv
        y = y[:, None]                                       # (B,1,H,V)
        new_cache = {"S": s_new, "last": x[:, -1, :]}
    else:
        q_sz = min(CHUNK, 64)
        nq = -(-t // q_sz)
        pad = nq * q_sz - t
        def padq(vv, fill=0.0):
            return jnp.pad(vv, ((0, 0), (0, pad), (0, 0), (0, 0)),
                           constant_values=fill)
        rq = padq(r).reshape(b, nq, q_sz, h, hk)
        kq = padq(k).reshape(b, nq, q_sz, h, hk)
        vq = padq(v).reshape(b, nq, q_sz, h, hk)
        lwq = padq(logw).reshape(b, nq, q_sz, h, hk)  # pads decay log(1)=0

        def step(s, i):
            ri, ki, vi, lw = rq[:, i], kq[:, i], vq[:, i], lwq[:, i]
            cl = jnp.cumsum(lw, axis=1)                      # (B,Q,H,K) incl.
            cl_excl = cl - lw
            q_eff = ri * jnp.exp(jnp.maximum(cl_excl, -_CLAMP))
            k_eff = ki * jnp.exp(jnp.minimum(-cl, _CLAMP))
            scores = jnp.einsum("bihk,bjhk->bhij", q_eff, k_eff)
            mask = jnp.tril(jnp.ones((q_sz, q_sz), bool), k=-1)
            scores = jnp.where(mask[None, None], scores, 0.0)
            bonus = jnp.einsum("bihk,hk,bihk->bih", ri, u, ki)
            y_intra = jnp.einsum("bhij,bjhv->bihv", scores, vi) \
                + bonus[..., None] * vi
            y_inter = jnp.einsum("bihk,bhkv->bihv", q_eff, s)
            cl_end = cl[:, -1]                               # (B,H,K)
            k_carry = ki * jnp.exp(jnp.maximum(cl_end[:, None] - cl, -_CLAMP))
            s_new = s * jnp.exp(cl_end)[..., None] \
                + jnp.einsum("bjhk,bjhv->bhkv", k_carry, vi)
            return s_new, y_intra + y_inter

        if unroll:
            ys, s = [], s0
            for i in range(nq):
                s, y_i = step(s, i)
                ys.append(y_i)
            y = jnp.concatenate(ys, axis=1)[:, :t]
        else:
            s, ys = jax.lax.scan(step, s0, jnp.arange(nq))
            y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_sz, h, hk)[:, :t]
        new_cache = None if cache is None else {"S": s, "last": x[:, -1, :]}

    # per-head groupnorm, gate, out-proj
    y = y.reshape(b, -1, h, hk)
    mu_ = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = ((y - mu_) * jax.lax.rsqrt(var + 1e-5)).reshape(b, y.shape[1], h * hk)
    y = (y.astype(x.dtype) * params["ln_scale"]) * g
    return linear(params["wo"], y), new_cache


def rwkv_channel_mix(params, x, cache: Optional[dict] = None):
    """Returns (out, new_cm_last). Reads the PREVIOUS segment tail from
    ``cache["cm_last"]``; the caller merges the returned tail into its new
    cache (the time-mix and channel-mix tails are distinct streams)."""
    last = None if cache is None else cache.get("cm_last")
    xk = _token_shift(x, params["cm_mu"][0], last)
    xr = _token_shift(x, params["cm_mu"][1], last)
    k = jnp.square(jax.nn.relu(linear(params["cm_wk"], xk)))
    out = jax.nn.sigmoid(linear(params["cm_wr"], xr)) * linear(params["cm_wv"], k)
    return out, x[:, -1, :]
