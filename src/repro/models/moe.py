"""Mixture-of-Experts block with capacity-based sort dispatch.

BitGNN tie-in (DESIGN.md §4): the token->(expert,slot) assignment built here
IS a binary sparse matrix D in {0,1}^(tokens x E*C); dispatch is D^T @ X and
combine is (D * gates) @ Y — the paper's BSpMM.FBF with "unweighted
adjacency" semantics. On TPU we realize D^T@X as gather/scatter (XLA lowers
to all-to-all under expert sharding), which is the dense-index equivalent of
the FRDC kernel's neighbor gather; the GNN stack exercises the actual packed
BSpMM kernel.

Experts are sharded over the ``model`` axis (EP); counts are padded to a
multiple of TP by ``resolve_for_mesh`` and padded experts are masked out of
routing (their FLOPs show up in the roofline useful-ratio, not in quality).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from .layers import _init, linear


def _maybe_constrain(x, *spec):
    """Apply a PartitionSpec constraint iff a mesh context is active (the
    dry-run / pjit path); no-op for single-device tests."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and "model" in (mesh.axis_names or ()):
            return jax.lax.with_sharding_constraint(
                x, jax.sharding.PartitionSpec(*spec))
    except Exception:
        pass
    return x


def init_moe(key, cfg: ModelConfig, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    e = cfg.moe_experts_padded or cfg.moe_experts
    ks = jax.random.split(key, 6)
    ff_in = 2 * ff if cfg.act == "swiglu" else ff
    p = {
        "router": _init(ks[0], (d, e), d, jnp.float32),
        "wi": _init(ks[1], (e, d, ff_in), d, dtype),
        "wo": _init(ks[2], (e, ff, d), ff, dtype),
    }
    if cfg.moe_shared_ff:
        sf = cfg.moe_shared_ff
        p["shared_wi"] = _init(ks[3], (d, 2 * sf if cfg.act == "swiglu" else sf),
                               d, dtype)
        p["shared_wo"] = _init(ks[4], (sf, d), sf, dtype)
        p["shared_gate"] = _init(ks[5], (d, 1), d, dtype)
    return p


def _capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(n_tokens * top_k * factor / n_experts)
    return max(-(-c // 8) * 8, 8)


def moe_block(params, x: jax.Array, cfg: ModelConfig):
    """x: (B, T, d) -> (B, T, d). Dispatch is GLOBAL by default;
    ``cfg.moe_groups > 1`` switches to per-data-shard grouped dispatch;
    ``cfg.moe_groups == -1`` uses the shard_map implementation (§Perf A3:
    per-device local routing + expert compute + ONE tensor-parallel psum —
    no global token gathers at all)."""
    if getattr(cfg, "moe_groups", 0) == -1:
        return _moe_shard_map(params, x, cfg)
    if getattr(cfg, "moe_groups", 0) > 1:
        return _moe_grouped(params, x, cfg)
    b, t, d = x.shape
    n = b * t
    e = cfg.moe_experts_padded or cfg.moe_experts
    k = cfg.moe_top_k
    flat = x.reshape(n, d)

    logits = (flat.astype(jnp.float32) @ params["router"])        # (N, E)
    if e > cfg.moe_experts:  # mask padded experts out of routing
        pad = jnp.full((e - cfg.moe_experts,), -1e9, logits.dtype)
        logits = logits + jnp.concatenate(
            [jnp.zeros((cfg.moe_experts,), logits.dtype), pad])[None, :]
    gates_all = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(gates_all, k)           # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    cap = _capacity(n, e, k, cfg.capacity_factor)

    # ---- build the binary dispatch matrix D (sorted-rank formulation) ----
    fe = expert_idx.reshape(-1)                                    # (N*k,)
    ft = jnp.repeat(jnp.arange(n), k)
    fg = gate_vals.reshape(-1).astype(x.dtype)
    order = jnp.argsort(fe, stable=True)
    se, st, sg = fe[order], ft[order], fg[order]
    starts = jnp.searchsorted(se, jnp.arange(e))
    rank = jnp.arange(n * k) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)               # trash slot

    # dispatch: Xe = D^T @ X  (binary-sparse x dense — BSpMM.FBF semantics)
    xe = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(flat[st])
    xe = xe[:-1].reshape(e, cap, d)
    # EP x DP: experts over "model", capacity rows over the dp axes — the
    # dispatch scatter becomes the MoE all-to-all.
    xe = _maybe_constrain(xe, "model", "data", None)

    # expert FFNs (EP-sharded einsums)
    h = jnp.einsum("ecd,edf->ecf", xe, params["wi"])
    if cfg.act == "swiglu":
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("ecf,efd->ecd", h, params["wo"])

    # combine: Y = (D * gates) @ Ye
    y_tok = ye.reshape(e * cap, d)[jnp.minimum(slot, e * cap - 1)]
    y_tok = y_tok * (sg * keep.astype(x.dtype))[:, None]
    out = jnp.zeros((n, d), x.dtype).at[st].add(y_tok)

    if "shared_wi" in params:
        out = out + _shared_expert(params, flat, cfg)
    return out.reshape(b, t, d)


def _shared_expert(params, flat, cfg):
    h = linear(params["shared_wi"], flat)
    if cfg.act == "swiglu":
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(h)
    shared = linear(params["shared_wo"], h)
    sgate = jax.nn.sigmoid(flat @ params["shared_gate"])
    return shared * sgate


def _moe_grouped(params, x: jax.Array, cfg: ModelConfig):
    """Per-dp-shard dispatch (§Perf): tokens are split into ``moe_groups``
    groups aligned with the dp axis; routing, capacity, sort, gather and
    combine are all group-local, so the only cross-device traffic is the
    expert-parallel exchange of the (G, E, cap_loc, d) dispatch buffer —
    no global token all-gather."""
    b, t, d = x.shape
    n = b * t
    g = cfg.moe_groups
    e = cfg.moe_experts_padded or cfg.moe_experts
    k = cfg.moe_top_k
    nl = n // g
    flat = x.reshape(g, nl, d)
    flat = _maybe_constrain(flat, "data", None, None)

    logits = flat.astype(jnp.float32) @ params["router"]          # (G,NL,E)
    if e > cfg.moe_experts:
        pad = jnp.full((e - cfg.moe_experts,), -1e9, logits.dtype)
        logits = logits + jnp.concatenate(
            [jnp.zeros((cfg.moe_experts,), logits.dtype), pad])[None, None, :]
    gates_all = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(gates_all, k)           # (G,NL,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    cap = _capacity(nl, e, k, cfg.capacity_factor)

    fe = expert_idx.reshape(g, nl * k)
    ft = jnp.broadcast_to(jnp.repeat(jnp.arange(nl), k)[None], (g, nl * k))
    fg = gate_vals.reshape(g, nl * k).astype(x.dtype)
    order = jnp.argsort(fe, axis=-1, stable=True)
    se = jnp.take_along_axis(fe, order, axis=-1)
    st = jnp.take_along_axis(ft, order, axis=-1)
    sg = jnp.take_along_axis(fg, order, axis=-1)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(se)
    rank = jnp.arange(nl * k)[None, :] - jnp.take_along_axis(starts, se, axis=-1)
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)

    gi = jnp.arange(g)[:, None]
    xe = jnp.zeros((g, e * cap + 1, d), x.dtype)
    xe = xe.at[gi, slot].set(jnp.take_along_axis(
        flat, st[..., None], axis=1))
    xe = xe[:, :-1].reshape(g, e, cap, d)
    xe = _maybe_constrain(xe, "data", "model", None, None)

    h = jnp.einsum("gecd,edf->gecf", xe, params["wi"])
    if cfg.act == "swiglu":
        gg, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gg) * u
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"])
    ye = _maybe_constrain(ye, "data", "model", None, None)

    y_rows = ye.reshape(g, e * cap, d)[gi, jnp.minimum(slot, e * cap - 1)]
    y_rows = y_rows * (sg * keep.astype(x.dtype))[..., None]
    out = jnp.zeros((g, nl, d), x.dtype).at[gi, st].add(y_rows)
    out = _maybe_constrain(out, "data", None, None)
    out = out.reshape(n, d)

    if "shared_wi" in params:
        out = out + _shared_expert(params, x.reshape(n, d), cfg)
    return out.reshape(b, t, d)


def _moe_shard_map(params, x: jax.Array, cfg: ModelConfig):
    """§Perf A3: explicit-SPMD MoE.

    Every device holds a data-shard of tokens (replicated across the model
    axis) and a model-shard of experts. Each device routes ITS tokens, keeps
    only assignments to ITS experts (local mask + local capacity slots — a
    purely local binary dispatch matrix, the paper's BSpMM operand), runs its
    expert FFNs, combines locally, and a single ``psum`` over the model axis
    adds up per-expert partial outputs. Collectives per layer: ONE (nl, d)
    all-reduce — no token all-gathers.
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or "model" not in (mesh.axis_names or ()):
        return moe_block(params, x,
                         __import__("dataclasses").replace(cfg, moe_groups=0))
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    b, t, d = x.shape
    n = b * t
    e = cfg.moe_experts_padded or cfg.moe_experts
    k = cfg.moe_top_k
    tp = mesh.shape["model"]
    e_loc = e // tp
    P = jax.sharding.PartitionSpec

    def body(flat, router, wi, wo):
        # flat (nl, d) local tokens; wi (e_loc, d, ff_in); wo (e_loc, ff, d)
        nl = flat.shape[0]
        m_idx = jax.lax.axis_index("model")
        e0 = m_idx * e_loc
        logits = flat.astype(jnp.float32) @ router                 # (nl, E)
        if e > cfg.moe_experts:
            pad = jnp.full((e - cfg.moe_experts,), -1e9, logits.dtype)
            logits = logits + jnp.concatenate(
                [jnp.zeros((cfg.moe_experts,), logits.dtype), pad])[None]
        gates_all = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(gates_all, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        cap = _capacity(nl, e, k, cfg.capacity_factor)

        fe = expert_idx.reshape(-1)
        ft = jnp.repeat(jnp.arange(nl), k)
        fg = gate_vals.reshape(-1).astype(flat.dtype)
        order = jnp.argsort(fe, stable=True)
        se, st, sg = fe[order], ft[order], fg[order]
        starts = jnp.searchsorted(se, jnp.arange(e))
        rank = jnp.arange(nl * k) - starts[se]
        local = (se >= e0) & (se < e0 + e_loc) & (rank < cap)
        slot = jnp.where(local, (se - e0) * cap + rank, e_loc * cap)

        xe = jnp.zeros((e_loc * cap + 1, d), flat.dtype).at[slot].set(flat[st])
        xe = xe[:-1].reshape(e_loc, cap, d)
        h = jnp.einsum("ecd,edf->ecf", xe, wi)
        if cfg.act == "swiglu":
            g_, u = jnp.split(h, 2, axis=-1)
            h = jax.nn.silu(g_) * u
        else:
            h = jax.nn.gelu(h)
        ye = jnp.einsum("ecf,efd->ecd", h, wo)
        y_rows = ye.reshape(e_loc * cap, d)[jnp.minimum(slot, e_loc * cap - 1)]
        y_rows = y_rows * (sg * local.astype(flat.dtype))[:, None]
        out = jnp.zeros((nl, d), flat.dtype).at[st].add(y_rows)
        return jax.lax.psum(out, "model")

    flat = x.reshape(n, d)
    out = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_axes, None), P(None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=P(dp_axes, None), check_vma=False,
    )(flat, params["router"], params["wi"], params["wo"])
    if "shared_wi" in params:
        out = out + _shared_expert(params, flat, cfg)
    return out.reshape(b, t, d)
