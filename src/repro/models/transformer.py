"""Unified model assembly for all 10 assigned architectures.

One parameter pytree + one forward covers: dense decoders (GQA+RoPE+SwiGLU),
MoE decoders (qwen2-moe, llama4-scout), the Zamba2 hybrid (Mamba2 backbone +
one weight-tied shared attention block), RWKV6, the enc-dec audio backbone
(seamless-m4t; frontend stub supplies frames), and the LLaVA VLM (frontend
stub supplies patch embeddings, projector in-model).

``unroll=True`` (dry-run) lays every layer out in the HLO so
``cost_analysis`` counts all FLOPs (DESIGN.md §7); ``unroll=False`` uses
``lax.scan`` over stacked homogeneous layers for training compile time.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import layers, moe as moe_mod, ssm
from .layers import (attention_block, embed, init_attention, init_embedding,
                     init_mlp, lm_head, linear, mlp_block, rmsnorm, _init)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_norm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def _init_block(key, kind: str, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind == "dense":
        return {"ln1": _init_norm(d, dtype),
                "attn": init_attention(ks[0], cfg, dtype),
                "ln2": _init_norm(d, dtype),
                "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.act, dtype)}
    if kind == "moe":
        return {"ln1": _init_norm(d, dtype),
                "attn": init_attention(ks[0], cfg, dtype),
                "ln2": _init_norm(d, dtype),
                "moe": moe_mod.init_moe(ks[1], cfg, dtype)}
    if kind in ("mamba", "mamba_attn"):
        return {"ln1": _init_norm(d, dtype),
                "mamba": ssm.init_mamba(ks[0], cfg, dtype)}
    if kind == "rwkv":
        return {"ln1": _init_norm(d, dtype),
                "tm": ssm.init_rwkv(ks[0], cfg, dtype),
                "ln2": _init_norm(d, dtype)}
    if kind == "encdec":   # decoder block with cross attention
        return {"ln1": _init_norm(d, dtype),
                "attn": init_attention(ks[0], cfg, dtype),
                "ln_x": _init_norm(d, dtype),
                "cross": init_attention(ks[1], cfg, dtype),
                "ln2": _init_norm(d, dtype),
                "mlp": init_mlp(ks[2], d, cfg.d_ff, cfg.act, dtype)}
    raise ValueError(kind)


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = cfg.compute_dtype
    d = cfg.d_model
    keys = jax.random.split(key, cfg.n_layers + cfg.enc_layers + 8)
    p = {"embed": init_embedding(keys[0], cfg, dtype),
         "final_ln": _init_norm(d, dtype)}

    if cfg.is_encdec:
        p["frontend_proj"] = _init(keys[1], (cfg.frontend_dim, d),
                                   cfg.frontend_dim, dtype)
        p["enc_blocks"] = [
            _init_block(keys[2 + i], "dense", cfg, dtype)
            for i in range(cfg.enc_layers)]
        p["enc_ln"] = _init_norm(d, dtype)
        p["blocks"] = [
            _init_block(keys[2 + cfg.enc_layers + i], "encdec", cfg, dtype)
            for i in range(cfg.dec_layers)]
        return p

    if cfg.family == "vlm":
        k1, k2 = jax.random.split(keys[1])
        p["projector"] = {
            "w1": _init(k1, (cfg.frontend_dim, d), cfg.frontend_dim, dtype),
            "w2": _init(k2, (d, d), d, dtype)}

    pattern = cfg.block_pattern()
    p["blocks"] = [
        _init_block(keys[2 + i], pattern[i], cfg, dtype)
        for i in range(cfg.n_layers)]
    if cfg.family == "hybrid" and cfg.attn_every:
        # ONE shared (weight-tied) attention+mlp block (Zamba2)
        p["shared_attn"] = {
            "ln1": _init_norm(d, dtype),
            "attn": init_attention(keys[-2], cfg, dtype),
            "ln2": _init_norm(d, dtype),
            "mlp": init_mlp(keys[-1], d, cfg.d_ff, cfg.act, dtype)}
    return p


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _apply_block(bp, kind, x, positions, cfg, unroll, q_chunk,
                 cache=None, cache_pos=None, shared=None, enc_memory_kv=None):
    """Returns (x, new_cache)."""
    if kind in ("dense", "moe"):
        h, new_c = attention_block(bp["attn"], rmsnorm(x, bp["ln1"]["scale"]),
                                   positions, cfg, q_chunk=q_chunk,
                                   cache=cache, cache_pos=cache_pos)
        x = x + h
        inner = rmsnorm(x, bp["ln2"]["scale"])
        if kind == "moe":
            x = x + moe_mod.moe_block(bp["moe"], inner, cfg)
        else:
            x = x + mlp_block(bp["mlp"], inner, cfg.act)
        return x, new_c
    if kind in ("mamba", "mamba_attn"):
        h, new_c = ssm.mamba_block(bp["mamba"], rmsnorm(x, bp["ln1"]["scale"]),
                                   cfg, unroll, cache=cache)
        x = x + h
        if kind == "mamba_attn":
            sc = None if cache is None else cache.get("shared")
            h, new_sc = attention_block(
                shared["attn"], rmsnorm(x, shared["ln1"]["scale"]), positions,
                cfg, q_chunk=q_chunk, cache=sc, cache_pos=cache_pos)
            x = x + h
            x = x + mlp_block(shared["mlp"],
                              rmsnorm(x, shared["ln2"]["scale"]), cfg.act)
            if new_c is not None or new_sc is not None:
                new_c = {**(new_c or {}), "shared": new_sc}
        return x, new_c
    if kind == "rwkv":
        h, tm_c = ssm.rwkv_time_mix(bp["tm"], rmsnorm(x, bp["ln1"]["scale"]),
                                    cfg, unroll, cache=cache)
        x = x + h
        inner = rmsnorm(x, bp["ln2"]["scale"])
        h, cm_last = ssm.rwkv_channel_mix(bp["tm"], inner, cache=cache)
        new_c = None if cache is None else {**tm_c, "cm_last": cm_last}
        return x + h, new_c
    if kind == "encdec":
        h, new_c = attention_block(bp["attn"], rmsnorm(x, bp["ln1"]["scale"]),
                                   positions, cfg, q_chunk=q_chunk,
                                   cache=cache, cache_pos=cache_pos)
        x = x + h
        h, _ = attention_block(bp["cross"], rmsnorm(x, bp["ln_x"]["scale"]),
                               positions, cfg, q_chunk=q_chunk,
                               kv_override=enc_memory_kv)
        x = x + h
        x = x + mlp_block(bp["mlp"], rmsnorm(x, bp["ln2"]["scale"]), cfg.act)
        return x, new_c
    raise ValueError(kind)


def _encode(params, cfg, frames, q_chunk):
    """Audio/speech encoder: frontend stub frames -> memory (B, Tf, d)."""
    x = frames.astype(cfg.compute_dtype) @ params["frontend_proj"]
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                                 frames.shape[:2])
    for bp in params["enc_blocks"]:
        h, _ = attention_block(bp["attn"], rmsnorm(x, bp["ln1"]["scale"]),
                               positions, cfg, causal=False, q_chunk=q_chunk)
        x = x + h
        x = x + mlp_block(bp["mlp"], rmsnorm(x, bp["ln2"]["scale"]), cfg.act)
    return rmsnorm(x, params["enc_ln"]["scale"])


def _cross_kv(params, cfg, memory):
    """Precompute cross-attention K/V per decoder layer from enc memory."""
    b, tf, d = memory.shape
    hd, kvc = cfg.head_dim, layers.kv_compute_heads(cfg)
    out = []
    for bp in params["blocks"]:
        k = linear(bp["cross"]["wk"], memory).reshape(b, tf, kvc, hd)
        v = linear(bp["cross"]["wv"], memory).reshape(b, tf, kvc, hd)
        out.append((k, v))
    return out


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens: jax.Array,
            image_embeds: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None,
            unroll: bool = True, q_chunk: int = 0,
            block_remat: bool = False, boundary_sharding=None,
            logits_sharding=None) -> jax.Array:
    """tokens (B, T_text) -> logits (B, T_total, vocab_padded).

    ``block_remat``: jax.checkpoint around every block (activation memory =
    layer boundaries only). ``boundary_sharding``: NamedSharding constraint
    applied to the residual stream between blocks — P(dp, "model", None)
    gives Megatron-style sequence-parallel boundaries so per-device
    activation memory divides by TP as well as DP. ``logits_sharding``:
    constraint on the (B, T, V) logits (vocab-sharded xent)."""
    x = embed(params["embed"], tokens)
    if cfg.family == "vlm":
        assert image_embeds is not None
        img = image_embeds.astype(cfg.compute_dtype)
        img = jnp.tanh(img @ params["projector"]["w1"]) @ params["projector"]["w2"]
        x = jnp.concatenate([img, x], axis=1)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))

    enc_kv = None
    if cfg.is_encdec:
        assert frames is not None
        memory = _encode(params, cfg, frames, q_chunk)
        enc_kv = _cross_kv(params, cfg, memory)

    pattern = (("encdec",) * cfg.dec_layers if cfg.is_encdec
               else cfg.block_pattern())
    shared = params.get("shared_attn")

    def constrain(h):
        if boundary_sharding is not None:
            return jax.lax.with_sharding_constraint(h, boundary_sharding)
        return h

    def one_block(bp, h, kind, ekv):
        def blockfn(bp_, h_):
            out, _ = _apply_block(bp_, kind, h_, positions, cfg, unroll,
                                  q_chunk, shared=shared, enc_memory_kv=ekv)
            return out
        if block_remat:
            blockfn = jax.checkpoint(blockfn)
        return constrain(blockfn(bp, h))

    if unroll or cfg.is_encdec:
        for i, bp in enumerate(params["blocks"]):
            x = one_block(bp, x, pattern[i],
                          None if enc_kv is None else enc_kv[i])
    else:
        # scan over layers (or over PERIODS for periodic hybrid patterns):
        # one compiled body regardless of depth — the production train path.
        if len(set(pattern)) == 1:
            period = 1
        elif cfg.family == "hybrid" and cfg.attn_every:
            period = cfg.attn_every
        else:  # irregular pattern: no scan form — fall back to unrolled
            for i, bp in enumerate(params["blocks"]):
                x = one_block(bp, x, pattern[i], None)
            x = rmsnorm(x, params["final_ln"]["scale"])
            logits = lm_head(params["embed"], x, cfg.vocab)
            if logits_sharding is not None:
                logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
            return logits
        n_scan = (len(pattern) // period) * period
        if period == 1:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *params["blocks"][:n_scan])

            def body(h, bp):
                return one_block(bp, h, pattern[0], None), ()
            x, _ = jax.lax.scan(body, x, stacked)
        else:
            groups = [params["blocks"][i:i + period]
                      for i in range(0, n_scan, period)]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *[
                jax.tree.map(lambda *ys: jnp.stack(ys), *g) for g in groups])

            def body(h, grp):
                for j in range(period):
                    bp_j = jax.tree.map(lambda a: a[j], grp)
                    h = one_block(bp_j, h, pattern[j], None)
                return h, ()
            x, _ = jax.lax.scan(body, x, stacked)
        for i in range(n_scan, len(pattern)):          # leftover tail layers
            x = one_block(params["blocks"][i], x, pattern[i], None)

    x = rmsnorm(x, params["final_ln"]["scale"])
    logits = lm_head(params["embed"], x, cfg.vocab)
    if logits_sharding is not None:
        logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
    return logits


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0) -> dict:
    """Allocate decode caches (KV ring buffers / SSM states)."""
    dtype = cfg.compute_dtype
    hd, kvc = cfg.head_dim, layers.kv_compute_heads(cfg)
    h_ssm = cfg.ssm_heads_padded or (
        cfg.d_model // cfg.ssm_head_dim if cfg.ssm_head_dim else 0)

    def attn_cache():
        if cfg.kv_cache_quant == "int8":
            return {"k": jnp.zeros((batch, max_len, kvc, hd), jnp.int8),
                    "v": jnp.zeros((batch, max_len, kvc, hd), jnp.int8),
                    "k_scale": jnp.zeros((batch, max_len, kvc, 1), dtype),
                    "v_scale": jnp.zeros((batch, max_len, kvc, 1), dtype)}
        return {"k": jnp.zeros((batch, max_len, kvc, hd), dtype),
                "v": jnp.zeros((batch, max_len, kvc, hd), dtype)}

    caches = []
    pattern = (("encdec",) * cfg.dec_layers if cfg.is_encdec
               else cfg.block_pattern())
    for kind in pattern:
        if kind in ("dense", "moe", "encdec"):
            caches.append(attn_cache())
        elif kind in ("mamba", "mamba_attn"):
            c = {"S": jnp.zeros((batch, cfg.ssm_heads_padded or cfg.ssm_heads,
                                 cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
                 "conv": jnp.zeros((batch, 3, (cfg.ssm_heads_padded or
                                               cfg.ssm_heads) * cfg.ssm_head_dim),
                                   dtype)}
            if kind == "mamba_attn":
                c["shared"] = attn_cache()
            caches.append(c)
        elif kind == "rwkv":
            caches.append({"S": jnp.zeros((batch, h_ssm, cfg.ssm_head_dim,
                                           cfg.ssm_head_dim), jnp.float32),
                           "last": jnp.zeros((batch, cfg.d_model), dtype),
                           "cm_last": jnp.zeros((batch, cfg.d_model), dtype)})
    cache = {"layers": caches}
    if cfg.is_encdec and enc_len:
        d = cfg.d_model
        cache["enc_memory"] = jnp.zeros((batch, enc_len, d), dtype)
    return cache


def decode_step(params, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                pos: jax.Array):
    """One-token decode: tokens (B, 1), pos scalar -> (logits, new_cache)."""
    x = embed(params["embed"], tokens)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    pattern = (("encdec",) * cfg.dec_layers if cfg.is_encdec
               else cfg.block_pattern())
    enc_kv = None
    if cfg.is_encdec:
        enc_kv = _cross_kv(params, cfg, cache["enc_memory"])
    shared = params.get("shared_attn")
    new_layers = []
    for i, bp in enumerate(params["blocks"]):
        x, nc = _apply_block(
            bp, pattern[i], x, positions, cfg, unroll=True, q_chunk=0,
            cache=cache["layers"][i], cache_pos=pos, shared=shared,
            enc_memory_kv=None if enc_kv is None else enc_kv[i])
        new_layers.append(nc)
    x = rmsnorm(x, params["final_ln"]["scale"])
    logits = lm_head(params["embed"], x, cfg.vocab)
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    return logits, new_cache


def decode_chunk(params, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                 pos0: jax.Array):
    """``T`` single-token decode steps fused into one program: tokens
    (B, T), pos0 the position of tokens[:, 0] -> (logits (B, T, V),
    new_cache).

    Bit-exact with a python loop of :func:`decode_step` by construction —
    the scan body IS ``decode_step``, so every step runs the exact
    single-token kernels (including the SSM blocks' exact recurrent branch,
    not the O(T^2) chunked prefill path). One compile covers any decode
    length that scans the same ``T``, which is what lets the token serving
    tier hold steady-state recompiles at zero across varied prompt/decode
    lengths."""

    def body(c, xs):
        tok, pos = xs
        logits, c = decode_step(params, cfg, c, tok[:, None], pos)
        return c, logits[:, 0]

    t = tokens.shape[1]
    positions = pos0 + jnp.arange(t, dtype=jnp.int32)
    new_cache, logits = jax.lax.scan(body, cache,
                                     (jnp.swapaxes(tokens, 0, 1), positions))
    return jnp.swapaxes(logits, 0, 1), new_cache
