"""Token data pipeline: deterministic synthetic corpus + prefetching loader
with straggler mitigation.

The loader runs sample generation on a worker thread into a bounded queue;
``next_batch(timeout)`` implements BACKUP-SAMPLE substitution: if the worker
misses the deadline (a straggling input shard on a real cluster), the batch
is served from the last known-good batch so the training step never blocks —
the standard trade of determinism for tail latency. Misses are counted for
monitoring.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    """Markov-ish synthetic token stream — learnable next-token structure."""

    def __init__(self, vocab: int, seq_len: int, seed: int = 0,
                 n_states: int = 64):
        self.vocab = vocab
        self.seq_len = seq_len
        rng = np.random.default_rng(seed)
        self.trans = rng.integers(0, vocab, size=(n_states, 8))
        self.n_states = n_states

    def sample(self, rng: np.random.Generator, batch: int) -> dict:
        state = rng.integers(0, self.n_states, size=batch)
        toks = np.empty((batch, self.seq_len + 1), np.int32)
        for t in range(self.seq_len + 1):
            choice = rng.integers(0, 8, size=batch)
            toks[:, t] = self.trans[state, choice]
            state = (state * 31 + toks[:, t]) % self.n_states
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class PrefetchLoader:
    def __init__(self, source: SyntheticLM, batch: int, seed: int = 0,
                 prefetch: int = 2, timeout_s: float = 10.0):
        self.source = source
        self.batch = batch
        self.timeout_s = timeout_s
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._rng = np.random.default_rng(seed)
        self._last_good: Optional[dict] = None
        self.straggler_misses = 0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            b = self.source.sample(self._rng, self.batch)
            while not self._stop.is_set():
                try:
                    self._q.put(b, timeout=0.5)
                    break
                except queue.Full:
                    continue

    def next_batch(self) -> dict:
        try:
            b = self._q.get(timeout=self.timeout_s)
            self._last_good = b
            return b
        except queue.Empty:
            # straggler mitigation: serve the backup batch instead of stalling
            self.straggler_misses += 1
            if self._last_good is None:
                b = self.source.sample(np.random.default_rng(0), self.batch)
                self._last_good = b
            return self._last_good

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
