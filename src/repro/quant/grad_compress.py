"""1-bit gradient compression with error feedback (beyond-paper; built on the
paper's bit-packing substrate — DESIGN.md §4.3).

``compress_tree`` is the in-graph numerics (sign + per-tensor L1 scale +
EF residual). ``allreduce_1bit`` is the wire-level shard_map collective that
actually moves PACKED bits between data-parallel replicas — 32x fewer bytes
than an fp32 ring all-reduce; its HLO is measured by
``benchmarks/bench_grad_compress.py``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import bitops


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_leaf(g: jax.Array, err: jax.Array):
    """sign+scale with error feedback: returns (g_hat, new_err)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.mean(jnp.abs(gf))
    g_hat = jnp.where(gf >= 0, scale, -scale)
    return g_hat.astype(g.dtype), gf - g_hat


def compress_tree(grads: Any, err_state: Any) -> Tuple[Any, Any]:
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        gh, en = compress_leaf(g, e)
        out_g.append(gh)
        out_e.append(en)
    return jax.tree.unflatten(tdef, out_g), jax.tree.unflatten(tdef, out_e)


def allreduce_1bit(local_grad: jax.Array, mesh, axis: str = "data"):
    """Cross-replica mean of sign-compressed gradients, packed on the wire.

    Each replica packs sign bits (32x smaller), all-gathers the packed words
    + one fp scale, then votes: the decompressed mean of ±scale_i values.
    Input must be flat (n,) fp32; returns (n,) fp32.
    """
    n = local_grad.shape[0]

    def body(g):
        scale = jnp.mean(jnp.abs(g))
        packed = bitops.pack_bits((g >= 0).reshape(1, -1)).reshape(-1)
        all_packed = jax.lax.all_gather(packed, axis)        # (R, W)
        all_scale = jax.lax.all_gather(scale, axis)          # (R,)
        signs = bitops.unpack_pm1(all_packed, n, axis=-1)    # (R, n)
        return jnp.mean(signs * all_scale[:, None], axis=0)

    return compat.shard_map(body, mesh=mesh, in_specs=P(None),
                            out_specs=P(None), check_vma=False)(local_grad)
