from . import binary_linear, grad_compress
