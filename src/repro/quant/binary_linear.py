"""BitGNN binarized linear layers for the LM framework (DESIGN.md §4.1).

Weights are factorized Bi-GCN style — ``W ~= diag-free sign(W) * scale_out``
with a positive per-output-channel L1 scale — and stored bit-packed along the
contraction axis: 32x less HBM than bf16. ``layers.linear`` consumes the
packed dict transparently; on TPU the XNOR-popc Pallas kernel
(`repro.kernels.bmm_kernel`) is the fused execution path when activations are
also binarized (the in-graph unpack path keeps XLA-visibility for the
dry-run's cost analysis).

Quantization works on abstract (ShapeDtypeStruct) pytrees too, so the
dry-run can lower bit-packed models without allocating anything.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# weight-matrix keys eligible for binarization (projections only; SSM decay /
# norm / router params stay fp — DESIGN.md §Arch-applicability)
_QUANT_KEYS = frozenset({
    "wq", "wk", "wv", "wo", "wi", "wz", "wx", "wr", "wg",
    "shared_wi", "shared_wo", "cm_wk", "cm_wv", "cm_wr",
})


def quantize_linear(w: jax.Array) -> dict:
    """(in, out) fp weight -> {"packed": (out, ceil(in/32)) u32, "scale": (out,)}."""
    n_in = w.shape[0]
    scale = jnp.mean(jnp.abs(w.astype(jnp.float32)), axis=0).astype(w.dtype)
    wt = w.T                                         # (out, in)
    # pad the packed-word count to a multiple of 16 so the word axis divides
    # the model-parallel mesh axis (pad bits are 0 and sliced off on unpack)
    pad = (-n_in) % (32 * 16)
    bits = (wt >= 0)
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, pad)))
    grouped = bits.reshape(wt.shape[0], -1, 32).astype(jnp.uint32)
    weights = jnp.left_shift(jnp.uint32(1), jnp.arange(32, dtype=jnp.uint32))
    packed = jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)
    return {"packed": packed, "scale": scale}


def dequantize_linear(q: dict, n_in: int, dtype=jnp.bfloat16) -> jax.Array:
    k = jnp.arange(32, dtype=jnp.uint32)
    bits = (q["packed"][:, :, None] >> k) & jnp.uint32(1)
    pm1 = (2.0 * bits.astype(dtype) - 1.0).reshape(q["packed"].shape[0], -1)
    return (pm1[:, :n_in] * q["scale"][:, None].astype(dtype)).T


def _should_quantize(path, leaf) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim != 2:
        return False
    key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    return key in _QUANT_KEYS


def quantize_params(params: Any) -> Any:
    """Replace every eligible 2-D projection with its bit-packed form."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = {}
    for path, leaf in flat:
        out[path] = quantize_linear(leaf) if _should_quantize(path, leaf) else leaf
    # rebuild: quantized leaves are dicts -> rebuild the nested structure
    return _rebuild(params, out, ())


def _rebuild(node, table, path):
    if isinstance(node, dict):
        return {k: _rebuild(v, table, path + (jax.tree_util.DictKey(k),))
                for k, v in node.items()}
    if isinstance(node, list):
        return [_rebuild(v, table, path + (jax.tree_util.SequenceKey(i),))
                for i, v in enumerate(node)]
    if isinstance(node, tuple):
        return tuple(_rebuild(v, table, path + (jax.tree_util.SequenceKey(i),))
                     for i, v in enumerate(node))
    return table[path]


def quantized_param_bytes(params: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
