"""Pure-JAX optimizers: AdamW + schedules + clipping.

Sharding-aware by construction: optimizer state mirrors the param pytree, so
whatever NamedSharding the params carry propagates to ``mu``/``nu`` under jit
(first/second moments co-locate with their weights — FSDP-compatible).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = None

    def init(self, params: PyTree) -> AdamWState:
        zeros = jax.tree.map(jnp.zeros_like, params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.zeros_like, params))

    def _lr(self, step: jax.Array) -> jax.Array:
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads: PyTree, state: AdamWState,
               params: PyTree) -> tuple[PyTree, AdamWState]:
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return p - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                             + self.weight_decay * p)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(peak_lr: float, warmup_steps: int,
                    total_steps: int, floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def sgd_momentum(params: PyTree, grads: PyTree, velocity: PyTree,
                 lr: float, momentum: float = 0.9):
    velocity = jax.tree.map(lambda v, g: momentum * v + g, velocity, grads)
    params = jax.tree.map(lambda p, v: p - lr * v, params, velocity)
    return params, velocity
