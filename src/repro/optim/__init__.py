from .optimizer import AdamW, AdamWState, cosine_schedule, global_norm
