"""repro: BitGNN-on-TPU — multi-pod JAX framework (see README.md)."""
__version__ = "1.0.0"
