"""Serving launcher: continuous-batching engine on a reduced config (host) or
the full-config decode dry-run (single/multi mesh).

    python -m repro.launch.serve --arch stablelm-1.6b --requests 8
    python -m repro.launch.serve --arch llava-next-34b --mesh single
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--quant", default="none", choices=["none", "bitgnn"])
    args = ap.parse_args()

    if args.mesh in ("single", "multi"):
        from repro.launch.dryrun import run_cell
        import json
        r = run_cell(args.arch, "decode_32k", args.mesh, quant=args.quant)
        print(json.dumps(r, indent=2))
        return

    import jax
    import numpy as np
    from repro.configs import get_config, reduced_config
    from repro.models import transformer
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced_config(get_config(args.arch)).resolve_for_mesh(tp=1)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    if args.quant == "bitgnn":
        from repro.quant.binary_linear import quantize_params
        params = quantize_params(params)
    eng = ServeEngine(cfg, params, max_batch=4, max_len=256)
    rng = np.random.default_rng(0)
    import time
    t0 = time.time()
    for rid in range(args.requests):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, 8),
                           max_new_tokens=args.max_new))
    done = eng.run_until_done()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
