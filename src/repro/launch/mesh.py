"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (16, 16) = 256 chips, axes
("data", "model"). Multi-pod: (2, 16, 16) = 512 chips with a leading "pod"
axis (pure data parallelism across pods; ICI within a pod, DCN across).
"""
from __future__ import annotations

import jax


def _axis_types_kw(n_axes: int) -> dict:
    """``axis_types`` only exists on newer jax; older versions (<=0.4.x)
    default every axis to Auto anyway, so omit the kwarg there."""
    if hasattr(jax.sharding, "AxisType"):
        return dict(axis_types=(jax.sharding.AxisType.Auto,) * n_axes)
    return {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over the locally available devices (tests / examples)."""
    n = len(jax.devices())
    data = max(n // model, 1)
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_types_kw(2))


def make_shard_mesh(n_shards: int):
    """Mesh with a ``data`` axis of exactly ``n_shards`` devices — the shape
    the sharded serving halo collectives (ppermute ring) run over. Returns
    None when the host exposes fewer devices (callers fall back to the host
    loopback transport). CPU-only runners get multiple devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    import numpy as np
    devs = jax.devices()
    if len(devs) < n_shards:
        return None
    return jax.sharding.Mesh(np.asarray(devs[:n_shards]), ("data",))


def ensure_host_devices(n: int) -> bool:
    """Best-effort: expose >= ``n`` host (CPU) devices for the SPMD shard
    executor. Only effective BEFORE the jax backend initializes — appends
    the XLA host-platform flag to ``XLA_FLAGS`` (the same mechanism the CI
    multi-device job uses); once a backend exists the flag is inert and the
    caller must fall back (e.g. to the host-orchestrated executor). Returns
    whether ``n`` devices are actually available afterwards."""
    import os
    flag = "--xla_force_host_platform_device_count"
    flags = os.environ.get("XLA_FLAGS", "")
    if flag not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {flag}={int(n)}".strip()
    return len(jax.devices()) >= n


def dp_axes(mesh: jax.sharding.Mesh):
    """The data-parallel mesh axes (includes "pod" when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
