"""Production training launcher.

    python -m repro.launch.train --arch smollm-135m --steps 100 \
        [--mesh host|single|multi] [--quant bitgnn] [--compress-grads]

On this CPU box ``--mesh host`` (default) trains a reduced config for real;
``single``/``multi`` run the full config through the 256/512-chip dry-run
path instead (no hardware here — lower+compile+report, same code path a TPU
pod would execute). Real-TPU deployments add:
    --xla-flags "--xla_tpu_enable_async_collective_fusion=true
                 --xla_tpu_overlap_compute_collective_tc=true"
(plumbed through XLA_FLAGS for compute/communication overlap).
"""
from __future__ import annotations

import argparse
import dataclasses
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--quant", default="none", choices=["none", "bitgnn"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--xla-flags", default="")
    args = ap.parse_args()
    if args.xla_flags:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                                   + args.xla_flags)

    if args.mesh in ("single", "multi"):
        from repro.launch.dryrun import run_cell
        import json
        r = run_cell(args.arch, "train_4k", args.mesh, quant=args.quant)
        print(json.dumps(r, indent=2))
        return

    import jax
    from repro.configs import get_config, reduced_config
    from repro.data.pipeline import PrefetchLoader, SyntheticLM
    from repro.models import transformer
    from repro.optim.optimizer import AdamW, cosine_schedule
    from repro.quant import grad_compress as gc
    from repro.train.train_step import make_train_step
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = reduced_config(get_config(args.arch)).resolve_for_mesh(tp=1)
    opt = AdamW(lr=cosine_schedule(args.lr, 10, args.steps), clip_norm=1.0)
    step = make_train_step(cfg, opt, unroll=False,
                           compress_grads=args.compress_grads)
    loader = PrefetchLoader(SyntheticLM(cfg.vocab, args.seq), args.batch)

    def init_state():
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        if args.quant == "bitgnn":
            from repro.quant.binary_linear import quantize_params
            params = quantize_params(params)
        extra = gc.init_error_state(params) if args.compress_grads else ()
        return params, opt.init(params), extra

    trainer = Trainer(cfg, step, init_state, loader, args.ckpt_dir,
                      TrainerConfig(total_steps=args.steps, ckpt_every=25,
                                    log_every=10,
                                    compress_grads=args.compress_grads))
    out = trainer.run()
    loader.close()
    print(f"arch={args.arch} steps={out['steps']} "
          f"final_loss={out['final_loss']:.4f} wall={out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
