import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run (deliverable e): ``.lower().compile()`` every
(architecture x input-shape x mesh) cell on 512 placeholder devices.

Compile strategy (DESIGN.md §7): the PRODUCTION program — layers scanned for
train/prefill, fully unrolled for one-token decode — is what must lower and
compile per cell; its ``memory_analysis`` is the fit proof. Because XLA's
``cost_analysis`` counts a ``lax.scan`` body ONCE (verified), per-layer FLOP/
byte/collective numbers for scanned programs come from two small UNROLLED
probe compiles (1 and 2 pattern-periods) whose delta is extrapolated to the
full depth — exact for homogeneous stacks, period-aware for the zamba2
hybrid, validated against a fully-unrolled smollm reference cell.

Run one cell:   python -m repro.launch.dryrun --arch smollm-135m \
                    --shape train_4k --mesh single
Run everything: python -m repro.launch.dryrun --all   (a subprocess per cell)
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _opts(cfg, shape):
    return dict(
        remat=shape.kind == "train",
        seq_shard=shape.kind in ("train", "prefill"),
        q_chunk=2048 if shape.seq_len >= 8192 else 0,
        donate_cache=shape.kind == "decode",
    )


def _lower_cell(cfg, shape, mesh, opts, unroll: bool):
    """Build + lower the cell's program; returns (lowered, aux)."""
    from repro.distributed import sharding
    from repro.models import transformer
    from repro.optim.optimizer import AdamW
    from repro.quant.binary_linear import quantize_params
    from repro.train import train_step as ts
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    boundary = NamedSharding(mesh, P(dp, "model", None)) \
        if opts["seq_shard"] else None
    logits_sh = sharding.logits_sharding(mesh, shape.global_batch)

    abstract_params = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    if cfg.quant == "bitgnn":
        abstract_params = jax.eval_shape(quantize_params, abstract_params)
    p_shardings = sharding.param_shardings(abstract_params, mesh,
                                           fsdp=(shape.kind == "train"))
    batch = ts.input_specs(cfg, shape)

    if shape.kind == "train":
        opt = AdamW(lr=1e-4, weight_decay=0.1, clip_norm=1.0)
        abstract_opt = jax.eval_shape(opt.init, abstract_params)
        o_shardings = _opt_shardings(abstract_opt, p_shardings, mesh)
        step = ts.make_train_step(cfg, opt, unroll=unroll,
                                  q_chunk=opts["q_chunk"],
                                  remat=opts["remat"],
                                  boundary_sharding=boundary,
                                  logits_sharding=logits_sh)
        b_shardings = sharding.data_shardings(batch, mesh)
        jitted = jax.jit(step,
                         in_shardings=(p_shardings, o_shardings, b_shardings),
                         out_shardings=(p_shardings, o_shardings,
                                        sharding.replicated(mesh)),
                         donate_argnums=(0, 1))
        return jitted.lower(abstract_params, abstract_opt, batch)
    if shape.kind == "prefill":
        from repro.models import transformer as tr

        def prefill(params, b):
            kw = {k: b[k] for k in ("image_embeds", "frames") if k in b}
            return tr.forward(params, cfg, b["tokens"], unroll=unroll,
                              q_chunk=opts["q_chunk"],
                              boundary_sharding=boundary,
                              logits_sharding=logits_sh, **kw)
        b_shardings = sharding.data_shardings(batch, mesh)
        jitted = jax.jit(prefill, in_shardings=(p_shardings, b_shardings),
                         out_shardings=logits_sh)
        return jitted.lower(abstract_params, batch)
    # decode (always exact / unrolled)
    step = ts.make_serve_step(cfg)
    c_shardings = sharding.cache_shardings(batch["cache"], mesh)
    tok_sh = sharding.data_shardings(batch["tokens"], mesh)
    jitted = jax.jit(
        step,
        in_shardings=(p_shardings, c_shardings, tok_sh,
                      sharding.replicated(mesh)),
        out_shardings=(sharding.logits_sharding(mesh, shape.global_batch),
                       c_shardings),
        donate_argnums=(1,) if opts["donate_cache"] else ())
    return jitted.lower(abstract_params, batch["cache"], batch["tokens"],
                        batch["pos"])


def _measure(compiled) -> dict:
    from repro.distributed import hlo_analysis
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else (ca or {})
    colls = hlo_analysis.analyze_collectives(compiled.as_text())
    return dict(
        flops=float(ca.get("flops", 0.0)),
        bytes=float(ca.get("bytes accessed", 0.0)),
        coll_wire=int(colls.wire_bytes),
        coll_by_op={k: [int(colls.bytes_by_op[k]),
                        int(colls.count_by_op.get(k, 0))]
                    for k in colls.bytes_by_op},
        mem=dict(argument=int(mem.argument_size_in_bytes),
                 output=int(mem.output_size_in_bytes),
                 temp=int(mem.temp_size_in_bytes),
                 alias=int(mem.alias_size_in_bytes)),
    )


def _affine_probe(cfg, shape, mesh, opts, measure_key_fn):
    """SSM/hybrid probes: chunked-linear archs have step cost AFFINE in
    (L, T) — f(L,T) = ba + bb*T + L*la + L*lb*T. Four small probes at
    (L1,T1),(L1,T2),(L2,T1),(L2,T2) with chunks UNROLLED (tiny T) solve the
    system exactly; evaluate at (L*, T*). Zamba2's shared-attention is
    quadratic in T — corrected analytically (DESIGN.md §7)."""
    from repro.configs.base import SHAPES, ShapeConfig
    import dataclasses as dc
    hybrid = cfg.family == "hybrid" and cfg.attn_every
    p = cfg.attn_every if hybrid else 1
    l1, l2 = p, 2 * p
    t1, t2 = 512, 1024
    ls, ts = cfg.n_layers / p * p, shape.seq_len   # L* counted in layers
    lstar = cfg.n_layers / p                        # in periods
    fs = {}
    for li in (l1, l2):
        for ti in (t1, t2):
            pcfg = dc.replace(cfg, n_layers=li)
            pshape = dc.replace(shape, seq_len=ti)
            low = _lower_cell(pcfg, pshape, mesh, {**opts, "q_chunk": 0},
                              unroll=True)
            comp = low.compile()
            fs[(li, ti)] = _measure(comp)
            del comp, low

    def solve(key):
        f11, f12 = fs[(l1, t1)][key], fs[(l1, t2)][key]
        f21, f22 = fs[(l2, t1)][key], fs[(l2, t2)][key]
        lb = (f22 - f21 - f12 + f11) / ((l2 - l1) / p * (t2 - t1))
        la = (f21 - f11) / ((l2 - l1) / p) - lb * t1
        bb = (f12 - f11) / (t2 - t1) - (l1 / p) * lb
        ba = f11 - bb * t1 - (l1 / p) * (la + lb * t1)
        return ba + bb * ts + lstar * (la + lb * ts)

    out = {k: solve(k) for k in ("flops", "bytes", "coll_wire")}
    if hybrid and cfg.n_heads:
        # quadratic shared-attention correction (scores + AV): the affine
        # fit linearizes through (t1, t2); add the residual at T*.
        dp = mesh.shape["data"] * mesh.shape.get("pod", 1)
        b_loc = max(shape.global_batch // dp, 1)
        h_loc = (cfg.n_heads_padded or cfg.n_heads) // cfg.tp
        passes = 4.0 if shape.kind == "train" else 1.0
        n_attn = cfg.n_layers / cfg.attn_every

        def quad(t):
            return 2 * 2 * b_loc * h_loc * float(t) ** 2 * cfg.head_dim
        line = quad(t1) + (quad(t2) - quad(t1)) / (t2 - t1) * (ts - t1)
        out["flops"] += passes * n_attn * (quad(ts) - line)
    return out


def _probe_plan(cfg):
    """(probe configs, combine fn) for per-layer extrapolation."""
    if cfg.is_encdec:
        p1 = dataclasses.replace(cfg, enc_layers=1, dec_layers=1)
        p2 = dataclasses.replace(cfg, enc_layers=2, dec_layers=2)
        n = cfg.dec_layers

        def combine(f1, f2):
            return f1 + (n - 1) * (f2 - f1)
        return [p1, p2], combine
    if cfg.family == "hybrid" and cfg.attn_every:
        p = cfg.attn_every
        n_periods, leftover = divmod(cfg.n_layers, p)
        p1 = dataclasses.replace(cfg, n_layers=p)
        p2 = dataclasses.replace(cfg, n_layers=2 * p)
        p3 = dataclasses.replace(cfg, n_layers=p + 1)

        def combine(f1, f2, f3):
            return (f1 + (n_periods - 1) * (f2 - f1) + leftover * (f3 - f1))
        return [p1, p2, p3], combine
    p1 = dataclasses.replace(cfg, n_layers=1)
    p2 = dataclasses.replace(cfg, n_layers=2)
    n = cfg.n_layers

    def combine(f1, f2):
        return f1 + (n - 1) * (f2 - f1)
    return [p1, p2], combine


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             quant: str = "none", probe: bool = True,
             opt_overrides: dict | None = None,
             cfg_overrides: dict | None = None) -> dict:
    from repro.configs import SHAPES, get_config
    from repro.launch.mesh import make_production_mesh

    t_start = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    shape = SHAPES[shape_name]
    cfg = get_config(arch).resolve_for_mesh(tp=mesh.shape["model"])
    if quant != "none":
        cfg = dataclasses.replace(cfg, quant=quant)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    opts = _opts(cfg, shape)
    if opt_overrides:
        opts.update(opt_overrides)

    unroll_main = shape.kind == "decode"
    with jax.set_mesh(mesh):
        lowered = _lower_cell(cfg, shape, mesh, opts, unroll=unroll_main)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
        main = _measure(compiled)
        del compiled, lowered

        probes = {}
        if probe and not unroll_main:
            if cfg.family in ("ssm", "hybrid"):
                probes = _affine_probe(cfg, shape, mesh, opts, None)
            else:
                probe_cfgs, combine = _probe_plan(cfg)
                ms = []
                for i, pcfg in enumerate(probe_cfgs):
                    pl = _lower_cell(pcfg, shape, mesh, opts, unroll=True)
                    pc = pl.compile()
                    ms.append(_measure(pc))
                    del pc, pl
                probes = {
                    "flops": combine(*[m["flops"] for m in ms]),
                    "bytes": combine(*[m["bytes"] for m in ms]),
                    "coll_wire": combine(*[float(m["coll_wire"]) for m in ms]),
                }
    t_probe = time.time()

    n_dev = mesh.devices.size
    flops = probes.get("flops", main["flops"])
    hbytes = probes.get("bytes", main["bytes"])
    coll = probes.get("coll_wire", main["coll_wire"])
    base = get_config(arch)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "quant": quant, "n_devices": int(n_dev),
        "opts": {k: (bool(v) if isinstance(v, bool) else v)
                 for k, v in opts.items()},
        "mode": "unrolled-exact" if unroll_main else "scan+probe",
        "lower_s": round(t_lower - t_start, 2),
        "compile_s": round(t_compile - t_lower, 2),
        "probe_s": round(t_probe - t_compile, 2),
        "flops_per_device": float(flops),
        "bytes_per_device": float(hbytes),
        "collective_bytes_per_device": float(coll),
        "collectives_scanned_program": main["coll_by_op"],
        "memory": {**main["mem"],
                   "per_device_hbm_bytes": int(
                       (main["mem"]["argument"] + main["mem"]["output"]
                        - main["mem"]["alias"]) / n_dev
                       + main["mem"]["temp"] / n_dev)},
        "model": {
            "params": int(base.param_count()),
            "params_padded": int(cfg.param_count(padded=True)),
            "active_params": int(base.active_param_count()),
        },
    }
    return result


def _opt_shardings(abstract_opt, p_shardings, mesh):
    from repro.distributed.sharding import replicated
    from repro.optim.optimizer import AdamWState
    return AdamWState(step=replicated(mesh),
                      mu=jax.tree.map(lambda s: s, p_shardings),
                      nu=jax.tree.map(lambda s: s, p_shardings))


def cell_name(arch, shape, mesh_kind, quant="none"):
    q = "" if quant == "none" else f"-{quant}"
    return f"{arch}__{shape}__{mesh_kind}{q}"


def all_cells():
    """Single-pod cells first (they feed the roofline), then multi-pod."""
    from repro.configs import ARCHS, shapes_for
    for mesh_kind in ("single", "multi"):
        for arch in sorted(ARCHS):
            for shape in shapes_for(arch):
                yield arch, shape, mesh_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--quant", default="none", choices=["none", "bitgnn"])
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    if args.all:
        failures = []
        for arch, shape, mesh_kind in all_cells():
            out = RESULTS / f"{cell_name(arch, shape, mesh_kind)}.json"
            if out.exists() and not args.force:
                print(f"[skip] {out.name}", flush=True)
                continue
            print(f"[run ] {arch} x {shape} x {mesh_kind}", flush=True)
            t0 = time.time()
            try:
                # in-process: saves ~60s interpreter/jax startup per cell
                result = run_cell(arch, shape, mesh_kind,
                                  probe=(mesh_kind == "single"))
                out.write_text(json.dumps(result, indent=2))
                print(f"[done] {out.name} ({time.time()-t0:.0f}s)",
                      flush=True)
            except Exception:
                failures.append((arch, shape, mesh_kind))
                traceback.print_exc()
        print(f"\n{len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    try:
        result = run_cell(args.arch, args.shape, args.mesh, quant=args.quant,
                          probe=not args.no_probe)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    out = RESULTS / f"{cell_name(args.arch, args.shape, args.mesh, args.quant)}.json"
    out.write_text(json.dumps(result, indent=2))
    print(json.dumps(result, indent=2))


if __name__ == "__main__":
    main()
