"""Opt-in XLA process-environment tuning (latency hiding, async collectives).

XLA only reads ``XLA_FLAGS`` when the backend initializes, so these flags
must land in the environment BEFORE anything imports a jax backend — which
is why this module imports no jax and the benchmark harness calls
:func:`xla_tuned` before loading its sections. The flag set follows the
jax GPU performance guidance (latency-hiding scheduler + async collectives
+ priority async stream): it lets the scheduler overlap the serve path's
halo collectives and kernel DMA with compute, which is exactly the
overlap the multi-bucket co-launch and the fused per-layer kernels are
shaped for. Harmless off-GPU — unknown ``--xla_gpu_*`` flags are ignored
by the CPU/TPU backends.

Deliberately OPT-IN and never overriding: a user-set ``XLA_FLAGS`` wins
unconditionally (their tuning, not ours), and a backend that already
initialized makes the write a silent no-op, so we refuse and warn instead
of pretending the flags took effect.
"""
from __future__ import annotations

import os
import sys
import warnings

XLA_TUNED_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def _backend_initialized() -> bool:
    """Whether a jax backend already exists in this process (best effort:
    the bridge module's backend cache is non-empty)."""
    bridge = sys.modules.get("jax._src.xla_bridge")
    return bool(getattr(bridge, "_backends", None))


def xla_tuned(env: dict = os.environ) -> bool:
    """Install :data:`XLA_TUNED_FLAGS` into ``env``; True when applied.

    No-op returning False when ``XLA_FLAGS`` is already set (the user's
    flags win) or when a jax backend has already initialized (the flags
    could no longer take effect — warns, so a mis-ordered call site is
    loud rather than silently untuned)."""
    if env.get("XLA_FLAGS"):
        return False
    if _backend_initialized():
        warnings.warn(
            "repro.env.xla_tuned() called after jax backend init; "
            "XLA_FLAGS would be ignored — call it before importing "
            "anything that touches jax", RuntimeWarning, stacklevel=2)
        return False
    env["XLA_FLAGS"] = " ".join(XLA_TUNED_FLAGS)
    return True
