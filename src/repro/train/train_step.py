"""Train-step builder: loss, grad, AdamW update — donation-friendly and
pjit-shardable. Also ``input_specs()``: the ShapeDtypeStruct stand-ins for
every (arch x shape) dry-run cell (weak-type-correct, no allocation)."""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer
from repro.optim.optimizer import AdamW, AdamWState
from repro.quant import grad_compress as gc


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract model inputs for one dry-run cell.

    train/prefill: token batch (+ stub frontend tensors for vlm/audio);
    decode: one-token batch + the KV/state cache at seq_len.
    """
    b, t = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        t_text = t
        batch = {}
        if cfg.family == "vlm":
            t_text = t - cfg.frontend_len
            batch["image_embeds"] = sds((b, cfg.frontend_len,
                                         cfg.frontend_dim), jnp.bfloat16)
        if cfg.is_encdec:
            batch["frames"] = sds((b, cfg.frontend_len, cfg.frontend_dim),
                                  jnp.bfloat16)
        batch["tokens"] = sds((b, t_text), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = sds((b, t_text), jnp.int32)
        return batch
    # decode: cache holds seq_len history
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, t, enc_len=cfg.frontend_len
                                       if cfg.is_encdec else 0))
    return {"tokens": sds((b, 1), jnp.int32), "cache": cache,
            "pos": sds((), jnp.int32)}


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def make_loss_fn(cfg: ModelConfig, unroll: bool, q_chunk: int,
                 block_remat: bool = False, boundary_sharding=None,
                 logits_sharding=None) -> Callable:
    def loss_fn(params, batch):
        kw = {k: batch[k] for k in ("image_embeds", "frames") if k in batch}
        logits = transformer.forward(params, cfg, batch["tokens"],
                                     unroll=unroll, q_chunk=q_chunk,
                                     block_remat=block_remat,
                                     boundary_sharding=boundary_sharding,
                                     logits_sharding=logits_sharding, **kw)
        labels = batch["labels"]
        # align labels with the (possibly frontend-prefixed) logit sequence
        t_total = logits.shape[1]
        if labels.shape[1] < t_total:
            labels = jnp.pad(labels, ((0, 0), (t_total - labels.shape[1], 0)))
        return softmax_xent(logits, labels)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: AdamW, unroll: bool = False,
                    q_chunk: int = 0, compress_grads: bool = False,
                    remat: bool = False, boundary_sharding=None,
                    logits_sharding=None) -> Callable:
    """Returns train_step(params, opt_state, [err_state,] batch) -> ...

    ``compress_grads``: 1-bit sign+scale gradient compression with error
    feedback (paper's bit-packing substrate applied to the DP collective;
    DESIGN.md §4.3). ``remat``: per-block activation checkpointing.
    """
    loss_fn = make_loss_fn(cfg, unroll, q_chunk, block_remat=remat,
                           boundary_sharding=boundary_sharding,
                           logits_sharding=logits_sharding)

    if not compress_grads:
        def train_step(params, opt_state: AdamWState, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, {"loss": loss}
        return train_step

    def train_step_c(params, opt_state: AdamWState, err_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, err_state = gc.compress_tree(grads, err_state)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, err_state, {"loss": loss}
    return train_step_c


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, tokens, pos):
        logits, cache = transformer.decode_step(params, cfg, cache, tokens, pos)
        return logits, cache
    return serve_step


def make_prefill_step(cfg: ModelConfig, q_chunk: int = 2048,
                      boundary_sharding=None,
                      logits_sharding=None) -> Callable:
    def prefill_step(params, batch):
        kw = {k: batch[k] for k in ("image_embeds", "frames") if k in batch}
        return transformer.forward(params, cfg, batch["tokens"],
                                   unroll=True, q_chunk=q_chunk,
                                   boundary_sharding=boundary_sharding,
                                   logits_sharding=logits_sharding, **kw)
    return prefill_step
