"""Fault-tolerant training loop: periodic async checkpoints, crash-restart
recovery, failure injection for tests, elastic re-mesh on restore.

The recovery contract: a Trainer constructed over the same checkpoint dir
resumes from the newest COMPLETE manifest (atomic saves), replaying the data
stream deterministically from the restored step. ``FailureInjector`` raises
at a chosen step to exercise the path in CI — the same exception surface a
preempted TPU worker produces (the outer launcher restarts the process).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import PrefetchLoader, SyntheticLM
from repro.optim.optimizer import AdamW
from repro.quant import grad_compress as gc


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_step: int = -1
    fired: bool = False

    def maybe_fail(self, step: int):
        if step == self.fail_at_step and not self.fired:
            self.fired = True
            raise InjectedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    compress_grads: bool = False


class Trainer:
    def __init__(self, cfg, train_step: Callable, init_state: Callable,
                 loader: PrefetchLoader, ckpt_dir: str,
                 tcfg: TrainerConfig = TrainerConfig(),
                 failer: Optional[FailureInjector] = None,
                 shardings: Any = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.train_step = jax.jit(train_step, donate_argnums=(0, 1))
        self.init_state = init_state
        self.loader = loader
        self.ckpt = Checkpointer(ckpt_dir)
        self.failer = failer
        self.shardings = shardings
        self.history: list = []

    def _fresh_or_restored(self):
        params, opt_state, extra = self.init_state()
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            state = self.ckpt.restore(latest, (params, opt_state, extra),
                                      self.shardings)
            params, opt_state, extra = state
            start = latest
        return params, opt_state, extra, start

    def run(self) -> dict:
        params, opt_state, extra, start = self._fresh_or_restored()
        losses = []
        t0 = time.time()
        step = start
        for step in range(start, self.tcfg.total_steps):
            if self.failer is not None:
                self.failer.maybe_fail(step)
            batch = self.loader.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if self.tcfg.compress_grads:
                params, opt_state, extra, metrics = self.train_step(
                    params, opt_state, extra, batch)
            else:
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step + 1, (params, opt_state, extra))
            if (step + 1) % self.tcfg.log_every == 0:
                self.history.append(dict(step=step + 1, loss=losses[-1]))
        self.ckpt.save(self.tcfg.total_steps, (params, opt_state, extra),
                       blocking=True)
        return dict(final_loss=losses[-1] if losses else float("nan"),
                    losses=losses, steps=self.tcfg.total_steps - start,
                    wall_s=time.time() - t0,
                    straggler_misses=self.loader.straggler_misses)


def run_with_restarts(make_trainer: Callable[[], Trainer],
                      max_failures: int = 3) -> dict:
    """The outer launcher loop: restart the trainer on (injected) failures —
    the single-process analogue of a cluster controller rescheduling a job."""
    failures = 0
    while True:
        trainer = make_trainer()
        try:
            return trainer.run() | {"restarts": failures}
        except InjectedFailure:
            failures += 1
            trainer.ckpt.wait()
            if failures > max_failures:
                raise
