"""Sharded, async, fault-tolerant checkpointing.

Layout per step: ``<dir>/step_<N>/shard_<i>.npz`` + ``manifest.json``
(written LAST — a checkpoint without a complete manifest is ignored, which
makes saves atomic under crash). Restore reshards automatically: each leaf is
reassembled from its saved global array and re-placed under the CURRENT mesh,
so a run restarted on a different data-axis size (elastic scaling) just
works. Saves run on a background thread (training continues while the
previous step serializes) — ``wait()`` joins before the next save or exit.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save ----
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        self.wait()
        keys, leaves, _ = _flatten(state)
        # device -> host copy happens HERE (synchronously, consistent view);
        # serialization happens on the thread.
        host_leaves = [np.asarray(x) for x in leaves]

        def _write():
            out = self.dir / f"step_{step:08d}"
            out.mkdir(parents=True, exist_ok=True)
            npz_path = out / "shard_0.npz"
            np.savez(npz_path, **{f"a{i}": v for i, v in enumerate(host_leaves)})
            manifest = {
                "step": step,
                "time": time.time(),
                "keys": keys,
                "n_leaves": len(host_leaves),
                "shards": ["shard_0.npz"],
            }
            (out / "manifest.json").write_text(json.dumps(manifest))
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        done = sorted(p for p in self.dir.glob("step_*")
                      if (p / "manifest.json").exists())
        for old in done[: -self.keep]:
            for f in old.glob("*"):
                f.unlink()
            old.rmdir()

    # ---------------------------------------------------------- restore ----
    def latest_step(self) -> Optional[int]:
        done = sorted(p for p in self.dir.glob("step_*")
                      if (p / "manifest.json").exists())
        if not done:
            return None
        return int(done[-1].name.split("_")[1])

    def restore(self, step: Optional[int], like: Any,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (params pytree or abstract
        pytree); re-place under ``shardings`` when given (elastic re-mesh)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        out = self.dir / f"step_{step:08d}"
        manifest = json.loads((out / "manifest.json").read_text())
        data = np.load(out / manifest["shards"][0])
        leaves = [data[f"a{i}"] for i in range(manifest["n_leaves"])]
        keys, like_leaves, treedef = _flatten(like)
        assert keys == manifest["keys"], "checkpoint/model structure mismatch"
        if shardings is not None:
            sh_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
            leaves = [jax.device_put(v, s) for v, s in zip(leaves, sh_leaves)]
        else:
            leaves = [jnp.asarray(v) for v in leaves]
        return jax.tree_util.tree_unflatten(treedef, leaves)
