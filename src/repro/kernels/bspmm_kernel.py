"""Pallas TPU kernel: FRDC binary sparse x dense matmul (paper Algorithm 1).

TPU mapping of the paper's warp algorithm (§3.3.2):

  GPU (per warp)                      TPU (per grid step = one tile-GROUP)
  ------------------------------      ------------------------------------
  ① warp <- one 4x4-tile row          grid iterates the flattened group list
  ② 32 thr load 8 tiles + B rows      8 async DMAs gather neighbor rows
                                      HBM->VMEM scratch (scalar-prefetched
                                      col_idx drives the dynamic offsets)
  ③ shfl bit-concatenate              coarsen: shift/OR eight 4x4 tiles into
                                      four 32-bit adjacency words (VPU)
  ④ ballot+brev bit-transpose         vectorized 32x32 bit transpose of the
                                      gathered activation words
  ⑤ popc trinary dot                  popcount AND/ANDNOT on (Wf,32) lanes
  ⑥ ballot+brev binarized store       compare>=0, shift/OR pack, masked store
                                      on the LAST group of each tile-row

The grid walks groups in CSR order; groups of one tile-row are consecutive so
the (4, F) accumulator lives in VMEM scratch across steps (group_first resets
it, group_last flushes it). Output rows never revisit after their flush.

Two kernels:
  * ``bspmm_bits``  — packed ±1 activations (BSpMM.BB?; Algorithm 1 proper);
  * ``bspmm_fp``    — fp activations (BSpMM.FB?): the gathered (32, F) rows
    hit the MXU via a (4, 32) mask matmul instead of Step ④/⑤.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.frdc import FRDCMatrix, GROUP, TILE

WORD = 32


def _gather_copy(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t):
    """The Step-② DMA descriptor for neighbor slab ``t`` of group ``g``:
    4 packed activation rows at ``col_idx[g, t] * TILE`` -> VMEM scratch.

    Built through ONE helper for both halves of the start/wait pair: a
    TPU DMA wait must be issued with the SAME descriptor (source slice,
    destination, semaphore) the copy was started with — reconstructing
    the wait from a different source slice (as an earlier version did,
    waiting on ``x_hbm[0:TILE]`` for copies started at dynamic rows) is a
    latent hazard off interpret mode on real hardware."""
    row4 = col_idx_ref[g, t] * TILE
    return pltpu.make_async_copy(
        x_hbm.at[pl.ds(row4, TILE)], xg_ref.at[pl.ds(t * TILE, TILE)],
        copy_sems.at[t])


def _coarsen_one(tiles_i32: jax.Array) -> jax.Array:
    """(1, GROUP) int32 4x4-tiles -> (TILE,) uint32 adjacency words (Step ③)."""
    t32 = tiles_i32.astype(jnp.uint32).reshape(GROUP)
    j = jnp.arange(TILE, dtype=jnp.uint32)
    i = jnp.arange(TILE, dtype=jnp.uint32)
    tpos = jnp.arange(GROUP, dtype=jnp.uint32)
    bits = (t32[None, :, None] >> (i[:, None, None] * TILE + j)) & 1
    return jnp.sum(bits << (tpos[:, None] * TILE + j), axis=(1, 2),
                   dtype=jnp.uint32)


def _bit_transpose(bg: jax.Array) -> jax.Array:
    """(32, Wf) words-over-features -> (Wf, 32) words-over-neighbors (Step ④)."""
    k = jnp.arange(WORD, dtype=jnp.uint32)
    # bits[n, w, f] = bit f of word (n, w)
    bits = (bg[:, :, None] >> k) & jnp.uint32(1)
    # out[w, f] collects neighbor n at bit n
    return jnp.sum(bits << k[:, None, None], axis=0, dtype=jnp.uint32)


def _bits_kernel(col_idx_ref, first_ref, last_ref, row_ref, tiles_ref,
                 x_hbm, prefill_ref, out_ref, acc_ref, xg_ref, copy_sems, *,
                 trinary_s2: bool, binarize: bool, n_feat: int):
    del prefill_ref  # aliased to out; only read through the alias
    g = pl.program_id(0)

    # -- Step ②: gather 8 neighbor 4-row slabs of packed activations ---------
    for t in range(GROUP):
        _gather_copy(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t).start()
    for t in range(GROUP):
        _gather_copy(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t).wait()

    # -- Step ③: dynamic coarsening ------------------------------------------
    a_words = _coarsen_one(tiles_ref[...])                 # (TILE,) uint32

    # -- Step ④: bit-transpose the gathered activations ----------------------
    bt = _bit_transpose(xg_ref[...])                       # (Wf, 32)

    # -- Step ⑤: trinary popc dot-product ------------------------------------
    @pl.when(first_ref[g] == 1)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for i in range(TILE):
        a = a_words[i]
        if trinary_s2:
            c = (jax.lax.population_count(a & bt).astype(jnp.int32)
                 - jax.lax.population_count(a & ~bt).astype(jnp.int32))
        else:
            c = (2 * jax.lax.population_count(a & bt).astype(jnp.int32)
                 - jax.lax.population_count(a).astype(jnp.int32))
        acc_ref[i, :] += c.reshape(-1)                     # (Wf*32,) == (F,)

    # -- Step ⑥: binarize + pack + store on the row's last group -------------
    @pl.when(last_ref[g] == 1)
    def _():
        if binarize:
            signs = (acc_ref[...] >= 0)
            wf = signs.shape[1] // WORD
            grouped = signs.reshape(TILE, wf, WORD).astype(jnp.uint32)
            w = jnp.left_shift(jnp.uint32(1),
                               jnp.arange(WORD, dtype=jnp.uint32))
            packed = jnp.sum(grouped * w, axis=-1, dtype=jnp.uint32)
            if n_feat % WORD:
                mask = jnp.uint32((1 << (n_feat % WORD)) - 1)
                packed = packed.at[:, -1].set(packed[:, -1] & mask)
            out_ref[...] = packed
        else:
            out_ref[...] = acc_ref[...]


def _fp_kernel(col_idx_ref, first_ref, last_ref, row_ref, tiles_ref,
               x_hbm, prefill_ref, out_ref, acc_ref, xg_ref, copy_sems):
    del prefill_ref
    g = pl.program_id(0)
    for t in range(GROUP):
        _gather_copy(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t).start()
    for t in range(GROUP):
        _gather_copy(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t).wait()

    a_words = _coarsen_one(tiles_ref[...])                 # (TILE,)
    k = jnp.arange(GROUP * TILE, dtype=jnp.uint32)
    mask = ((a_words[:, None] >> k) & 1).astype(xg_ref.dtype)  # (4, 32)

    @pl.when(first_ref[g] == 1)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(mask, xg_ref[...],
                                preferred_element_type=acc_ref.dtype)

    @pl.when(last_ref[g] == 1)
    def _():
        out_ref[...] = acc_ref[...]


def _group_last(adj: FRDCMatrix) -> jax.Array:
    """1 iff the group is the last NONZERO group of its tile-row.

    All-zero groups are ``pad_frdc`` bucket padding (mapped to tile-row 0
    WITHOUT a first-of-row reset): they must never close a row — that would
    flush a stale accumulator over row 0's output — AND they must not hide
    the real last group of row 0 behind them (comparing against the
    immediate successor's row would). So each nonzero group flushes iff the
    NEXT nonzero group belongs to a different tile-row; zero groups
    contribute nothing and never flush (rows with no real groups keep the
    prefill value, which is exact)."""
    g = adj.group_row.shape[0]
    nonzero = (adj.tiles != 0).any(axis=-1)
    idx = jnp.arange(g, dtype=jnp.int32)
    key = jnp.where(nonzero, idx, g)
    # suffix-min -> index of the next nonzero group at-or-after each slot
    at_or_after = jax.lax.cummin(key[::-1])[::-1]
    nxt_idx = jnp.concatenate([at_or_after[1:],
                               jnp.full((1,), g, jnp.int32)])
    nxt_row = jnp.where(nxt_idx < g,
                        adj.group_row[jnp.clip(nxt_idx, 0, g - 1)], -1)
    return (nonzero & (adj.group_row != nxt_row)).astype(jnp.int32)


def _resolve_block(block_shape, f: int, packed_width: bool) -> int:
    """Validate the (rows, feats) block-shape tunable and return the padded
    feature width of one grid step's output block.

    The supported grid today is one FRDC tile-row (``TILE`` output rows) per
    step over the full feature width; ``feats`` pads the feature dimension
    up to a multiple of the requested block width (exact — zero columns).
    Multi-row blocks and a feature-block grid are the open TPU tuning
    directions this seam exists for; asking for them is an explicit error,
    not a silent fallback. Packed-word paths (``packed_width``) carry their
    features as 32-bit words, so the block width must be word-aligned there
    and the kernel keeps its word-native width.
    """
    if block_shape is None:
        return f
    rows, feats = block_shape
    if int(rows) != TILE:
        raise ValueError(
            f"bspmm block rows must be the FRDC tile-row height {TILE} "
            f"(got {rows}); multi-row output blocks are the open TPU "
            f"block-shape tuning direction")
    if feats is None:
        return f
    feats = int(feats)
    if feats <= 0:
        raise ValueError(f"block feats must be positive, got {feats}")
    if packed_width:
        # the packed kernels keep their word-native storage width, so a
        # block is legal when word-aligned OR exactly the REAL feature
        # width (which may be narrower than the padded word width — the
        # tail-masked last word); validation must therefore see the real
        # width, not the word-padded one
        if feats % WORD and feats != f:
            raise ValueError(
                f"packed BSpMM features are {WORD}-bit words; block feats "
                f"{feats} must be word-aligned or equal the real feature "
                f"width {f}")
        return f
    return -(-f // feats) * feats


def bspmm_bits(adj: FRDCMatrix, x_packed: jax.Array, n_feat: int | None = None,
               binarize: bool = True, trinary_mode: str = "s3_two_popc",
               interpret: bool = True, block_shape=None) -> jax.Array:
    """FRDC trinary aggregation of packed ±1 activations (Algorithm 1).

    ``x_packed``: (N, Wf) uint32. Returns (R4, Wf) uint32 bits when
    ``binarize`` else (R4, F) int32 counts, R4 = n_tile_rows*4 (crop to
    n_rows at the caller). Rows with no groups keep the prefill value
    (0 counts / all-ones bits == sign(0)).
    """
    n, wf = x_packed.shape
    f = wf * WORD if n_feat is None else int(n_feat)
    # validate the block tunable against the ACTUAL feature width (a caller
    # may serve n_feat narrower than the padded word width wf * WORD)
    _resolve_block(block_shape, f, packed_width=True)
    pad_rows = (-n) % TILE
    x_p = jnp.pad(x_packed, ((0, pad_rows), (0, 0)))
    r4 = adj.n_tile_rows * TILE
    g = adj.n_groups

    if binarize:
        out_shape = jax.ShapeDtypeStruct((r4, wf), jnp.uint32)
        out_spec = pl.BlockSpec((TILE, wf), lambda g_, ci, fi, la, ro: (ro[g_], 0))
        tailmask = jnp.uint32((1 << (f % WORD)) - 1) if f % WORD else jnp.uint32(0xFFFFFFFF)
        prefill = jnp.full((r4, wf), tailmask, jnp.uint32)
        prefill = prefill.at[:, :-1].set(jnp.uint32(0xFFFFFFFF)) if wf > 1 else prefill
    else:
        out_shape = jax.ShapeDtypeStruct((r4, wf * WORD), jnp.int32)
        out_spec = pl.BlockSpec((TILE, wf * WORD), lambda g_, ci, fi, la, ro: (ro[g_], 0))
        prefill = jnp.zeros((r4, wf * WORD), jnp.int32)

    kernel = functools.partial(
        _bits_kernel, trinary_s2=(trinary_mode == "s2_and_andnot"),
        binarize=binarize, n_feat=f)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(g,),
            in_specs=[
                pl.BlockSpec((1, GROUP), lambda g_, ci, fi, la, ro: (g_, 0)),
                pl.BlockSpec(memory_space=pl.ANY),         # activations in HBM
                pl.BlockSpec(memory_space=pl.ANY),         # prefill (aliased)
            ],
            out_specs=out_spec,
            scratch_shapes=[
                pltpu.VMEM((TILE, wf * WORD), jnp.int32),   # trinary acc
                pltpu.VMEM((GROUP * TILE, wf), jnp.uint32),  # gathered rows
                pltpu.SemaphoreType.DMA((GROUP,)),
            ],
        ),
        out_shape=out_shape,
        input_output_aliases={6: 0},
        interpret=interpret,
    )(adj.col_idx, adj.group_first, _group_last(adj), adj.group_row,
      adj.tiles.astype(jnp.int32), x_p, prefill)
    return out


def bspmm_fp(adj: FRDCMatrix, x: jax.Array, interpret: bool = True,
             block_shape=None) -> jax.Array:
    """FRDC aggregation of fp activations via MXU mask-matmul (BSpMM.FB?).

    ``x``: (N, F) float. Returns (R4, F) float; caller applies row/col scales
    and crops to n_rows. Col scales must already be folded into ``x``.
    ``block_shape``: optional (rows, feats) tunable — feats pads the feature
    dimension to the block-width grid (exact), rows must stay the tile-row
    height for now (see :func:`_resolve_block`).
    """
    n, f = x.shape
    f_pad = _resolve_block(block_shape, f, packed_width=False)
    pad_rows = (-n) % TILE
    x_p = jnp.pad(x, ((0, pad_rows), (0, f_pad - f)))
    r4 = adj.n_tile_rows * TILE
    g = adj.n_groups
    prefill = jnp.zeros((r4, f_pad), x.dtype)

    out = pl.pallas_call(
        _fp_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(g,),
            in_specs=[
                pl.BlockSpec((1, GROUP), lambda g_, ci, fi, la, ro: (g_, 0)),
                pl.BlockSpec(memory_space=pl.ANY),
                pl.BlockSpec(memory_space=pl.ANY),         # prefill (aliased)
            ],
            out_specs=pl.BlockSpec((TILE, f_pad), lambda g_, ci, fi, la, ro: (ro[g_], 0)),
            scratch_shapes=[
                pltpu.VMEM((TILE, f_pad), x.dtype),
                pltpu.VMEM((GROUP * TILE, f_pad), x.dtype),
                pltpu.SemaphoreType.DMA((GROUP,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((r4, f_pad), x.dtype),
        input_output_aliases={6: 0},
        interpret=interpret,
    )(adj.col_idx, adj.group_first, _group_last(adj), adj.group_row,
      adj.tiles.astype(jnp.int32), x_p, prefill)
    return out[:, :f]
