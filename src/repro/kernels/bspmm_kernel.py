"""Pallas TPU kernel: FRDC binary sparse x dense matmul (paper Algorithm 1).

TPU mapping of the paper's warp algorithm (§3.3.2):

  GPU (per warp)                      TPU (per grid step = one tile-GROUP)
  ------------------------------      ------------------------------------
  ① warp <- one 4x4-tile row          grid iterates the flattened group list
  ② 32 thr load 8 tiles + B rows      8 async DMAs gather neighbor rows
                                      HBM->VMEM scratch (scalar-prefetched
                                      col_idx drives the dynamic offsets)
  ③ shfl bit-concatenate              coarsen: shift/OR eight 4x4 tiles into
                                      four 32-bit adjacency words (VPU)
  ④ ballot+brev bit-transpose         vectorized 32x32 bit transpose of the
                                      gathered activation words
  ⑤ popc trinary dot                  popcount AND/ANDNOT on (Wf,32) lanes
  ⑥ ballot+brev binarized store       compare>=0, shift/OR pack, masked store
                                      on the LAST group of each tile-row

The grid walks groups in CSR order; groups of one tile-row are consecutive so
the (4, F) accumulator lives in VMEM scratch across steps (group_first resets
it, group_last flushes it). Output rows never revisit after their flush.

Two kernels:
  * ``bspmm_bits``  — packed ±1 activations (BSpMM.BB?; Algorithm 1 proper);
  * ``bspmm_fp``    — fp activations (BSpMM.FB?): the gathered (32, F) rows
    hit the MXU via a (4, 32) mask matmul instead of Step ④/⑤.

Two grid layouts per kernel:
  * default (``block_shape=None``): 1D grid over the flattened group list —
    the accumulator persists across grid steps (group_first resets, the last
    nonzero group of each tile-row flushes);
  * 2D block grid (``block_shape=(rows, feats)``): ``rows/TILE`` tile-rows x
    one feature block per grid step. Each step walks its tile-rows' group
    ranges off the scalar-prefetched ``grp_ptr`` with DOUBLE-BUFFERED DMA
    (the next group's packed columns stream in while the current one
    accumulates), writes its output block once, and — unlike the 1D grid —
    never visits ``pad_frdc`` bucket-padding groups (they live past
    ``grp_ptr[-1]``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.frdc import FRDCMatrix, GROUP, TILE

WORD = 32


def _gather_copy(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t):
    """The Step-② DMA descriptor for neighbor slab ``t`` of group ``g``:
    4 packed activation rows at ``col_idx[g, t] * TILE`` -> VMEM scratch.

    Built through ONE helper for both halves of the start/wait pair: a
    TPU DMA wait must be issued with the SAME descriptor (source slice,
    destination, semaphore) the copy was started with — reconstructing
    the wait from a different source slice (as an earlier version did,
    waiting on ``x_hbm[0:TILE]`` for copies started at dynamic rows) is a
    latent hazard off interpret mode on real hardware."""
    row4 = col_idx_ref[g, t] * TILE
    return pltpu.make_async_copy(
        x_hbm.at[pl.ds(row4, TILE)], xg_ref.at[pl.ds(t * TILE, TILE)],
        copy_sems.at[t])


def _coarsen_one(tiles_i32: jax.Array) -> jax.Array:
    """(1, GROUP) int32 4x4-tiles -> (TILE,) uint32 adjacency words (Step ③)."""
    t32 = tiles_i32.astype(jnp.uint32).reshape(GROUP)
    j = jnp.arange(TILE, dtype=jnp.uint32)
    i = jnp.arange(TILE, dtype=jnp.uint32)
    tpos = jnp.arange(GROUP, dtype=jnp.uint32)
    bits = (t32[None, :, None] >> (i[:, None, None] * TILE + j)) & 1
    return jnp.sum(bits << (tpos[:, None] * TILE + j), axis=(1, 2),
                   dtype=jnp.uint32)


def _bit_transpose(bg: jax.Array) -> jax.Array:
    """(32, Wf) words-over-features -> (Wf, 32) words-over-neighbors (Step ④)."""
    k = jnp.arange(WORD, dtype=jnp.uint32)
    # bits[n, w, f] = bit f of word (n, w)
    bits = (bg[:, :, None] >> k) & jnp.uint32(1)
    # out[w, f] collects neighbor n at bit n
    return jnp.sum(bits << k[:, None, None], axis=0, dtype=jnp.uint32)


def _bits_kernel(col_idx_ref, first_ref, last_ref, row_ref, tiles_ref,
                 x_hbm, prefill_ref, out_ref, acc_ref, xg_ref, copy_sems, *,
                 trinary_s2: bool, binarize: bool, n_feat: int):
    del prefill_ref  # aliased to out; only read through the alias
    g = pl.program_id(0)

    # -- Step ②: gather 8 neighbor 4-row slabs of packed activations ---------
    for t in range(GROUP):
        _gather_copy(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t).start()
    for t in range(GROUP):
        _gather_copy(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t).wait()

    # -- Step ③: dynamic coarsening ------------------------------------------
    a_words = _coarsen_one(tiles_ref[...])                 # (TILE,) uint32

    # -- Step ④: bit-transpose the gathered activations ----------------------
    bt = _bit_transpose(xg_ref[...])                       # (Wf, 32)

    # -- Step ⑤: trinary popc dot-product ------------------------------------
    @pl.when(first_ref[g] == 1)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for i in range(TILE):
        a = a_words[i]
        if trinary_s2:
            c = (jax.lax.population_count(a & bt).astype(jnp.int32)
                 - jax.lax.population_count(a & ~bt).astype(jnp.int32))
        else:
            c = (2 * jax.lax.population_count(a & bt).astype(jnp.int32)
                 - jax.lax.population_count(a).astype(jnp.int32))
        acc_ref[i, :] += c.reshape(-1)                     # (Wf*32,) == (F,)

    # -- Step ⑥: binarize + pack + store on the row's last group -------------
    @pl.when(last_ref[g] == 1)
    def _():
        if binarize:
            signs = (acc_ref[...] >= 0)
            wf = signs.shape[1] // WORD
            grouped = signs.reshape(TILE, wf, WORD).astype(jnp.uint32)
            w = jnp.left_shift(jnp.uint32(1),
                               jnp.arange(WORD, dtype=jnp.uint32))
            packed = jnp.sum(grouped * w, axis=-1, dtype=jnp.uint32)
            if n_feat % WORD:
                mask = jnp.uint32((1 << (n_feat % WORD)) - 1)
                packed = packed.at[:, -1].set(packed[:, -1] & mask)
            out_ref[...] = packed
        else:
            out_ref[...] = acc_ref[...]


def _fp_kernel(col_idx_ref, first_ref, last_ref, row_ref, tiles_ref,
               x_hbm, prefill_ref, out_ref, acc_ref, xg_ref, copy_sems):
    del prefill_ref
    g = pl.program_id(0)
    for t in range(GROUP):
        _gather_copy(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t).start()
    for t in range(GROUP):
        _gather_copy(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t).wait()

    a_words = _coarsen_one(tiles_ref[...])                 # (TILE,)
    k = jnp.arange(GROUP * TILE, dtype=jnp.uint32)
    mask = ((a_words[:, None] >> k) & 1).astype(xg_ref.dtype)  # (4, 32)

    @pl.when(first_ref[g] == 1)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(mask, xg_ref[...],
                                preferred_element_type=acc_ref.dtype)

    @pl.when(last_ref[g] == 1)
    def _():
        out_ref[...] = acc_ref[...]


def _group_last(adj: FRDCMatrix) -> jax.Array:
    """1 iff the group is the last NONZERO group of its tile-row.

    All-zero groups are ``pad_frdc`` bucket padding (mapped to tile-row 0
    WITHOUT a first-of-row reset): they must never close a row — that would
    flush a stale accumulator over row 0's output — AND they must not hide
    the real last group of row 0 behind them (comparing against the
    immediate successor's row would). So each nonzero group flushes iff the
    NEXT nonzero group belongs to a different tile-row; zero groups
    contribute nothing and never flush (rows with no real groups keep the
    prefill value, which is exact)."""
    g = adj.group_row.shape[0]
    nonzero = (adj.tiles != 0).any(axis=-1)
    idx = jnp.arange(g, dtype=jnp.int32)
    key = jnp.where(nonzero, idx, g)
    # suffix-min -> index of the next nonzero group at-or-after each slot
    at_or_after = jax.lax.cummin(key[::-1])[::-1]
    nxt_idx = jnp.concatenate([at_or_after[1:],
                               jnp.full((1,), g, jnp.int32)])
    nxt_row = jnp.where(nxt_idx < g,
                        adj.group_row[jnp.clip(nxt_idx, 0, g - 1)], -1)
    return (nonzero & (adj.group_row != nxt_row)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# 2D block grid: multi-row output blocks x feature blocks
# ---------------------------------------------------------------------------

class BlockPlan(NamedTuple):
    """Resolved (rows, feats) block tunable for the 2D grid.

    ``rows``: output rows per grid step — a positive multiple of TILE.
    ``feats``: feature width per grid step, or None for the full width.
    """
    rows: int
    feats: Optional[int]


def block_probe(block_shape, f: int, packed_width: bool) -> Optional[str]:
    """Capability probe for a (rows, feats) block shape: ``None`` when the
    grid supports it, else ONE message naming the violation AND the legal
    block-shape space (word alignment, real feature width) — callers get the
    whole picture from any rejection instead of three divergent branches."""
    if block_shape is None:
        return None
    if packed_width:
        feat_space = (f"a positive multiple of the {WORD}-bit word or "
                      f"exactly the real feature width {f} (packed kernels "
                      f"carry word-native features)")
    else:
        feat_space = (f"any positive width (the fp feature dim is "
                      f"zero-padded to the block grid; real width {f})")
    space = (f"legal BSpMM block shapes: rows = a positive multiple of the "
             f"FRDC tile-row height {TILE}; feats = None (full width) or "
             f"{feat_space}")
    rows, feats = block_shape
    rows = int(rows)
    if rows <= 0 or rows % TILE:
        return (f"unsupported bspmm block {tuple(block_shape)!r}: rows "
                f"{rows} is not a positive multiple of {TILE}; {space}")
    if feats is None:
        return None
    feats = int(feats)
    if feats <= 0:
        return (f"unsupported bspmm block {tuple(block_shape)!r}: feats "
                f"{feats} is not positive; {space}")
    if packed_width and feats % WORD and feats != f:
        return (f"unsupported bspmm block {tuple(block_shape)!r}: feats "
                f"{feats} is neither word-aligned nor the real feature "
                f"width; {space}")
    return None


def _block_plan(block_shape, f: int, packed_width: bool) -> Optional[BlockPlan]:
    """Validate the tunable; None routes to the 1D grid, a BlockPlan to the
    2D grid."""
    reason = block_probe(block_shape, f, packed_width)
    if reason is not None:
        raise ValueError(reason)
    if block_shape is None:
        return None
    rows, feats = block_shape
    return BlockPlan(int(rows), None if feats is None else int(feats))


def _resolve_block(block_shape, f: int, packed_width: bool) -> int:
    """Validate the (rows, feats) block-shape tunable and return the padded
    feature width of one grid step's output row-block.

    Packed-word paths (``packed_width``) keep their word-native storage
    width; fp paths zero-pad the feature dimension up to a multiple of the
    block width (exact). Rejections carry the full legal block-shape space —
    see :func:`block_probe`, which is also the non-raising capability test.
    """
    plan = _block_plan(block_shape, f, packed_width)
    if plan is None or plan.feats is None or packed_width:
        return f
    return -(-f // plan.feats) * plan.feats


def _gather_copy_grid(x_hbm, xg_ref, copy_sems, col_idx_ref, g, t, slot,
                      f0, fw):
    """Step-② DMA descriptor on the 2D grid: neighbor slab ``t`` of group
    ``g``, feature block ``[f0, f0+fw)``, into double-buffer slot ``slot``.

    Same discipline as :func:`_gather_copy`: the start AND wait halves are
    built through this ONE helper so the wait always carries the descriptor
    the copy was started with (source slice, destination, semaphore)."""
    row4 = col_idx_ref[g, t] * TILE
    return pltpu.make_async_copy(
        x_hbm.at[pl.ds(row4, TILE), pl.ds(f0, fw)],
        xg_ref.at[slot, pl.ds(t * TILE, TILE)],
        copy_sems.at[slot, t])


def _coarsen_group(tiles_ref, g) -> jax.Array:
    """Scalar-prefetched tiles row ``g`` -> (TILE,) uint32 adjacency words
    (Step ③ with SMEM-friendly scalar reads)."""
    t32 = jnp.stack([tiles_ref[g, t] for t in range(GROUP)])
    return _coarsen_one(t32.reshape(1, GROUP))


def _grid_walk(col_idx_ref, grp_ptr_ref, x_hbm, xg_ref, copy_sems,
               tr, f0, fw, process):
    """Double-buffered walk over tile-row ``tr``'s group range.

    Groups come from the scalar-prefetched ``grp_ptr`` (``pad_frdc`` bucket
    padding lives past ``grp_ptr[-1]`` and is never visited). While group
    ``i`` is processed out of slot ``i % 2``, group ``i+1``'s eight slabs
    stream into the other slot — the DMA overlap the 1D grid gets from the
    pipelined grid steps, kept here where one grid step owns many groups.
    ``process(g, slot)`` consumes the gathered slab."""
    g_lo = grp_ptr_ref[tr]
    n_g = grp_ptr_ref[tr + 1] - g_lo

    @pl.when(n_g > 0)
    def _():
        for t in range(GROUP):
            _gather_copy_grid(x_hbm, xg_ref, copy_sems, col_idx_ref,
                              g_lo, t, 0, f0, fw).start()

    def body(i, _):
        g = g_lo + i
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < n_g)
        def _():
            for t in range(GROUP):
                _gather_copy_grid(x_hbm, xg_ref, copy_sems, col_idx_ref,
                                  g + 1, t, jax.lax.rem(i + 1, 2),
                                  f0, fw).start()
        for t in range(GROUP):
            _gather_copy_grid(x_hbm, xg_ref, copy_sems, col_idx_ref,
                              g, t, slot, f0, fw).wait()
        process(g, slot)
        return 0

    jax.lax.fori_loop(0, n_g, body, 0)


def _fp_grid_kernel(col_idx_ref, grp_ptr_ref, tiles_ref, x_hbm, out_ref,
                    acc_ref, xg_ref, copy_sems, *, tb_rows: int, fw: int):
    rb = pl.program_id(0)
    f0 = pl.program_id(1) * fw
    acc_ref[...] = jnp.zeros_like(acc_ref)

    for tb in range(tb_rows):
        def process(g, slot, tb=tb):
            a_words = _coarsen_group(tiles_ref, g)             # (TILE,)
            k = jnp.arange(GROUP * TILE, dtype=jnp.uint32)
            mask = ((a_words[:, None] >> k) & 1).astype(xg_ref.dtype)
            acc_ref[tb * TILE:(tb + 1) * TILE, :] += jax.lax.dot(
                mask, xg_ref[slot], preferred_element_type=acc_ref.dtype)

        _grid_walk(col_idx_ref, grp_ptr_ref, x_hbm, xg_ref, copy_sems,
                   rb * tb_rows + tb, f0, fw, process)
    out_ref[...] = acc_ref[...]


def _bits_grid_kernel(col_idx_ref, grp_ptr_ref, tiles_ref, x_hbm, out_ref,
                      acc_ref, xg_ref, copy_sems, *, tb_rows: int, fbw: int,
                      trinary_s2: bool, binarize: bool, n_feat: int):
    rb = pl.program_id(0)
    w0 = pl.program_id(1) * fbw
    acc_ref[...] = jnp.zeros_like(acc_ref)

    for tb in range(tb_rows):
        def process(g, slot, tb=tb):
            a_words = _coarsen_group(tiles_ref, g)             # (TILE,)
            bt = _bit_transpose(xg_ref[slot])                  # (fbw, 32)
            for i in range(TILE):
                a = a_words[i]
                if trinary_s2:
                    c = (jax.lax.population_count(a & bt).astype(jnp.int32)
                         - jax.lax.population_count(a & ~bt).astype(jnp.int32))
                else:
                    c = (2 * jax.lax.population_count(a & bt).astype(jnp.int32)
                         - jax.lax.population_count(a).astype(jnp.int32))
                acc_ref[tb * TILE + i, :] += c.reshape(-1)

        _grid_walk(col_idx_ref, grp_ptr_ref, x_hbm, xg_ref, copy_sems,
                   rb * tb_rows + tb, w0, fbw, process)

    # rows whose group range is empty keep 0 counts — binarize packs them as
    # sign(0) = +1, matching the 1D grid's prefill semantics with no alias
    if binarize:
        signs = (acc_ref[...] >= 0)
        grouped = signs.reshape(tb_rows * TILE, fbw, WORD).astype(jnp.uint32)
        w = jnp.left_shift(jnp.uint32(1), jnp.arange(WORD, dtype=jnp.uint32))
        packed = jnp.sum(grouped * w, axis=-1, dtype=jnp.uint32)
        if n_feat % WORD:
            tail = jnp.uint32((1 << (n_feat % WORD)) - 1)
            widx = w0 + jnp.arange(fbw, dtype=jnp.int32)
            wmask = jnp.where(widx == n_feat // WORD, tail,
                              jnp.uint32(0xFFFFFFFF))
            packed = packed & wmask[None, :]
        out_ref[...] = packed
    else:
        out_ref[...] = acc_ref[...]


def _grid_dims(adj: FRDCMatrix, plan: BlockPlan, width: int):
    """Grid geometry + the grp_ptr cover for the padded row blocks.

    Returns (tb_rows, n_rb, fw, n_fb, grp_ptr) where ``grp_ptr`` is extended
    with repeats of its last value so every padded tile-row has an EMPTY
    group range (the pad groups past ``grp_ptr[-1]`` stay unvisited)."""
    tb_rows = plan.rows // TILE
    n_rb = -(-adj.n_tile_rows // tb_rows)
    fw = width if plan.feats is None else min(plan.feats, width)
    n_fb = -(-width // fw)
    gp = adj.grp_ptr
    extra = n_rb * tb_rows - adj.n_tile_rows
    if extra:
        gp = jnp.concatenate(
            [gp, jnp.broadcast_to(gp[-1], (extra,)).astype(gp.dtype)])
    return tb_rows, n_rb, fw, n_fb, gp


def _bspmm_fp_grid(adj: FRDCMatrix, x: jax.Array, plan: BlockPlan,
                   interpret: bool) -> jax.Array:
    n, f = x.shape
    tb_rows, n_rb, fw, n_fb, gp = _grid_dims(adj, plan, f)
    f_pad = n_fb * fw
    x_p = jnp.pad(x, (((0, (-n) % TILE), (0, f_pad - f))))
    r4 = adj.n_tile_rows * TILE

    out = pl.pallas_call(
        functools.partial(_fp_grid_kernel, tb_rows=tb_rows, fw=fw),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(n_rb, n_fb),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((tb_rows * TILE, fw),
                                   lambda rb, fb, ci, gp_, ti: (rb, fb)),
            scratch_shapes=[
                pltpu.VMEM((tb_rows * TILE, fw), x.dtype),
                pltpu.VMEM((2, GROUP * TILE, fw), x.dtype),
                pltpu.SemaphoreType.DMA((2, GROUP)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_rb * tb_rows * TILE, f_pad),
                                       x.dtype),
        interpret=interpret,
    )(adj.col_idx, gp, adj.tiles.astype(jnp.int32), x_p)
    return out[:r4, :f]


def _bspmm_bits_grid(adj: FRDCMatrix, x_packed: jax.Array, f: int,
                     binarize: bool, trinary_mode: str, plan: BlockPlan,
                     interpret: bool) -> jax.Array:
    n, wf = x_packed.shape
    feats_w = None if (plan.feats is None or plan.feats % WORD) \
        else plan.feats // WORD
    tb_rows, n_rb, fbw, n_fb, gp = _grid_dims(
        adj, BlockPlan(plan.rows, feats_w), wf)
    wf_pad = n_fb * fbw
    x_p = jnp.pad(x_packed, (((0, (-n) % TILE), (0, wf_pad - wf))))
    r4 = adj.n_tile_rows * TILE
    rb_rows = tb_rows * TILE

    if binarize:
        out_shape = jax.ShapeDtypeStruct((n_rb * rb_rows, wf_pad), jnp.uint32)
        out_spec = pl.BlockSpec((rb_rows, fbw),
                                lambda rb, fb, ci, gp_, ti: (rb, fb))
    else:
        out_shape = jax.ShapeDtypeStruct((n_rb * rb_rows, wf_pad * WORD),
                                         jnp.int32)
        out_spec = pl.BlockSpec((rb_rows, fbw * WORD),
                                lambda rb, fb, ci, gp_, ti: (rb, fb))

    kernel = functools.partial(
        _bits_grid_kernel, tb_rows=tb_rows, fbw=fbw,
        trinary_s2=(trinary_mode == "s2_and_andnot"),
        binarize=binarize, n_feat=f)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(n_rb, n_fb),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=out_spec,
            scratch_shapes=[
                pltpu.VMEM((rb_rows, fbw * WORD), jnp.int32),
                pltpu.VMEM((2, GROUP * TILE, fbw), jnp.uint32),
                pltpu.SemaphoreType.DMA((2, GROUP)),
            ],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(adj.col_idx, gp, adj.tiles.astype(jnp.int32), x_p)
    return out[:r4, :wf] if binarize else out[:r4, :wf * WORD]


def bspmm_bits(adj: FRDCMatrix, x_packed: jax.Array, n_feat: int | None = None,
               binarize: bool = True, trinary_mode: str = "s3_two_popc",
               interpret: bool = True, block_shape=None) -> jax.Array:
    """FRDC trinary aggregation of packed ±1 activations (Algorithm 1).

    ``x_packed``: (N, Wf) uint32. Returns (R4, Wf) uint32 bits when
    ``binarize`` else (R4, F) int32 counts, R4 = n_tile_rows*4 (crop to
    n_rows at the caller). Rows with no groups keep the prefill value
    (0 counts / all-ones bits == sign(0)). A ``block_shape`` routes to the
    2D block grid (multi-row x word-aligned feature blocks); None keeps the
    1D flattened-group grid.
    """
    n, wf = x_packed.shape
    f = wf * WORD if n_feat is None else int(n_feat)
    # validate the block tunable against the ACTUAL feature width (a caller
    # may serve n_feat narrower than the padded word width wf * WORD)
    plan = _block_plan(block_shape, f, packed_width=True)
    if plan is not None:
        return _bspmm_bits_grid(adj, x_packed, f, binarize, trinary_mode,
                                plan, interpret)
    pad_rows = (-n) % TILE
    x_p = jnp.pad(x_packed, ((0, pad_rows), (0, 0)))
    r4 = adj.n_tile_rows * TILE
    g = adj.n_groups

    if binarize:
        out_shape = jax.ShapeDtypeStruct((r4, wf), jnp.uint32)
        out_spec = pl.BlockSpec((TILE, wf), lambda g_, ci, fi, la, ro: (ro[g_], 0))
        tailmask = jnp.uint32((1 << (f % WORD)) - 1) if f % WORD else jnp.uint32(0xFFFFFFFF)
        prefill = jnp.full((r4, wf), tailmask, jnp.uint32)
        prefill = prefill.at[:, :-1].set(jnp.uint32(0xFFFFFFFF)) if wf > 1 else prefill
    else:
        out_shape = jax.ShapeDtypeStruct((r4, wf * WORD), jnp.int32)
        out_spec = pl.BlockSpec((TILE, wf * WORD), lambda g_, ci, fi, la, ro: (ro[g_], 0))
        prefill = jnp.zeros((r4, wf * WORD), jnp.int32)

    kernel = functools.partial(
        _bits_kernel, trinary_s2=(trinary_mode == "s2_and_andnot"),
        binarize=binarize, n_feat=f)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(g,),
            in_specs=[
                pl.BlockSpec((1, GROUP), lambda g_, ci, fi, la, ro: (g_, 0)),
                pl.BlockSpec(memory_space=pl.ANY),         # activations in HBM
                pl.BlockSpec(memory_space=pl.ANY),         # prefill (aliased)
            ],
            out_specs=out_spec,
            scratch_shapes=[
                pltpu.VMEM((TILE, wf * WORD), jnp.int32),   # trinary acc
                pltpu.VMEM((GROUP * TILE, wf), jnp.uint32),  # gathered rows
                pltpu.SemaphoreType.DMA((GROUP,)),
            ],
        ),
        out_shape=out_shape,
        input_output_aliases={6: 0},
        interpret=interpret,
    )(adj.col_idx, adj.group_first, _group_last(adj), adj.group_row,
      adj.tiles.astype(jnp.int32), x_p, prefill)
    return out


def bspmm_fp(adj: FRDCMatrix, x: jax.Array, interpret: bool = True,
             block_shape=None) -> jax.Array:
    """FRDC aggregation of fp activations via MXU mask-matmul (BSpMM.FB?).

    ``x``: (N, F) float. Returns (R4, F) float; caller applies row/col scales
    and crops to n_rows. Col scales must already be folded into ``x``.
    ``block_shape``: optional (rows, feats) tunable routing to the 2D block
    grid — multi-row output blocks x feature blocks, feats zero-padded to
    the block grid (exact); None keeps the 1D flattened-group grid (see
    :func:`block_probe` for the legal space).
    """
    n, f = x.shape
    plan = _block_plan(block_shape, f, packed_width=False)
    if plan is not None:
        return _bspmm_fp_grid(adj, x, plan, interpret)
    f_pad = f
    pad_rows = (-n) % TILE
    x_p = jnp.pad(x, ((0, pad_rows), (0, f_pad - f)))
    r4 = adj.n_tile_rows * TILE
    g = adj.n_groups
    prefill = jnp.zeros((r4, f_pad), x.dtype)

    out = pl.pallas_call(
        _fp_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(g,),
            in_specs=[
                pl.BlockSpec((1, GROUP), lambda g_, ci, fi, la, ro: (g_, 0)),
                pl.BlockSpec(memory_space=pl.ANY),
                pl.BlockSpec(memory_space=pl.ANY),         # prefill (aliased)
            ],
            out_specs=pl.BlockSpec((TILE, f_pad), lambda g_, ci, fi, la, ro: (ro[g_], 0)),
            scratch_shapes=[
                pltpu.VMEM((TILE, f_pad), x.dtype),
                pltpu.VMEM((GROUP * TILE, f_pad), x.dtype),
                pltpu.SemaphoreType.DMA((GROUP,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((r4, f_pad), x.dtype),
        input_output_aliases={6: 0},
        interpret=interpret,
    )(adj.col_idx, adj.group_first, _group_last(adj), adj.group_row,
      adj.tiles.astype(jnp.int32), x_p, prefill)
    return out[:, :f]
