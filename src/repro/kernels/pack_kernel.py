"""Pallas TPU kernel: fused binarize-and-pack (the BIN op, paper Fig. 2 ③).

The GPU version ballots a warp's 32 lane predicates into one word; on TPU we
compare a (TM, TF) VMEM tile against 0 and reduce 32-bit lane groups with a
shift/OR (a small reduction along the minor axis — stays in VREGs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD = 32


def _pack_kernel(x_ref, o_ref):
    signs = (x_ref[...] >= 0)
    tm, tf = signs.shape
    grouped = signs.reshape(tm, tf // WORD, WORD).astype(jnp.uint32)
    weights = jnp.left_shift(jnp.uint32(1), jnp.arange(WORD, dtype=jnp.uint32))
    o_ref[...] = jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_m", "block_f", "interpret"))
def binarize_pack(x: jax.Array, block_m: int = 256, block_f: int = 1024,
                  interpret: bool = True) -> jax.Array:
    """(M, F) float -> (M, ceil(F/32)) uint32 sign bits (bit=1 iff x>=0).

    Padding columns pack as 0 (pad-safety invariant: padded fp values are
    filled with -1 so their sign bit is 0).
    """
    m, f = x.shape
    bm = min(block_m, _ceil_mult(m, 8))
    bf = min(block_f, _ceil_mult(f, WORD))
    mp, fp_ = _ceil_mult(m, bm), _ceil_mult(f, bf)
    x_p = jnp.pad(x, ((0, mp - m), (0, fp_ - f)), constant_values=-1.0)

    out = pl.pallas_call(
        _pack_kernel,
        grid=(mp // bm, fp_ // bf),
        in_specs=[pl.BlockSpec((bm, bf), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bf // WORD), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, fp_ // WORD), jnp.uint32),
        interpret=interpret,
    )(x_p)
    return out[:m, : (f + WORD - 1) // WORD]


def _ceil_mult(x: int, m: int) -> int:
    return -(-x // m) * m
