"""Fused per-layer kernels: one Pallas launch per GNN layer.

The unfused serve path emits several device dispatches per layer — the dense
binary transform, one BSpMM ``pallas_call`` per adjacency (two for the
sharded intra+halo split), BN and the activation as separate XLA ops. The
bit-tensor-core study (Li & Su; PAPERS.md) shows the packed-bit-ops ceiling
sits far above what separate small launches reach, so this module emits the
WHOLE layer — BN -> binary transform -> BSpMM aggregation -> combine /
activation — as ONE ``pallas_call``:

  * :func:`fused_call` — the generic runner: evaluates an arbitrary jnp
    layer function over whole-array operands inside a single kernel body
    (no grid: one launch, one trace). Model weights enter as closure
    constants; every traced value (activations, BN stats, FRDC fields)
    is a kernel operand.
  * :func:`agg_fp` / :func:`agg_counts` / :func:`agg_fp_pair` — the
    aggregation stages expressed as VALUE-level group walks that a kernel
    body can trace (a ``pallas_call`` cannot nest another one). They walk
    ``grp_ptr`` row ranges and accumulate groups in EXACTLY the kernel
    order — sequential per tile-row, one ``(TILE, 32) @ (32, F)`` dot or
    popc per group — so fused results are BITWISE identical to the unfused
    kernels (both the 1D and 2D grids), not merely close. Scale handling
    mirrors ``ops._serve_fp_backend`` / ``ops.serve_fp_pair`` (col scales
    folded into the operand, the shared row scale applied ONCE after the
    intra+halo add).

Calls are counted in :data:`KERNEL_CALLS` at trace time — the
launches-per-layer regression metric (fused layer == 1) benches and tests
key on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.frdc import FRDCMatrix, GROUP, TILE

from .bspmm_kernel import WORD, _bit_transpose, _coarsen_one

# trace-time counters: [fused kernel launches, fused layers' aggregation
# calls folded into them] — reset/read by tests and the launch benches.
KERNEL_CALLS = {"fused": 0, "fused_aggs": 0}


def reset_counters() -> None:
    KERNEL_CALLS["fused"] = 0
    KERNEL_CALLS["fused_aggs"] = 0


# ---------------------------------------------------------------------------
# Value-level aggregation (kernel-body traceable, kernel-order bitwise)
# ---------------------------------------------------------------------------

def _pad_rows(x: jax.Array) -> jax.Array:
    pad = (-x.shape[0]) % TILE
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


def _neighbor_rows(col_idx_g: jax.Array) -> jax.Array:
    """(GROUP,) tile-columns of one group -> (32,) gathered row ids."""
    offs = jnp.arange(TILE, dtype=col_idx_g.dtype)
    return (col_idx_g[:, None] * TILE + offs).reshape(-1)


def _walk_fp(adj: FRDCMatrix, x: jax.Array) -> jax.Array:
    """Raw fp aggregation in kernel order: per tile-row, accumulate the
    ``grp_ptr`` group range sequentially with the (TILE, 32) mask dot —
    the same adds in the same order as the Pallas grids, so results are
    bitwise identical to them."""
    n_tr = adj.n_tile_rows
    f = x.shape[1]
    k = jnp.arange(GROUP * TILE, dtype=jnp.uint32)

    def g_body(g, acc):
        a_words = _coarsen_one(adj.tiles[g].astype(jnp.int32)[None])
        mask = ((a_words[:, None] >> k) & 1).astype(x.dtype)
        xg = x[_neighbor_rows(adj.col_idx[g])]
        return acc + jax.lax.dot(mask, xg, preferred_element_type=acc.dtype)

    def row_body(r, out):
        acc = jax.lax.fori_loop(adj.grp_ptr[r], adj.grp_ptr[r + 1], g_body,
                                jnp.zeros((TILE, f), x.dtype))
        return jax.lax.dynamic_update_slice(out, acc, (r * TILE, 0))

    return jax.lax.fori_loop(0, n_tr, row_body,
                             jnp.zeros((n_tr * TILE, f), x.dtype))


def _walk_counts(adj: FRDCMatrix, xp: jax.Array, trinary_s2: bool
                 ) -> jax.Array:
    """Raw trinary popc counts in kernel order (integer — exact)."""
    n_tr = adj.n_tile_rows
    wf = xp.shape[1]

    def g_body(g, acc):
        a_words = _coarsen_one(adj.tiles[g].astype(jnp.int32)[None])
        bt = _bit_transpose(xp[_neighbor_rows(adj.col_idx[g])])    # (wf, 32)
        rows = []
        for i in range(TILE):
            a = a_words[i]
            if trinary_s2:
                c = (jax.lax.population_count(a & bt).astype(jnp.int32)
                     - jax.lax.population_count(a & ~bt).astype(jnp.int32))
            else:
                c = (2 * jax.lax.population_count(a & bt).astype(jnp.int32)
                     - jax.lax.population_count(a).astype(jnp.int32))
            rows.append(c.reshape(-1))
        return acc + jnp.stack(rows)

    def row_body(r, out):
        acc = jax.lax.fori_loop(adj.grp_ptr[r], adj.grp_ptr[r + 1], g_body,
                                jnp.zeros((TILE, wf * WORD), jnp.int32))
        return jax.lax.dynamic_update_slice(out, acc, (r * TILE, 0))

    return jax.lax.fori_loop(0, n_tr, row_body,
                             jnp.zeros((n_tr * TILE, wf * WORD), jnp.int32))


def agg_fp(adj: FRDCMatrix, x: jax.Array, block_shape=None) -> jax.Array:
    """In-kernel twin of ``ops._serve_fp_backend``: col scales folded into
    the operand, raw kernel-order aggregation, crop, row scale."""
    del block_shape  # math-neutral inside one kernel body
    KERNEL_CALLS["fused_aggs"] += 1
    xin = x
    if adj.col_scale is not None:
        xin = xin * adj.col_scale[:, None].astype(x.dtype)
    out = _walk_fp(adj, _pad_rows(xin))[: adj.n_rows]
    if adj.row_scale is not None:
        out = out * adj.row_scale[:, None].astype(out.dtype)
    return out


def agg_counts(adj: FRDCMatrix, x_packed: jax.Array,
               trinary_mode: str = "s3_two_popc",
               block_shape=None) -> jax.Array:
    """In-kernel twin of ``ops._serve_bits_backend`` / ``serve_counts``:
    raw trinary counts, cropped to real rows (integer — exact across any
    intra/halo split)."""
    del block_shape
    KERNEL_CALLS["fused_aggs"] += 1
    xp = _pad_rows(x_packed)
    return _walk_counts(adj, xp, trinary_mode == "s2_and_andnot")[
        : adj.n_rows]


def agg_fp_pair(intra: FRDCMatrix, halo: FRDCMatrix, x_local: jax.Array,
                x_remote: jax.Array) -> jax.Array:
    """In-kernel twin of ``ops.serve_fp_pair``: the shared row scale is
    applied ONCE after the intra+halo add (the factored form XLA would
    rewrite to anyway — keeping host/SPMD/fused bit-identical)."""
    y = agg_fp(intra._replace(row_scale=None), x_local) \
        + agg_fp(halo._replace(row_scale=None), x_remote)
    if intra.row_scale is not None:
        y = y * intra.row_scale[:, None].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# The fused-layer runner
# ---------------------------------------------------------------------------

def fused_call(fn, *args, interpret: bool = True):
    """Evaluate ``fn(*args)`` as ONE Pallas kernel over whole-array operands.

    ``args`` is a pytree whose array leaves become kernel operands (``None``
    subtrees pass through); ``fn`` must return an array or a pytree of
    arrays. The kernel has no grid — a single launch computes the whole
    layer, with the layer's aggregation expressed through the value-level
    walks above (a kernel body cannot nest another ``pallas_call``).

    Model weights captured in ``fn``'s closure are hoisted into kernel
    operands too (``jax.closure_convert`` — Pallas forbids captured array
    constants); whole-array operands mean the layer must fit the serving
    bucket sizes this repo pads to (it does — the same arrays already live
    in VMEM across the unfused kernels' grid steps).
    """
    leaves, treedef = jax.tree.flatten(args)
    arrs = [jnp.asarray(l) for l in leaves]

    def call(*flat):
        return fn(*jax.tree.unflatten(treedef, flat))

    out_sds = jax.eval_shape(call, *arrs)
    out_leaves, out_tree = jax.tree.flatten(out_sds)
    # Hoist EVERY captured constant (weights, iotas) into an operand —
    # Pallas forbids captured array constants, and jax.closure_convert
    # only lifts differentiable ones. The kernel replays the jaxpr.
    closed = jax.make_jaxpr(call)(*arrs)
    consts = [jnp.asarray(c) for c in closed.consts]
    operands = arrs + consts
    KERNEL_CALLS["fused"] += 1

    def kernel(*refs):
        ins = [r[...] for r in refs[:len(operands)]]
        outs = jax.core.eval_jaxpr(closed.jaxpr, ins[len(arrs):],
                                   *ins[:len(arrs)])
        for r, o in zip(refs[len(operands):], outs):
            r[...] = o

    out = pl.pallas_call(
        kernel,
        out_shape=tuple(
            jax.ShapeDtypeStruct(s.shape, s.dtype) for s in out_leaves),
        interpret=interpret,
    )(*operands)
    return jax.tree.unflatten(out_tree, list(out))
