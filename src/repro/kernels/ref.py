"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These re-derive each kernel's math from first principles with dense jnp ops —
no shared code with the kernels beyond the bit-packing convention — so a test
failure localizes to the kernel, not the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops
from repro.core.frdc import FRDCMatrix, to_dense

WORD = 32


def bmm_xnor_ref(a_packed: jax.Array, b_packed: jax.Array,
                 n_bits: int) -> jax.Array:
    """Dense oracle: unpack to ±1 and matmul in int32."""
    a = np.asarray(bitops.unpack_pm1(a_packed, n_bits, dtype=jnp.int32))
    b = np.asarray(bitops.unpack_pm1(b_packed, n_bits, dtype=jnp.int32))
    return jnp.asarray(a @ b.T)


def bmm_xnor_bin_ref(a_packed: jax.Array, b_packed: jax.Array,
                     n_bits: int) -> jax.Array:
    out = bmm_xnor_ref(a_packed, b_packed, n_bits)
    return bitops.pack_bits(out >= 0, axis=-1)


def binarize_pack_ref(x: jax.Array) -> jax.Array:
    return bitops.pack_bits(np.asarray(x) >= 0, axis=-1)


def bspmm_bits_ref(adj: FRDCMatrix, x_packed: jax.Array, n_feat: int,
                   binarize: bool = True) -> jax.Array:
    """Dense oracle: decode FRDC to dense, unpack ±1 activations, matmul."""
    a = np.asarray(to_dense(adj, apply_scales=False))
    n = a.shape[1]
    act = np.asarray(bitops.unpack_pm1(x_packed, n_feat, dtype=jnp.int32))[:n]
    counts = (a.astype(np.int64) @ act.astype(np.int64)).astype(np.int32)
    r4 = adj.n_tile_rows * 4
    full = np.zeros((r4, n_feat), np.int32)
    full[:counts.shape[0]] = counts
    if not binarize:
        return jnp.asarray(full)
    return bitops.pack_bits(full >= 0, axis=-1)


def bspmm_fp_ref(adj: FRDCMatrix, x: jax.Array) -> jax.Array:
    """Dense oracle for the fp kernel (scales excluded, as in the kernel)."""
    a = np.asarray(to_dense(adj, apply_scales=False))
    out = a @ np.asarray(x)[: a.shape[1]]
    r4 = adj.n_tile_rows * 4
    full = np.zeros((r4, out.shape[1]), out.dtype)
    full[: out.shape[0]] = out
    return jnp.asarray(full)
