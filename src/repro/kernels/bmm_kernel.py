"""Pallas TPU kernel: dense binary (XNOR-popc) matmul — BMM.BB? compute core.

TPU adaptation of BSTC-style bit-GEMM (paper §3.3 references [28,31]):
operands are bit-packed along the contraction axis K into uint32 lanes; each
grid cell owns a (TM, TN) output tile held in VREGs/VMEM and marches over the
packed words with XOR+popcount on the VPU (there is no 1-bit MXU mode).

Layout: A (M, Wk) uint32, B (N, Wk) uint32 — B is the *transposed* weight
(packed along K), matching ``core.bmm.quantize_weight``. Output (M, N) int32
sign-count, or fused-binarized (M, N/32) uint32 when ``binarize=True``
(the paper's Step ⑥ fused bit-tensor store).

Block sizes default to (128, 128): MXU/VPU-aligned, VMEM per step =
TM*Wk*4 + TN*Wk*4 + TM*TN*4 bytes (< 1.5 MB for K=20480).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD = 32


def _xnor_popc_tile(a, b):
    """(TM, Wk) x (TN, Wk) -> (TM, TN) popcount(XOR) accumulated over words."""
    tm, wk = a.shape
    tn = b.shape[0]

    def body(w, acc):
        aw = jax.lax.dynamic_slice(a, (0, w), (tm, 1))        # (TM, 1)
        bw = jax.lax.dynamic_slice(b, (0, w), (tn, 1))        # (TN, 1)
        x = jax.lax.population_count(aw ^ bw.reshape(1, tn))  # (TM, TN)
        return acc + x.astype(jnp.int32)

    acc = jnp.zeros((tm, tn), jnp.int32)
    return jax.lax.fori_loop(0, wk, body, acc)


def _bmm_xnor_kernel(a_ref, b_ref, o_ref, *, n_bits: int):
    acc = _xnor_popc_tile(a_ref[...], b_ref[...])
    o_ref[...] = n_bits - 2 * acc


def _bmm_xnor_bin_kernel(a_ref, b_ref, o_ref, *, n_bits: int):
    """Fused Step ⑥: binarize the sign-counts and pack to uint32 in-kernel."""
    acc = _xnor_popc_tile(a_ref[...], b_ref[...])
    signs = (n_bits - 2 * acc) >= 0                          # (TM, TN) bool
    tm, tn = signs.shape
    grouped = signs.reshape(tm, tn // WORD, WORD).astype(jnp.uint32)
    weights = jnp.left_shift(jnp.uint32(1), jnp.arange(WORD, dtype=jnp.uint32))
    o_ref[...] = jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("n_bits", "binarize", "block_m",
                                             "block_n", "interpret"))
def bmm_xnor(a_packed: jax.Array, b_packed: jax.Array, n_bits: int,
             binarize: bool = False, block_m: int = 128, block_n: int = 128,
             interpret: bool = True) -> jax.Array:
    """sign(X) @ sign(W) on packed operands.

    a_packed: (M, Wk) uint32; b_packed: (N, Wk) uint32 (weight transposed).
    Returns (M, N) int32, or (M, N/32) uint32 bits when ``binarize``.
    M, N are padded up to block multiples internally and cropped.
    """
    m, wk = a_packed.shape
    n = b_packed.shape[0]
    assert b_packed.shape[1] == wk
    bm, bn = min(block_m, _ceil_mult(m, 8)), min(block_n, _ceil_mult(n, WORD))
    mp, np_ = _ceil_mult(m, bm), _ceil_mult(n, bn)
    a_p = jnp.pad(a_packed, ((0, mp - m), (0, 0)))
    b_p = jnp.pad(b_packed, ((0, np_ - n), (0, 0)))

    if binarize:
        kernel = functools.partial(_bmm_xnor_bin_kernel, n_bits=n_bits)
        out_shape = jax.ShapeDtypeStruct((mp, np_ // WORD), jnp.uint32)
        out_spec = pl.BlockSpec((bm, bn // WORD), lambda i, j: (i, j))
    else:
        kernel = functools.partial(_bmm_xnor_kernel, n_bits=n_bits)
        out_shape = jax.ShapeDtypeStruct((mp, np_), jnp.int32)
        out_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))

    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[pl.BlockSpec((bm, wk), lambda i, j: (i, 0)),
                  pl.BlockSpec((bn, wk), lambda i, j: (j, 0))],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(a_p, b_p)
    if not binarize:
        return out[:m, :n]
    # Crop to the logical word count and ZERO any tail bits belonging to
    # padded columns — chained popc consumers rely on 0-padding (pad-safety
    # invariant of core.bitops).
    wn = (n + WORD - 1) // WORD
    out = out[:m, :wn]
    tail = n % WORD
    if tail:
        mask = jnp.uint32((1 << tail) - 1)
        out = out.at[:, -1].set(out[:, -1] & mask)
    return out


def _ceil_mult(x: int, m: int) -> int:
    return -(-x // m) * m
