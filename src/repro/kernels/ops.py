"""Jitted dispatch layer over the Pallas kernels.

On TPU the kernels compile natively (``interpret=False``); on CPU they run in
interpret mode for correctness, but the pure-jnp ``repro.core`` paths are much
faster there — so dispatch prefers jnp off-TPU unless ``force_kernels`` is on
(tests set it to exercise the kernels).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bitops, bspmm as bspmm_core
from repro.core.binarize import BinTensor
from repro.core.frdc import FRDCMatrix, TILE

from . import bmm_kernel, bspmm_kernel, fused_layer, pack_kernel

_FORCE_KERNELS = False


def force_kernels(on: bool = True) -> None:
    global _FORCE_KERNELS
    _FORCE_KERNELS = on


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_kernels() -> bool:
    return _FORCE_KERNELS or _on_tpu()


def _interpret() -> bool:
    return not _on_tpu()


def kernels_active(use_pallas: bool = True) -> bool:
    """Whether a ``use_pallas`` request actually routes through Pallas
    (TPU backend, or ``force_kernels`` in tests)."""
    return use_pallas and _use_kernels()


def launch_stats(fn, *args) -> dict:
    """Trace ``fn(*args)`` and count its device-operation footprint.

    Returns ``dict(eqns=..., pallas_calls=...)`` where ``eqns`` is the
    number of jaxpr equations (recursing through control-flow/pjit
    sub-jaxprs, but treating each ``pallas_call`` as ONE opaque equation —
    its body is a single launch no matter how much math it folds in) and
    ``pallas_calls`` the number of Pallas launches among them. ``eqns`` is
    an upper bound on device dispatches before XLA fusion; the delta
    between the unfused and fused serve paths is the launches-per-layer
    reduction the fused kernels buy, measured on the ACTUAL traced
    program rather than asserted."""
    closed = jax.make_jaxpr(fn)(*args)

    def _jaxprs_in(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (list, tuple)):
            for item in v:
                yield from _jaxprs_in(item)

    def _count(jaxpr):
        eqns = pallas = 0
        for eqn in jaxpr.eqns:
            eqns += 1
            if eqn.primitive.name == "pallas_call":
                pallas += 1
                continue                     # one launch, however big
            for v in eqn.params.values():
                for sub in _jaxprs_in(v):
                    e, p = _count(sub)
                    eqns += e
                    pallas += p
        return eqns, pallas

    eqns, pallas = _count(closed.jaxpr)
    return dict(eqns=eqns, pallas_calls=pallas)


def interpret_mode() -> bool:
    """Interpret flag callers must pass to kernels they launch directly
    (e.g. ``fused_layer.fused_call``)."""
    return _interpret()


def bmm_xnor(a_packed: jax.Array, b_packed: jax.Array, n_bits: int,
             binarize: bool = False) -> jax.Array:
    """Packed ±1 matmul; kernel on TPU, word-level jnp elsewhere."""
    if _use_kernels():
        return bmm_kernel.bmm_xnor(a_packed, b_packed, n_bits,
                                   binarize=binarize, interpret=_interpret())
    out = bitops.bmm_xnor_words(a_packed, b_packed, n_bits)
    if binarize:
        return bitops.pack_bits(out >= 0, axis=-1)
    return out


def binarize_pack(x: jax.Array) -> jax.Array:
    if _use_kernels():
        return pack_kernel.binarize_pack(x, interpret=_interpret())
    return bitops.sign_bits(x, axis=-1)


def bspmm_bits(adj: FRDCMatrix, x_packed: jax.Array, n_feat: int,
               binarize: bool = True,
               trinary_mode: str = "s3_two_popc") -> jax.Array:
    """FRDC trinary aggregation; returns (n_rows, ...) cropped."""
    if _use_kernels():
        out = bspmm_kernel.bspmm_bits(adj, x_packed, n_feat,
                                      binarize=binarize,
                                      trinary_mode=trinary_mode,
                                      interpret=_interpret())
        return out[: adj.n_rows]
    xt = BinTensor(packed=x_packed, scale=jnp.ones((x_packed.shape[0], 1)),
                   n=n_feat)
    res = bspmm_core.bspmm(adj, xt, "BBB" if binarize else "BBF",
                           trinary_mode=trinary_mode)
    return res.packed if binarize else res


def _serve_fp_backend(adj: FRDCMatrix, x: jax.Array,
                      block_shape=None) -> jax.Array:
    """core.bspmm fp-stage hook: Pallas BSpMM.FB? with scales applied here
    (the kernel computes raw masked matmuls)."""
    xin = x
    if adj.col_scale is not None:
        xin = xin * adj.col_scale[:, None].astype(x.dtype)
    out = bspmm_kernel.bspmm_fp(adj, xin, interpret=_interpret(),
                                block_shape=block_shape)
    out = out[: adj.n_rows]
    if adj.row_scale is not None:
        out = out * adj.row_scale[:, None].astype(out.dtype)
    return out


def _serve_bits_backend(adj: FRDCMatrix, x_packed: jax.Array,
                        trinary_mode: str, block_shape=None) -> jax.Array:
    """core.bspmm trinary-counts hook: Pallas BSpMM.BB? raw counts."""
    out = bspmm_kernel.bspmm_bits(adj, x_packed, binarize=False,
                                  trinary_mode=trinary_mode,
                                  interpret=_interpret(),
                                  block_shape=block_shape)
    return out[: adj.n_rows]


@contextlib.contextmanager
def serve_kernels(enabled: bool = True, block_shape=None,
                  fused: bool = False):
    """Route BSpMM aggregation through the Pallas kernels while active.

    The serving sessions enter this at jit TRACE time (``use_pallas``
    config flag), so the kernel calls are baked into the compiled serve
    executables. Off-TPU (and without ``force_kernels``) it is a no-op and
    the reference jnp path runs instead — the sessions' documented fallback.
    ``block_shape`` is the session plan's BSpMM block-shape selection
    (``SessionPlan.bspmm_block``), forwarded to every kernel call the
    context routes — the TPU block-shape tuning seam; None keeps the
    kernel-native defaults. Yields whether the kernels are actually active.

    ``fused=True`` installs the VALUE-level aggregation backends from
    :mod:`repro.kernels.fused_layer` instead of the standalone
    ``pallas_call`` kernels — the form a fused per-layer kernel BODY can
    trace (Pallas cannot nest launches). The caller is then responsible
    for wrapping each layer in ``fused_layer.fused_call`` so the whole
    layer compiles to one launch; results stay bitwise identical to the
    unfused kernels (the walks accumulate in kernel order).
    """
    if not (enabled and _use_kernels()):
        yield False
        return
    if fused:
        fp = functools.partial(fused_layer.agg_fp, block_shape=block_shape)
        bits = functools.partial(fused_layer.agg_counts,
                                 block_shape=block_shape)
    else:
        fp = functools.partial(_serve_fp_backend, block_shape=block_shape)
        bits = functools.partial(_serve_bits_backend, block_shape=block_shape)
    with bspmm_core.override_backends(fp=fp, bits=bits):
        yield True


def bspmm_fp(adj: FRDCMatrix, x: jax.Array) -> jax.Array:
    """FRDC fp aggregation (scales applied here, kernel does raw counts)."""
    if _use_kernels():
        return _serve_fp_backend(adj, x)
    return bspmm_core.bspmm(adj, x, "FBF")


# ---------------------------------------------------------------------------
# Explicit-backend serve aggregations (shard_map-safe)
# ---------------------------------------------------------------------------
# The ``serve_kernels`` context mutates module globals, which is fine for
# the single jit trace of a ServeCore forward but fragile inside shard_map
# bodies (the SPMD layer executor traces P-way programs whose retraces are
# not under the session's control). These two entry points take the backend
# choice as an ARGUMENT instead, so a shard_map body is a pure function of
# its inputs; Pallas runs natively per shard on TPU and in interpret mode
# elsewhere (the callers must build their shard_map with ``check_vma=False``
# when routing through the kernels — pallas_call has no replication rule).

def serve_fp(adj: FRDCMatrix, x: jax.Array,
             use_pallas: bool = False) -> jax.Array:
    """BSpMM.FBF for the layer executors: exact scaled fp aggregation."""
    if use_pallas and _use_kernels():
        return _serve_fp_backend(adj, x)
    return bspmm_core.bspmm(adj, x, "FBF")


def serve_counts(adj: FRDCMatrix, x_packed: jax.Array,
                 trinary_mode: str = bspmm_core.TRINARY_DEFAULT,
                 use_pallas: bool = False) -> jax.Array:
    """BSpMM.BB? raw trinary counts for the layer executors — the integer
    partial sums of the distributed binary-aggregation layer (they add
    EXACTLY across the intra/halo split)."""
    xp = bspmm_core._pad_rows(x_packed, TILE)
    if use_pallas and _use_kernels():
        return _serve_bits_backend(adj, xp, trinary_mode)
    return bspmm_core._spmm_bits(adj, xp, trinary_mode)


def serve_fp_pair(intra: FRDCMatrix, halo: FRDCMatrix, x_local: jax.Array,
                  x_remote: jax.Array, use_pallas: bool = False
                  ) -> jax.Array:
    """Distributed FBF layer aggregation:
    ``(intra_raw @ x_local + halo_raw @ x_remote) * row_scale``.

    Both matrices share the owning shard's row scale, and XLA's algebraic
    simplifier factors ``a*r + b*r`` into ``(a+b)*r`` inside fused programs
    — which changes fp rounding vs two eagerly-scaled partials. Applying
    the (identical) row scale ONCE after the add writes the factored form
    explicitly, so the eager host executor and the fused SPMD layer
    programs stay bit-identical."""
    y = serve_fp(intra._replace(row_scale=None), x_local, use_pallas) \
        + serve_fp(halo._replace(row_scale=None), x_remote, use_pallas)
    if intra.row_scale is not None:
        y = y * intra.row_scale[:, None].astype(y.dtype)
    return y
