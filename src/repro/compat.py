"""Version-compatibility shims for the jax API surface this repo uses.

Newer jax promotes ``shard_map`` to ``jax.shard_map`` (with ``check_vma``);
older 0.4.x only has ``jax.experimental.shard_map.shard_map`` (with the
equivalent ``check_rep``). Callers import from here so both work.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def get_abstract_mesh():
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    return fn() if fn is not None else None
