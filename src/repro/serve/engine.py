"""Batched serving engine: continuous-batching-style request scheduler over
prefill + decode steps (the inference-side end-to-end driver).

Requests join a waiting queue; free cache slots are claimed, the prompt is
prefilled into the slot's KV/state, and every engine tick decodes ONE token
for all live slots (decode is batched across requests — the decode_32k shape
of the dry-run). Finished requests free their slots. Single-host here;
the pjit shardings of serve_step make the same loop pod-scale.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (T,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[list] = None
    slot: int = -1


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_len: int = 512, eos_id: int = -1):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = transformer.init_cache(cfg, max_batch, max_len)
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.slot_live = np.zeros(max_batch, bool)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.waiting: List[Request] = []
        self.finished: List[Request] = []

        self._decode = jax.jit(
            lambda p, c, t, pos: transformer.decode_step(p, cfg, c, t, pos))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.out_tokens = []
        self.waiting.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slot_live[slot] or not self.waiting:
                continue
            req = self.waiting.pop(0)
            req.slot = slot
            # prefill token-by-token into this slot's cache region (decode
            # path reused; a chunked prefill step is the production variant)
            for i, tok in enumerate(req.prompt):
                t = jnp.zeros((self.max_batch, 1), jnp.int32
                              ).at[slot, 0].set(int(tok))
                _, self.cache = self._decode(self.params, self.cache, t,
                                             jnp.int32(i))
            self.slot_pos[slot] = len(req.prompt)
            self.slot_live[slot] = True
            self.slot_req[slot] = req

    def tick(self) -> int:
        """One engine iteration: admit + batched single-token decode."""
        self._admit()
        if not self.slot_live.any():
            return 0
        last = np.zeros((self.max_batch, 1), np.int32)
        for slot in range(self.max_batch):
            req = self.slot_req[slot]
            if req is None:
                continue
            last[slot, 0] = (req.out_tokens[-1] if req.out_tokens
                             else req.prompt[-1])
        pos = int(self.slot_pos.max()) - 1
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(last), jnp.int32(pos + 1))
        nxt = np.asarray(jnp.argmax(logits[:, -1, : self.cfg.vocab], axis=-1))
        n_active = 0
        for slot in range(self.max_batch):
            req = self.slot_req[slot]
            if req is None:
                continue
            req.out_tokens.append(int(nxt[slot]))
            self.slot_pos[slot] += 1
            n_active += 1
            done = (len(req.out_tokens) >= req.max_new_tokens
                    or int(nxt[slot]) == self.eos_id
                    or self.slot_pos[slot] >= self.max_len - 1)
            if done:
                self.slot_live[slot] = False
                self.slot_req[slot] = None
                self.finished.append(req)
        return n_active

    def run_until_done(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.waiting or self.slot_live.any()) and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.finished
