"""DEPRECATED — thin compatibility shim over the token serving tier.

The original slot-based continuous-batching loop that lived here (prefill
token-by-token into shared cache slots, one shared decode position per
tick) predates the family-adapter serving core. Token serving now lives in
:mod:`repro.serve.token_session` / :mod:`repro.serve.token_engine`: the
same scheduler the GNN engines run (queues, admission, cost attribution,
span tracing) over chunked exact-``decode_step`` launches with pow2
bucketed cache shapes (zero steady-state recompiles).

This module keeps the old names importable: :class:`Request` is unchanged,
and :class:`ServeEngine` preserves the submit/tick/run_until_done surface
by routing batches through a :class:`~repro.serve.token_session.
TokenSession` — which also fixes the old loop's shared-position decode
(every slot advanced at the batch-max position, misaligning heterogeneous
prompt lengths). New code should use
:class:`~repro.serve.token_engine.TokenServeEngine` directly.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.token_session import TokenSession


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (T,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[list] = None
    slot: int = -1


class ServeEngine:
    """Compatibility wrapper: the old engine surface over a TokenSession."""

    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_len: int = 512, eos_id: int = -1):
        warnings.warn(
            "repro.serve.engine.ServeEngine is deprecated; use "
            "repro.serve.token_engine.TokenServeEngine (or TokenSession) "
            "instead", DeprecationWarning, stacklevel=2)
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        self._session = TokenSession("compat", cfg, params,
                                     max_batch=max_batch, max_len=max_len,
                                     eos_id=eos_id)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.out_tokens = []
        self.waiting.append(req)

    def tick(self) -> int:
        """One engine iteration: serve the next FIFO batch of waiting
        requests through the token session's chunked decode."""
        if not self.waiting:
            return 0
        batch = [self.waiting.pop(0)
                 for _ in range(min(self.max_batch, len(self.waiting)))]
        outs = self._session.run(
            [np.asarray(r.prompt, np.int32) for r in batch],
            [r.max_new_tokens for r in batch])
        for r, toks in zip(batch, outs):
            r.out_tokens = [int(t) for t in toks]
            self.finished.append(r)
        return len(batch)

    def run_until_done(self, max_ticks: int = 10_000):
        ticks = 0
        while self.waiting and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.finished
