"""Serving metrics: latency percentiles, QPS, cache hit-rates, jit-compile
counters — the observability layer of the GNN serving subsystem.

Single-process and allocation-light: a flat sample list per histogram and
plain integer counters. ``snapshot()`` returns a JSON-serializable dict, the
payload of ``BENCH_serve_gnn.json`` and the example's final report.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


class LatencyStats:
    """Wall-clock latency samples with percentile summaries.

    Bounded: keeps the most recent ``max_samples`` in a ring buffer so a
    long-running engine doesn't grow without limit; ``count`` stays exact
    over the full lifetime, percentiles are over the retained window."""

    def __init__(self, max_samples: int = 100_000) -> None:
        self.max_samples = max_samples
        self._samples: List[float] = []
        self._pos = 0
        self._total = 0

    def record(self, seconds: float) -> None:
        s = float(seconds)
        if len(self._samples) < self.max_samples:
            self._samples.append(s)
        else:
            self._samples[self._pos] = s
            self._pos = (self._pos + 1) % self.max_samples
        self._total += 1

    @property
    def count(self) -> int:
        return self._total

    def percentile(self, p: float) -> float:
        if not self._samples:
            return float("nan")
        return float(np.percentile(np.asarray(self._samples), p))

    def summary(self) -> Dict[str, float]:
        if not self._samples:
            return dict(count=0, mean_ms=float("nan"), p50_ms=float("nan"),
                        p90_ms=float("nan"), p99_ms=float("nan"),
                        max_ms=float("nan"))
        a = np.asarray(self._samples) * 1e3
        return dict(count=self._total, mean_ms=float(a.mean()),
                    p50_ms=float(np.percentile(a, 50)),
                    p90_ms=float(np.percentile(a, 90)),
                    p99_ms=float(np.percentile(a, 99)),
                    max_ms=float(a.max()))


@dataclasses.dataclass
class ServeMetrics:
    """Counters + histograms for one engine (or one session)."""
    latency: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    batch_latency: LatencyStats = dataclasses.field(
        default_factory=LatencyStats)
    queries: int = 0
    batches: int = 0
    full_cache_hits: int = 0       # answered from the cached full-graph pass
    subgraph_queries: int = 0      # answered via the micro-batched k-hop path
    started_at: Optional[float] = None
    finished_at: Optional[float] = None

    def start_clock(self) -> None:
        if self.started_at is None:
            self.started_at = time.perf_counter()

    def stop_clock(self) -> None:
        self.finished_at = time.perf_counter()

    @property
    def elapsed_s(self) -> float:
        if self.started_at is None:
            return 0.0
        end = self.finished_at or time.perf_counter()
        return max(end - self.started_at, 1e-9)

    @property
    def qps(self) -> float:
        return self.queries / self.elapsed_s

    @property
    def cache_hit_rate(self) -> float:
        return self.full_cache_hits / max(self.queries, 1)

    def snapshot(self, extra: Optional[dict] = None) -> dict:
        out = dict(
            queries=self.queries, batches=self.batches, qps=self.qps,
            full_cache_hits=self.full_cache_hits,
            subgraph_queries=self.subgraph_queries,
            cache_hit_rate=self.cache_hit_rate,
            elapsed_s=self.elapsed_s,
            latency=self.latency.summary(),
            batch_latency=self.batch_latency.summary(),
        )
        if extra:
            out.update(extra)
        return out
