"""Serving metrics: latency percentiles, QPS, cache hit-rates, jit-compile
counters — the observability layer of the GNN serving subsystem.

Single-process and allocation-light: a flat sample list per histogram and
plain integer counters. ``snapshot()`` returns a JSON-serializable dict, the
payload of ``BENCH_serve_gnn.json`` and the example's final report.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


class LatencyStats:
    """Wall-clock latency samples with percentile summaries.

    Bounded: keeps the most recent ``max_samples`` in a ring buffer so a
    long-running engine doesn't grow without limit; ``count`` stays exact
    over the full lifetime, percentiles are over the retained window."""

    def __init__(self, max_samples: int = 100_000) -> None:
        self.max_samples = max_samples
        self._samples: List[float] = []
        self._pos = 0
        self._total = 0

    def record(self, seconds: float) -> None:
        s = float(seconds)
        if len(self._samples) < self.max_samples:
            self._samples.append(s)
        else:
            self._samples[self._pos] = s
            self._pos = (self._pos + 1) % self.max_samples
        self._total += 1

    @property
    def count(self) -> int:
        return self._total

    def percentile(self, p: float) -> float:
        if not self._samples:
            return float("nan")
        return float(np.percentile(np.asarray(self._samples), p))

    @property
    def window(self) -> int:
        """Samples currently retained — the population behind the
        percentiles. Equals ``count`` until the ring wraps."""
        return len(self._samples)

    def summary(self) -> Dict[str, float]:
        if not self._samples:
            return dict(count=0, window=0, mean_ms=float("nan"),
                        p50_ms=float("nan"), p90_ms=float("nan"),
                        p99_ms=float("nan"), max_ms=float("nan"))
        a = np.asarray(self._samples) * 1e3
        return dict(count=self._total, window=len(self._samples),
                    mean_ms=float(a.mean()),
                    p50_ms=float(np.percentile(a, 50)),
                    p90_ms=float(np.percentile(a, 90)),
                    p99_ms=float(np.percentile(a, 99)),
                    max_ms=float(a.max()))


@dataclasses.dataclass
class TenantMetrics:
    """Per-tenant slice of an engine's metrics: admission outcomes
    (``accepted`` / ``throttled`` / ``shed`` submissions) plus the answered
    queries and their end-to-end latency histogram."""
    accepted: int = 0
    throttled: int = 0
    shed: int = 0
    queries: int = 0
    # cost accounting (all 0 until the engine wires a CostEstimator):
    # predicted units admitted, rejections charged to the cost budget
    # specifically, and measured service seconds attributed pro rata
    cost_units: float = 0.0
    cost_throttled: int = 0
    attributed_cost_s: float = 0.0
    latency: LatencyStats = dataclasses.field(default_factory=LatencyStats)

    @property
    def submitted(self) -> int:
        return self.accepted + self.throttled + self.shed

    @property
    def shed_rate(self) -> float:
        """Fraction of submissions shed (overload rejections only — kept
        consistent with the adjacent ``shed`` counter; rate-limit bounces
        are ``throttle_rate``, and ``reject_rate`` is their sum)."""
        return self.shed / max(self.submitted, 1)

    @property
    def throttle_rate(self) -> float:
        return self.throttled / max(self.submitted, 1)

    @property
    def reject_rate(self) -> float:
        """Fraction of submissions NOT admitted (throttled or shed)."""
        return (self.throttled + self.shed) / max(self.submitted, 1)

    def snapshot(self, elapsed_s: float) -> dict:
        return dict(accepted=self.accepted, throttled=self.throttled,
                    shed=self.shed, shed_rate=self.shed_rate,
                    throttle_rate=self.throttle_rate,
                    reject_rate=self.reject_rate,
                    queries=self.queries,
                    qps=self.queries / max(elapsed_s, 1e-9),
                    cost_units=self.cost_units,
                    cost_throttled=self.cost_throttled,
                    attributed_cost_s=self.attributed_cost_s,
                    latency=self.latency.summary())


@dataclasses.dataclass
class ServeMetrics:
    """Counters + histograms for one engine (or one session).

    The per-batch service time is broken into the two pipeline stages:
    **extract** (queue pick -> k-hop/routed extraction -> FRDC build ->
    bucket pad; pure host work) and **compute** (jitted forward launch ->
    device result fetch -> gather). ``batch_latency`` stays the total.
    ``serve_wall_s`` accumulates the wall time the engine actually spent
    inside its serve loop, so ``overlap_ratio`` — the fraction of stage time
    hidden behind the other stage — is ``(extract + compute - wall) /
    (extract + compute)``: 0 for the serial loop, approaching 0.5 when a
    double-buffered pipeline fully hides extraction behind the in-flight
    device computation.
    """
    # model-family namespace ("gnn", "transformer", "ssm", ...): carried in
    # the snapshot and merged as a ``family`` label onto every Prometheus
    # series, so engines of different families exported from one process
    # never collide on a series name.
    family: str = "gnn"
    latency: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    batch_latency: LatencyStats = dataclasses.field(
        default_factory=LatencyStats)
    extract_latency: LatencyStats = dataclasses.field(
        default_factory=LatencyStats)
    compute_latency: LatencyStats = dataclasses.field(
        default_factory=LatencyStats)
    queries: int = 0
    batches: int = 0
    full_cache_hits: int = 0       # answered from the cached full-graph pass
    subgraph_queries: int = 0      # answered via the micro-batched k-hop path
    extract_s: float = 0.0         # summed extract-stage seconds
    compute_s: float = 0.0         # summed compute-stage seconds
    serve_wall_s: float = 0.0      # wall seconds inside the serve loop
    # failure-path accounting (the bounded-retry / drain machinery):
    # batches bounced back to their queue, queries dropped with a typed
    # per-query failure after max_retries, and accepted-but-unserved
    # queries typed-shed by a drain timeout
    requeues: int = 0
    retry_shed: int = 0
    drain_shed: int = 0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    # wall time banked from previous start/stop waves (restart-safe clock)
    _elapsed_base: float = 0.0
    # per-tenant breakdowns (admission outcomes + answered latency)
    tenants: Dict[str, TenantMetrics] = dataclasses.field(
        default_factory=dict)

    def tenant(self, name: str) -> TenantMetrics:
        tm = self.tenants.get(name)
        if tm is None:
            tm = self.tenants[name] = TenantMetrics()
        return tm

    def record_admission(self, tenant: str, action: str,
                         cost: float = 0.0,
                         cost_limited: bool = False) -> None:
        """Fold one admission outcome in; an accepted submission's
        predicted ``cost`` units are counted against the tenant, and a
        throttle charged to the COST budget (vs the QPS rate) is split out
        into ``cost_throttled``."""
        tm = self.tenant(tenant)
        if action == "accept":
            tm.accepted += 1
            tm.cost_units += float(cost)
        elif action == "throttle":
            tm.throttled += 1
            if cost_limited:
                tm.cost_throttled += 1
        else:
            tm.shed += 1

    def record_tenant_query(self, tenant: str, latency_s: float) -> None:
        tm = self.tenant(tenant)
        tm.queries += 1
        tm.latency.record(latency_s)

    def record_tenant_cost_attributed(self, tenant: str,
                                      seconds: float) -> None:
        """Credit a tenant its pro-rata share of one batch's measured
        service seconds (the cost attribution the estimator computes)."""
        self.tenant(tenant).attributed_cost_s += float(seconds)

    def record_stages(self, extract_s: float, compute_s: float) -> None:
        """Record one batch's per-stage breakdown (both histogrammed and
        summed for the overlap gauge)."""
        self.extract_latency.record(extract_s)
        self.compute_latency.record(compute_s)
        self.extract_s += float(extract_s)
        self.compute_s += float(compute_s)

    @property
    def overlap_ratio(self) -> float:
        stage_s = self.extract_s + self.compute_s
        if stage_s <= 0.0:
            return 0.0
        return max(0.0, stage_s - self.serve_wall_s) / stage_s

    def start_clock(self) -> None:
        """Start (or RESUME) the serving clock. Restart-safe: a second
        serve wave after ``stop_clock()`` banks the finished wave's wall
        time and reopens the clock, so ``elapsed_s`` keeps accumulating
        and ``qps`` stays total-queries / total-serving-time instead of
        freezing at the first wave's window."""
        if self.started_at is None:
            self.started_at = time.perf_counter()
        elif self.finished_at is not None:
            self._elapsed_base += self.finished_at - self.started_at
            self.started_at = time.perf_counter()
            self.finished_at = None
        # else: clock already running — idempotent, like the original

    def stop_clock(self) -> None:
        if self.started_at is not None and self.finished_at is None:
            self.finished_at = time.perf_counter()

    @property
    def elapsed_s(self) -> float:
        if self.started_at is None:
            return max(self._elapsed_base, 0.0) or 0.0
        end = self.finished_at or time.perf_counter()
        return max(self._elapsed_base + (end - self.started_at), 1e-9)

    @property
    def qps(self) -> float:
        return self.queries / self.elapsed_s

    @property
    def cache_hit_rate(self) -> float:
        return self.full_cache_hits / max(self.queries, 1)

    def snapshot(self, extra: Optional[dict] = None) -> dict:
        out = dict(
            family=self.family,
            queries=self.queries, batches=self.batches, qps=self.qps,
            full_cache_hits=self.full_cache_hits,
            subgraph_queries=self.subgraph_queries,
            cache_hit_rate=self.cache_hit_rate,
            elapsed_s=self.elapsed_s,
            latency=self.latency.summary(),
            batch_latency=self.batch_latency.summary(),
            batch_breakdown=dict(extract=self.extract_latency.summary(),
                                 compute=self.compute_latency.summary(),
                                 total=self.batch_latency.summary()),
            overlap_ratio=self.overlap_ratio,
            serve_wall_s=self.serve_wall_s,
            requeues=self.requeues,
            retry_shed=self.retry_shed,
            drain_shed=self.drain_shed,
            tenants={name: tm.snapshot(self.elapsed_s)
                     for name, tm in sorted(self.tenants.items())},
        )
        if extra:
            out.update(extra)
        return out
