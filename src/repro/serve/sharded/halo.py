"""Halo (shard-boundary) row exchange.

Two transports with identical semantics:

  * :func:`gather_rows` — host loopback: assemble requested global rows from
    per-shard row blocks. Runs everywhere (including a 1-device box, where
    the shards are simulated), and is the reference the mesh path is tested
    against.
  * :func:`mesh_exchange` — device collectives: every shard's block lives on
    its own device along the ``data`` mesh axis; for each ring shift
    ``d = 1..P-1``, shard ``t`` sends exactly the rows shard ``(t+d) % P``
    requested of it via ``jax.lax.ppermute`` (payloads padded to the shift's
    max count so the collective is shape-uniform), and the receiver scatters
    them into its halo buffer. Only boundary rows ever move — and when the
    payload is bit-packed (the BSpMM.BBB layer of the GCN "bin" scheme), the
    words on the wire are the paper's 32x-compressed representation: FRDC's
    memory saving becomes a collective saving.

Byte accounting is explicit (:class:`HaloStats`): the serving benchmark
reports halo bytes per layer, packed vs fp.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from .routing import RoutingTable


class HaloStats:
    """Per-tag byte counters for cross-shard row movement."""

    def __init__(self) -> None:
        self.bytes_by_tag: Dict[str, int] = {}
        self.events = 0

    def add(self, tag: str, nbytes: int) -> None:
        self.bytes_by_tag[tag] = self.bytes_by_tag.get(tag, 0) + int(nbytes)
        self.events += 1

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_tag.values())

    def snapshot(self) -> dict:
        return dict(total_bytes=self.total_bytes, events=self.events,
                    by_tag=dict(self.bytes_by_tag))


def gather_rows(blocks: List[np.ndarray], routing: RoutingTable,
                nodes: np.ndarray, home: Optional[int] = None,
                stats: Optional[HaloStats] = None,
                tag: str = "halo") -> np.ndarray:
    """Assemble rows ``nodes`` (global ids, any order) from per-shard row
    blocks. Rows served by a shard other than ``home`` count as halo traffic.
    Works for any trailing shape/dtype (fp features, packed uint32 words,
    1-D factorization vectors)."""
    nodes = np.asarray(nodes, np.int64)
    owner = routing.owner(nodes)
    first = np.asarray(blocks[0])
    out = np.empty((nodes.size,) + first.shape[1:], first.dtype)
    for s in range(routing.n_shards):
        sel = np.nonzero(owner == s)[0]
        if sel.size == 0:
            continue
        rows = np.asarray(blocks[s])[nodes[sel] - routing.bounds[s]]
        out[sel] = rows
        if stats is not None and s != home:
            stats.add(tag, rows.nbytes)
    return out


# ---------------------------------------------------------------------------
# Mesh transport
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MeshHaloPlan:
    """Static send/receive schedule of the ring exchange.

    ``send_idx[d-1]``: (P, m_d) local row ids shard ``t`` sends to shard
    ``(t+d) % P`` (padded with 0 — masked out by the receiver's positions).
    ``recv_pos[d-1]``: (P, m_d) positions in the RECEIVER's halo buffer
    (padded with ``n_halo_buf``, an overflow slot sliced off afterwards).

    ``n_halo_buf`` is the receive-buffer row count — ``n_halo_max`` by
    default, or the SPMD executor's uniform tile-aligned halo pad.

    ``payload_bytes`` is THE byte-accounting source for every transport that
    runs this schedule: it is a pure function of the static plan, so jitted
    steady-state passes record exactly one schedule's bytes per exchange —
    never trace-time-captured counters (which would freeze at whatever the
    first trace saw and then under-/over-count).
    """
    n_shards: int
    n_halo_max: int
    halo_sizes: List[int]
    send_idx: List[np.ndarray]
    recv_pos: List[np.ndarray]
    n_halo_buf: Optional[int] = None

    @property
    def buf_rows(self) -> int:
        return self.n_halo_max if self.n_halo_buf is None else self.n_halo_buf

    def payload_bytes(self, width: int, itemsize: int) -> int:
        """Wire bytes of one exchange (padded payloads included)."""
        return sum(int(si.size) * width * itemsize for si in self.send_idx)

    def to_json(self) -> dict:
        return dict(n_shards=self.n_shards, n_halo_max=self.n_halo_max,
                    n_halo_buf=self.buf_rows, halo_sizes=self.halo_sizes,
                    send_idx=[si.tolist() for si in self.send_idx],
                    recv_pos=[rp.tolist() for rp in self.recv_pos])

    @classmethod
    def from_json(cls, d: dict) -> "MeshHaloPlan":
        return cls(n_shards=int(d["n_shards"]),
                   n_halo_max=int(d["n_halo_max"]),
                   halo_sizes=[int(h) for h in d["halo_sizes"]],
                   send_idx=[np.asarray(a, np.int32) for a in d["send_idx"]],
                   recv_pos=[np.asarray(a, np.int32) for a in d["recv_pos"]],
                   n_halo_buf=int(d["n_halo_buf"]))


def build_mesh_plan(routing: RoutingTable, halo_nodes: List[np.ndarray],
                    n_halo_buf: Optional[int] = None) -> MeshHaloPlan:
    p = routing.n_shards
    n_halo_max = max([h.size for h in halo_nodes] + [1])
    buf = n_halo_max if n_halo_buf is None else int(n_halo_buf)
    if buf < n_halo_max:
        raise ValueError(f"n_halo_buf {buf} < n_halo_max {n_halo_max}")
    send_idx, recv_pos = [], []
    for d in range(1, p):
        pair_send, pair_recv = [], []
        for t in range(p):                       # sender t -> receiver s
            s = (t + d) % p
            h = halo_nodes[s]
            lo, hi = routing.shard_range(t)
            m = (h >= lo) & (h < hi)
            pair_send.append(h[m] - lo)
            pair_recv.append(np.nonzero(m)[0])
        width = max([a.size for a in pair_send] + [1])
        si = np.zeros((p, width), np.int32)
        rp = np.full((p, width), buf, np.int32)           # overflow slot
        for t in range(p):
            si[t, :pair_send[t].size] = pair_send[t]
            s = (t + d) % p
            rp[s, :pair_recv[t].size] = pair_recv[t]
        send_idx.append(si)
        recv_pos.append(rp)
    return MeshHaloPlan(n_shards=p, n_halo_max=n_halo_max,
                        halo_sizes=[int(h.size) for h in halo_nodes],
                        send_idx=send_idx, recv_pos=recv_pos,
                        n_halo_buf=buf)


def ring_perms(p: int) -> List[List[tuple]]:
    """The P-1 ring-shift permutations of the exchange (shift d sends
    shard t's payload to shard (t+d) % P)."""
    return [[(t, (t + d) % p) for t in range(p)] for d in range(1, p)]


def ring_scatter(x_block, send_idx, recv_pos, perms, n_buf: int,
                 axis: str = "data"):
    """Traced body of the ring halo exchange — shared by the standalone
    :func:`mesh_exchange` transport and the SPMD layer executor's fused
    per-layer programs.

    ``x_block``: this shard's (n_local_pad, F) operand; ``send_idx`` /
    ``recv_pos``: this shard's slices of the static schedule (one (m_d,)
    pair per shift); returns the (n_buf, F) halo operand (rows in
    ``halo_nodes`` order, padded rows zero — the overflow slot at
    ``n_buf`` absorbs schedule padding and is sliced off here)."""
    halo = jnp.zeros((n_buf + 1,) + x_block.shape[1:], x_block.dtype)
    for sidx, rpos, perm in zip(send_idx, recv_pos, perms):
        payload = x_block[sidx]
        recv = jax.lax.ppermute(payload, axis, perm)
        halo = halo.at[rpos].set(recv)
    return halo[:n_buf]


def mesh_exchange(mesh, blocks: List[np.ndarray], plan: MeshHaloPlan,
                  stats: Optional[HaloStats] = None,
                  tag: str = "halo") -> List[np.ndarray]:
    """Run the ring halo exchange over the mesh's ``data`` axis; the mesh
    must span exactly ``plan.n_shards`` devices. Returns the per-shard halo
    blocks (shard ``s``'s rows of every remote node it references, in
    ``halo_nodes[s]`` order)."""
    from jax.sharding import PartitionSpec as P
    p = plan.n_shards
    n_local_max = max(b.shape[0] for b in blocks)
    width = blocks[0].shape[1]
    dtype = np.asarray(blocks[0]).dtype
    stacked = np.zeros((p, n_local_max, width), dtype)
    for s, b in enumerate(blocks):
        stacked[s, :b.shape[0]] = b
    perms = ring_perms(p)

    def body(x, *sched):
        sidx = [sched[2 * i][0] for i in range(p - 1)]
        rpos = [sched[2 * i + 1][0] for i in range(p - 1)]
        return ring_scatter(x[0], sidx, rpos, perms, plan.buf_rows)[None]

    sched = []
    for i in range(p - 1):
        sched += [plan.send_idx[i], plan.recv_pos[i]]
    n_args = 1 + len(sched)
    out = shard_map(body, mesh, in_specs=(P("data"),) * n_args,
                    out_specs=P("data"))(stacked, *sched)
    out = np.asarray(out)
    if stats is not None:
        stats.add(tag, plan.payload_bytes(width, dtype.itemsize))
    return [out[s, :plan.halo_sizes[s]] for s in range(p)]
