"""Halo (shard-boundary) row exchange.

Two transports with identical semantics:

  * :func:`gather_rows` — host loopback: assemble requested global rows from
    per-shard row blocks. Runs everywhere (including a 1-device box, where
    the shards are simulated), and is the reference the mesh path is tested
    against.
  * :func:`mesh_exchange` — device collectives: every shard's block lives on
    its own device along the ``data`` mesh axis; for each ring shift
    ``d = 1..P-1``, shard ``t`` sends exactly the rows shard ``(t+d) % P``
    requested of it via ``jax.lax.ppermute`` (payloads padded to the shift's
    max count so the collective is shape-uniform), and the receiver scatters
    them into its halo buffer. Only boundary rows ever move — and when the
    payload is bit-packed (the BSpMM.BBB layer of the GCN "bin" scheme), the
    words on the wire are the paper's 32x-compressed representation: FRDC's
    memory saving becomes a collective saving.

Byte accounting is explicit (:class:`HaloStats`): the serving benchmark
reports halo bytes per layer, packed vs fp.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from .routing import RoutingTable


class HaloStats:
    """Per-tag byte counters for cross-shard row movement."""

    def __init__(self) -> None:
        self.bytes_by_tag: Dict[str, int] = {}
        self.events = 0

    def add(self, tag: str, nbytes: int) -> None:
        self.bytes_by_tag[tag] = self.bytes_by_tag.get(tag, 0) + int(nbytes)
        self.events += 1

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_tag.values())

    def snapshot(self) -> dict:
        return dict(total_bytes=self.total_bytes, events=self.events,
                    by_tag=dict(self.bytes_by_tag))


def gather_rows(blocks: List[np.ndarray], routing: RoutingTable,
                nodes: np.ndarray, home: Optional[int] = None,
                stats: Optional[HaloStats] = None,
                tag: str = "halo") -> np.ndarray:
    """Assemble rows ``nodes`` (global ids, any order) from per-shard row
    blocks. Rows served by a shard other than ``home`` count as halo traffic.
    Works for any trailing shape/dtype (fp features, packed uint32 words,
    1-D factorization vectors)."""
    nodes = np.asarray(nodes, np.int64)
    owner = routing.owner(nodes)
    first = np.asarray(blocks[0])
    out = np.empty((nodes.size,) + first.shape[1:], first.dtype)
    for s in range(routing.n_shards):
        sel = np.nonzero(owner == s)[0]
        if sel.size == 0:
            continue
        rows = np.asarray(blocks[s])[nodes[sel] - routing.bounds[s]]
        out[sel] = rows
        if stats is not None and s != home:
            stats.add(tag, rows.nbytes)
    return out


# ---------------------------------------------------------------------------
# Mesh transport
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MeshHaloPlan:
    """Static send/receive schedule of the ring exchange.

    ``send_idx[d-1]``: (P, m_d) local row ids shard ``t`` sends to shard
    ``(t+d) % P`` (padded with 0 — masked out by the receiver's positions).
    ``recv_pos[d-1]``: (P, m_d) positions in the RECEIVER's halo buffer
    (padded with ``n_halo_max``, an overflow slot sliced off afterwards).
    """
    n_shards: int
    n_halo_max: int
    halo_sizes: List[int]
    send_idx: List[np.ndarray]
    recv_pos: List[np.ndarray]

    def payload_bytes(self, width: int, itemsize: int) -> int:
        """Wire bytes of one exchange (padded payloads included)."""
        return sum(int(si.size) * width * itemsize for si in self.send_idx)


def build_mesh_plan(routing: RoutingTable,
                    halo_nodes: List[np.ndarray]) -> MeshHaloPlan:
    p = routing.n_shards
    n_halo_max = max([h.size for h in halo_nodes] + [1])
    send_idx, recv_pos = [], []
    for d in range(1, p):
        pair_send, pair_recv = [], []
        for t in range(p):                       # sender t -> receiver s
            s = (t + d) % p
            h = halo_nodes[s]
            lo, hi = routing.shard_range(t)
            m = (h >= lo) & (h < hi)
            pair_send.append(h[m] - lo)
            pair_recv.append(np.nonzero(m)[0])
        width = max([a.size for a in pair_send] + [1])
        si = np.zeros((p, width), np.int32)
        rp = np.full((p, width), n_halo_max, np.int32)    # overflow slot
        for t in range(p):
            si[t, :pair_send[t].size] = pair_send[t]
            s = (t + d) % p
            rp[s, :pair_recv[t].size] = pair_recv[t]
        send_idx.append(si)
        recv_pos.append(rp)
    return MeshHaloPlan(n_shards=p, n_halo_max=n_halo_max,
                        halo_sizes=[int(h.size) for h in halo_nodes],
                        send_idx=send_idx, recv_pos=recv_pos)


def mesh_exchange(mesh, blocks: List[np.ndarray], plan: MeshHaloPlan,
                  stats: Optional[HaloStats] = None,
                  tag: str = "halo") -> List[np.ndarray]:
    """Run the ring halo exchange over the mesh's ``data`` axis; the mesh
    must span exactly ``plan.n_shards`` devices. Returns the per-shard halo
    blocks (shard ``s``'s rows of every remote node it references, in
    ``halo_nodes[s]`` order)."""
    from jax.sharding import PartitionSpec as P
    p = plan.n_shards
    n_local_max = max(b.shape[0] for b in blocks)
    width = blocks[0].shape[1]
    dtype = np.asarray(blocks[0]).dtype
    stacked = np.zeros((p, n_local_max, width), dtype)
    for s, b in enumerate(blocks):
        stacked[s, :b.shape[0]] = b
    perms = [[(t, (t + d) % p) for t in range(p)] for d in range(1, p)]

    def body(x, *sched):
        xb = x[0]
        halo = jnp.zeros((plan.n_halo_max + 1, width), xb.dtype)
        for i in range(p - 1):
            sidx, rpos = sched[2 * i][0], sched[2 * i + 1][0]
            payload = xb[sidx]
            recv = jax.lax.ppermute(payload, "data", perms[i])
            halo = halo.at[rpos].set(recv)
        return halo[None]

    sched = []
    for i in range(p - 1):
        sched += [plan.send_idx[i], plan.recv_pos[i]]
    n_args = 1 + len(sched)
    out = shard_map(body, mesh, in_specs=(P("data"),) * n_args,
                    out_specs=P("data"))(stacked, *sched)
    out = np.asarray(out)
    if stats is not None:
        stats.add(tag, plan.payload_bytes(width, dtype.itemsize))
    return [out[s, :plan.halo_sizes[s]] for s in range(p)]
