"""ShardedGraphSession: one (graph, model) serving artifact split over P shards.

Compared with the single-host :class:`~repro.serve.gnn_session.
CompiledGraphSession`, the graph state is partitioned (contiguous
tile-row-aligned node ranges, :mod:`.planner`): each shard owns its feature
rows, its block of the CSR, an intra-shard FRDC adjacency and a bit-packed
halo adjacency over the boundary edges. Serving has two paths:

  * **routed subgraph** (the scale path): a k-hop query is answered by its
    seed's OWNING shard — the frontier is routed across shard boundaries
    (:mod:`.routing`), remote features and factorization-vector entries are
    fetched through the halo transport, and the owning shard's
    :class:`~repro.serve.session_core.ServeCore` runs the same bucketed
    jitted forward as the single-host session with the same frozen BN stats.
    Because the assembled subgraph, adjacency, features and calibration are
    identical, the outputs are bit-exact against single-host serving.

  * **distributed full pass**: layer-wise per-shard aggregation — each shard
    computes its output rows from ``intra @ local + halo @ remote``, where
    the remote operand arrives via halo exchange (:mod:`.halo`); for the
    binary-aggregation layer of the GCN "bin" scheme the exchanged rows are
    bit-PACKED (uint32 words, 32x smaller than fp) and the partial popc
    counts add exactly. This pass fills the per-shard full-logits caches and
    is the path whose halo bytes the benchmark reports. Its fp aggregations
    reassociate across the intra/halo split, so it matches single-host
    full-graph logits to fp tolerance (binary layers: exactly).

BN calibration runs one full-graph pass through the shared
:func:`~repro.serve.session_core.family_forward` (bit-identical to the
single-host session's calibration — the invariant behind the exactness
guarantee above); sharded/sampled calibration for beyond-memory graphs is a
ROADMAP item.

Artifacts (per-shard FRDC + CSR + routing table) serialize through the
checkpointer with a ``routing.json`` sidecar; a restore re-builds the
session without re-partitioning or re-tuning.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import bitops, frdc
from repro.core.binarize import BinTensor
from repro.core.bmm import bmm, quantize_act
from repro.core.bspmm import _pad_rows, _spmm_bits, bspmm
from repro.models import gnn
from repro.serve import session_core
from repro.serve.session_core import ServeCore, SessionPlan
from . import halo as halo_mod
from .planner import ShardPart, ShardPlan
from .routing import RoutingTable, ShardedCSR
from .routing import khop_subgraph as routed_khop_subgraph


def _binarize_counts(counts: jax.Array, n_feat: int) -> BinTensor:
    """Sign-binarize summed trinary counts — the BSpMM.BBB output stage
    (``out_scale=False``: positive scales are elided by the consumer)."""
    counts = counts.astype(jnp.float32)
    if counts.shape[-1] > n_feat:
        counts = counts[:, :n_feat]
    return BinTensor(packed=bitops.sign_bits(counts, axis=-1),
                     scale=jnp.ones((counts.shape[0], 1), counts.dtype),
                     n=n_feat)


class ShardedGraphSession:
    """Partitioned compiled serving artifact. See module docstring."""

    def __init__(self, graph, model, plan: SessionPlan, qparams,
                 shard_plan: ShardPlan, khop: int = 2, max_batch: int = 32,
                 use_pallas: bool = False, mesh=None):
        if shard_plan.family != plan.family:
            raise ValueError(f"shard plan family {shard_plan.family!r} != "
                             f"session family {plan.family!r}")
        self.graph = graph
        self.model = model
        self.plan = plan
        self.qparams = qparams
        self.shard_plan = shard_plan
        self.routing: RoutingTable = shard_plan.routing
        self.khop = khop
        self.max_batch = max_batch
        self.use_pallas = use_pallas
        self.mesh = mesh
        self.key = f"{graph.name}__{model.name}__P{shard_plan.n_shards}"
        self.feature_version = -1
        self.bn: Optional[tuple] = None
        self.halo_stats = halo_mod.HaloStats()
        self._caches: Optional[List[np.ndarray]] = None
        self._assembled: Optional[np.ndarray] = None
        self._invalidations = 0
        self._scsr: ShardedCSR = shard_plan.sharded_csr()
        self._adj_full: Optional[Dict[str, frdc.FRDCMatrix]] = None
        self._jit_calibrate = None
        self._mesh_plan = None
        # one bucketed serve core per shard; a routed subgraph can span the
        # whole graph, so every core's node cap is the full padded graph
        node_cap = -(-shard_plan.n_nodes // frdc.TILE) * frdc.TILE
        self.cores = [ServeCore(plan, qparams, max_batch, node_cap,
                                use_pallas=use_pallas)
                      for _ in range(shard_plan.n_shards)]

    # ------------------------------------------------------------ state ----
    @property
    def n_shards(self) -> int:
        return self.shard_plan.n_shards

    @property
    def parts(self) -> List[ShardPart]:
        return self.shard_plan.parts

    @property
    def compile_count(self) -> int:
        """Total jit traces across the per-shard bucketed forwards."""
        return sum(c.compile_count for c in self.cores)

    @property
    def compile_count_by_shard(self) -> List[int]:
        return [c.compile_count for c in self.cores]

    @property
    def invalidations(self) -> int:
        return self._invalidations

    def _x_blocks(self) -> List[np.ndarray]:
        x = self.graph.data.x
        return [x[p.row_start:p.row_end] for p in self.parts]

    def _dinv_blocks(self) -> Optional[List[np.ndarray]]:
        if self.parts[0].dinv is None:
            return None
        return [p.dinv for p in self.parts]

    def _use_mesh(self) -> bool:
        return (self.mesh is not None
                and self.mesh.shape.get("data", 0) == self.n_shards)

    def set_mesh(self, mesh) -> None:
        """Swap the halo transport (None = host loopback). Numerics are
        transport-independent; only the exchange mechanism changes."""
        if mesh is not self.mesh:
            self.mesh = mesh
            self._mesh_plan = None

    # ------------------------------------------------------- calibrate -----
    def _calibrate_fn(self):
        """The shared full-graph calibration forward — the SAME jitted
        computation the single-host session freezes its BN stats from, so a
        sharded and a single-host session over one graph agree bit-for-bit
        on the calibration constants."""
        if self._jit_calibrate is None:
            d = self.graph.data
            fam = self.plan.family
            if fam == "gcn":
                adjs = {"adj": d.adjacency("gcn"),
                        "bin": d.adjacency("binary")}
            elif fam == "sage":
                adjs = {"mean": d.adjacency("mean")}
            else:
                adjs = {"sum": d.adjacency("binary")}
            self._adj_full = adjs
            plan, qparams, use_pallas = self.plan, self.qparams, \
                self.use_pallas

            def full(x):
                return session_core.family_forward(
                    plan, qparams, x, adjs, use_pallas=use_pallas,
                    return_bn_stats=True)

            self._jit_calibrate = jax.jit(full)
        return self._jit_calibrate

    def sync(self) -> None:
        """Adopt the store's current features: recalibrate BN (full-graph
        pass through the shared forward) and refresh the per-shard logits
        caches through the DISTRIBUTED layer-wise pass. No-op when current."""
        if self.feature_version == self.graph.version:
            return
        invalidated = self.feature_version >= 0
        _, bn = self._calibrate_fn()(jnp.asarray(self.graph.data.x))
        self.bn = bn
        self._caches = self._sharded_full_pass()
        self._assembled = None
        self.feature_version = self.graph.version
        if invalidated:
            self._invalidations += 1

    # ----------------------------------------------------- full pass -------
    def _exchange(self, blocks: List[np.ndarray], tag: str
                  ) -> List[np.ndarray]:
        """Fetch every shard's halo rows of a per-shard row-block operand —
        device collectives over the mesh when one is attached, host loopback
        otherwise. Returns per-shard (max(n_halo,1), F) operands (zero-padded
        so degenerate halo matrices aggregate exact zeros)."""
        blocks = [np.asarray(b) for b in blocks]
        if self._use_mesh():
            if self._mesh_plan is None:
                self._mesh_plan = halo_mod.build_mesh_plan(
                    self.routing, [p.halo_nodes for p in self.parts])
            gathered = halo_mod.mesh_exchange(
                self.mesh, blocks, self._mesh_plan,
                stats=self.halo_stats, tag=tag)
        else:
            gathered = [
                halo_mod.gather_rows(blocks, self.routing, p.halo_nodes,
                                     home=p.index, stats=self.halo_stats,
                                     tag=tag)
                for p in self.parts]
        out = []
        for p, g in zip(self.parts, gathered):
            buf = np.zeros((max(p.n_halo, 1),) + blocks[0].shape[1:],
                           blocks[0].dtype)
            buf[:p.n_halo] = g
            out.append(buf)
        return out

    def _partial_fbf(self, kind: str, blocks: List, tag: str) -> List:
        """out_s = intra_s @ local_s + halo_s @ (exchanged remote rows) —
        the distributed BSpMM.FBF. The halo operand crosses the wire in fp.
        A shard that owns no nodes (edge-balanced cuts on extreme skew)
        contributes an empty row block — its phantom 1-row FRDC placeholder
        must not run, it would gather from the 0-row operand."""
        halo_in = self._exchange(blocks, tag)
        out = []
        for p, loc, rem in zip(self.parts, blocks, halo_in):
            if p.n_local == 0:
                out.append(jnp.zeros((0, np.asarray(loc).shape[1]),
                                     jnp.float32))
                continue
            y = bspmm(p.intra[kind], jnp.asarray(loc), "FBF")
            y = y + bspmm(p.halo[kind], jnp.asarray(rem), "FBF")
            out.append(y)
        return out

    def _partial_bbb(self, kind: str, packed_blocks: List[np.ndarray],
                     n_feat: int, tag: str) -> List[BinTensor]:
        """Distributed BSpMM.BBB: per-shard trinary popc counts over the
        intra bits plus the halo bits — integer partial sums, so the split
        is EXACT — then one sign binarization. The exchanged operand is the
        bit-packed activation block (uint32 words, 32x smaller than fp)."""
        halo_in = self._exchange(packed_blocks, tag)
        mode = self.plan.trinary_mode
        out = []
        for p, loc, rem in zip(self.parts, packed_blocks, halo_in):
            if p.n_local == 0:
                out.append(BinTensor(
                    packed=jnp.zeros((0, np.asarray(loc).shape[1]),
                                     jnp.uint32),
                    scale=jnp.ones((0, 1), jnp.float32), n=n_feat))
                continue
            counts = _spmm_bits(p.intra[kind],
                                _pad_rows(jnp.asarray(loc), frdc.TILE), mode)
            counts = counts + _spmm_bits(
                p.halo[kind], _pad_rows(jnp.asarray(rem), frdc.TILE), mode)
            out.append(_binarize_counts(counts, n_feat))
        return out

    def _sharded_full_pass(self) -> List[np.ndarray]:
        """Layer-wise distributed inference with frozen BN stats; returns the
        per-shard logits blocks."""
        fam, q, bn = self.plan.family, self.qparams, self.bn
        xs = [jnp.asarray(b) for b in self._x_blocks()]
        if fam == "gcn" and self.plan.scheme == "bin":
            z = [gnn.batch_norm(x, stats=bn[0]) for x in xs]
            hb = [bmm(zz, q.w1, "FBB", out_scale=False) for zz in z]
            n_hidden = hb[0].n
            h1 = self._partial_bbb("bin", [np.asarray(t.packed) for t in hb],
                                   n_hidden, tag="layer1/packed")
            h2 = [bmm(t, q.w2, "BBF") for t in h1]
            out = self._partial_fbf("adj", h2, tag="layer2/fp")
        elif fam == "gcn":
            z1 = [quantize_act(gnn.batch_norm(x, stats=bn[0])) for x in xs]
            t1 = [bmm(zz, q.w1, "BBF") for zz in z1]
            h = [jax.nn.relu(y)
                 for y in self._partial_fbf("adj", t1, tag="layer1/fp")]
            z2 = [quantize_act(gnn.batch_norm(hh, stats=bn[1])) for hh in h]
            t2 = [bmm(zz, q.w2, "BBF") for zz in z2]
            out = self._partial_fbf("adj", t2, tag="layer2/fp")
        elif fam == "sage":
            xq = [quantize_act(gnn.batch_norm(x, stats=bn[0])) for x in xs]
            a1 = [bmm(v, q.w1_agg, "BBF") for v in xq]
            agg1 = self._partial_fbf("mean", a1, tag="layer1/fp")
            h = [jax.nn.relu(bmm(v, q.w1_self, "BBF") + g)
                 for v, g in zip(xq, agg1)]
            hq = [quantize_act(gnn.batch_norm(hh, stats=bn[1])) for hh in h]
            a2 = [bmm(v, q.w2_agg, "BBF") for v in hq]
            agg2 = self._partial_fbf("mean", a2, tag="layer2/fp")
            out = [bmm(v, q.w2_self, "BBF") + g for v, g in zip(hq, agg2)]
        else:                                                   # saint
            xq = [quantize_act(gnn.batch_norm(x, stats=bn[0])) for x in xs]
            a1 = [bmm(v, q.w1_agg, "BBF") for v in xq]
            agg1 = self._partial_fbf("sum", a1, tag="layer1/fp")
            h = [jax.nn.relu(bmm(v, q.w1_self, "BBF") + g)
                 for v, g in zip(xq, agg1)]
            hq = [quantize_act(gnn.batch_norm(hh, stats=bn[1])) for hh in h]
            a2 = [bmm(v, q.w2_agg, "BBF") for v in hq]
            agg2 = self._partial_fbf("sum", a2, tag="layer2/fp")
            h2 = [jax.nn.relu(bmm(v, q.w2_self, "BBF") + g)
                  for v, g in zip(hq, agg2)]
            out = [bmm(quantize_act(gnn.batch_norm(hh, stats=bn[2])),
                       q.w_fc, "BBF") for hh in h2]
        return [np.asarray(o) for o in out]

    # ------------------------------------------------------ full path ------
    def full_logits(self) -> np.ndarray:
        """Full-graph logits assembled from the per-shard caches (each
        filled by the distributed pass). The concatenation is memoized per
        feature version — the full-cache serve path gathers from it every
        tick."""
        self.sync()
        if self._assembled is None:
            self._assembled = np.concatenate(self._caches, axis=0)
        return self._assembled

    # -------------------------------------------------- subgraph path ------
    def _extract(self, uniq_seeds: np.ndarray):
        """Routed k-hop extraction + subgraph FRDC build for one owner's
        seed group (host-side; also used by warmup shape probing)."""
        sub_nodes, sub_edges, seed_pos = routed_khop_subgraph(
            self._scsr, uniq_seeds, self.khop)
        dinv_blocks = self._dinv_blocks()
        dinv_sub = None
        if dinv_blocks is not None:
            dinv_sub = halo_mod.gather_rows(dinv_blocks, self.routing,
                                            sub_nodes)
        mats = session_core.sub_adjacency(self.plan.family, sub_nodes.size,
                                          sub_edges, dinv_sub)
        return sub_nodes, mats, seed_pos

    def _serve_owner_batch(self, owner: int,
                           uniq_seeds: np.ndarray) -> np.ndarray:
        """Answer one owner shard's routed seed group: extract the (possibly
        boundary-crossing) k-hop subgraph, fetch remote feature rows through
        the halo transport, and run the owner's bucketed jitted forward."""
        sub_nodes, mats, seed_pos = self._extract(uniq_seeds)
        x_sub = halo_mod.gather_rows(self._x_blocks(), self.routing,
                                     sub_nodes, home=owner,
                                     stats=self.halo_stats, tag="serve/x")
        return self.cores[owner].run(x_sub, mats, seed_pos, self.bn)

    def serve_subgraph(self, seeds: np.ndarray) -> np.ndarray:
        """Micro-batched node-level inference across shards: group the batch
        by owning shard (routing table), answer each group on its owner, and
        merge the logits back into request order."""
        self.sync()
        seeds = np.asarray(seeds, np.int64)
        uniq, inverse = np.unique(seeds, return_inverse=True)
        owners = self.routing.owner(uniq)
        out = np.zeros((uniq.size,) + self._out_shape(), np.float32)
        for s in np.unique(owners):
            sel = owners == s
            out[sel] = self._serve_owner_batch(int(s), uniq[sel])
        return out[inverse]

    def _out_shape(self) -> tuple:
        if self._caches is not None:
            return self._caches[0].shape[1:]
        q = self.qparams
        last = q[-2] if self.plan.family == "sage" else q[-1]
        # BinTensor of W.T: packed rows = out features
        return (last.packed.shape[0],)

    def warmup(self, rng: Optional[np.random.Generator] = None,
               probes: int = 16, margin: float = 1.125) -> int:
        """Per-shard high-water warmup: probe ``probes`` max-width batches
        host-side, route each probe's seeds to their owners to find every
        shard's steady node/group maxima, preset the water marks, then run
        one real forward per shard. Returns compiles triggered."""
        rng = rng or np.random.default_rng(0)
        before = self.compile_count
        self.sync()
        n = self.shard_plan.n_nodes
        n_max = [0] * self.n_shards
        g_max: List[Dict[str, int]] = [{} for _ in range(self.n_shards)]
        for _ in range(probes):
            seeds = np.unique(rng.integers(0, n, size=self.max_batch))
            owners = self.routing.owner(seeds)
            for s in np.unique(owners):
                sub_nodes, mats, _ = self._extract(seeds[owners == s])
                n_max[s] = max(n_max[s], sub_nodes.size)
                for k, m in mats.items():
                    g_max[s][k] = max(g_max[s].get(k, 0), m.n_groups)
        for s, core in enumerate(self.cores):
            if n_max[s] == 0:
                continue
            core.preset_water(n_max[s], g_max[s], margin)
        self.serve_subgraph(rng.integers(0, n, size=self.max_batch))
        return self.compile_count - before

    # ------------------------------------------------------- artifact ------
    def fingerprint(self) -> dict:
        return session_core.session_fingerprint(self.graph, self.model)

    def _state(self) -> dict:
        shards = []
        for p in self.parts:
            shards.append({
                "intra": {k: session_core.frdc_arrays(m)
                          for k, m in p.intra.items()},
                "halo": {k: session_core.frdc_arrays(m)
                         for k, m in p.halo.items()},
                "halo_nodes": p.halo_nodes,
                "indptr": p.indptr, "indices": p.indices,
                **({} if p.dinv is None else {"dinv": p.dinv}),
            })
        return {"qparams": self.qparams, "shards": shards}

    def save(self, directory: Path) -> None:
        """Serialize per-shard FRDC + CSR + routing table via the
        checkpointer; plan/fingerprint/dims in the ``routing.json`` sidecar
        (format documented in the README next to ``plan.json``)."""
        self.sync()
        directory = Path(directory)
        ckpt = Checkpointer(directory, keep=1)
        ckpt.save(0, self._state(), blocking=True)
        sidecar = dict(
            plan=self.plan.to_json(), fingerprint=self.fingerprint(),
            khop=self.khop, max_batch=self.max_batch,
            n_shards=self.n_shards,
            routing=self.routing.to_json(),
            shards=[dict(
                row_start=p.row_start, row_end=p.row_end, n_halo=p.n_halo,
                intra_dims={k: [m.n_rows, m.n_cols, m.nnz]
                            for k, m in p.intra.items()},
                halo_dims={k: [m.n_rows, m.n_cols, m.nnz]
                           for k, m in p.halo.items()},
            ) for p in self.parts])
        (directory / "routing.json").write_text(json.dumps(sidecar))

    @classmethod
    def load(cls, directory: Path, graph, model, khop: Optional[int] = None,
             max_batch: Optional[int] = None, use_pallas: bool = False,
             mesh=None) -> Optional["ShardedGraphSession"]:
        """Restore a sharded artifact WITHOUT re-partitioning or re-tuning;
        returns None on any mismatch so the caller replans."""
        directory = Path(directory)
        sidecar_path = directory / "routing.json"
        if not sidecar_path.exists():
            return None
        sidecar = json.loads(sidecar_path.read_text())
        if khop is not None and sidecar["khop"] != khop:
            return None
        if max_batch is not None and sidecar["max_batch"] != max_batch:
            return None
        plan = SessionPlan.from_json(sidecar["plan"])
        if session_core.session_fingerprint(graph, model) \
                != sidecar["fingerprint"]:
            return None
        fam = model.family
        has_dinv = fam in ("gcn", "sage")
        kinds = session_core.FAMILY_ADJ_KINDS[fam]
        scale_extra = session_core.ADJ_SCALE_FIELDS[fam]

        def frdc_like(kind):
            # halo matrices carry the same scale fields as intra ones
            return {f: np.zeros(0)
                    for f in session_core.FRDC_BASE_FIELDS
                    + scale_extra[kind]}

        like_shards = []
        for sd in sidecar["shards"]:
            like_shards.append({
                "intra": {k: frdc_like(k) for k in kinds},
                "halo": {k: frdc_like(k) for k in kinds},
                "halo_nodes": np.zeros(0, np.int64),
                "indptr": np.zeros(0, np.int64),
                "indices": np.zeros(0, np.int64),
                **({"dinv": np.zeros(0)} if has_dinv else {}),
            })
        like = {"qparams": session_core.quantize_family(fam, model.params),
                "shards": like_shards}
        try:
            state = Checkpointer(directory, keep=1).restore(None, like)
        except (FileNotFoundError, AssertionError):
            return None

        routing = RoutingTable.from_json(sidecar["routing"])
        parts = []
        for s, (sd, st) in enumerate(zip(sidecar["shards"],
                                         state["shards"])):
            intra = {k: session_core.frdc_rebuild(st["intra"][k],
                                                  *sd["intra_dims"][k])
                     for k in kinds}
            halo_m = {k: session_core.frdc_rebuild(st["halo"][k],
                                                   *sd["halo_dims"][k])
                      for k in kinds}
            parts.append(ShardPart(
                index=s, row_start=int(sd["row_start"]),
                row_end=int(sd["row_end"]),
                halo_nodes=np.asarray(st["halo_nodes"], np.int64),
                intra=intra, halo=halo_m,
                indptr=np.asarray(st["indptr"], np.int64),
                indices=np.asarray(st["indices"], np.int64),
                dinv=(np.asarray(st["dinv"]) if has_dinv else None)))
        shard_plan = ShardPlan(family=fam, routing=routing, parts=parts,
                               n_nodes=int(graph.data.n_nodes),
                               n_edges=int(graph.data.n_edges))
        return cls(graph, model, plan,
                   session_core.coerce_quant(state["qparams"]), shard_plan,
                   khop=sidecar["khop"], max_batch=sidecar["max_batch"],
                   use_pallas=use_pallas, mesh=mesh)
