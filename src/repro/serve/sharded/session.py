"""ShardedGraphSession: one (graph, model) serving artifact split over P shards.

Compared with the single-host :class:`~repro.serve.gnn_session.
CompiledGraphSession`, the graph state is partitioned (contiguous
tile-row-aligned node ranges, :mod:`.planner`): each shard owns its feature
rows, its block of the CSR, an intra-shard FRDC adjacency and a bit-packed
halo adjacency over the boundary edges. Serving has two paths:

  * **routed subgraph** (the scale path): a k-hop query is answered by its
    seed's OWNING shard — the frontier is routed across shard boundaries
    (:mod:`.routing`), remote features and factorization-vector entries are
    fetched through the halo transport, and the owning shard's
    :class:`~repro.serve.session_core.ServeCore` runs the same bucketed
    jitted forward as the single-host session with the same frozen BN stats.
    Because the assembled subgraph, adjacency, features and calibration are
    identical, the outputs are bit-exact against single-host serving.

  * **distributed full pass**: layer-wise per-shard aggregation — each shard
    computes its output rows from ``intra @ local + halo @ remote``, where
    the remote operand arrives via halo exchange (:mod:`.halo`); for the
    binary-aggregation layer of the GCN "bin" scheme the exchanged rows are
    bit-PACKED (uint32 words, 32x smaller than fp) and the partial popc
    counts add exactly. This pass fills the per-shard full-logits caches and
    is the path whose halo bytes the benchmark reports. Its fp aggregations
    reassociate across the intra/halo split, so it matches single-host
    full-graph logits to fp tolerance (binary layers: exactly).

The pass itself is delegated to a :class:`~repro.serve.session_core.
LayerExecutor` running the family's layer program (``executor=``):

  * ``"host"`` — PR 2's host-orchestrated per-shard stages (the
    bit-exactness reference, runs on any device count);
  * ``"spmd"`` — each layer as ONE ``shard_map`` program over uniformly
    padded stacked shards with the halo exchange fused in
    (:mod:`.executor`); requires a mesh with a ``data`` axis of exactly P
    devices and matches the host executor bit-for-bit under shared BN
    constants.

BN calibration (``bn_mode=``): ``"single_host"`` runs one full-graph pass
through the shared :func:`~repro.serve.session_core.family_forward`
(bit-identical to the single-host session's calibration — the invariant
behind the exactness guarantee above); ``"distributed"`` computes each BN
site's (mu, sd) from the distributed pass itself (psum moments across
shards) so no host ever needs the whole graph — serving drift vs the anchor
is quantified in ``benchmarks/bench_sharded_serve.py``.

Artifacts (per-shard FRDC + CSR + routing table) serialize through the
checkpointer with a ``routing.json`` sidecar (now carrying the ``spmd``
uniform-dims/schedule field; older artifacts without it still load and
rebuild it); a restore re-builds the session without re-partitioning or
re-tuning.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import frdc
from repro.graphs import sampling
from repro.launch.mesh import make_shard_mesh
from repro.serve import adapters, session_core
from repro.serve.session_core import ServeCore, SessionPlan
from . import halo as halo_mod
from .executor import HostLayerExecutor, SpmdLayerExecutor
from .planner import ShardPart, ShardPlan, SpmdPlan
from .routing import RoutingTable, ShardedCSR
from .routing import khop_subgraph as routed_khop_subgraph

EXECUTORS = ("host", "spmd")
BN_MODES = ("single_host", "distributed")


class ShardedGraphSession:
    """Partitioned compiled serving artifact. See module docstring."""

    def __init__(self, graph, model, plan: SessionPlan, qparams,
                 shard_plan: ShardPlan, khop: int = 2, max_batch: int = 32,
                 use_pallas: bool = False, mesh=None,
                 executor: str = "host", bn_mode: str = "single_host"):
        if shard_plan.family != plan.family:
            raise ValueError(f"shard plan family {shard_plan.family!r} != "
                             f"session family {plan.family!r}")
        if executor not in EXECUTORS:
            raise ValueError(f"unknown executor {executor!r}; "
                             f"have {EXECUTORS}")
        if bn_mode not in BN_MODES:
            raise ValueError(f"unknown bn_mode {bn_mode!r}; have {BN_MODES}")
        self.graph = graph
        self.model = model
        self.plan = plan
        self.qparams = qparams
        self.shard_plan = shard_plan
        self.routing: RoutingTable = shard_plan.routing
        self.khop = khop
        self.max_batch = max_batch
        self.use_pallas = use_pallas
        self.mesh = mesh
        self.executor = executor
        self.bn_mode = bn_mode
        self.key = f"{graph.name}__{model.name}__P{shard_plan.n_shards}"
        self.feature_version = -1
        self.bn: Optional[tuple] = None
        self.halo_stats = halo_mod.HaloStats()
        self._caches: Optional[List[np.ndarray]] = None
        self._assembled: Optional[np.ndarray] = None
        self._invalidations = 0
        self._scsr: ShardedCSR = shard_plan.sharded_csr()
        self._adj_full: Optional[Dict[str, frdc.FRDCMatrix]] = None
        self._jit_calibrate = None
        self._executor_obj: Optional[session_core.LayerExecutor] = None
        self.program = session_core.build_layer_program(plan, qparams)
        # one bucketed serve core per shard (all composing ONE stateless
        # family adapter — the water marks live per core); a routed subgraph
        # can span the whole graph, so every core's node cap is the full
        # padded graph
        node_cap = -(-shard_plan.n_nodes // frdc.TILE) * frdc.TILE
        self.adapter = adapters.GNNAdapter(plan)
        self.cores = [ServeCore(plan, qparams, max_batch, node_cap,
                                use_pallas=use_pallas, adapter=self.adapter)
                      for _ in range(shard_plan.n_shards)]
        # observability callback cb(label, shape_dict), fanned out to every
        # per-shard core and (on build) the layer executor
        self._trace_hook = None

    # ------------------------------------------------------------ state ----
    @property
    def n_shards(self) -> int:
        return self.shard_plan.n_shards

    @property
    def parts(self) -> List[ShardPart]:
        return self.shard_plan.parts

    @property
    def compile_count(self) -> int:
        """Total jit traces across the per-shard bucketed forwards."""
        return sum(c.compile_count for c in self.cores)

    @property
    def compile_count_by_shard(self) -> List[int]:
        return [c.compile_count for c in self.cores]

    @property
    def dispatch_count(self) -> int:
        """Device dispatches across the per-shard serve cores (a
        multi-bucket co-launch counts 1 per participating core)."""
        return sum(c.n_dispatches for c in self.cores)

    @property
    def invalidations(self) -> int:
        return self._invalidations

    def _x_blocks(self) -> List[np.ndarray]:
        x = self.graph.data.x
        return [x[p.row_start:p.row_end] for p in self.parts]

    def _dinv_blocks(self) -> Optional[List[np.ndarray]]:
        if self.parts[0].dinv is None:
            return None
        return [p.dinv for p in self.parts]

    def _use_mesh(self) -> bool:
        return (self.mesh is not None
                and self.mesh.shape.get("data", 0) == self.n_shards)

    def set_mesh(self, mesh) -> None:
        """Swap the halo transport (None = host loopback). Numerics are
        transport-independent; only the exchange mechanism changes."""
        if mesh is not self.mesh:
            self.mesh = mesh
            self._executor_obj = None

    # ------------------------------------------------------- executor ------
    @property
    def layer_executor(self) -> session_core.LayerExecutor:
        """The distributed-pass executor (built lazily; rebuilt on
        ``set_mesh``). ``executor="spmd"`` auto-builds a shard mesh when
        none was attached and raises when the host cannot supply one."""
        if self._executor_obj is None:
            if self.executor == "spmd":
                mesh = self.mesh if self._use_mesh() else \
                    make_shard_mesh(self.n_shards)
                if mesh is None:
                    raise RuntimeError(
                        f"executor='spmd' needs {self.n_shards} devices "
                        f"(have {len(jax.devices())}); force them with "
                        f"XLA_FLAGS=--xla_force_host_platform_device_count")
                self.mesh = mesh
                self._executor_obj = SpmdLayerExecutor(
                    self.parts, self.shard_plan.spmd_plan(), self.plan,
                    self.halo_stats, mesh, use_pallas=self.use_pallas)
            else:
                self._executor_obj = HostLayerExecutor(
                    self.parts, self.shard_plan.spmd_plan(), self.plan,
                    self.halo_stats, self.routing,
                    mesh=self.mesh if self._use_mesh() else None,
                    use_pallas=self.use_pallas)
            self._wire_executor_hook()
        return self._executor_obj

    def set_trace_hook(self, cb) -> None:
        """Wire an observability callback ``cb(label, shape_dict)`` to fire
        on every NEW jit trace of any per-shard serve core or layer-executor
        program (the engines' recompile watchdog). ``None`` unwires. A lazy
        executor built later inherits the hook."""
        self._trace_hook = cb
        for i, core in enumerate(self.cores):
            if cb is None:
                core.on_trace = None
            else:
                core.on_trace = (lambda shape, _i=i:
                                 cb(f"shard{_i}/core", shape))
        self._wire_executor_hook()

    def _wire_executor_hook(self) -> None:
        if self._executor_obj is None:
            return
        cb = self._trace_hook
        self._executor_obj.on_trace = (
            None if cb is None
            else (lambda label, shape: cb(f"executor/{label}", shape)))

    @property
    def executor_compile_count(self) -> int:
        """Jit traces of the distributed-pass layer programs — exactly one
        per (layer program, mode, shapes) in steady state for both
        executors (the host executor traces a stage + an operand program
        per exchange layer; SPMD one shard_map program per layer)."""
        return (0 if self._executor_obj is None
                else self._executor_obj.compile_count)

    # ------------------------------------------------------- calibrate -----
    def _calibrate_fn(self):
        """The shared full-graph calibration forward — the SAME jitted
        computation the single-host session freezes its BN stats from, so a
        sharded and a single-host session over one graph agree bit-for-bit
        on the calibration constants."""
        if self._jit_calibrate is None:
            d = self.graph.data
            fam = self.plan.family
            if fam == "gcn":
                adjs = {"adj": d.adjacency("gcn"),
                        "bin": d.adjacency("binary")}
            elif fam == "sage":
                adjs = {"mean": d.adjacency("mean")}
            else:
                adjs = {"sum": d.adjacency("binary")}
            self._adj_full = adjs
            plan, qparams, use_pallas = self.plan, self.qparams, \
                self.use_pallas

            def full(x):
                return session_core.family_forward(
                    plan, qparams, x, adjs, use_pallas=use_pallas,
                    return_bn_stats=True)

            self._jit_calibrate = jax.jit(full)
        return self._jit_calibrate

    def sync(self) -> None:
        """Adopt the store's current features: recalibrate BN and refresh
        the per-shard logits caches through the DISTRIBUTED layer-wise pass
        (the configured executor). ``bn_mode="single_host"`` freezes the
        stats from the shared full-graph anchor forward first;
        ``"distributed"`` computes them inside the pass itself (psum
        moments), so one run both calibrates and fills the caches and no
        host ever materializes the full graph. No-op when current."""
        if self.feature_version == self.graph.version:
            return
        invalidated = self.feature_version >= 0
        if self.bn_mode == "distributed":
            self._caches, bn = self.layer_executor.run_pass(
                self.program, self._x_blocks(), None, calibrate=True)
            self.bn = bn
        else:
            _, bn = self._calibrate_fn()(jnp.asarray(self.graph.data.x))
            self.bn = bn
            self._caches, _ = self.layer_executor.run_pass(
                self.program, self._x_blocks(), self.bn)
        self._assembled = None
        self.feature_version = self.graph.version
        if invalidated:
            self._invalidations += 1

    # ----------------------------------------------------- full pass -------
    def run_distributed_pass(self) -> List[np.ndarray]:
        """One distributed full pass with the CURRENT frozen calibration
        (no cache mutation) — the benchmark's executor latency probe."""
        self.sync()
        blocks, _ = self.layer_executor.run_pass(
            self.program, self._x_blocks(), self.bn)
        return blocks

    # ------------------------------------------------------ full path ------
    def full_logits(self) -> np.ndarray:
        """Full-graph logits assembled from the per-shard caches (each
        filled by the distributed pass). The concatenation is memoized per
        feature version — the full-cache serve path gathers from it every
        tick."""
        self.sync()
        if self._assembled is None:
            self._assembled = np.concatenate(self._caches, axis=0)
        return self._assembled

    # -------------------------------------------------- subgraph path ------
    def _extract(self, uniq_seeds: np.ndarray):
        """Routed k-hop extraction + subgraph FRDC build for one owner's
        seed group (host-side; also used by warmup shape probing). Same
        prepared-subgraph object as the single-host extractor — the routed
        expansion is bit-identical to ``sampling.khop_subgraph``."""
        ex = sampling.ExtractedSubgraph(*routed_khop_subgraph(
            self._scsr, uniq_seeds, self.khop))
        dinv_blocks = self._dinv_blocks()
        dinv_sub = None
        if dinv_blocks is not None:
            dinv_sub = halo_mod.gather_rows(dinv_blocks, self.routing,
                                            ex.sub_nodes)
        mats = self.adapter.sub_operands(ex.sub_nodes.size, ex.sub_edges,
                                         dinv_sub)
        return ex.sub_nodes, mats, ex.seed_pos

    def prepare_batch(self, seeds: np.ndarray) -> session_core.PreparedBatch:
        """EXTRACT stage: routed k-hop extraction, halo feature fetch and
        bucket padding for every owner group in the batch — pure host work
        (the ``serve/x`` halo bytes are accounted here, where the gather
        happens). The engine's single-owner queues make this one group per
        batch in practice; mixed-owner batches stage one group per owner."""
        self.sync()
        seeds = np.asarray(seeds, np.int64)
        uniq, inverse = np.unique(seeds, return_inverse=True)
        owners = self.routing.owner(uniq)
        groups = []
        for s in np.unique(owners):
            sel = np.nonzero(owners == s)[0]
            sub_nodes, mats, seed_pos = self._extract(uniq[sel])
            x_sub = halo_mod.gather_rows(self._x_blocks(), self.routing,
                                         sub_nodes, home=int(s),
                                         stats=self.halo_stats, tag="serve/x")
            staged = self.cores[int(s)].stage(x_sub, mats, seed_pos)
            groups.append(session_core.PreparedGroup(
                core=self.cores[int(s)], sel=sel, staged=staged))
        return session_core.PreparedBatch(n_uniq=uniq.size, inverse=inverse,
                                          groups=groups,
                                          out_shape=self._out_shape(),
                                          bn=self.bn)

    def launch_batch(self, prepared) -> list:
        """COMPUTE-stage head: dispatch every owner group's jitted forward
        (with the calibration captured when the batch was staged)."""
        return prepared.launch()

    def finish_batch(self, prepared, devs) -> np.ndarray:
        """COMPUTE-stage tail: block and merge owner groups back into
        request order."""
        return prepared.finish(devs)

    def serve_subgraph(self, seeds: np.ndarray) -> np.ndarray:
        """Micro-batched node-level inference across shards: group the batch
        by owning shard (routing table), answer each group on its owner, and
        merge the logits back into request order. Serial composition of the
        same prepare/launch/finish stages the pipelined engine drives."""
        prepared = self.prepare_batch(seeds)
        return self.finish_batch(prepared, self.launch_batch(prepared))

    def seed_halo_tiles(self, node: int) -> frozenset:
        """Cheap per-seed halo signature for halo-aware batch formation: the
        FRDC tile ids (global node id // TILE) of the seed's REMOTE 1-hop
        neighbors — a one-CSR-row proxy for which halo tiles the seed's
        k-hop closure will request over the ``serve/x`` gather. Seeds with
        overlapping signatures share halo traffic when co-batched."""
        owner = int(self.routing.owner(np.asarray([node]))[0])
        lo, hi = self.routing.shard_range(owner)
        nbrs = self._scsr.shards[owner].neighbors(int(node) - lo)
        remote = nbrs[(nbrs < lo) | (nbrs >= hi)]
        return frozenset((remote // frdc.TILE).tolist())

    def _out_shape(self) -> tuple:
        if self._caches is not None:
            return self._caches[0].shape[1:]
        q = self.qparams
        last = q[-2] if self.plan.family == "sage" else q[-1]
        # BinTensor of W.T: packed rows = out features
        return (last.packed.shape[0],)

    def warmup(self, rng: Optional[np.random.Generator] = None,
               probes: int = 16, margin: float = 1.125) -> int:
        """Per-shard high-water warmup: probe ``probes`` max-width batches
        host-side, route each probe's seeds to their owners to find every
        shard's steady node/group maxima, preset the water marks, then run
        one real forward per shard. Returns compiles triggered."""
        rng = rng or np.random.default_rng(0)
        before = self.compile_count
        self.sync()
        n = self.shard_plan.n_nodes
        n_max = [0] * self.n_shards
        g_max: List[Dict[str, int]] = [{} for _ in range(self.n_shards)]

        def _probe(s: int, seeds: np.ndarray) -> None:
            sub_nodes, mats, _ = self._extract(seeds)
            n_max[s] = max(n_max[s], sub_nodes.size)
            for k, m in mats.items():
                g_max[s][k] = max(g_max[s].get(k, 0), m.n_groups)

        for _ in range(probes):
            seeds = np.unique(rng.integers(0, n, size=self.max_batch))
            owners = self.routing.owner(seeds)
            for s in np.unique(owners):
                _probe(s, seeds[owners == s])
            # steady state forms SINGLE-owner batches up to max_batch wide
            # (per-owner queues), so a mixed-owner probe understates every
            # shard's closure — also probe each shard at full batch width
            # from its own contiguous node range
            for s in range(self.n_shards):
                lo, hi = self.routing.shard_range(s)
                if hi > lo:
                    _probe(s, np.unique(rng.integers(lo, hi,
                                                     size=self.max_batch)))
        for s, core in enumerate(self.cores):
            if n_max[s] == 0:
                continue
            core.preset_water(n_max[s], g_max[s], margin)
        self.serve_subgraph(rng.integers(0, n, size=self.max_batch))
        return self.compile_count - before

    # ------------------------------------------------------- artifact ------
    def fingerprint(self) -> dict:
        return session_core.session_fingerprint(self.graph, self.model)

    def _state(self) -> dict:
        shards = []
        for p in self.parts:
            shards.append({
                "intra": {k: session_core.frdc_arrays(m)
                          for k, m in p.intra.items()},
                "halo": {k: session_core.frdc_arrays(m)
                         for k, m in p.halo.items()},
                "halo_nodes": p.halo_nodes,
                "indptr": p.indptr, "indices": p.indices,
                **({} if p.dinv is None else {"dinv": p.dinv}),
            })
        return {"qparams": self.qparams, "shards": shards}

    def save(self, directory: Path) -> None:
        """Serialize per-shard FRDC + CSR + routing table via the
        checkpointer; plan/fingerprint/dims in the ``routing.json`` sidecar
        (format documented in the README next to ``plan.json``)."""
        self.sync()
        directory = Path(directory)
        ckpt = Checkpointer(directory, keep=1)
        ckpt.save(0, self._state(), blocking=True)
        sidecar = dict(
            plan=self.plan.to_json(), fingerprint=self.fingerprint(),
            khop=self.khop, max_batch=self.max_batch,
            n_shards=self.n_shards,
            routing=self.routing.to_json(),
            spmd=self.shard_plan.spmd_plan().to_json(),
            shards=[dict(
                row_start=p.row_start, row_end=p.row_end, n_halo=p.n_halo,
                intra_dims={k: [m.n_rows, m.n_cols, m.nnz]
                            for k, m in p.intra.items()},
                halo_dims={k: [m.n_rows, m.n_cols, m.nnz]
                           for k, m in p.halo.items()},
            ) for p in self.parts])
        (directory / "routing.json").write_text(json.dumps(sidecar))

    @classmethod
    def load(cls, directory: Path, graph, model, khop: Optional[int] = None,
             max_batch: Optional[int] = None, use_pallas: bool = False,
             mesh=None, executor: str = "host",
             bn_mode: str = "single_host", bspmm_block="unchanged",
             fused="unchanged",
             ) -> Optional["ShardedGraphSession"]:
        """Restore a sharded artifact WITHOUT re-partitioning or re-tuning;
        returns None on any mismatch so the caller replans. ``executor`` /
        ``bn_mode`` are runtime choices, not artifact properties — any
        artifact serves under either executor; pre-``spmd``-field sidecars
        rebuild the uniform-dims plan from the restored parts."""
        directory = Path(directory)
        sidecar_path = directory / "routing.json"
        sidecar = session_core.load_sidecar(
            sidecar_path, required=("plan", "fingerprint", "khop",
                                    "max_batch", "n_shards", "routing",
                                    "shards"))
        if sidecar is None:
            return None
        if khop is not None and sidecar["khop"] != khop:
            return None
        if max_batch is not None and sidecar["max_batch"] != max_batch:
            return None
        try:
            plan = SessionPlan.from_json(sidecar["plan"])
        except (KeyError, TypeError, ValueError) as e:
            raise session_core.ArtifactError(sidecar_path, field="plan",
                                             detail=repr(e))
        if session_core.session_fingerprint(graph, model) \
                != sidecar["fingerprint"]:
            return None
        # trace-time kernel choices: a different block shape or fused
        # selection must recompile
        if bspmm_block != "unchanged" and plan.bspmm_block != bspmm_block:
            return None
        if fused != "unchanged" and plan.fused != fused:
            return None
        fam = model.family
        has_dinv = fam in ("gcn", "sage")
        kinds = session_core.FAMILY_ADJ_KINDS[fam]
        scale_extra = session_core.ADJ_SCALE_FIELDS[fam]

        def frdc_like(kind):
            # halo matrices carry the same scale fields as intra ones
            return {f: np.zeros(0)
                    for f in session_core.FRDC_BASE_FIELDS
                    + scale_extra[kind]}

        like_shards = []
        for sd in sidecar["shards"]:
            like_shards.append({
                "intra": {k: frdc_like(k) for k in kinds},
                "halo": {k: frdc_like(k) for k in kinds},
                "halo_nodes": np.zeros(0, np.int64),
                "indptr": np.zeros(0, np.int64),
                "indices": np.zeros(0, np.int64),
                **({"dinv": np.zeros(0)} if has_dinv else {}),
            })
        like = {"qparams": session_core.quantize_family(fam, model.params),
                "shards": like_shards}
        # typed restore: missing/mismatched checkpoint -> None (recompile),
        # truncated/corrupt npz or manifest -> ArtifactError naming the file
        state = session_core.restore_artifact_state(directory, like)
        if state is None:
            return None

        try:
            routing = RoutingTable.from_json(sidecar["routing"])
        except (KeyError, TypeError, ValueError) as e:
            raise session_core.ArtifactError(sidecar_path, field="routing",
                                             detail=repr(e))
        parts = []
        for s, (sd, st) in enumerate(zip(sidecar["shards"],
                                         state["shards"])):
            intra = {k: session_core.frdc_rebuild(st["intra"][k],
                                                  *sd["intra_dims"][k])
                     for k in kinds}
            halo_m = {k: session_core.frdc_rebuild(st["halo"][k],
                                                   *sd["halo_dims"][k])
                      for k in kinds}
            parts.append(ShardPart(
                index=s, row_start=int(sd["row_start"]),
                row_end=int(sd["row_end"]),
                halo_nodes=np.asarray(st["halo_nodes"], np.int64),
                intra=intra, halo=halo_m,
                indptr=np.asarray(st["indptr"], np.int64),
                indices=np.asarray(st["indices"], np.int64),
                dinv=(np.asarray(st["dinv"]) if has_dinv else None)))
        spmd = (SpmdPlan.from_json(sidecar["spmd"])
                if "spmd" in sidecar else None)
        shard_plan = ShardPlan(family=fam, routing=routing, parts=parts,
                               n_nodes=int(graph.data.n_nodes),
                               n_edges=int(graph.data.n_edges), spmd=spmd)
        return cls(graph, model, plan,
                   session_core.coerce_quant(state["qparams"]), shard_plan,
                   khop=sidecar["khop"], max_batch=sidecar["max_batch"],
                   use_pallas=use_pallas, mesh=mesh, executor=executor,
                   bn_mode=bn_mode)
