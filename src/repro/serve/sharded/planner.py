"""Shard planning: cut a graph into P serving shards.

``ShardPlanner`` reuses :mod:`repro.graphs.partition`'s edge-balanced
tile-row-aligned boundaries and builds, per shard:

  * an **intra-shard FRDC adjacency** per adjacency kind the family's packed
    forward needs (rows AND columns local to the shard);
  * a **halo FRDC adjacency** per kind: the boundary edges (local row, remote
    column), columns re-indexed into the shard's sorted ``halo_nodes`` list —
    the bit-packed structure the layer-wise halo exchange aggregates over;
  * the shard's rows of the graph CSR (global column ids) for routed k-hop
    extraction;
  * the shard's slice of the FULL-graph factorization vector (GCN D^-1/2 /
    SAGE D^-1), so subgraph adjacencies assembled from any mix of shards
    normalize exactly like the full graph.

Every edge of the input lands in exactly one shard's intra OR halo
adjacency (the conservation property tested in
``tests/test_partition_properties.py``); self-loops added by the GCN
normalization are intra by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import frdc
from repro.graphs import partition, sampling
from repro.graphs.datasets import GraphData
from repro.serve import session_core
from .halo import MeshHaloPlan, build_mesh_plan
from .routing import RoutingTable, ShardedCSR


@dataclasses.dataclass
class SpmdPlan:
    """Uniform padded dims + halo schedule of the SPMD layer executor.

    Every shard's FRDC operands are padded to ONE static shape —
    ``(n_local_pad, n_local_pad)`` intra / ``(n_local_pad, n_halo_pad)``
    halo, per-kind shared group counts — following the bit-tensor-core
    batching insight (arXiv:2006.16578) that uniform bit-packed tiles are
    what let a whole layer run as a single program: stacked along a leading
    shard axis they become ``shard_map`` operands, and the ring exchange
    schedule (``mesh_plan``, overflow slot at ``n_halo_pad``) is fused into
    the same program. Serialized as the ``spmd`` field of ``routing.json``;
    artifacts predating the field rebuild it from the shard parts.
    """
    n_shards: int
    n_local_pad: int
    n_halo_pad: int
    intra_groups: Dict[str, int]
    halo_groups: Dict[str, int]
    mesh_plan: MeshHaloPlan

    def to_json(self) -> dict:
        return dict(n_shards=self.n_shards, n_local_pad=self.n_local_pad,
                    n_halo_pad=self.n_halo_pad,
                    intra_groups=dict(self.intra_groups),
                    halo_groups=dict(self.halo_groups),
                    mesh_plan=self.mesh_plan.to_json())

    @classmethod
    def from_json(cls, d: dict) -> "SpmdPlan":
        return cls(n_shards=int(d["n_shards"]),
                   n_local_pad=int(d["n_local_pad"]),
                   n_halo_pad=int(d["n_halo_pad"]),
                   intra_groups={k: int(v)
                                 for k, v in d["intra_groups"].items()},
                   halo_groups={k: int(v)
                                for k, v in d["halo_groups"].items()},
                   mesh_plan=MeshHaloPlan.from_json(d["mesh_plan"]))


def build_spmd_plan(routing: RoutingTable, parts: List["ShardPart"]
                    ) -> SpmdPlan:
    """Derive the uniform SPMD dims + padded halo schedule from shard parts
    (tile-aligned covers of every shard's local/halo/group extents)."""
    n_local_pad = max(frdc.align_tile(p.n_local) for p in parts)
    n_halo_pad = max(frdc.align_tile(p.n_halo) for p in parts)
    kinds = list(parts[0].intra)
    intra_groups = {k: max(p.intra[k].n_groups for p in parts)
                    for k in kinds}
    halo_groups = {k: max(p.halo[k].n_groups for p in parts) for k in kinds}
    mesh_plan = build_mesh_plan(routing, [p.halo_nodes for p in parts],
                                n_halo_buf=n_halo_pad)
    return SpmdPlan(n_shards=len(parts), n_local_pad=n_local_pad,
                    n_halo_pad=n_halo_pad, intra_groups=intra_groups,
                    halo_groups=halo_groups, mesh_plan=mesh_plan)


@dataclasses.dataclass
class ShardPart:
    """Everything one shard owns."""
    index: int
    row_start: int
    row_end: int
    halo_nodes: np.ndarray                    # sorted global ids, may be empty
    intra: Dict[str, frdc.FRDCMatrix]         # kind -> (n_local, n_local)
    halo: Dict[str, frdc.FRDCMatrix]          # kind -> (n_local, max(n_halo,1))
    indptr: np.ndarray                        # local CSR rows -> global cols
    indices: np.ndarray
    dinv: Optional[np.ndarray]                # factorization rows [lo, hi)

    @property
    def n_local(self) -> int:
        return self.row_end - self.row_start

    @property
    def n_halo(self) -> int:
        return int(self.halo_nodes.size)


@dataclasses.dataclass
class ShardPlan:
    family: str
    routing: RoutingTable
    parts: List[ShardPart]
    n_nodes: int
    n_edges: int
    spmd: Optional[SpmdPlan] = None

    @property
    def n_shards(self) -> int:
        return len(self.parts)

    def spmd_plan(self) -> SpmdPlan:
        """The uniform-dims SPMD execution plan (built on demand for plans
        restored from pre-``spmd`` artifacts, recorded otherwise)."""
        if self.spmd is None:
            self.spmd = build_spmd_plan(self.routing, self.parts)
        return self.spmd

    def sharded_csr(self) -> ShardedCSR:
        return ShardedCSR.from_arrays(
            self.routing, [p.indptr for p in self.parts],
            [p.indices for p in self.parts])

    def stats(self) -> dict:
        intra = np.array([sum(m.nnz for m in p.intra.values())
                          for p in self.parts], np.float64)
        cut = np.array([sum(m.nnz for m in p.halo.values())
                        for p in self.parts], np.float64)
        kinds = len(self.parts[0].intra)
        total = max(float(intra.sum() + cut.sum()), 1.0)
        return dict(
            n_shards=self.n_shards, n_nodes=self.n_nodes,
            n_edges=self.n_edges,
            edge_cut_fraction=float(cut.sum()) / total,
            halo_nodes=[p.n_halo for p in self.parts],
            local_nodes=[p.n_local for p in self.parts],
            imbalance=float((intra + cut).max()
                            / max((intra + cut).mean(), 1.0)),
            adjacency_kinds=kinds,
        )


class ShardPlanner:
    """Plan P serving shards for one (graph, model family) pair."""

    def __init__(self, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards

    def plan(self, data: GraphData, family: str) -> ShardPlan:
        if family not in session_core.FAMILIES:
            raise ValueError(f"unknown family {family!r}")
        rows = np.asarray(data.edges[0], np.int64)
        cols = np.asarray(data.edges[1], np.int64)
        n = data.n_nodes
        bounds = partition.shard_node_bounds(rows, n, self.n_shards)
        routing = RoutingTable(bounds=bounds)
        deg = np.bincount(rows, minlength=n)
        dinv = session_core.dinv_for_family(family, deg)

        parts = []
        for s in range(self.n_shards):
            lo, hi = routing.shard_range(s)
            n_local = max(hi - lo, 1)
            rmask = (rows >= lo) & (rows < hi)
            rs, cs = rows[rmask] - lo, cols[rmask]
            # local CSR over GLOBAL columns (same stable sort as the
            # single-host CSR -> identical per-row neighbor order)
            csr = sampling.to_csr(np.stack([rs, cs]), n_local)
            cmask = (cs >= lo) & (cs < hi)
            ir, ic = rs[cmask], cs[cmask] - lo
            hr, hc_global = rs[~cmask], cs[~cmask]
            halo_nodes = np.unique(hc_global)
            hc = np.searchsorted(halo_nodes, hc_global)
            n_halo = max(halo_nodes.size, 1)
            # degenerate dims (empty shard / no halo) keep unit scales so the
            # FRDC scale vectors always match the padded matrix dims
            rsc = None if dinv is None else (
                dinv[lo:hi] if hi > lo else np.ones(n_local))
            hcsc = (dinv[halo_nodes] if dinv is not None and halo_nodes.size
                    else np.ones(n_halo))

            intra: Dict[str, frdc.FRDCMatrix] = {}
            halo_m: Dict[str, frdc.FRDCMatrix] = {}
            if family == "gcn":
                loops = np.arange(hi - lo, dtype=np.int64)
                intra["adj"] = frdc.from_coo(
                    np.concatenate([ir, loops]), np.concatenate([ic, loops]),
                    n_local, n_local, row_scale=rsc, col_scale=rsc)
                halo_m["adj"] = frdc.from_coo(
                    hr, hc, n_local, n_halo, row_scale=rsc, col_scale=hcsc)
                intra["bin"] = frdc.from_coo(ir, ic, n_local, n_local)
                halo_m["bin"] = frdc.from_coo(hr, hc, n_local, n_halo)
            elif family == "sage":
                intra["mean"] = frdc.from_coo(ir, ic, n_local, n_local,
                                              row_scale=rsc)
                halo_m["mean"] = frdc.from_coo(hr, hc, n_local, n_halo,
                                               row_scale=rsc)
            else:
                intra["sum"] = frdc.from_coo(ir, ic, n_local, n_local)
                halo_m["sum"] = frdc.from_coo(hr, hc, n_local, n_halo)

            parts.append(ShardPart(
                index=s, row_start=lo, row_end=hi, halo_nodes=halo_nodes,
                intra=intra, halo=halo_m, indptr=csr.indptr,
                indices=csr.indices,
                dinv=None if dinv is None else dinv[lo:hi]))
        plan = ShardPlan(family=family, routing=routing, parts=parts,
                         n_nodes=n, n_edges=int(rows.size))
        plan.spmd_plan()            # record the uniform dims + halo schedule
        return plan


def validate_reshard(old_routing: RoutingTable, new_routing: RoutingTable,
                     n_nodes: int) -> None:
    """Pre-swap consistency gate for a live reshard P -> P': both routing
    tables must be well-formed contiguous covers of the SAME node id space
    ``[0, n_nodes)`` — a reshard redistributes ownership, it never changes
    the graph. Raises ValueError naming the violated invariant (the reshard
    aborts before any traffic moves)."""
    for name, rt in (("old", old_routing), ("new", new_routing)):
        b = np.asarray(rt.bounds, np.int64)
        if b.size < 2:
            raise ValueError(f"reshard: {name} routing has {b.size} bounds "
                             f"(need >= 2)")
        if int(b[0]) != 0 or int(b[-1]) != n_nodes:
            raise ValueError(
                f"reshard: {name} routing covers [{int(b[0])}, "
                f"{int(b[-1])}) but the graph has {n_nodes} nodes")
        if np.any(np.diff(b) < 0):
            raise ValueError(f"reshard: {name} routing bounds are not "
                             f"monotone: {b.tolist()}")
