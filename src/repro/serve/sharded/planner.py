"""Shard planning: cut a graph into P serving shards.

``ShardPlanner`` reuses :mod:`repro.graphs.partition`'s edge-balanced
tile-row-aligned boundaries and builds, per shard:

  * an **intra-shard FRDC adjacency** per adjacency kind the family's packed
    forward needs (rows AND columns local to the shard);
  * a **halo FRDC adjacency** per kind: the boundary edges (local row, remote
    column), columns re-indexed into the shard's sorted ``halo_nodes`` list —
    the bit-packed structure the layer-wise halo exchange aggregates over;
  * the shard's rows of the graph CSR (global column ids) for routed k-hop
    extraction;
  * the shard's slice of the FULL-graph factorization vector (GCN D^-1/2 /
    SAGE D^-1), so subgraph adjacencies assembled from any mix of shards
    normalize exactly like the full graph.

Every edge of the input lands in exactly one shard's intra OR halo
adjacency (the conservation property tested in
``tests/test_partition_properties.py``); self-loops added by the GCN
normalization are intra by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import frdc
from repro.graphs import partition, sampling
from repro.graphs.datasets import GraphData
from repro.serve import session_core
from .routing import RoutingTable, ShardedCSR


@dataclasses.dataclass
class ShardPart:
    """Everything one shard owns."""
    index: int
    row_start: int
    row_end: int
    halo_nodes: np.ndarray                    # sorted global ids, may be empty
    intra: Dict[str, frdc.FRDCMatrix]         # kind -> (n_local, n_local)
    halo: Dict[str, frdc.FRDCMatrix]          # kind -> (n_local, max(n_halo,1))
    indptr: np.ndarray                        # local CSR rows -> global cols
    indices: np.ndarray
    dinv: Optional[np.ndarray]                # factorization rows [lo, hi)

    @property
    def n_local(self) -> int:
        return self.row_end - self.row_start

    @property
    def n_halo(self) -> int:
        return int(self.halo_nodes.size)


@dataclasses.dataclass
class ShardPlan:
    family: str
    routing: RoutingTable
    parts: List[ShardPart]
    n_nodes: int
    n_edges: int

    @property
    def n_shards(self) -> int:
        return len(self.parts)

    def sharded_csr(self) -> ShardedCSR:
        return ShardedCSR.from_arrays(
            self.routing, [p.indptr for p in self.parts],
            [p.indices for p in self.parts])

    def stats(self) -> dict:
        intra = np.array([sum(m.nnz for m in p.intra.values())
                          for p in self.parts], np.float64)
        cut = np.array([sum(m.nnz for m in p.halo.values())
                        for p in self.parts], np.float64)
        kinds = len(self.parts[0].intra)
        total = max(float(intra.sum() + cut.sum()), 1.0)
        return dict(
            n_shards=self.n_shards, n_nodes=self.n_nodes,
            n_edges=self.n_edges,
            edge_cut_fraction=float(cut.sum()) / total,
            halo_nodes=[p.n_halo for p in self.parts],
            local_nodes=[p.n_local for p in self.parts],
            imbalance=float((intra + cut).max()
                            / max((intra + cut).mean(), 1.0)),
            adjacency_kinds=kinds,
        )


class ShardPlanner:
    """Plan P serving shards for one (graph, model family) pair."""

    def __init__(self, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards

    def plan(self, data: GraphData, family: str) -> ShardPlan:
        if family not in session_core.FAMILIES:
            raise ValueError(f"unknown family {family!r}")
        rows = np.asarray(data.edges[0], np.int64)
        cols = np.asarray(data.edges[1], np.int64)
        n = data.n_nodes
        bounds = partition.shard_node_bounds(rows, n, self.n_shards)
        routing = RoutingTable(bounds=bounds)
        deg = np.bincount(rows, minlength=n)
        dinv = session_core.dinv_for_family(family, deg)

        parts = []
        for s in range(self.n_shards):
            lo, hi = routing.shard_range(s)
            n_local = max(hi - lo, 1)
            rmask = (rows >= lo) & (rows < hi)
            rs, cs = rows[rmask] - lo, cols[rmask]
            # local CSR over GLOBAL columns (same stable sort as the
            # single-host CSR -> identical per-row neighbor order)
            csr = sampling.to_csr(np.stack([rs, cs]), n_local)
            cmask = (cs >= lo) & (cs < hi)
            ir, ic = rs[cmask], cs[cmask] - lo
            hr, hc_global = rs[~cmask], cs[~cmask]
            halo_nodes = np.unique(hc_global)
            hc = np.searchsorted(halo_nodes, hc_global)
            n_halo = max(halo_nodes.size, 1)
            # degenerate dims (empty shard / no halo) keep unit scales so the
            # FRDC scale vectors always match the padded matrix dims
            rsc = None if dinv is None else (
                dinv[lo:hi] if hi > lo else np.ones(n_local))
            hcsc = (dinv[halo_nodes] if dinv is not None and halo_nodes.size
                    else np.ones(n_halo))

            intra: Dict[str, frdc.FRDCMatrix] = {}
            halo_m: Dict[str, frdc.FRDCMatrix] = {}
            if family == "gcn":
                loops = np.arange(hi - lo, dtype=np.int64)
                intra["adj"] = frdc.from_coo(
                    np.concatenate([ir, loops]), np.concatenate([ic, loops]),
                    n_local, n_local, row_scale=rsc, col_scale=rsc)
                halo_m["adj"] = frdc.from_coo(
                    hr, hc, n_local, n_halo, row_scale=rsc, col_scale=hcsc)
                intra["bin"] = frdc.from_coo(ir, ic, n_local, n_local)
                halo_m["bin"] = frdc.from_coo(hr, hc, n_local, n_halo)
            elif family == "sage":
                intra["mean"] = frdc.from_coo(ir, ic, n_local, n_local,
                                              row_scale=rsc)
                halo_m["mean"] = frdc.from_coo(hr, hc, n_local, n_halo,
                                               row_scale=rsc)
            else:
                intra["sum"] = frdc.from_coo(ir, ic, n_local, n_local)
                halo_m["sum"] = frdc.from_coo(hr, hc, n_local, n_halo)

            parts.append(ShardPart(
                index=s, row_start=lo, row_end=hi, halo_nodes=halo_nodes,
                intra=intra, halo=halo_m, indptr=csr.indptr,
                indices=csr.indices,
                dinv=None if dinv is None else dinv[lo:hi]))
        return ShardPlan(family=family, routing=routing, parts=parts,
                         n_nodes=n, n_edges=int(rows.size))
