"""Layer executors of the distributed full pass.

Two implementations of :class:`repro.serve.session_core.LayerExecutor`, both
running the SAME family layer program (:func:`~repro.serve.session_core.
build_layer_program`) over the SAME uniformly padded per-shard operands
(:class:`~.planner.SpmdPlan`), through the SAME traced step body
(:func:`layer_compute`):

  * :class:`HostLayerExecutor` — host-orchestrated, the reference that runs
    on any device count: each layer executes as P sequential jitted
    per-shard stage programs with the halo exchange as a host-side step
    between them (mesh ring collective when a mesh is attached, loopback
    otherwise). Compute serializes against communication — the
    orchestration overhead the SPMD path removes.

  * :class:`SpmdLayerExecutor` — each layer is ONE ``shard_map`` program
    over the shard-stacked operands: BN -> dense transform -> fused
    ``ppermute`` ring exchange -> intra+halo aggregation -> combine, all
    inside a single jitted SPMD computation, so a real multi-host
    deployment overlaps compute with the exchange.

Sharing ``layer_compute`` (and the padded shapes) between the two is what
makes them BIT-IDENTICAL: XLA applies fusion-dependent fp rewrites — FMA
contraction of ``a + b*c``, factoring of ``a*r + b*r`` — so the same math
split into different jit programs rounds differently. Both executors
therefore jit the exact same step body, differing only in where the halo
operand comes from (a parameter vs the in-program ring exchange), and the
shared aggregation applies the row scale once after the intra+halo add
(:func:`repro.kernels.ops.serve_fp_pair`) so the factored form is already
explicit. Per-row ops are exact under row padding and padded FRDC
groups/rows/columns carry no bits, so padding does not perturb real rows.

Distributed BN calibration (``calibrate=True``): each BN site's (mu, sd)
comes from the pass itself — masked per-shard moment partials combined with
``psum`` across the mesh (SPMD) or host-side summation (host executor, same
formula) — so calibration no longer needs the single-host full-graph
anchor.

Halo byte accounting is recorded OUTSIDE any trace — the SPMD executor adds
the static schedule's ``MeshHaloPlan.payload_bytes`` per jitted step
invocation, so steady-state passes that never retrace still account
correctly (and trace-time side effects never double-count).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.core import frdc
from repro.kernels import ops as kernel_ops
from repro.serve import session_core
from repro.serve.session_core import LayerExecutor, LayerStep, SessionPlan
from . import halo as halo_mod
from .planner import ShardPart, SpmdPlan
from .routing import RoutingTable


def layer_compute(step: LayerStep, trinary_mode: str, use_pallas: bool,
                  st, bn_stats, rem, intra, halo, fused: bool = False):
    """The traced core of one layer step, shared verbatim by both executors
    (identical jaxpr => identical XLA rewrites => bit-identical results).

    ``st``: this shard's padded carried state; ``bn_stats``: (mu, sd) or
    None; ``rem``: the (n_halo_pad, F) exchanged halo operand (None for
    exchange-free steps); ``intra``/``halo``: the shard's uniformly padded
    FRDC matrices of ``step.kind``.

    ``fused=True`` (``SessionPlan.fused``, only where the kernels are
    active) emits the whole step — BN, transform, intra+halo aggregation,
    combine — as ONE Pallas launch through
    :func:`repro.kernels.fused_layer.fused_call` instead of separate
    dispatches; the exchange stays outside (``rem`` is already this
    shard's gathered halo operand)."""
    if fused and kernel_ops.kernels_active(use_pallas):
        return _fused_layer_compute(step, trinary_mode, st, bn_stats, rem,
                                    intra, halo)
    z = session_core.apply_bn(st, *bn_stats) if bn_stats is not None else st
    operand, aux = step.pre(z)
    if step.kind is None:
        y = operand
    elif step.packed:
        y = kernel_ops.serve_counts(intra, operand, trinary_mode,
                                    use_pallas) \
            + kernel_ops.serve_counts(halo, rem, trinary_mode, use_pallas)
    else:
        y = kernel_ops.serve_fp_pair(intra, halo, operand, rem, use_pallas)
    return step.post(aux, y)


def _fused_layer_compute(step: LayerStep, trinary_mode: str,
                         st, bn_stats, rem, intra, halo):
    """One-launch form of :func:`layer_compute`: the step body traced inside
    a single ``fused_call`` kernel, aggregating through the value-level
    walks (kernel-order, so bitwise identical to the unfused kernels under
    the same jit). FRDC operands cross the kernel boundary as their array
    fields — the static row/col counts must stay python ints."""
    from repro.kernels import fused_layer

    dims = None
    ia = ha = None
    if step.kind is not None:
        ia = session_core.frdc_arrays(intra)
        ha = session_core.frdc_arrays(halo)
        dims = (intra.n_rows, intra.n_cols, halo.n_rows, halo.n_cols)

    def body(st_, bn_, rem_, ia_, ha_):
        z = session_core.apply_bn(st_, *bn_) if bn_ is not None else st_
        operand, aux = step.pre(z)
        if step.kind is None:
            y = operand
        else:
            im = session_core.frdc_rebuild(ia_, dims[0], dims[1])
            hm = session_core.frdc_rebuild(ha_, dims[2], dims[3])
            if step.packed:
                y = fused_layer.agg_counts(im, operand, trinary_mode) \
                    + fused_layer.agg_counts(hm, rem_, trinary_mode)
            else:
                y = fused_layer.agg_fp_pair(im, hm, operand, rem_)
        return step.post(aux, y)

    return fused_layer.fused_call(body, st, bn_stats, rem, ia, ha,
                                  interpret=kernel_ops.interpret_mode())


class _PaddedExecutor(LayerExecutor):
    """Shared state of both executors: the uniformly padded per-shard FRDC
    operands and the trace counter."""

    def __init__(self, parts: List[ShardPart], spmd: SpmdPlan,
                 plan: SessionPlan, stats: halo_mod.HaloStats,
                 use_pallas: bool = False):
        self.parts = parts
        self.spmd = spmd
        self.plan = plan
        self.stats = stats
        self.use_pallas = use_pallas
        self._n_traces = 0
        # observability hook: called as on_trace(label, shape_dict) from
        # inside a jitted program body — a python side effect that runs once
        # per NEW trace, exactly like the _n_traces counter above it
        self.on_trace = None
        self._fns: Dict[tuple, callable] = {}
        npd, nhp = spmd.n_local_pad, spmd.n_halo_pad
        # per-kind uniformly padded per-shard matrices + fixed field order
        self._fields: Dict[str, Tuple[tuple, tuple]] = {}
        self._intra: Dict[str, List[frdc.FRDCMatrix]] = {}
        self._halo: Dict[str, List[frdc.FRDCMatrix]] = {}
        for kind in parts[0].intra:
            self._intra[kind] = frdc.pad_frdc_uniform(
                [pt.intra[kind] for pt in parts], npd, npd,
                spmd.intra_groups[kind])
            self._halo[kind] = frdc.pad_frdc_uniform(
                [pt.halo[kind] for pt in parts], npd, nhp,
                spmd.halo_groups[kind])
            arrs_i = session_core.frdc_arrays(self._intra[kind][0])
            arrs_h = session_core.frdc_arrays(self._halo[kind][0])
            self._fields[kind] = (tuple(sorted(arrs_i)),
                                  tuple(sorted(arrs_h)))

    @property
    def compile_count(self) -> int:
        """Jit traces of the layer stage programs — exactly one per
        (program step, mode, shapes) in steady state."""
        return self._n_traces

    def _pad_state(self, xs: List[np.ndarray]) -> List[np.ndarray]:
        npd = self.spmd.n_local_pad
        out = []
        for b in xs:
            b = np.asarray(b)
            buf = np.zeros((npd,) + b.shape[1:], b.dtype)
            buf[:b.shape[0]] = b
            out.append(buf)
        return out

    def _mat_args(self, kind: str, s: int) -> List[jax.Array]:
        ifields, hfields = self._fields[kind]
        ia = session_core.frdc_arrays(self._intra[kind][s])
        ha = session_core.frdc_arrays(self._halo[kind][s])
        return [ia[f] for f in ifields] + [ha[f] for f in hfields]


class HostLayerExecutor(_PaddedExecutor):
    """Host-orchestrated distributed pass (sequential per-shard stages)."""

    name = "host"

    def __init__(self, parts: List[ShardPart], spmd: SpmdPlan,
                 plan: SessionPlan, stats: halo_mod.HaloStats,
                 routing: RoutingTable, mesh=None,
                 use_pallas: bool = False):
        super().__init__(parts, spmd, plan, stats, use_pallas=use_pallas)
        self.routing = routing
        self.mesh = mesh
        # cached per-shard mat args (device arrays, built once)
        self._margs = {kind: [self._mat_args(kind, s)
                              for s in range(len(parts))]
                       for kind in parts[0].intra}

    # ----------------------------------------------------------- exchange --
    def _exchange(self, blocks: List[np.ndarray], tag: str
                  ) -> List[np.ndarray]:
        """Fetch every shard's halo rows of a per-shard row-block operand —
        device collectives over the mesh when one is attached, host loopback
        otherwise. Returns per-shard (n_halo_pad, F) operands (zero-padded
        so padded halo columns aggregate exact zeros)."""
        blocks = [np.asarray(b) for b in blocks]
        if self.mesh is not None:
            # the SpmdPlan's schedule is the same send/recv table (only the
            # receive buffer is wider — mesh_exchange slices it back down),
            # so no second MeshHaloPlan is ever built.
            gathered = halo_mod.mesh_exchange(
                self.mesh, blocks, self.spmd.mesh_plan,
                stats=self.stats, tag=tag)
        else:
            gathered = [
                halo_mod.gather_rows(blocks, self.routing, p.halo_nodes,
                                     home=p.index, stats=self.stats,
                                     tag=tag)
                for p in self.parts]
        nhp = self.spmd.n_halo_pad
        out = []
        for p, g in zip(self.parts, gathered):
            buf = np.zeros((nhp,) + blocks[0].shape[1:], blocks[0].dtype)
            buf[:p.n_halo] = g
            out.append(buf)
        return out

    # ------------------------------------------------------ stage programs --
    def _stage_fn(self, program: Tuple[LayerStep, ...], i: int,
                  with_bn: bool):
        """The jitted per-shard stage of step ``i`` — the SAME
        :func:`layer_compute` body the SPMD program traces, with the halo
        operand as a parameter instead of an in-program collective. One
        executable serves every shard (uniform padded shapes; the FRDC
        arrays are traced arguments)."""
        key = ("stage", i, with_bn)
        if key in self._fns:
            return self._fns[key]
        step = program[i]
        trinary, up = self.plan.trinary_mode, self.use_pallas
        fused = self.plan.fused
        npd, nhp = self.spmd.n_local_pad, self.spmd.n_halo_pad
        ifields, hfields = self._fields[step.kind] if step.kind else ((), ())

        def fn(st, *rest):
            self._n_traces += 1
            if self.on_trace is not None:
                self.on_trace(f"{self.name}/stage{i}",
                              dict(n_local_pad=npd, n_halo_pad=nhp,
                                   with_bn=with_bn))
            it = iter(rest)
            bn_stats = (next(it), next(it)) if with_bn else None
            rem = intra = halo = None
            if step.kind is not None:
                rem = next(it)
                intra = session_core.frdc_rebuild(
                    {f: next(it) for f in ifields}, npd, npd)
                halo = session_core.frdc_rebuild(
                    {f: next(it) for f in hfields}, npd, nhp)
            return layer_compute(step, trinary, up, st, bn_stats, rem,
                                 intra, halo, fused=fused)

        self._fns[key] = jax.jit(fn)
        return self._fns[key]

    def _operand_fn(self, program: Tuple[LayerStep, ...], i: int,
                    with_bn: bool):
        """Jitted BN+pre producing the exchange operand (the pre chain is
        fusion-stable, so recomputing it inside the stage program rounds
        identically)."""
        key = ("operand", i, with_bn)
        if key in self._fns:
            return self._fns[key]
        step = program[i]

        def fn(st, *bn_stats):
            self._n_traces += 1
            if self.on_trace is not None:
                self.on_trace(f"{self.name}/operand{i}",
                              dict(with_bn=with_bn))
            z = session_core.apply_bn(st, *bn_stats) if with_bn else st
            return step.pre(z)[0]

        self._fns[key] = jax.jit(fn)
        return self._fns[key]

    # ---------------------------------------------------------------- pass --
    def run_pass(self, program: Tuple[LayerStep, ...], xs: List[np.ndarray],
                 bn: Optional[tuple], calibrate: bool = False):
        state = [jnp.asarray(b) for b in self._pad_state(xs)]
        collected = []
        for i, step in enumerate(program):
            with_bn = step.bn_site is not None
            if with_bn:
                if calibrate:
                    site = session_core.distributed_moments(
                        [s[:p.n_local]
                         for s, p in zip(state, self.parts)])
                    collected.append(site)
                else:
                    site = bn[step.bn_site]
                bn_args = [jnp.asarray(site[0]), jnp.asarray(site[1])]
            else:
                bn_args = []
            stage = self._stage_fn(program, i, with_bn)
            if step.kind is not None:
                pre = self._operand_fn(program, i, with_bn)
                operands = [np.asarray(pre(s, *bn_args))[:p.n_local]
                            for s, p in zip(state, self.parts)]
                halo_in = self._exchange(operands, step.tag)
                state = [stage(s, *bn_args, jnp.asarray(rem),
                               *self._margs[step.kind][p.index])
                         for s, rem, p in zip(state, halo_in, self.parts)]
            else:
                state = [stage(s, *bn_args) for s in state]
        blocks = [np.asarray(s)[:p.n_local]
                  for s, p in zip(state, self.parts)]
        return blocks, (tuple(collected) if calibrate else None)


class SpmdLayerExecutor(_PaddedExecutor):
    """One ``shard_map`` program per layer over the stacked padded shards."""

    name = "spmd"

    def __init__(self, parts: List[ShardPart], spmd: SpmdPlan,
                 plan: SessionPlan, stats: halo_mod.HaloStats, mesh,
                 use_pallas: bool = False):
        p = spmd.n_shards
        if mesh is None or "data" not in mesh.axis_names \
                or mesh.shape["data"] != p or mesh.devices.size != p:
            raise ValueError(
                f"SPMD executor needs a mesh with a 'data' axis of exactly "
                f"{p} devices (make_shard_mesh({p})); got {mesh}")
        super().__init__(parts, spmd, plan, stats, use_pallas=use_pallas)
        self.mesh = mesh
        # shard-stacked operand arrays: dict-field order of _fields[kind]
        self._stacked: Dict[str, List[jax.Array]] = {}
        for kind in parts[0].intra:
            istk = frdc.stack_frdc(self._intra[kind])
            hstk = frdc.stack_frdc(self._halo[kind])
            ifields, hfields = self._fields[kind]
            self._stacked[kind] = [istk[f] for f in ifields] \
                + [hstk[f] for f in hfields]
        # the SPMD path only ever reads the stacked copies — drop the
        # per-shard padded matrices so the operands aren't held twice.
        self._intra.clear()
        self._halo.clear()
        mp = spmd.mesh_plan
        self._sched = [jnp.asarray(a) for pair
                       in zip(mp.send_idx, mp.recv_pos) for a in pair]
        self._perms = halo_mod.ring_perms(p)
        self._n_local = jnp.asarray(
            np.array([[pt.n_local] for pt in parts], np.int32))

    # ------------------------------------------------------- step programs --
    def _step_fn(self, program: Tuple[LayerStep, ...], i: int,
                 calibrate: bool):
        key = (i, bool(calibrate))
        if key in self._fns:
            return self._fns[key]
        from jax.sharding import PartitionSpec as PS
        step = program[i]
        p = self.spmd.n_shards
        npd, nhp = self.spmd.n_local_pad, self.spmd.n_halo_pad
        kind, nshift = step.kind, p - 1
        trinary, up = self.plan.trinary_mode, self.use_pallas
        fused = self.plan.fused
        perms = self._perms
        ifields, hfields = self._fields[kind] if kind else ((), ())
        frozen_bn = step.bn_site is not None and not calibrate
        calib_bn = step.bn_site is not None and calibrate

        def body(*args):
            self._n_traces += 1            # python side effect: trace count
            if self.on_trace is not None:
                self.on_trace(f"{self.name}/step{i}",
                              dict(n_local_pad=npd, n_halo_pad=nhp,
                                   calibrate=calibrate))
            it = iter(args)
            st = next(it)[0]               # carried state (n_local_pad, F)
            nloc = next(it)[0][0]          # this shard's real row count
            bn_stats = None
            if frozen_bn:
                bn_stats = (next(it), next(it))
            elif calib_bn:
                # distributed BN moments: padded rows carry garbage from
                # earlier per-row stages, so they are masked out of the
                # partial sums; psum combines the per-shard partials.
                rows = jnp.arange(st.shape[0], dtype=jnp.int32)
                mask = (rows < nloc)[:, None].astype(st.dtype)
                cnt = jax.lax.psum(nloc.astype(jnp.float32), "data")
                s1 = jax.lax.psum(
                    jnp.sum(st * mask, axis=0, keepdims=True), "data")
                s2 = jax.lax.psum(
                    jnp.sum(st * st * mask, axis=0, keepdims=True), "data")
                bn_stats = session_core.moments_from_sums(s1, s2, cnt)
            rem = intra = halo = None
            if kind is not None:
                intra = session_core.frdc_rebuild(
                    {f: next(it)[0] for f in ifields}, npd, npd)
                halo = session_core.frdc_rebuild(
                    {f: next(it)[0] for f in hfields}, npd, nhp)
                sched = [next(it)[0] for _ in range(2 * nshift)]
                # the exchange operand is the same BN+pre chain
                # layer_compute recomputes below — fusion-stable, so the
                # two computations round identically.
                z = (session_core.apply_bn(st, *bn_stats)
                     if bn_stats is not None else st)
                operand, _ = step.pre(z)
                rem = halo_mod.ring_scatter(operand, sched[0::2],
                                            sched[1::2], perms, nhp)
            new = layer_compute(step, trinary, up, st, bn_stats, rem,
                                intra, halo, fused=fused)
            if calib_bn:
                return new[None], bn_stats[0][None], bn_stats[1][None]
            return new[None]

        in_specs = [PS("data"), PS("data")]
        if frozen_bn:
            in_specs += [PS(), PS()]
        if kind is not None:
            in_specs += [PS("data")] * (len(ifields) + len(hfields)
                                        + 2 * nshift)
        out_specs = (PS("data"),) * 3 if calib_bn else PS("data")
        # check_vma=False: pallas_call (the use_pallas backends) has no
        # replication rule; every output is explicitly sharded anyway.
        fn = jax.jit(shard_map(body, self.mesh, in_specs=tuple(in_specs),
                               out_specs=out_specs, check_vma=False))
        self._fns[key] = fn
        return fn

    # ---------------------------------------------------------------- pass --
    def run_pass(self, program: Tuple[LayerStep, ...], xs: List[np.ndarray],
                 bn: Optional[tuple], calibrate: bool = False):
        state = jnp.asarray(np.stack(self._pad_state(xs)))
        collected = []
        for i, step in enumerate(program):
            fn = self._step_fn(program, i, calibrate)
            args = [state, self._n_local]
            if step.bn_site is not None and not calibrate:
                mu, sd = bn[step.bn_site]
                args += [jnp.asarray(mu), jnp.asarray(sd)]
            if step.kind is not None:
                args += self._stacked[step.kind] + self._sched
            out = fn(*args)
            if step.bn_site is not None and calibrate:
                state, mu_stk, sd_stk = out
                collected.append((mu_stk[0], sd_stk[0]))
            else:
                state = out
            if step.kind is not None:
                # byte accounting from the STATIC schedule — correct even
                # when the jitted program never retraces (satellite fix).
                self.stats.add(step.tag, self.spmd.mesh_plan.payload_bytes(
                    step.payload_cols, step.payload_itemsize))
        full = np.asarray(state)
        blocks = [full[s, :pt.n_local] for s, pt in enumerate(self.parts)]
        return blocks, (tuple(collected) if calibrate else None)
