"""Sharded serving subsystem: partitioned graph sessions with cross-shard
k-hop routing and halo exchange.

``planner``  — ShardPlanner: per-shard intra FRDC + bit-packed halo
               adjacency + routing table (reuses graphs/partition.py).
``routing``  — RoutingTable + routed k-hop extraction (bit-identical to the
               single-host ``sampling.khop_subgraph``).
``halo``     — shard-boundary row exchange: host loopback + mesh collectives
               (``shard_map``/``ppermute``), packed payloads where the math
               allows, byte accounting throughout.
``executor`` — the distributed-pass LayerExecutor implementations: the
               host-orchestrated reference and the SPMD path (each layer as
               ONE shard_map program over uniformly padded stacked shards,
               halo exchange fused in, psum BN calibration).
``session``  — ShardedGraphSession: per-shard bucketed serve cores +
               distributed layer-wise full pass + checkpointer artifacts.
``engine``   — ShardedServeEngine: the micro-batching scheduler routed over
               partitioned sessions.
"""
from .engine import ShardedServeEngine
from .executor import HostLayerExecutor, SpmdLayerExecutor
from .halo import (HaloStats, MeshHaloPlan, build_mesh_plan, gather_rows,
                   mesh_exchange, ring_scatter)
from .planner import ShardPart, ShardPlan, ShardPlanner, SpmdPlan
from .routing import RoutingTable, ShardedCSR
from .session import ShardedGraphSession

__all__ = [
    "ShardedServeEngine", "ShardedGraphSession", "ShardPlanner", "ShardPlan",
    "ShardPart", "SpmdPlan", "RoutingTable", "ShardedCSR", "HaloStats",
    "MeshHaloPlan", "gather_rows", "mesh_exchange", "build_mesh_plan",
    "ring_scatter", "HostLayerExecutor", "SpmdLayerExecutor",
]
