"""Sharded serving subsystem: partitioned graph sessions with cross-shard
k-hop routing and halo exchange.

``planner``  — ShardPlanner: per-shard intra FRDC + bit-packed halo
               adjacency + routing table (reuses graphs/partition.py).
``routing``  — RoutingTable + routed k-hop extraction (bit-identical to the
               single-host ``sampling.khop_subgraph``).
``halo``     — shard-boundary row exchange: host loopback + mesh collectives
               (``shard_map``/``ppermute``), packed payloads where the math
               allows, byte accounting throughout.
``session``  — ShardedGraphSession: per-shard bucketed serve cores +
               distributed layer-wise full pass + checkpointer artifacts.
``engine``   — ShardedServeEngine: the micro-batching scheduler routed over
               partitioned sessions.
"""
from .engine import ShardedServeEngine
from .halo import HaloStats, build_mesh_plan, gather_rows, mesh_exchange
from .planner import ShardPart, ShardPlan, ShardPlanner
from .routing import RoutingTable, ShardedCSR
from .session import ShardedGraphSession

__all__ = [
    "ShardedServeEngine", "ShardedGraphSession", "ShardPlanner", "ShardPlan",
    "ShardPart", "RoutingTable", "ShardedCSR", "HaloStats", "gather_rows",
    "mesh_exchange", "build_mesh_plan",
]
