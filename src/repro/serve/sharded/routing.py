"""Routing table + cross-shard k-hop extraction.

The partition is contiguous (tile-row-aligned node ranges, see
:func:`repro.graphs.partition.shard_node_bounds`), so the routing table is
the ``(P+1,)`` bounds array: global node -> owning shard by bisection,
global -> local id by subtracting the owner's base. It is still serialized
as an explicit artifact (``routing.json``) because consumers of a saved
sharded session — including future non-contiguous planners — must not assume
the contiguity, only the table's API.

Cross-shard k-hop: each shard only knows its OWN adjacency rows (local CSR
over global column ids). Frontier expansion routes every frontier node to
its owning shard, gathers the per-shard neighbor lists with the exact same
vectorized gather the single-host path uses, and merges the returned
frontiers — nodes discovered past a shard boundary are routed onward on the
next hop. The resulting subgraph (node set, induced edges, seed positions)
is identical to the single-host :func:`repro.graphs.sampling.khop_subgraph`,
which is what makes sharded serving bit-exact.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.graphs import sampling


@dataclasses.dataclass
class RoutingTable:
    """Global node id -> (owning shard, local id)."""
    bounds: np.ndarray                 # (P+1,) int64, bounds[0]=0, [-1]=n

    @property
    def n_shards(self) -> int:
        return self.bounds.size - 1

    @property
    def n_nodes(self) -> int:
        return int(self.bounds[-1])

    def shard_range(self, s: int) -> Tuple[int, int]:
        return int(self.bounds[s]), int(self.bounds[s + 1])

    def owner(self, nodes: np.ndarray) -> np.ndarray:
        nodes = np.asarray(nodes, np.int64)
        return np.searchsorted(self.bounds, nodes, side="right") - 1

    def local(self, nodes: np.ndarray,
              owner: Optional[np.ndarray] = None) -> np.ndarray:
        nodes = np.asarray(nodes, np.int64)
        if owner is None:
            owner = self.owner(nodes)
        return nodes - self.bounds[owner]

    def to_json(self) -> dict:
        return dict(bounds=[int(b) for b in self.bounds])

    @classmethod
    def from_json(cls, d: dict) -> "RoutingTable":
        return cls(bounds=np.asarray(d["bounds"], np.int64))


class ShardedCSR:
    """The graph's adjacency partitioned by row ownership: shard ``s`` holds
    a local-row CSR (rows ``[bounds[s], bounds[s+1])`` re-based to 0) whose
    column ids stay GLOBAL. Built from the same edge list with the same
    stable sort as the single-host CSR, so per-row neighbor order matches."""

    def __init__(self, routing: RoutingTable,
                 shards: List[sampling.CSRGraph]):
        self.routing = routing
        self.shards = shards
        self.requests_by_shard = np.zeros(routing.n_shards, np.int64)

    @property
    def n_nodes(self) -> int:
        return self.routing.n_nodes

    @classmethod
    def from_edges(cls, edges: np.ndarray, routing: RoutingTable
                   ) -> "ShardedCSR":
        rows, cols = np.asarray(edges[0], np.int64), \
            np.asarray(edges[1], np.int64)
        shards = []
        for s in range(routing.n_shards):
            lo, hi = routing.shard_range(s)
            m = (rows >= lo) & (rows < hi)
            shards.append(sampling.to_csr(
                np.stack([rows[m] - lo, cols[m]]), max(hi - lo, 1)))
        return cls(routing, shards)

    @classmethod
    def from_arrays(cls, routing: RoutingTable,
                    indptrs: List[np.ndarray],
                    indices: List[np.ndarray]) -> "ShardedCSR":
        shards = [sampling.CSRGraph(indptr=np.asarray(p, np.int64),
                                    indices=np.asarray(i, np.int64),
                                    n_nodes=p.shape[0] - 1)
                  for p, i in zip(indptrs, indices)]
        return cls(routing, shards)

    def neighbors_concat(self, nodes: np.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """Concatenated neighbor lists + per-node counts for SORTED global
        ``nodes``. The routed equivalent of the single-host gather: each
        owner shard answers for its slice, slices concatenate back in global
        node order (ownership ranges are contiguous and ascending)."""
        nodes = np.asarray(nodes, np.int64)
        starts = np.searchsorted(nodes, self.routing.bounds)
        cols_parts, count_parts = [], []
        for s in range(self.routing.n_shards):
            sel = nodes[starts[s]:starts[s + 1]]
            if sel.size == 0:
                continue
            self.requests_by_shard[s] += sel.size
            lo, _ = self.routing.shard_range(s)
            c, k = sampling.gather_neighbors(self.shards[s], sel - lo)
            cols_parts.append(c)
            count_parts.append(k)
        if not cols_parts:
            return np.zeros(0, np.int64), np.zeros(nodes.size, np.int64)
        return np.concatenate(cols_parts), np.concatenate(count_parts)


def khop_nodes(scsr: ShardedCSR, seeds: np.ndarray, k: int) -> np.ndarray:
    """Sorted node ids of the full k-hop closure of ``seeds``, discovered by
    routed frontier expansion (mirror of ``sampling.khop_nodes``)."""
    seen = np.zeros(scsr.n_nodes, bool)
    frontier = np.unique(np.asarray(seeds, np.int64))
    seen[frontier] = True
    for _ in range(k):
        if frontier.size == 0:
            break
        nbrs, _ = scsr.neighbors_concat(frontier)
        if nbrs.size == 0:
            break
        nbrs = np.unique(nbrs)
        frontier = nbrs[~seen[nbrs]]
        seen[frontier] = True
    return np.nonzero(seen)[0]


def induced_edges(scsr: ShardedCSR, sub_nodes: np.ndarray) -> np.ndarray:
    """(2, E_sub) edge list among ``sub_nodes`` reindexed into the subgraph
    — per-shard adjacency rows routed back and reassembled in global node
    order, identical to the single-host ``sampling.induced_edges``."""
    remap = -np.ones(scsr.n_nodes, np.int64)
    remap[sub_nodes] = np.arange(sub_nodes.size)
    cols, counts = scsr.neighbors_concat(sub_nodes)
    if cols.size == 0:
        return np.zeros((2, 0), np.int64)
    rows = np.repeat(sub_nodes, counts)
    keep = remap[cols] >= 0
    return np.stack([remap[rows[keep]], remap[cols[keep]]])


def khop_subgraph(scsr: ShardedCSR, seeds: np.ndarray, k: int
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Routed k-hop subgraph extraction: (sorted sub_nodes, reindexed edges,
    seed positions) — bit-identical to ``sampling.khop_subgraph``."""
    seeds = np.asarray(seeds, np.int64)
    sub_nodes = khop_nodes(scsr, seeds, k)
    sub_edges = induced_edges(scsr, sub_nodes)
    seed_pos = np.searchsorted(sub_nodes, seeds)
    return sub_nodes, sub_edges, seed_pos
