"""ShardedServeEngine: micro-batched node queries over partitioned sessions.

Same queueing/metrics/warmup discipline as :class:`~repro.serve.gnn_engine.
GNNServeEngine` (it IS one — the scheduler, including the two-stage
extract/compute pipeline, is inherited); what changes:

  * **session resolution** — a queue key resolves to the store's
    :class:`~.session.ShardedGraphSession` for this engine's shard count; a
    served micro-batch is routed inside the session, each query's k-hop
    neighborhood answered by its seed's owning shard with remote rows
    fetched over the halo transport;
  * **halo-aware batch formation** — queues are keyed by owning shard
    (single-owner micro-batches, the bit-exactness invariant), and within a
    queue the strict FIFO pop is replaced by signature grouping: each seed's
    cheap halo signature (the FRDC tile ids of its remote 1-hop neighbors,
    :meth:`~.session.ShardedGraphSession.seed_halo_tiles`) lets formation
    greedily co-batch seeds whose k-hop closures request the same halo
    tiles, so the ``serve/x`` feature gather — the single largest halo byte
    tag — is issued once per shared tile instead of once per seed. A
    **staleness bound** caps the reordering: a request in the formation
    window whose wait exceeds ``staleness_s`` is taken in FIFO order by the
    next batch formed from its queue, never skipped for better overlap.

``mode`` defaults to ``"subgraph"``: the routed path is the scale path (a
sharded deployment serves graphs no single device could hold, so the
full-graph cache is per-shard and used only when asked for).

``snapshot()`` additionally reports halo traffic (bytes by layer/tag),
per-shard compile counters, and the formation counters
(``halo_tiles_shared`` / ``halo_bytes_saved`` — the signature-level halo
volume co-batching deduplicated vs a once-per-seed gather; the benchmark
additionally MEASURES the ``serve/x`` delta vs a strict-FIFO engine).
"""
from __future__ import annotations

import itertools
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import frdc

from ..admission import DEFAULT_TENANT
from ..gnn_engine import GNNServeEngine, NodeQuery
from ..gnn_session import GraphStore


class ShardedServeEngine(GNNServeEngine):
    """Micro-batching scheduler over a store's SHARDED sessions."""

    def __init__(self, store: GraphStore, n_shards: int,
                 max_batch=None, mode: str = "subgraph",
                 full_cache_max_nodes: int = 200_000,
                 keep_finished: int = 100_000, mesh=None,
                 executor: str = "host", bn_mode: str = "single_host",
                 pipeline_depth: int = 0, halo_aware: bool = True,
                 staleness_s: float = 0.25,
                 halo_window: Optional[int] = None, admission=None,
                 tracer=None, trace: bool = True, cost=None, slo=None,
                 multi_bucket: bool = False, faults=None,
                 max_retries: int = 8, retry_backoff_s: float = 0.05,
                 retry_backoff_max_s: float = 2.0):
        super().__init__(store, max_batch=max_batch, mode=mode,
                         full_cache_max_nodes=full_cache_max_nodes,
                         keep_finished=keep_finished,
                         pipeline_depth=pipeline_depth, admission=admission,
                         tracer=tracer, trace=trace, cost=cost, slo=slo,
                         multi_bucket=multi_bucket, faults=faults,
                         max_retries=max_retries,
                         retry_backoff_s=retry_backoff_s,
                         retry_backoff_max_s=retry_backoff_max_s)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        self.mesh = mesh
        self.executor = executor
        self.bn_mode = bn_mode
        self.halo_aware = halo_aware
        self.staleness_s = float(staleness_s)
        # how deep into a queue signature grouping may look for co-batching
        # candidates (bounds the formation cost per slot)
        self.halo_window = halo_window
        self.halo_tiles_shared = 0       # co-batched shared halo tiles
        self.halo_bytes_saved = 0        # est. serve/x bytes they deduplicate
        self.whale_splits = 0            # batches closed early to avoid
        #                                  co-batching two predicted whales
        # formation stats of the most recent _pop_batch, stashed for the
        # batch's trace (single extract worker: read before the next pop)
        self._last_formation: dict = {}
        self._routing_cache = {}
        self._sig_cache: Dict[Tuple[str, str], Dict[int, frozenset]] = {}
        self._feat_bytes_cache: Dict[Tuple[str, str], int] = {}

    def _get_session(self, key: Tuple[str, ...]):
        return self.store.sharded_session(*key[:2], self.n_shards,
                                          mesh=self.mesh,
                                          executor=self.executor,
                                          bn_mode=self.bn_mode)

    def engine_config(self) -> dict:
        """Rebuild kwargs incl. the sharded knobs — everything except the
        store and ``n_shards``, which the reshard path supplies (that pair
        IS the thing a reshard changes)."""
        cfg = super().engine_config()
        cfg.update(mesh=self.mesh, executor=self.executor,
                   bn_mode=self.bn_mode, halo_aware=self.halo_aware,
                   staleness_s=self.staleness_s,
                   halo_window=self.halo_window)
        return cfg

    def _queue_key(self, graph: str, model: str, node: int,
                   tenant: str = DEFAULT_TENANT) -> tuple:
        """One FIFO per (graph, model, owning shard, tenant): every served
        micro-batch is a single-owner group, so its routed subgraph — and
        therefore its logits — are bit-identical to the single-host session
        serving the same batch. Keeping the tenant in the key (LAST, the
        admission controller's convention) means halo-aware co-batching
        only ever groups seeds within one tenant's owner queue, so the
        single-owner bit-exactness invariant and the replayed ``batch_log``
        oracle survive tenancy unchanged.

        The routing bounds are cached per (graph, model); steady-state
        intake is one scalar bisection. NOTE: the FIRST submit for a pair
        whose sharded session is not built yet triggers the plan + compile
        (call ``engine.warmup(graph, model)`` beforehand to keep the intake
        path cheap, exactly like pre-warming the single-host engine)."""
        bounds = self._routing_cache.get((graph, model))
        if bounds is None:
            bounds = self._get_session((graph, model)).routing.bounds
            self._routing_cache[(graph, model)] = bounds
        owner = int(np.searchsorted(bounds, node, side="right")) - 1
        return (graph, model, owner, tenant)

    # -------------------------------------------- halo-aware formation -----
    # bound per (graph, model): a long-lived engine on a huge graph must
    # not accumulate one signature per node ever queried (the finished/
    # batch_log deques are bounded for the same reason)
    SIG_CACHE_MAX = 262_144

    def _seed_signature(self, session, graph: str, model: str,
                        node: int) -> frozenset:
        """Cached per-seed halo signature (structural: valid for the life of
        the graph's partition). ``session`` is the already-resolved sharded
        session — a cache miss is one CSR row read, cheap enough for the
        formation loop."""
        cache = self._sig_cache.setdefault((graph, model), {})
        sig = cache.get(node)
        if sig is None:
            if len(cache) >= self.SIG_CACHE_MAX:
                cache.pop(next(iter(cache)))     # evict oldest-inserted
            sig = session.seed_halo_tiles(node)
            cache[node] = sig
        return sig

    def _feat_row_bytes(self, graph: str, model: str) -> int:
        b = self._feat_bytes_cache.get((graph, model))
        if b is None:
            x = self.store.graphs[graph].data.x
            b = int(x.shape[1]) * x.dtype.itemsize
            self._feat_bytes_cache[(graph, model)] = b
        return b

    def _cost_halo_rows(self, graph: str, model: str,
                        node: int) -> Tuple[int, int]:
        """Predicted halo traffic of one seed from its static halo
        signature: every remote FRDC tile the signature names is
        ``frdc.TILE`` feature rows of ``serve/x`` gather — the same
        per-tile accounting the halo plan's ``payload_bytes`` uses. Reads
        only the cached signature/routing state ``_queue_key`` resolves on
        the same submit path."""
        session = self._get_session((graph, model))
        sig = self._seed_signature(session, graph, model, node)
        return len(sig) * frdc.TILE, self._feat_row_bytes(graph, model)

    def _prepare_formation(self, key: tuple, session) -> None:
        """Warm the halo-signature cache for every request the upcoming
        formation may touch — OUTSIDE ``_qlock``, so the locked pop does no
        CSR reads. The queue is snapshotted briefly; requests submitted
        between snapshot and pop fall back to the (cheap, one-row) in-lock
        cache miss."""
        if not self.halo_aware:
            return
        graph, model = key[0], key[1]
        window = (8 * self.max_batch if self.halo_window is None
                  else self.halo_window)
        with self._qlock:
            dq = self._queues.get(key)
            nodes = [q.node for q in
                     itertools.islice(dq or (), window + self.max_batch)]
        self._feat_row_bytes(graph, model)
        for n in nodes:
            self._seed_signature(session, graph, model, n)

    def _pop_batch(self, key: tuple, session) -> List[NodeQuery]:
        """Halo-aware batch formation (caller holds ``_qlock``): start from
        the queue head (the oldest request is never delayed by grouping),
        then fill the batch greedily with the in-window candidate sharing
        the most halo-signature tiles with the batch so far — EXCEPT that
        any request in the formation window whose wait already exceeds
        ``staleness_s`` preempts the grouping and is taken in FIFO order
        (the earliest overdue one first), so an overdue request is never
        skipped for better overlap. Queues are keyed by owning shard, so
        any formed batch is single-owner by construction. With no signature
        overlap anywhere (``halo_window=0``, or ``halo_aware=False``) this
        degrades to exactly the FIFO pop."""
        if not self.halo_aware:
            self._last_formation = {}
            return super()._pop_batch(key, session)
        graph, model = key[0], key[1]
        dq = self._queues[key]
        limit = min(self.max_batch, len(dq))
        now = time.perf_counter()
        window = (8 * self.max_batch if self.halo_window is None
                  else self.halo_window)
        batch = [dq.popleft()]
        sig = set(self._seed_signature(session, graph, model, batch[0].node))
        row_bytes = self._feat_row_bytes(graph, model)
        form_shared, form_saved = 0, 0
        # whale avoidance: with a cost model, a batch already carrying one
        # predicted whale never greedily picks up another — two whales in
        # one micro-batch make its padded bucket (and so EVERY member's
        # latency) pay for both closures. The staleness bound still wins:
        # an overdue whale is taken, never skipped.
        has_whale = self.cost is not None \
            and self.cost.is_whale(batch[0].cost)
        form_whale_split = False
        while len(batch) < limit and dq:
            # staleness bound: the earliest overdue request anywhere in the
            # window wins over signature grouping (the deque is in submit
            # order, so the first overdue found is the oldest)
            overdue_i = None
            for i, cand in enumerate(dq):
                if i >= window:
                    break
                if now - cand.t_submit >= self.staleness_s:
                    overdue_i = i
                    break
            if overdue_i is not None:
                q = dq[overdue_i]
                del dq[overdue_i]
            else:
                best_i, best_score = None, -1
                for i, cand in enumerate(dq):
                    if i >= window:
                        break
                    if has_whale and self.cost.is_whale(cand.cost):
                        continue
                    score = len(sig & self._seed_signature(
                        session, graph, model, cand.node))
                    if score > best_score:
                        best_i, best_score = i, score
                if best_i is None:
                    # every in-window candidate is another whale: close
                    # the batch early and leave them for their own batches
                    self.whale_splits += 1
                    form_whale_split = True
                    break
                q = dq[best_i]
                del dq[best_i]
            csig = self._seed_signature(session, graph, model, q.node)
            shared = len(sig & csig)
            if shared:
                self.halo_tiles_shared += shared
                self.halo_bytes_saved += shared * frdc.TILE * row_bytes
                form_shared += shared
                form_saved += shared * frdc.TILE * row_bytes
            sig |= csig
            batch.append(q)
            if self.cost is not None and self.cost.is_whale(q.cost):
                has_whale = True
        self._last_formation = dict(tiles=len(sig),
                                    tiles_shared=form_shared,
                                    bytes_saved=form_saved)
        if form_whale_split:
            self._last_formation["whale_split"] = True
        return batch

    # ------------------------------------------------------- trace hooks ---
    def _trace_shard(self, key: tuple):
        return int(key[2])       # (graph, model, owner, tenant)

    def _trace_halo_begin(self, session):
        """Snapshot the serve-path halo byte counter so the batch's trace
        carries ITS halo traffic (single extract worker: the delta across
        prepare_batch is this batch's)."""
        return int(session.halo_stats.bytes_by_tag.get("serve/x", 0))

    def _trace_halo_end(self, session, token) -> dict:
        out = dict(self._last_formation)
        if token is not None:
            now = int(session.halo_stats.bytes_by_tag.get("serve/x", 0))
            out["serve_x_bytes"] = now - token
        return out

    # ------------------------------------------------------------- state ---
    def _sessions(self):
        return (s for k, s in self.store._sharded_sessions.items()
                if k[2] == self.n_shards and k[3] == self.executor
                and k[4] == self.bn_mode)

    @property
    def compile_count_by_shard(self):
        totals = [0] * self.n_shards
        for s in self._sessions():
            for i, c in enumerate(s.compile_count_by_shard):
                totals[i] += c
        return totals

    def snapshot(self) -> dict:
        snap = super().snapshot()
        halo = {}
        total = 0
        for s in self._sessions():
            for tag, b in s.halo_stats.bytes_by_tag.items():
                halo[tag] = halo.get(tag, 0) + b
                total += b
        snap.update(n_shards=self.n_shards, halo_bytes=total,
                    halo_bytes_by_tag=halo,
                    compiles_by_shard=self.compile_count_by_shard,
                    executor=self.executor, bn_mode=self.bn_mode,
                    executor_compiles=sum(s.executor_compile_count
                                          for s in self._sessions()),
                    halo_aware=self.halo_aware,
                    halo_tiles_shared=self.halo_tiles_shared,
                    halo_bytes_saved=self.halo_bytes_saved,
                    whale_splits=self.whale_splits)
        return snap
