"""ShardedServeEngine: micro-batched node queries over partitioned sessions.

Same queueing/metrics/warmup discipline as :class:`~repro.serve.gnn_engine.
GNNServeEngine` (it IS one — the scheduler is inherited); what changes is
session resolution: a queue key resolves to the store's
:class:`~.session.ShardedGraphSession` for this engine's shard count, and a
served micro-batch is routed inside the session — each query's k-hop
neighborhood is answered by its seed's owning shard, with cross-boundary
frontiers merged through the routing table and remote rows fetched over the
halo transport. ``mode`` defaults to ``"subgraph"``: the routed path is the
scale path (a sharded deployment serves graphs no single device could hold,
so the full-graph cache is per-shard and used only when asked for).

``snapshot()`` additionally reports halo traffic (bytes by layer/tag) and
per-shard compile counters.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ..gnn_engine import GNNServeEngine
from ..gnn_session import GraphStore


class ShardedServeEngine(GNNServeEngine):
    """Micro-batching scheduler over a store's SHARDED sessions."""

    def __init__(self, store: GraphStore, n_shards: int,
                 max_batch=None, mode: str = "subgraph",
                 full_cache_max_nodes: int = 200_000,
                 keep_finished: int = 100_000, mesh=None,
                 executor: str = "host", bn_mode: str = "single_host"):
        super().__init__(store, max_batch=max_batch, mode=mode,
                         full_cache_max_nodes=full_cache_max_nodes,
                         keep_finished=keep_finished)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        self.mesh = mesh
        self.executor = executor
        self.bn_mode = bn_mode
        self._routing_cache = {}

    def _get_session(self, key: Tuple[str, ...]):
        return self.store.sharded_session(*key[:2], self.n_shards,
                                          mesh=self.mesh,
                                          executor=self.executor,
                                          bn_mode=self.bn_mode)

    def _queue_key(self, graph: str, model: str, node: int) -> tuple:
        """One FIFO per (graph, model, owning shard): every served
        micro-batch is a single-owner group, so its routed subgraph — and
        therefore its logits — are bit-identical to the single-host session
        serving the same batch.

        The routing bounds are cached per (graph, model); steady-state
        intake is one scalar bisection. NOTE: the FIRST submit for a pair
        whose sharded session is not built yet triggers the plan + compile
        (call ``engine.warmup(graph, model)`` beforehand to keep the intake
        path cheap, exactly like pre-warming the single-host engine)."""
        bounds = self._routing_cache.get((graph, model))
        if bounds is None:
            bounds = self._get_session((graph, model)).routing.bounds
            self._routing_cache[(graph, model)] = bounds
        owner = int(np.searchsorted(bounds, node, side="right")) - 1
        return (graph, model, owner)

    def _sessions(self):
        return (s for k, s in self.store._sharded_sessions.items()
                if k[2] == self.n_shards and k[3] == self.executor
                and k[4] == self.bn_mode)

    @property
    def compile_count_by_shard(self):
        totals = [0] * self.n_shards
        for s in self._sessions():
            for i, c in enumerate(s.compile_count_by_shard):
                totals[i] += c
        return totals

    def snapshot(self) -> dict:
        snap = super().snapshot()
        halo = {}
        total = 0
        for s in self._sessions():
            for tag, b in s.halo_stats.bytes_by_tag.items():
                halo[tag] = halo.get(tag, 0) + b
                total += b
        snap.update(n_shards=self.n_shards, halo_bytes=total,
                    halo_bytes_by_tag=halo,
                    compiles_by_shard=self.compile_count_by_shard,
                    executor=self.executor, bn_mode=self.bn_mode,
                    executor_compiles=sum(s.executor_compile_count
                                          for s in self._sessions()))
        return snap
