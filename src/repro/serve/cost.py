"""Per-query cost model for the GNN serving path.

The serving stack charges every submission one admission token and only
reports latency after the fact; but a k-hop hub-node query costs orders of
magnitude more service time than a leaf hit on the full cache. This module
predicts that cost at SUBMIT time from host-side statics — quantities that
are pure functions of the graph topology and the static serving plans, so
estimation never touches a session or the device:

  * **k-hop closure size** via the CSR sampling index
    (:func:`repro.graphs.sampling.khop_nodes`): the node/edge volume the
    extract stage must walk and the bucketed forward must aggregate;
  * **halo rows** from the static halo schedule (the sharded engine feeds
    the seed's remote-neighbor FRDC tiles — the same per-tile accounting
    :meth:`MeshHaloPlan.payload_bytes` uses for the distributed pass — so
    ``halo_bytes = rows * row_bytes`` is the ``serve/x`` gather this seed
    will request);
  * **bucket padding waste** from the pow2 bucket table
    (:func:`repro.serve.session_core.bucket_pow2`): padded rows cost real
    device time even though no query asked for them.

Predicted units are CALIBRATED online against the measured per-batch
service time from the engine's trace spans (extract + de-overlapped device
compute): :meth:`CostEstimator.observe_batch` keeps a per-bucket EWMA of
cost-units-per-second, and :meth:`attribute` splits a batch's measured
seconds back across its member queries pro rata by predicted units — the
per-tenant cost attribution the metrics/Prometheus layers surface.

Everything here is numpy + stdlib; :func:`spearman_rho` (the
calibration-accuracy gauge: rank correlation between predicted and measured
per-batch cost) is implemented with average ranks so scipy is not needed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..graphs import sampling
from .session_core import bucket_pow2


def spearman_rho(x: Sequence[float], y: Sequence[float]) -> float:
    """Spearman rank correlation (average ranks for ties; numpy only).

    Returns NaN for fewer than 3 points or a constant series — callers gate
    on ``rho >= threshold`` so NaN reads as "not enough signal", never as a
    pass."""
    xa = np.asarray(x, np.float64)
    ya = np.asarray(y, np.float64)
    if xa.size != ya.size:
        raise ValueError(f"length mismatch: {xa.size} vs {ya.size}")
    if xa.size < 3:
        return float("nan")

    def _ranks(a: np.ndarray) -> np.ndarray:
        order = np.argsort(a, kind="stable")
        ranks = np.empty(a.size, np.float64)
        sa = a[order]
        i = 0
        while i < a.size:
            j = i
            while j + 1 < a.size and sa[j + 1] == sa[i]:
                j += 1
            ranks[order[i:j + 1]] = 0.5 * (i + j) + 1.0   # average rank
            i = j + 1
        return ranks

    rx, ry = _ranks(xa), _ranks(ya)
    sx, sy = rx.std(), ry.std()
    if sx == 0.0 or sy == 0.0:
        return float("nan")
    return float(np.mean((rx - rx.mean()) * (ry - ry.mean())) / (sx * sy))


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Predicted cost of one query, with its feature breakdown."""
    units: float                 # predicted cost units (>= 1)
    closure_nodes: int = 1
    closure_edges: int = 0
    halo_rows: int = 0
    halo_bytes: int = 0
    pad_nodes: int = 0           # pow2 bucket rows beyond the closure
    full_cache: bool = False

    def to_json(self) -> dict:
        return dict(units=self.units, closure_nodes=self.closure_nodes,
                    closure_edges=self.closure_edges,
                    halo_rows=self.halo_rows, halo_bytes=self.halo_bytes,
                    pad_nodes=self.pad_nodes, full_cache=self.full_cache)


class CostEstimator:
    """Submit-time cost prediction + online calibration.

    Estimates are DETERMINISTIC functions of the graph topology (feature
    updates never move them — topology is what :meth:`estimate` reads), and
    are cached per ``(graph, node)`` with bounded occupancy, mirroring the
    sharded engine's halo-signature cache.

    Unit weights are relative work factors, not seconds: a closure node is
    one feature-transform row, a closure edge a quarter-row of aggregation,
    a halo row half a row of DMA, a padded row a sliver of wasted device
    time. Calibration (:meth:`observe_batch`) maps units to seconds —
    per-bucket EWMAs of units-per-second — so the absolute scale of the
    weights washes out; only their ratios (and hence the predicted RANKING
    of queries) matter, which is what the Spearman gate checks.
    """

    NODE_UNIT = 1.0
    EDGE_UNIT = 0.25
    HALO_ROW_UNIT = 0.5
    PAD_UNIT = 0.05
    FULL_CACHE_UNITS = 1.0       # O(1): a row gather from the cached pass

    CACHE_MAX = 262_144

    def __init__(self, khop: int = 2, bucket_floor: int = 64,
                 ewma_alpha: float = 0.25, whale_factor: float = 8.0,
                 whale_units: Optional[float] = None,
                 history: int = 4096):
        if khop < 1:
            raise ValueError(f"khop must be >= 1, got {khop}")
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha must be in (0, 1], "
                             f"got {ewma_alpha}")
        self.khop = int(khop)
        self.bucket_floor = int(bucket_floor)
        self.ewma_alpha = float(ewma_alpha)
        self.whale_factor = float(whale_factor)
        self.whale_units = whale_units   # explicit threshold wins over the
        #                                  traffic-relative whale_factor
        self._cache: Dict[Tuple[str, int, int, int], CostEstimate] = {}
        # typical per-query predicted units (EWMA over estimates issued) —
        # the denominator of the traffic-relative whale threshold
        self._unit_ewma: Optional[float] = None
        # calibration: per-bucket (n_pad) and overall units-per-second EWMAs
        self._rate_by_bucket: Dict[int, float] = {}
        self._rate_overall: Optional[float] = None
        self.batches_observed = 0
        self.queries_estimated = 0
        # bounded per-batch (predicted units, measured seconds) history —
        # the Spearman rank-correlation stream
        self._pred: List[float] = []
        self._meas: List[float] = []
        self._history = int(history)

    # ------------------------------------------------------------ predict ---
    def estimate(self, graph: str, node: int, csr: sampling.CSRGraph,
                 khop: Optional[int] = None, halo_rows: int = 0,
                 row_bytes: int = 0,
                 full_cache: bool = False) -> CostEstimate:
        """Predict one query's cost from host-side statics. ``csr`` is the
        graph's cached CSR index; ``halo_rows`` the remote feature rows the
        seed's halo signature requests (0 on the single-host path);
        ``full_cache=True`` short-circuits to the O(1) cached-pass cost."""
        if full_cache:
            est = CostEstimate(units=self.FULL_CACHE_UNITS, full_cache=True)
            self._note_estimate(est)
            return est
        k = self.khop if khop is None else int(khop)
        key = (graph, int(node), k, int(halo_rows))
        est = self._cache.get(key)
        if est is None:
            nodes = sampling.khop_nodes(csr, np.asarray([node], np.int64),
                                        k)
            n_closure = int(nodes.size)
            degs = csr.indptr[nodes + 1] - csr.indptr[nodes]
            n_edges = int(degs.sum())
            pad = bucket_pow2(max(n_closure, 1), self.bucket_floor) \
                - n_closure
            units = (self.NODE_UNIT * n_closure
                     + self.EDGE_UNIT * n_edges
                     + self.HALO_ROW_UNIT * halo_rows
                     + self.PAD_UNIT * pad)
            est = CostEstimate(units=max(units, 1.0),
                               closure_nodes=n_closure,
                               closure_edges=n_edges,
                               halo_rows=int(halo_rows),
                               halo_bytes=int(halo_rows) * int(row_bytes),
                               pad_nodes=int(pad))
            if len(self._cache) >= self.CACHE_MAX:
                self._cache.pop(next(iter(self._cache)))
            self._cache[key] = est
        self._note_estimate(est)
        return est

    def estimate_flat(self, units: float) -> CostEstimate:
        """Predict a query whose cost is already a unit count (the token
        engine's prompt+decode length) — no topology features, but the
        estimate still feeds the whale EWMA and calibration streams."""
        est = CostEstimate(units=max(float(units), 1.0))
        self._note_estimate(est)
        return est

    def _note_estimate(self, est: CostEstimate) -> None:
        self.queries_estimated += 1
        a = self.ewma_alpha
        self._unit_ewma = est.units if self._unit_ewma is None \
            else (1.0 - a) * self._unit_ewma + a * est.units

    def is_whale(self, est: Optional[CostEstimate]) -> bool:
        """Whether a query's predicted cost marks it as a whale the sharded
        formation should not co-batch with another whale. An explicit
        ``whale_units`` threshold is absolute; otherwise a whale costs
        ``whale_factor``x the typical (EWMA) query seen so far."""
        if est is None:
            return False
        if self.whale_units is not None:
            return est.units >= self.whale_units
        typical = max(self._unit_ewma or 1.0, 1.0)
        return est.units >= self.whale_factor * typical

    # -------------------------------------------------------- calibration ---
    def observe_batch(self, pred_units: float, measured_s: float,
                      n_pad: int = 0) -> None:
        """Fold one served batch into the calibration state: ``pred_units``
        the batch's summed predicted units, ``measured_s`` its measured
        service seconds (extract + de-overlapped device compute, from the
        batch's trace spans), ``n_pad`` the launched bucket (0 for a
        full-cache batch)."""
        if measured_s <= 0.0 or pred_units <= 0.0:
            return
        rate = pred_units / measured_s
        a = self.ewma_alpha
        cur = self._rate_by_bucket.get(int(n_pad))
        self._rate_by_bucket[int(n_pad)] = rate if cur is None \
            else (1.0 - a) * cur + a * rate
        self._rate_overall = rate if self._rate_overall is None \
            else (1.0 - a) * self._rate_overall + a * rate
        self.batches_observed += 1
        if len(self._pred) >= self._history:
            self._pred.pop(0)
            self._meas.pop(0)
        self._pred.append(float(pred_units))
        self._meas.append(float(measured_s))

    def attribute(self, units: Sequence[float],
                  measured_s: float) -> List[float]:
        """Split a batch's measured seconds across its queries pro rata by
        predicted units (equal shares when nothing was predicted)."""
        u = [max(float(v), 0.0) for v in units]
        total = sum(u)
        if total <= 0.0:
            n = max(len(u), 1)
            return [measured_s / n] * len(u)
        return [measured_s * v / total for v in u]

    def units_per_second(self, n_pad: Optional[int] = None
                         ) -> Optional[float]:
        if n_pad is not None and int(n_pad) in self._rate_by_bucket:
            return self._rate_by_bucket[int(n_pad)]
        return self._rate_overall

    def estimate_seconds(self, est: CostEstimate,
                         n_pad: Optional[int] = None) -> Optional[float]:
        """Predicted service seconds for one query (None before the first
        calibration sample)."""
        rate = self.units_per_second(n_pad)
        if rate is None or rate <= 0.0:
            return None
        return est.units / rate

    def predicted_vs_measured(self) -> Tuple[np.ndarray, np.ndarray]:
        """The per-batch (predicted units, measured seconds) history."""
        return (np.asarray(self._pred, np.float64),
                np.asarray(self._meas, np.float64))

    def rank_correlation(self, last: Optional[int] = None) -> float:
        """Spearman rho between predicted and measured per-batch cost over
        the (optionally truncated) calibration history."""
        p, m = self.predicted_vs_measured()
        if last is not None:
            p, m = p[-last:], m[-last:]
        return spearman_rho(p, m)

    def snapshot(self) -> dict:
        rho = self.rank_correlation()
        return dict(
            khop=self.khop,
            queries_estimated=self.queries_estimated,
            batches_observed=self.batches_observed,
            typical_units=self._unit_ewma,
            whale_threshold_units=(
                self.whale_units if self.whale_units is not None
                else self.whale_factor * max(self._unit_ewma or 1.0, 1.0)),
            units_per_second=self._rate_overall,
            units_per_second_by_bucket={
                str(k): v for k, v in sorted(self._rate_by_bucket.items())},
            rank_correlation=None if rho != rho else rho,
            cached_estimates=len(self._cache),
        )
