"""Token serving sessions: the binary transformer / SSM decode path riding
the SAME :class:`~repro.serve.session_core.ServeCore` the GNN sessions use.

A :class:`TokenSession` owns one serve core whose adapter is a
:class:`~repro.serve.adapters.TokenAdapter`: a launch runs one CHUNK of
exact single-token ``decode_step`` bodies (teacher-forced scan — see the
adapter), and a batch of requests becomes a :class:`TokenPreparedBatch`
whose groups are the decode's chunks in step order. ``launch_batch``
threads the ``(cache, prev)`` carry through the chunk launches — each
chunk's dispatch is async and chained on device, so the whole decode is in
flight after the last launch returns. ``finish_batch`` blocks chunk by
chunk (stamping per-chunk completion times, the engine's time-to-first-
token source) and slices each request's generated tokens out of the global
argmax stream.

Step math: global step ``t`` consumes slot ``s``'s prompt token while
``t < len_s`` and its previous argmax after; generated token ``j`` of slot
``s`` is the argmax output of step ``len_s - 1 + j``. The batch runs
``ceil(S / chunk)`` chunks where ``S = max_s(len_s + max_new_s - 1)``; the
decode-cache length is the pow2 high-water bucket of the total step count,
so steady-state serving never recompiles across prompt/decode lengths.

The staged chunk arrays are pure host work (the extract-stage purity the
transfer watchdog checks); the decode caches are allocated at LAUNCH. A
prepared batch pins its serve core at extract time — ``update_params``
swaps the session's core, and in-flight batches finish under the params
they were staged for (the token twin of the GNN sessions' pinned BN).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from . import adapters
from .session_core import (PreparedBatch, PreparedGroup, ServeCore,
                           SessionPlan, StagedBatch)


@dataclasses.dataclass
class TokenPreparedBatch(PreparedBatch):
    """Extract-stage output of one token micro-batch: the decode's chunks
    as :class:`PreparedGroup`\\ s (all on the session's core, in step
    order) plus the per-request slicing data. ``bn`` stays None — the
    decode carry is built fresh at launch, and the params are pinned via
    the groups' core."""
    lens: Optional[np.ndarray] = None       # (n,) prompt lengths
    max_news: Optional[np.ndarray] = None   # (n,) decode budgets
    cache_len: int = 0                      # bucketed decode-cache length
    chunk: int = 0
    eos_id: int = -1
    # finish() fills this: wall time each chunk's result became host-ready,
    # the engine's per-query time-to-first-token source
    chunk_done_t: List[float] = dataclasses.field(default_factory=list)

    def launch(self) -> list:
        """Dispatch the decode: fresh carry, then every chunk chained on
        the previous chunk's device state. Async — each launch returns with
        the device work in flight; only finish() blocks."""
        core = self.groups[0].core
        state = core.adapter.init_state(core.max_batch, self.cache_len)
        devs = []
        for g in self.groups:
            out = core.launch(g.staged, state)
            state = out["state"]
            devs.append(out["gens"])
        return devs

    def finish(self, devs: list) -> List[np.ndarray]:
        """Block on the chunks in step order and slice each request's
        generated tokens (truncated at ``eos_id`` inclusive, when set) out
        of the global argmax stream. Returns per-request int32 arrays in
        request order."""
        self.chunk_done_t = []
        cols = []
        for d in devs:
            cols.append(np.asarray(d))
            self.chunk_done_t.append(time.perf_counter())
        gens = np.concatenate(cols, axis=1)
        outs: List[np.ndarray] = []
        for i in range(self.n_uniq):
            ln, mn = int(self.lens[i]), int(self.max_news[i])
            row = gens[i, ln - 1: ln - 1 + mn]
            if self.eos_id >= 0:
                hit = np.nonzero(row == self.eos_id)[0]
                if hit.size:
                    row = row[: int(hit[0]) + 1]
            outs.append(np.array(row, np.int32))
        return outs

    def first_token_chunk(self, i: int) -> int:
        """Chunk index whose completion carries request ``i``'s first
        generated token (step ``len_i - 1``)."""
        return (int(self.lens[i]) - 1) // self.chunk


class TokenSession:
    """One compiled token-serving session: config + (optionally bit-packed)
    params behind one :class:`ServeCore` running the chunked decode.

    Mirrors the surface the serving engines drive on the GNN sessions:
    ``prepare_batch`` / ``launch_batch`` / ``finish_batch``, ``warmup``,
    ``sync``, ``set_trace_hook``, ``compile_count`` / ``dispatch_count`` /
    ``invalidations``. ``run`` composes the three stages serially, so
    serial and pipelined serving are bit-exact by construction."""

    def __init__(self, name: str, cfg, params, max_batch: int = 4,
                 max_len: int = 1024, chunk: int = 8,
                 quantize: bool = False, eos_id: int = -1,
                 warm_len: int = 16, warm_new: int = 8):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.name = name
        self.cfg = cfg
        self.params = params
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.chunk = int(chunk)
        self.quantized = bool(quantize)
        self.eos_id = int(eos_id)
        self.warm_len = int(warm_len)
        self.warm_new = int(warm_new)
        self.adapter = adapters.TokenAdapter(cfg)
        self.plan = SessionPlan(family=self.adapter.kind, scheme="token")
        self.invalidations = 0
        self._trace_cb = None
        # cumulative counters across param swaps (a swap rebuilds the core)
        self._compiles_base = 0
        self._dispatch_base = 0
        self.core = self._build_core(params)

    def _build_core(self, params) -> ServeCore:
        qp = self.adapter.quantize(params) if self.quantized else params
        core = ServeCore(self.plan, qp, self.max_batch,
                         node_cap=self.max_len, adapter=self.adapter)
        if self._trace_cb is not None:
            cb = self._trace_cb
            core.on_trace = lambda shape: cb("token", shape)
        return core

    # ------------------------------------------------------------ counters --
    @property
    def compile_count(self) -> int:
        return self._compiles_base + self.core.compile_count

    @property
    def dispatch_count(self) -> int:
        return self._dispatch_base + self.core.n_dispatches

    def set_trace_hook(self, cb) -> None:
        self._trace_cb = cb
        self.core.on_trace = lambda shape: cb("token", shape)

    def sync(self) -> None:
        """No cached full pass on the token path — nothing to build."""

    # -------------------------------------------------------------- stages --
    def prepare_batch(self, prompts: Sequence[np.ndarray],
                      max_news: Sequence[int]) -> TokenPreparedBatch:
        """EXTRACT: stage one batch's chunk grid. Pure host work — the
        water-mark update happens here, so staging order is what the
        zero-recompile guarantee keys on (exactly like the GNN stage)."""
        n = len(prompts)
        if not 0 < n <= self.max_batch:
            raise ValueError(f"batch of {n} prompts for a session with "
                             f"max_batch={self.max_batch}")
        lens = np.asarray([int(np.asarray(p).size) for p in prompts],
                          np.int64)
        mns = np.asarray([int(m) for m in max_news], np.int64)
        if lens.min() < 1:
            raise ValueError("empty prompt")
        if mns.min() < 1:
            raise ValueError("max_new must be >= 1")
        s_needed = int((lens + mns).max()) - 1
        n_chunks = -(-s_needed // self.chunk)
        steps = n_chunks * self.chunk
        cache_len, _ = self.adapter.pad_operands(self.core, {}, steps)
        grid = np.zeros((self.max_batch, steps), np.int32)
        lens_pad = np.zeros((self.max_batch,), np.int32)
        for i, p in enumerate(prompts):
            p = np.asarray(p, np.int32).ravel()
            grid[i, :p.size] = p
            lens_pad[i] = p.size
        groups = []
        for c in range(n_chunks):
            staged = StagedBatch(
                x_pad=grid[:, c * self.chunk:(c + 1) * self.chunk],
                adjs=self.adapter.sub_operands(c * self.chunk),
                pos_pad=lens_pad, n_seeds=n)
            groups.append(PreparedGroup(core=self.core,
                                        sel=np.arange(n), staged=staged))
        return TokenPreparedBatch(
            n_uniq=n, inverse=np.arange(n), groups=groups, bn=None,
            lens=lens, max_news=mns, cache_len=cache_len,
            chunk=self.chunk, eos_id=self.eos_id)

    def launch_batch(self, prepared: TokenPreparedBatch) -> list:
        return prepared.launch()

    def finish_batch(self, prepared: TokenPreparedBatch,
                     devs: list) -> List[np.ndarray]:
        return prepared.finish(devs)

    def run(self, prompts: Sequence[np.ndarray],
            max_news: Sequence[int]) -> List[np.ndarray]:
        """Serial stage -> launch -> finish of one batch of prompts."""
        prepared = self.prepare_batch(prompts, max_news)
        return self.finish_batch(prepared, self.launch_batch(prepared))

    # -------------------------------------------------------------- warmup --
    def warmup(self, rng: np.random.Generator, probes: int = 2) -> int:
        """Populate the jit cache and set the cache-length water at the
        session's warm sizes (``warm_len`` + ``warm_new``); any workload
        whose step count stays under the resulting pow2 bucket then serves
        with zero steady-state recompiles. Returns compiles triggered."""
        c0 = self.compile_count
        for _ in range(max(1, min(int(probes), 2))):
            prompts = [rng.integers(0, self.cfg.vocab,
                                    self.warm_len).astype(np.int32)
                       for _ in range(self.max_batch)]
            self.run(prompts, [self.warm_new] * self.max_batch)
        return self.compile_count - c0

    # --------------------------------------------------------- param swaps --
    def update_params(self, params, quantize: Optional[bool] = None) -> None:
        """Hot-swap the served params: a NEW core (the jitted program
        closes over the packed weights) while in-flight prepared batches
        keep the old core pinned via their groups. The bucket water carries
        over, so the swap costs one re-trace at the established shapes,
        not a warmup."""
        if quantize is not None:
            self.quantized = bool(quantize)
        self.params = params
        old = self.core
        self._compiles_base += old.compile_count
        self._dispatch_base += old.n_dispatches
        self.core = self._build_core(params)
        self.core._n_water = old._n_water
        self.invalidations += 1


@dataclasses.dataclass
class TokenModelEntry:
    """Registry entry of one servable token model."""
    name: str
    cfg: object
    params: object
    quantize: bool = False
    kind: str = "transformer"


class TokenStore:
    """Registry of token models + their lazily-built sessions — the token
    twin of :class:`~repro.serve.gnn_session.GraphStore`, exposing the
    surface the engines read (``models``, ``_sessions``, ``max_batch``,
    ``session()``)."""

    def __init__(self, max_batch: int = 4, max_len: int = 1024,
                 chunk: int = 8, eos_id: int = -1,
                 warm_len: int = 16, warm_new: int = 8):
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.chunk = int(chunk)
        self.eos_id = int(eos_id)
        self.warm_len = int(warm_len)
        self.warm_new = int(warm_new)
        self.models: Dict[str, TokenModelEntry] = {}
        self._sessions: Dict[str, TokenSession] = {}

    @property
    def kind(self) -> str:
        """Model-family namespace of the store's engines: the registered
        models' shared kind, or "token" for an empty/mixed store."""
        kinds = {e.kind for e in self.models.values()}
        return kinds.pop() if len(kinds) == 1 else "token"

    def register_model(self, name: str, cfg, params,
                       quantize: bool = False) -> TokenModelEntry:
        entry = TokenModelEntry(name=name, cfg=cfg, params=params,
                                quantize=bool(quantize),
                                kind=adapters.TokenAdapter(cfg).kind)
        self.models[name] = entry
        return entry

    def session(self, name: str) -> TokenSession:
        s = self._sessions.get(name)
        if s is None:
            e = self.models[name]
            s = self._sessions[name] = TokenSession(
                name, e.cfg, e.params, max_batch=self.max_batch,
                max_len=self.max_len, chunk=self.chunk,
                quantize=e.quantize, eos_id=self.eos_id,
                warm_len=self.warm_len, warm_new=self.warm_new)
        return s

    def update_params(self, name: str, params) -> None:
        e = self.models[name]
        e.params = params
        s = self._sessions.get(name)
        if s is not None:
            s.update_params(params)
