"""Shared compile/calibrate/serve machinery of the GNN serving sessions.

This module is the seam between the single-host :class:`CompiledGraphSession`
(:mod:`repro.serve.gnn_session`) and the partitioned
:class:`ShardedGraphSession` (:mod:`repro.serve.sharded`): everything that is
NOT about who owns the graph lives here —

  * :class:`SessionPlan` + the tuner-driven plan selection (paper §3.4);
  * family-dispatched bitgnn forwards (optionally routed through the Pallas
    kernels, see :func:`family_forward`);
  * :class:`ServeCore`, the bucket-shaped jitted subgraph forward with the
    HIGH-WATER pow2 shape buckets and the jit trace counter (the
    zero-steady-state-recompiles verification counter);
  * subgraph FRDC construction carrying FULL-graph factorization vectors, so
    a k-hop forward reproduces the full-graph computation for the seed rows
    exactly — on one host or on the seed's owning shard;
  * FRDC array (de)serialization helpers shared by both artifact formats.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import frdc, tuner
from repro.core.bspmm import TRINARY_DEFAULT
from repro.kernels import ops as kernel_ops
from repro.models import gnn

FAMILIES = ("gcn", "sage", "saint")

# layer_variants of the two legal GCN end-to-end schemes (paper Table 3);
# SAGE/SAINT run the fixed Fig. 2 pipeline (BMM.BBF branches + BSpMM.FBF).
GCN_SCHEME_VARIANTS = {
    "full": (("BMM.BBF", "BSpMM.FBF"), ("BMM.BBF", "BSpMM.FBF")),
    "bin": (("BMM.FBB", "BSpMM.BBB"), ("BMM.BBF", "BSpMM.FBF")),
}
FIXED_VARIANTS = (("BMM.BBF", "BSpMM.FBF"), ("BMM.BBF", "BSpMM.FBF"))

# adjacency kinds each family's packed forward consumes
FAMILY_ADJ_KINDS = {"gcn": ("adj", "bin"), "sage": ("mean",), "saint": ("sum",)}

# number of aggregation layers per family: the k of the k-hop closure a
# served node needs, and the hop count of the out-neighborhood a feature
# update invalidates.
FAMILY_AGG_LAYERS = {"gcn": 2, "sage": 2, "saint": 2}


def bucket_pow2(n: int, floor: int, cap: Optional[int] = None) -> int:
    """Round up to the power-of-two bucket grid (>= floor, <= cap)."""
    b = floor
    while b < n:
        b *= 2
    return b if cap is None else min(b, cap)


@dataclasses.dataclass
class SessionPlan:
    """Tuner-selected execution plan of one compiled session."""
    family: str
    scheme: str                       # gcn: "full" | "bin"; else "fixed"
    trinary_mode: str = TRINARY_DEFAULT
    layer_variants: tuple = FIXED_VARIANTS
    tuned_latency_s: float = float("nan")
    output_delta: float = float("nan")

    def name(self) -> str:
        layers = ";".join(f"{m}+{s}" for m, s in self.layer_variants)
        return f"{self.family}/{self.scheme}[{layers}|{self.trinary_mode}]"

    def to_json(self) -> dict:
        return dict(family=self.family, scheme=self.scheme,
                    trinary_mode=self.trinary_mode,
                    layer_variants=[list(v) for v in self.layer_variants],
                    tuned_latency_s=self.tuned_latency_s,
                    output_delta=self.output_delta)

    @classmethod
    def from_json(cls, d: dict) -> "SessionPlan":
        return cls(family=d["family"], scheme=d["scheme"],
                   trinary_mode=d["trinary_mode"],
                   layer_variants=tuple(tuple(v) for v in d["layer_variants"]),
                   tuned_latency_s=d.get("tuned_latency_s", float("nan")),
                   output_delta=d.get("output_delta", float("nan")))


def quantize_family(family: str, params):
    return {"gcn": gnn.quantize_gcn, "sage": gnn.quantize_sage,
            "saint": gnn.quantize_saint}[family](params)


def family_forward(plan: SessionPlan, qparams, x,
                   adjs: Dict[str, frdc.FRDCMatrix],
                   use_pallas: bool = False, **kw):
    """Dispatch the family's packed forward under ``plan``.

    ``use_pallas`` routes the BSpMM aggregations through the Pallas kernels
    (:func:`repro.kernels.ops.serve_kernels`) — native on TPU, and a no-op
    fallback to the reference jnp path off-TPU. The flag is consulted at jit
    TRACE time, so a session built with it bakes the kernel calls into its
    compiled executables.
    """
    with kernel_ops.serve_kernels(use_pallas):
        if plan.family == "gcn":
            return gnn.gcn_forward_bitgnn(
                qparams, x, adjs["adj"], adjs["bin"], scheme=plan.scheme,
                trinary_mode=plan.trinary_mode, **kw)
        if plan.family == "sage":
            return gnn.sage_forward_bitgnn(qparams, x, adjs["mean"], **kw)
        return gnn.saint_forward_bitgnn(qparams, x, adjs["sum"], **kw)


# ---------------------------------------------------------------------------
# FRDC array (de)serialization — shared by both artifact formats
# ---------------------------------------------------------------------------

def frdc_arrays(m: frdc.FRDCMatrix) -> dict:
    out = dict(tiles=m.tiles, col_idx=m.col_idx, group_row=m.group_row,
               group_first=m.group_first, grp_ptr=m.grp_ptr)
    if m.row_scale is not None:
        out["row_scale"] = m.row_scale
    if m.col_scale is not None:
        out["col_scale"] = m.col_scale
    return out


def frdc_rebuild(arrs: dict, n_rows: int, n_cols: int,
                 nnz: int = 0) -> frdc.FRDCMatrix:
    return frdc.FRDCMatrix(
        tiles=arrs["tiles"], col_idx=arrs["col_idx"],
        group_row=arrs["group_row"], group_first=arrs["group_first"],
        grp_ptr=arrs["grp_ptr"], n_rows=int(n_rows), n_cols=int(n_cols),
        nnz=int(nnz), row_scale=arrs.get("row_scale"),
        col_scale=arrs.get("col_scale"))


# FRDC array fields per adjacency kind of each family — the (deterministic)
# pytree structure of a saved artifact, so load() can build the restore
# template without encoding any adjacency.
FRDC_BASE_FIELDS = ("tiles", "col_idx", "group_row", "group_first", "grp_ptr")
ADJ_SCALE_FIELDS = {
    "gcn": {"adj": ("row_scale", "col_scale"), "bin": ()},
    "sage": {"mean": ("row_scale",)},
    "saint": {"sum": ()},
}


def adj_like(family: str) -> dict:
    return {kind: {f: np.zeros(0) for f in FRDC_BASE_FIELDS + extra}
            for kind, extra in ADJ_SCALE_FIELDS[family].items()}


def coerce_quant(q):
    """Re-type a checkpoint-restored quantized param tree: the static ``n``
    field of each BinTensor round-trips through npz as a 0-d array and must
    come back as a python int (it participates in jit-static shape logic)."""
    from repro.core.binarize import BinTensor
    return type(q)(*(BinTensor(packed=jnp.asarray(t.packed),
                               scale=jnp.asarray(t.scale), n=int(t.n))
                     for t in q))


def feature_fingerprint(x: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(x).tobytes()).hexdigest()[:16]


def session_fingerprint(graph, model) -> dict:
    """Identity of a (graph, model) pair a serving artifact was compiled
    for — THE match key of every artifact restore path (single-host
    ``plan.json`` and sharded ``routing.json`` alike), so it lives here
    once. ``graph``/``model`` are the store's registry entries."""
    d = graph.data
    return dict(graph=graph.name, model=model.name, family=model.family,
                n_nodes=int(d.n_nodes), n_edges=int(d.n_edges),
                features=feature_fingerprint(d.x))


# ---------------------------------------------------------------------------
# Subgraph adjacency construction (full-graph factorization vectors)
# ---------------------------------------------------------------------------

def sub_adjacency(family: str, n_sub: int, sub_edges: np.ndarray,
                  dinv_sub: Optional[np.ndarray]
                  ) -> Dict[str, frdc.FRDCMatrix]:
    """Per-family subgraph FRDC matrices. ``dinv_sub`` is the FULL-graph
    factorization vector gathered at the subgraph's nodes (GCN: D^-1/2 with
    self-loops; SAGE: D^-1 mean; SAINT: None) so seed-row aggregation is
    identical to the full graph no matter which host gathered it."""
    if family == "gcn":
        loops = np.arange(n_sub, dtype=np.int64)
        r = np.concatenate([sub_edges[0], loops])
        c = np.concatenate([sub_edges[1], loops])
        return {
            "adj": frdc.from_coo(r, c, n_sub, n_sub, row_scale=dinv_sub,
                                 col_scale=dinv_sub),
            "bin": frdc.from_coo(sub_edges[0], sub_edges[1], n_sub, n_sub),
        }
    if family == "sage":
        return {"mean": frdc.from_coo(sub_edges[0], sub_edges[1], n_sub,
                                      n_sub, row_scale=dinv_sub)}
    return {"sum": frdc.from_coo(sub_edges[0], sub_edges[1], n_sub, n_sub)}


def dinv_for_family(family: str, degrees: np.ndarray) -> Optional[np.ndarray]:
    """Full-graph factorization vector from full-graph receiver degrees."""
    if family == "gcn":
        return 1.0 / np.sqrt(degrees + 1.0)          # self-loops included
    if family == "sage":
        return 1.0 / np.maximum(degrees.astype(np.float64), 1.0)
    return None


# ---------------------------------------------------------------------------
# ServeCore — the bucket-shaped jitted subgraph forward
# ---------------------------------------------------------------------------

class ServeCore:
    """One jitted bucketed subgraph forward + its high-water shape buckets.

    Node and FRDC group counts are padded up to pow2 marks that only ever
    grow (capped at ``node_cap``), so the jitted forward converges to one
    steady padded shape after a short warmup and never recompiles in steady
    state. ``compile_count`` counts jit traces (python side effect on trace)
    and IS the verification counter. Both the single-host session and every
    shard of a sharded session own exactly one of these.
    """

    NODE_BUCKET_FLOOR = 64
    GROUP_BUCKET_FLOOR = 16

    def __init__(self, plan: SessionPlan, qparams, max_batch: int,
                 node_cap: int, use_pallas: bool = False):
        self.plan = plan
        self.qparams = qparams
        self.max_batch = max_batch
        self.node_cap = node_cap
        self.use_pallas = use_pallas
        self._n_traces = 0
        # high-water shape buckets: node and group pads only ever GROW (in
        # pow2 steps, capped at node_cap), so serving stops recompiling —
        # warmup is a handful of max-width batches, not a shape sweep.
        self._n_water = 0
        self._g_water: Dict[Tuple[int, str], int] = {}
        self._jit_serve = jax.jit(self._serve)

    @property
    def compile_count(self) -> int:
        return self._n_traces

    def _serve(self, x, bn, adjs, seeds):
        self._n_traces += 1
        n_pad = x.shape[0]
        mats = {k: frdc_rebuild(v, n_pad, n_pad) for k, v in adjs.items()}
        out = family_forward(self.plan, self.qparams, x, mats,
                             use_pallas=self.use_pallas, bn_stats=bn)
        return out[seeds]

    def _pad_mats(self, mats: Dict[str, frdc.FRDCMatrix], n_sub: int):
        n_pad = bucket_pow2(max(n_sub, self._n_water),
                            self.NODE_BUCKET_FLOOR, self.node_cap)
        self._n_water = n_pad
        adjs = {}
        for k, m in mats.items():
            wkey = (n_pad, k)
            g_pad = max(self._g_water.get(wkey, 0),
                        bucket_pow2(m.n_groups, self.GROUP_BUCKET_FLOOR))
            self._g_water[wkey] = g_pad
            adjs[k] = frdc_arrays(frdc.pad_frdc(m, n_pad, n_groups=g_pad))
        return n_pad, adjs

    def run(self, x_sub: np.ndarray, mats: Dict[str, frdc.FRDCMatrix],
            seed_pos: np.ndarray, bn: tuple) -> np.ndarray:
        """Bucket-pad one extracted subgraph and run the jitted forward.

        ``x_sub``: (n_sub, F) features of the subgraph nodes (global order);
        ``seed_pos``: positions of the seeds inside the subgraph. Returns
        (len(seed_pos), n_out) logits.
        """
        n_pad, adjs = self._pad_mats(mats, x_sub.shape[0])
        x_pad = np.zeros((n_pad, x_sub.shape[1]), np.float32)
        x_pad[:x_sub.shape[0]] = x_sub
        pos_pad = np.zeros((self.max_batch,), np.int32)
        pos_pad[:seed_pos.size] = seed_pos
        out = self._jit_serve(jnp.asarray(x_pad), bn, adjs,
                              jnp.asarray(pos_pad))
        return np.asarray(out)[:seed_pos.size]

    def preset_water(self, n_max: int, g_max: Dict[str, int],
                     margin: float) -> None:
        """Set the water marks ``margin`` above probed maxima (pow2-rounded);
        a workload batch can only recompile by exceeding the margined bucket,
        and the monotone water then absorbs it after one compile."""
        n_pad = bucket_pow2(min(int(n_max * margin), self.node_cap),
                            self.NODE_BUCKET_FLOOR, self.node_cap)
        self._n_water = max(self._n_water, n_pad)
        for k, g in g_max.items():
            wkey = (self._n_water, k)
            g_pad = bucket_pow2(int(g * margin), self.GROUP_BUCKET_FLOOR)
            self._g_water[wkey] = max(self._g_water.get(wkey, 0), g_pad)


# ---------------------------------------------------------------------------
# Plan selection (default + tuner; paper §3.4)
# ---------------------------------------------------------------------------

def default_plan(family: str) -> SessionPlan:
    if family == "gcn":
        return SessionPlan(family, "bin",
                           layer_variants=GCN_SCHEME_VARIANTS["bin"])
    return SessionPlan(family, "fixed")


def tune_plan(data, family: str, qparams, repeats: int = 2) -> SessionPlan:
    """Time the legal end-to-end variant assignments on the actual graph
    (paper §3.4) and pick the fastest. ``data``: the host GraphData."""
    x = jnp.asarray(data.x)
    if family == "gcn":
        adj, adj_bin = data.adjacency("gcn"), data.adjacency("binary")
        cands = [
            tuner.Candidate(GCN_SCHEME_VARIANTS["full"], "s3_two_popc"),
            tuner.Candidate(GCN_SCHEME_VARIANTS["bin"], "s3_two_popc"),
            tuner.Candidate(GCN_SCHEME_VARIANTS["bin"], "s2_and_andnot"),
        ]

        def build(cand):
            scheme = ("bin" if cand.layer_variants[0][0] == "BMM.FBB"
                      else "full")
            def fwd(xx):
                return gnn.gcn_forward_bitgnn(
                    qparams, xx, adj, adj_bin, scheme=scheme,
                    trinary_mode=cand.trinary_mode)
            return fwd
    else:
        adj = data.adjacency("mean" if family == "sage" else "binary")
        fwd_fn = (gnn.sage_forward_bitgnn if family == "sage"
                  else gnn.saint_forward_bitgnn)
        cands = [tuner.Candidate(FIXED_VARIANTS, TRINARY_DEFAULT)]

        def build(cand):
            def fwd(xx):
                return fwd_fn(qparams, xx, adj)
            return fwd

    results = tuner.tune(build, (x,), cands, repeats=repeats)
    best = results[0]
    scheme = "fixed"
    if family == "gcn":
        scheme = ("bin" if best.candidate.layer_variants[0][0] == "BMM.FBB"
                  else "full")
    return SessionPlan(
        family=family, scheme=scheme,
        trinary_mode=best.candidate.trinary_mode,
        layer_variants=best.candidate.layer_variants,
        tuned_latency_s=best.latency_s,
        output_delta=best.output_delta)
