"""Shared compile/calibrate/serve machinery of the GNN serving sessions.

This module is the seam between the single-host :class:`CompiledGraphSession`
(:mod:`repro.serve.gnn_session`) and the partitioned
:class:`ShardedGraphSession` (:mod:`repro.serve.sharded`): everything that is
NOT about who owns the graph lives here —

  * :class:`SessionPlan` + the tuner-driven plan selection (paper §3.4);
  * family-dispatched bitgnn forwards (optionally routed through the Pallas
    kernels, see :func:`family_forward`);
  * :class:`ServeCore`, the bucket-shaped jitted subgraph forward with the
    HIGH-WATER pow2 shape buckets and the jit trace counter (the
    zero-steady-state-recompiles verification counter);
  * subgraph FRDC construction carrying FULL-graph factorization vectors, so
    a k-hop forward reproduces the full-graph computation for the seed rows
    exactly — on one host or on the seed's owning shard;
  * FRDC array (de)serialization helpers shared by both artifact formats.
  * the :class:`LayerExecutor` seam of the DISTRIBUTED full pass: a family
    forward is decomposed into :class:`LayerStep`\\ s (BN site -> per-shard
    transform -> halo exchange -> aggregation -> combine) by
    :func:`build_layer_program`; an executor (host-orchestrated or SPMD,
    :mod:`repro.serve.sharded.executor`) runs the same program either as
    eager per-shard stages or as one ``shard_map`` program per layer.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops, frdc, tuner
from repro.core.binarize import BinTensor
from repro.core.bmm import bmm, quantize_act
from repro.core.bspmm import TRINARY_DEFAULT
from repro.kernels import ops as kernel_ops
from repro.models import gnn

FAMILIES = ("gcn", "sage", "saint")

# layer_variants of the two legal GCN end-to-end schemes (paper Table 3);
# SAGE/SAINT run the fixed Fig. 2 pipeline (BMM.BBF branches + BSpMM.FBF).
GCN_SCHEME_VARIANTS = {
    "full": (("BMM.BBF", "BSpMM.FBF"), ("BMM.BBF", "BSpMM.FBF")),
    "bin": (("BMM.FBB", "BSpMM.BBB"), ("BMM.BBF", "BSpMM.FBF")),
}
FIXED_VARIANTS = (("BMM.BBF", "BSpMM.FBF"), ("BMM.BBF", "BSpMM.FBF"))

# adjacency kinds each family's packed forward consumes
FAMILY_ADJ_KINDS = {"gcn": ("adj", "bin"), "sage": ("mean",), "saint": ("sum",)}

# number of aggregation layers per family: the k of the k-hop closure a
# served node needs, and the hop count of the out-neighborhood a feature
# update invalidates.
FAMILY_AGG_LAYERS = {"gcn": 2, "sage": 2, "saint": 2}


def bucket_pow2(n: int, floor: int, cap: Optional[int] = None) -> int:
    """Round up to the power-of-two bucket grid (>= floor, <= cap)."""
    b = floor
    while b < n:
        b *= 2
    return b if cap is None else min(b, cap)


@dataclasses.dataclass
class SessionPlan:
    """Tuner-selected execution plan of one compiled session.

    ``bspmm_block`` is the Pallas BSpMM block-shape tunable — ``(rows,
    feats)`` of one kernel grid step's output block, or None for the
    kernel-native defaults (one FRDC tile-row of ``frdc.TILE`` rows x the
    full feature width). It rides in ``plan.json`` with the rest of the
    plan, so a TPU block-shape sweep (ROADMAP open item) records its winner
    in the same artifact the tuner's variant choice lives in.

    ``fused`` selects the fused per-layer kernel path: each GNN layer —
    BN, binary transform, BSpMM aggregation, combine/activation — compiles
    to ONE Pallas launch (:mod:`repro.kernels.fused_layer`). Bitwise
    identical to the unfused path; only effective where the kernels are
    active (``use_pallas`` on TPU / ``force_kernels``), and calibration
    passes (which must RECORD bn stats) always run unfused.
    """
    family: str
    scheme: str                       # gcn: "full" | "bin"; else "fixed"
    trinary_mode: str = TRINARY_DEFAULT
    layer_variants: tuple = FIXED_VARIANTS
    tuned_latency_s: float = float("nan")
    output_delta: float = float("nan")
    bspmm_block: Optional[Tuple[int, int]] = None
    fused: bool = False

    def name(self) -> str:
        layers = ";".join(f"{m}+{s}" for m, s in self.layer_variants)
        blk = ("" if self.bspmm_block is None
               else f"|blk{self.bspmm_block[0]}x{self.bspmm_block[1]}")
        fz = "|fused" if self.fused else ""
        return f"{self.family}/{self.scheme}[{layers}|{self.trinary_mode}" \
               f"{blk}{fz}]"

    def to_json(self) -> dict:
        return dict(family=self.family, scheme=self.scheme,
                    trinary_mode=self.trinary_mode,
                    layer_variants=[list(v) for v in self.layer_variants],
                    tuned_latency_s=self.tuned_latency_s,
                    output_delta=self.output_delta,
                    bspmm_block=(None if self.bspmm_block is None
                                 else list(self.bspmm_block)),
                    fused=self.fused)

    @classmethod
    def from_json(cls, d: dict) -> "SessionPlan":
        blk = d.get("bspmm_block")
        return cls(family=d["family"], scheme=d["scheme"],
                   trinary_mode=d["trinary_mode"],
                   layer_variants=tuple(tuple(v) for v in d["layer_variants"]),
                   tuned_latency_s=d.get("tuned_latency_s", float("nan")),
                   output_delta=d.get("output_delta", float("nan")),
                   bspmm_block=None if blk is None else tuple(blk),
                   fused=bool(d.get("fused", False)))


def quantize_family(family: str, params):
    return {"gcn": gnn.quantize_gcn, "sage": gnn.quantize_sage,
            "saint": gnn.quantize_saint}[family](params)


def family_forward(plan: SessionPlan, qparams, x,
                   adjs: Dict[str, frdc.FRDCMatrix],
                   use_pallas: bool = False, **kw):
    """Dispatch the family's packed forward under ``plan``.

    ``use_pallas`` routes the BSpMM aggregations through the Pallas kernels
    (:func:`repro.kernels.ops.serve_kernels`) — native on TPU, and a no-op
    fallback to the reference jnp path off-TPU. The flag is consulted at jit
    TRACE time, so a session built with it bakes the kernel calls into its
    compiled executables. ``plan.bspmm_block`` rides along as the kernels'
    block-shape selection.
    """
    fused = (plan.fused and kernel_ops.kernels_active(use_pallas)
             and kw.get("bn_stats") is not None
             and not kw.get("return_bn_stats", False))
    if fused:
        return _fused_family_forward(plan, qparams, x, adjs,
                                     kw["bn_stats"])
    with kernel_ops.serve_kernels(use_pallas, block_shape=plan.bspmm_block):
        if plan.family == "gcn":
            return gnn.gcn_forward_bitgnn(
                qparams, x, adjs["adj"], adjs["bin"], scheme=plan.scheme,
                trinary_mode=plan.trinary_mode, **kw)
        if plan.family == "sage":
            return gnn.sage_forward_bitgnn(qparams, x, adjs["mean"], **kw)
        return gnn.saint_forward_bitgnn(qparams, x, adjs["sum"], **kw)


def _fused_family_forward(plan: SessionPlan, qparams, x,
                          adjs: Dict[str, frdc.FRDCMatrix],
                          bn_stats: tuple):
    """Serve the forward as ONE Pallas kernel per layer.

    Each layer callable from :func:`repro.models.gnn.bitgnn_layers` is
    traced inside a single ``fused_layer.fused_call`` launch with the
    VALUE-level aggregation backends installed (``serve_kernels(fused=
    True)``) — BN, transform, aggregation and activation all land in one
    kernel body. Traced values (activations, bn stats, FRDC fields) enter
    as kernel operands; concrete weights ride in the layer closures. The
    inter-layer carry is ARRAY-only: a binary carry (gcn "bin" layer 1,
    ``out_scale=False`` => unit scales) crosses the boundary as its packed
    words and is re-wrapped inside the next body — ``BinTensor.n`` must
    stay a python int, which a kernel boundary would not preserve.

    Bitwise identical to the unfused path: the value walks accumulate in
    kernel order, and the BN-site cursor threads across layers at trace
    time exactly as the monolithic forward's ``_BNTap`` does.
    """
    from repro.kernels import fused_layer

    layers = gnn.bitgnn_layers(plan.family, qparams, plan.scheme,
                               plan.trinary_mode)
    key = {"gcn": None, "sage": "mean", "saint": "sum"}[plan.family]
    mats_src = adjs if key is None else {"adj": adjs[key]}
    arrs = {k: frdc_arrays(m) for k, m in mats_src.items()}
    interp = kernel_ops.interpret_mode()

    h = x
    site = 0
    bin_n = None
    meta: dict = {}
    with kernel_ops.serve_kernels(True, block_shape=plan.bspmm_block,
                                  fused=True):
        for fn in layers:
            def body(h_in, stats, ar, fn=fn, start=site, bin_n=bin_n):
                mats = {k: frdc_rebuild(ar[k], mats_src[k].n_rows,
                                        mats_src[k].n_cols, mats_src[k].nnz)
                        for k in ar}
                tap = gnn._BNTap(stats)
                tap._i = start
                hh = h_in
                if bin_n is not None:
                    hh = BinTensor(
                        packed=h_in,
                        scale=jnp.ones((h_in.shape[0], 1), jnp.float32),
                        n=bin_n)
                out = fn(tap, hh, mats)
                meta["site"] = tap._i
                if isinstance(out, BinTensor):
                    meta["bin_n"] = out.n
                    return out.packed
                meta["bin_n"] = None
                return out

            h = fused_layer.fused_call(body, h, bn_stats, arrs,
                                       interpret=interp)
            site, bin_n = meta["site"], meta["bin_n"]
    return h


# ---------------------------------------------------------------------------
# FRDC array (de)serialization — shared by both artifact formats
# ---------------------------------------------------------------------------

def frdc_arrays(m: frdc.FRDCMatrix) -> dict:
    out = dict(tiles=m.tiles, col_idx=m.col_idx, group_row=m.group_row,
               group_first=m.group_first, grp_ptr=m.grp_ptr)
    if m.row_scale is not None:
        out["row_scale"] = m.row_scale
    if m.col_scale is not None:
        out["col_scale"] = m.col_scale
    return out


def frdc_rebuild(arrs: dict, n_rows: int, n_cols: int,
                 nnz: int = 0) -> frdc.FRDCMatrix:
    return frdc.FRDCMatrix(
        tiles=arrs["tiles"], col_idx=arrs["col_idx"],
        group_row=arrs["group_row"], group_first=arrs["group_first"],
        grp_ptr=arrs["grp_ptr"], n_rows=int(n_rows), n_cols=int(n_cols),
        nnz=int(nnz), row_scale=arrs.get("row_scale"),
        col_scale=arrs.get("col_scale"))


# FRDC array fields per adjacency kind of each family — the (deterministic)
# pytree structure of a saved artifact, so load() can build the restore
# template without encoding any adjacency.
FRDC_BASE_FIELDS = ("tiles", "col_idx", "group_row", "group_first", "grp_ptr")
ADJ_SCALE_FIELDS = {
    "gcn": {"adj": ("row_scale", "col_scale"), "bin": ()},
    "sage": {"mean": ("row_scale",)},
    "saint": {"sum": ()},
}


def adj_like(family: str) -> dict:
    return {kind: {f: np.zeros(0) for f in FRDC_BASE_FIELDS + extra}
            for kind, extra in ADJ_SCALE_FIELDS[family].items()}


def coerce_quant(q):
    """Re-type a checkpoint-restored quantized param tree: the static ``n``
    field of each BinTensor round-trips through npz as a 0-d array and must
    come back as a python int (it participates in jit-static shape logic)."""
    from repro.core.binarize import BinTensor
    return type(q)(*(BinTensor(packed=jnp.asarray(t.packed),
                               scale=jnp.asarray(t.scale), n=int(t.n))
                     for t in q))


def feature_fingerprint(x: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(x).tobytes()).hexdigest()[:16]


def session_fingerprint(graph, model) -> dict:
    """Identity of a (graph, model) pair a serving artifact was compiled
    for — THE match key of every artifact restore path (single-host
    ``plan.json`` and sharded ``routing.json`` alike), so it lives here
    once. ``graph``/``model`` are the store's registry entries."""
    d = graph.data
    return dict(graph=graph.name, model=model.name, family=model.family,
                n_nodes=int(d.n_nodes), n_edges=int(d.n_edges),
                features=feature_fingerprint(d.x))


# ---------------------------------------------------------------------------
# Artifact robustness — typed corruption errors for both restore paths
# ---------------------------------------------------------------------------

class ArtifactError(RuntimeError):
    """A serving artifact on disk is CORRUPT (truncated sidecar, unparsable
    JSON, a half-written npz) — as opposed to merely missing or mismatched,
    which the load paths report by returning None so the caller recompiles.
    Corruption must not silently recompile (the artifact the operator
    deployed is broken and someone should know) and must not surface as a
    raw JSONDecodeError/BadZipFile traceback either; it names the file and
    the field that failed."""

    def __init__(self, path, field: str = "", detail: str = ""):
        self.path = str(path)
        self.field = field
        self.detail = detail
        msg = f"corrupt serving artifact {self.path}"
        if field:
            msg += f" (field {field!r})"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


def load_sidecar(path, required: Tuple[str, ...] = ()) -> Optional[dict]:
    """Read an artifact sidecar (``plan.json`` / ``routing.json``). Missing
    file -> None (no artifact: recompile). Unparsable JSON, a non-object
    payload, or a missing required field -> :class:`ArtifactError` naming
    the file and field."""
    import json
    import pathlib
    path = pathlib.Path(path)
    if not path.exists():
        return None
    try:
        sidecar = json.loads(path.read_text())
    except (ValueError, OSError) as e:
        raise ArtifactError(path, field="json", detail=str(e))
    if not isinstance(sidecar, dict):
        raise ArtifactError(path, field="json",
                            detail=f"expected an object, got "
                                   f"{type(sidecar).__name__}")
    for f in required:
        if f not in sidecar:
            raise ArtifactError(path, field=f, detail="missing field")
    return sidecar


def restore_artifact_state(directory, like):
    """Checkpointer restore with typed corruption reporting: None when no
    complete checkpoint exists or its pytree structure mismatches ``like``
    (recompile), :class:`ArtifactError` when the manifest or npz payload is
    present but unreadable (truncated write, bad zip, missing leaves)."""
    import json
    import pathlib
    import zipfile
    from repro.checkpoint.checkpointer import Checkpointer, _flatten
    ckpt = Checkpointer(directory, keep=1)
    step = ckpt.latest_step()
    if step is None:
        return None
    out = pathlib.Path(directory) / f"step_{step:08d}"
    man_path = out / "manifest.json"
    try:
        manifest = json.loads(man_path.read_text())
    except (ValueError, OSError) as e:
        raise ArtifactError(man_path, field="json", detail=str(e))
    for f in ("keys", "n_leaves", "shards"):
        if f not in manifest:
            raise ArtifactError(man_path, field=f, detail="missing field")
    keys, _, treedef = _flatten(like)
    if keys != manifest["keys"]:
        return None                    # structure mismatch: recompile
    npz_path = out / manifest["shards"][0]
    if not npz_path.exists():
        raise ArtifactError(npz_path, field="shards",
                            detail="manifest names a missing shard file")
    try:
        data = np.load(npz_path)
        leaves = [jnp.asarray(data[f"a{i}"])
                  for i in range(int(manifest["n_leaves"]))]
    except (zipfile.BadZipFile, KeyError, ValueError, OSError) as e:
        raise ArtifactError(npz_path, field="leaves", detail=str(e))
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Subgraph adjacency construction (full-graph factorization vectors)
# ---------------------------------------------------------------------------

def sub_adjacency(family: str, n_sub: int, sub_edges: np.ndarray,
                  dinv_sub: Optional[np.ndarray]
                  ) -> Dict[str, frdc.FRDCMatrix]:
    """Per-family subgraph FRDC matrices. ``dinv_sub`` is the FULL-graph
    factorization vector gathered at the subgraph's nodes (GCN: D^-1/2 with
    self-loops; SAGE: D^-1 mean; SAINT: None) so seed-row aggregation is
    identical to the full graph no matter which host gathered it.

    Built NUMPY-backed (``device=False``): this sits in the serving
    pipeline's extract stage, which must stay pure host work — the jit call
    boundary converts the staged arrays at launch."""
    if family == "gcn":
        loops = np.arange(n_sub, dtype=np.int64)
        r = np.concatenate([sub_edges[0], loops])
        c = np.concatenate([sub_edges[1], loops])
        return {
            "adj": frdc.from_coo(r, c, n_sub, n_sub, row_scale=dinv_sub,
                                 col_scale=dinv_sub, device=False),
            "bin": frdc.from_coo(sub_edges[0], sub_edges[1], n_sub, n_sub,
                                 device=False),
        }
    if family == "sage":
        return {"mean": frdc.from_coo(sub_edges[0], sub_edges[1], n_sub,
                                      n_sub, row_scale=dinv_sub,
                                      device=False)}
    return {"sum": frdc.from_coo(sub_edges[0], sub_edges[1], n_sub, n_sub,
                                 device=False)}


def dinv_for_family(family: str, degrees: np.ndarray) -> Optional[np.ndarray]:
    """Full-graph factorization vector from full-graph receiver degrees."""
    if family == "gcn":
        return 1.0 / np.sqrt(degrees + 1.0)          # self-loops included
    if family == "sage":
        return 1.0 / np.maximum(degrees.astype(np.float64), 1.0)
    return None


# ---------------------------------------------------------------------------
# Layer programs — the distributed full pass decomposed into executor steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerStep:
    """One step of a family's distributed layer program.

    A step runs per shard as: optional BN (site ``bn_site`` of the frozen
    calibration tuple — or, in calibrate mode, distributed moments computed
    across shards) -> ``pre`` (dense per-shard transform producing the
    exchange operand + any aux state ``post`` needs) -> halo exchange of the
    operand (bit-packed uint32 words when ``packed``) -> aggregation
    ``intra @ operand + halo @ exchanged`` over adjacency ``kind`` (trinary
    popc counts when ``packed`` — integer partial sums, exact across the
    split) -> ``post(aux, y)`` producing the next carried state. Steps with
    ``kind=None`` skip the exchange/aggregation and feed ``pre``'s operand
    straight to ``post`` (SAINT's trailing FC).

    The carried state between steps is a single array (fp activations, or
    the packed uint32 words of the GCN "bin" scheme's binarized hidden
    layer) so it crosses SPMD program boundaries without pytree gymnastics.

    ``payload_cols``/``payload_itemsize`` describe the exchange operand's
    static row width — the wire-byte schedule of the step
    (``MeshHaloPlan.payload_bytes``), recorded by the executors OUTSIDE any
    trace so jitted steady-state passes account correctly.
    """
    name: str
    kind: Optional[str]
    packed: bool
    bn_site: Optional[int]
    pre: Callable
    post: Callable
    payload_cols: int = 0
    payload_itemsize: int = 4

    @property
    def tag(self) -> str:
        """Halo byte-accounting tag (stable across PR 2's benchmark keys)."""
        return f"{self.name}/{'packed' if self.packed else 'fp'}"


def binarize_counts(counts: jax.Array, n_feat: int) -> BinTensor:
    """Sign-binarize summed trinary counts — the BSpMM.BBB output stage
    (``out_scale=False``: positive scales are elided by the consumer)."""
    counts = counts.astype(jnp.float32)
    if counts.shape[-1] > n_feat:
        counts = counts[:, :n_feat]
    return BinTensor(packed=bitops.sign_bits(counts, axis=-1),
                     scale=jnp.ones((counts.shape[0], 1), counts.dtype),
                     n=n_feat)


def build_layer_program(plan: SessionPlan, q) -> Tuple[LayerStep, ...]:
    """Decompose ``plan``'s family forward into executor layer steps.

    Executing the program per shard with the single-host BN constants is
    arithmetically IDENTICAL to the family's ``*_forward_bitgnn`` over the
    whole graph wherever the aggregation split is exact (binary layers) and
    fp-reassociation-close elsewhere — the same invariant the PR 2
    host-orchestrated pass relied on, now stated once and shared by both
    executors.
    """
    fam = plan.family
    if fam == "gcn" and plan.scheme == "bin":
        n_hidden = int(q.w1.packed.shape[0])
        n_out = int(q.w2.packed.shape[0])

        def pre1(z):
            hb = bmm(z, q.w1, "FBB", out_scale=False)
            return hb.packed, None

        def post1(aux, counts):
            return binarize_counts(counts, n_hidden).packed

        def pre2(st):
            h1 = BinTensor(packed=st,
                           scale=jnp.ones((st.shape[0], 1), jnp.float32),
                           n=n_hidden)
            return bmm(h1, q.w2, "BBF"), None

        return (
            LayerStep("layer1", "bin", True, 0, pre1, post1,
                      payload_cols=-(-n_hidden // 32)),
            LayerStep("layer2", "adj", False, None, pre2,
                      lambda aux, y: y, payload_cols=n_out),
        )
    if fam == "gcn":
        n_hidden = int(q.w1.packed.shape[0])
        n_out = int(q.w2.packed.shape[0])

        def pre_l(w):
            def pre(z):
                return bmm(quantize_act(z), w, "BBF"), None
            return pre

        return (
            LayerStep("layer1", "adj", False, 0, pre_l(q.w1),
                      lambda aux, y: jax.nn.relu(y), payload_cols=n_hidden),
            LayerStep("layer2", "adj", False, 1, pre_l(q.w2),
                      lambda aux, y: y, payload_cols=n_out),
        )

    # sage / saint: self + aggregated branch merged by ADD per layer
    kind = "mean" if fam == "sage" else "sum"

    def branch_pre(w_agg):
        def pre(z):
            xq = quantize_act(z)
            return bmm(xq, w_agg, "BBF"), xq
        return pre

    def branch_post(w_self, relu):
        def post(xq, agg):
            h = bmm(xq, w_self, "BBF") + agg
            return jax.nn.relu(h) if relu else h
        return post

    steps = [
        LayerStep("layer1", kind, False, 0, branch_pre(q.w1_agg),
                  branch_post(q.w1_self, True),
                  payload_cols=int(q.w1_agg.packed.shape[0])),
        LayerStep("layer2", kind, False, 1, branch_pre(q.w2_agg),
                  branch_post(q.w2_self, fam == "saint"),
                  payload_cols=int(q.w2_agg.packed.shape[0])),
    ]
    if fam == "saint":
        steps.append(LayerStep(
            "fc", None, False, 2,
            lambda z: (bmm(quantize_act(z), q.w_fc, "BBF"), None),
            lambda aux, y: y))
    return tuple(steps)


def apply_bn(x: jax.Array, mu: jax.Array, sd: jax.Array) -> jax.Array:
    """Frozen-stats batch norm in the executors' bit-stable form.

    XLA CPU compiles an EAGER broadcast division ``x / sd`` and the same
    division inside a jitted program to differently-rounded code (~1 ulp),
    which would break host-vs-SPMD bit-exactness at every BN site; the
    multiply-by-reciprocal form is bit-stable across both, so both layer
    executors normalize through this helper."""
    return (x - mu) * (1.0 / sd)


# the eps of gnn.bn_stats — shared by BOTH distributed-calibration
# implementations (host partial sums below, SPMD psum moments in
# serve/sharded/executor.py) so the two formulas cannot silently diverge.
BN_EPS = 1e-5


def moments_from_sums(s1, s2, cnt, eps: float = BN_EPS) -> tuple:
    """(mu, sd) from sum / sum-of-squares / count partials — THE formula of
    distributed BN calibration, shared by the host executor (python-summed
    partials) and the SPMD executor (psum-combined partials)."""
    mu = s1 / cnt
    sd = jnp.sqrt(jnp.maximum(s2 / cnt - mu * mu, 0.0)) + eps
    return mu, sd


def distributed_moments(blocks: List[jax.Array],
                        eps: float = BN_EPS) -> tuple:
    """Per-feature (mu, sd) over the GLOBAL node axis from per-shard row
    blocks — the host-side twin of the SPMD executor's psum moments (sum /
    sum-of-squares partials combined across shards), so both executors'
    "distributed" BN calibrations agree to reduction-order tolerance."""
    cnt = float(sum(int(b.shape[0]) for b in blocks))
    s1 = sum(jnp.sum(b, axis=0, keepdims=True) for b in blocks)
    s2 = sum(jnp.sum(b * b, axis=0, keepdims=True) for b in blocks)
    return moments_from_sums(s1, s2, cnt, eps)


class LayerExecutor:
    """Executes a layer program over per-shard feature blocks.

    ``run_pass(program, xs, bn, calibrate=False)`` takes the per-shard
    UNPADDED feature blocks and either the frozen BN tuple (site-indexed) or
    ``calibrate=True`` to compute the stats from the pass itself; returns
    ``(per-shard output blocks, collected stats or None)``. Implementations:
    :class:`repro.serve.sharded.executor.HostLayerExecutor` (eager per-shard
    stages, PR 2 semantics — the bit-exactness reference) and
    :class:`repro.serve.sharded.executor.SpmdLayerExecutor` (one
    ``shard_map`` program per layer, fused halo exchange, psum BN moments).
    """
    name = "?"

    @property
    def compile_count(self) -> int:
        """Traces of the executor's jitted layer programs — constant after
        the first pass (zero steady-state recompiles)."""
        return 0

    def run_pass(self, program, xs, bn, calibrate: bool = False):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# ServeCore — the bucket-shaped jitted subgraph forward
# ---------------------------------------------------------------------------

class ServeCore:
    """One jitted bucketed forward + its high-water shape buckets.

    The core owns the family-AGNOSTIC serving machinery: the jit cache and
    its trace counter, the high-water pow2 buckets, async launch/finish,
    and multi-bucket co-launch. What a launch actually computes is the
    ``adapter``'s business (:class:`repro.serve.adapters.ModelFamilyAdapter`
    — quantize, traced body, operand padding, result crop); when no adapter
    is given a :class:`~repro.serve.adapters.GNNAdapter` is built from the
    plan, which keeps every pre-existing call site bitwise unchanged.

    For the GNN adapter: node and FRDC group counts are padded up to pow2
    marks that only ever grow (capped at ``node_cap``), so the jitted
    forward converges to one steady padded shape after a short warmup and
    never recompiles in steady state. ``compile_count`` counts jit traces
    (python side effect on trace) and IS the verification counter. Both the
    single-host session and every shard of a sharded session own exactly
    one of these; a token session owns one running its chunked decode.
    """

    NODE_BUCKET_FLOOR = 64
    GROUP_BUCKET_FLOOR = 16

    def __init__(self, plan: SessionPlan, qparams, max_batch: int,
                 node_cap: int, use_pallas: bool = False, adapter=None):
        if adapter is None:
            from .adapters import GNNAdapter
            adapter = GNNAdapter(plan)
        self.adapter = adapter
        self.plan = plan
        self.qparams = qparams
        self.max_batch = max_batch
        self.node_cap = node_cap
        self.use_pallas = use_pallas
        self._n_traces = 0
        # observability hook: called as on_trace(shape_key_dict) whenever a
        # launch triggers a NEW jit trace (the engines' recompile watchdog
        # wires this via the sessions' set_trace_hook)
        self.on_trace = None
        # high-water shape buckets: node and group pads only ever GROW (in
        # pow2 steps, capped at node_cap), so serving stops recompiling —
        # warmup is a handful of max-width batches, not a shape sweep.
        self._n_water = 0
        self._g_water: Dict[Tuple[int, str], int] = {}
        self._jit_serve = jax.jit(self._serve)
        self._jit_serve_many = jax.jit(self._serve_many)
        # device dispatches issued (a launch_many of K buckets counts 1) —
        # the launches-per-tick regression metric benches and tests key on
        self.n_dispatches = 0

    @property
    def compile_count(self) -> int:
        return self._n_traces

    def _serve(self, x, bn, adjs, seeds):
        self._n_traces += 1
        return self._serve_one(x, bn, adjs, seeds)

    def _serve_one(self, x, bn, adjs, seeds):
        return self.adapter.serve_body(self, x, bn, adjs, seeds)

    def _serve_many(self, batches):
        """K bucketed forwards UNROLLED into one jitted program (one device
        dispatch serves K staged buckets). Unrolled — not vmapped — so each
        batch's sub-jaxpr is exactly ``_serve_one``'s and the K outputs stay
        bitwise identical to K serial launches; buckets of different padded
        shapes (and different captured ``bn``) co-launch freely. One program
        trace counts as ONE compile regardless of K."""
        self._n_traces += 1
        return tuple(self._serve_one(x, bn, adjs, seeds)
                     for (x, bn, adjs, seeds) in batches)

    def _pad_mats(self, mats: Dict[str, frdc.FRDCMatrix], n_sub: int):
        return self.adapter.pad_operands(self, mats, n_sub)

    def stage(self, x_sub: np.ndarray, mats: Dict[str, frdc.FRDCMatrix],
              seed_pos: np.ndarray) -> "StagedBatch":
        """EXTRACT-stage tail: bucket-pad one extracted subgraph into the
        launch-ready host arrays. Pure host work (the water-mark update
        happens here, so staging order — not launch order — is what the
        zero-recompile guarantee keys on)."""
        n_pad, adjs = self._pad_mats(mats, x_sub.shape[0])
        x_pad = np.zeros((n_pad, x_sub.shape[1]), np.float32)
        x_pad[:x_sub.shape[0]] = x_sub
        pos_pad = np.zeros((self.max_batch,), np.int32)
        pos_pad[:seed_pos.size] = seed_pos
        return StagedBatch(x_pad=x_pad, adjs=adjs, pos_pad=pos_pad,
                           n_seeds=int(seed_pos.size))

    def launch(self, staged: "StagedBatch", bn: tuple) -> jax.Array:
        """COMPUTE-stage head: dispatch the jitted bucketed forward. Under
        jax's async dispatch this returns before the device finishes, so the
        caller can overlap the next batch's extraction with it."""
        c0 = self._n_traces
        self.n_dispatches += 1
        out = self._jit_serve(jnp.asarray(staged.x_pad), bn, staged.adjs,
                              jnp.asarray(staged.pos_pad))
        if self._n_traces > c0 and self.on_trace is not None:
            # a NEW trace: report the offending shape key (the padded dims
            # that define the jit cache entry)
            self.on_trace(self.adapter.trace_shape(staged))
        return out

    def launch_many(self, entries: List[Tuple["StagedBatch", tuple]]
                    ) -> List[jax.Array]:
        """Dispatch SEVERAL staged buckets as one jitted program (one device
        dispatch, K results). ``entries``: (staged, bn) pairs — each bucket
        launches under its own captured calibration. Bitwise identical to K
        serial :meth:`launch` calls (the program is the K ``_serve_one``
        bodies unrolled); the jit cache keys on the (K, shapes) pytree, so a
        workload whose tick widths vary pays one extra trace per distinct
        composition during warmup."""
        if len(entries) == 1:
            staged, bn = entries[0]
            return [self.launch(staged, bn)]
        c0 = self._n_traces
        self.n_dispatches += 1
        batches = tuple(
            (jnp.asarray(s.x_pad), bn, s.adjs, jnp.asarray(s.pos_pad))
            for s, bn in entries)
        outs = self._jit_serve_many(batches)
        if self._n_traces > c0 and self.on_trace is not None:
            self.on_trace(self.adapter.trace_shape_many(
                [s for s, _ in entries]))
        return list(outs)

    def finish(self, out_dev: jax.Array, staged: "StagedBatch") -> np.ndarray:
        """COMPUTE-stage tail: block on the device result and crop it back
        to host answers (GNN: the seed rows)."""
        return self.adapter.finish(out_dev, staged)

    def run(self, x_sub: np.ndarray, mats: Dict[str, frdc.FRDCMatrix],
            seed_pos: np.ndarray, bn: tuple) -> np.ndarray:
        """Serial stage -> launch -> finish of one extracted subgraph.

        ``x_sub``: (n_sub, F) features of the subgraph nodes (global order);
        ``seed_pos``: positions of the seeds inside the subgraph. Returns
        (len(seed_pos), n_out) logits. The pipelined engine calls the three
        stages itself; composing them here keeps serial and pipelined
        serving bit-exact by construction.
        """
        staged = self.stage(x_sub, mats, seed_pos)
        return self.finish(self.launch(staged, bn), staged)

    def preset_water(self, n_max: int, g_max: Dict[str, int],
                     margin: float) -> None:
        """Set the water marks ``margin`` above probed maxima (pow2-rounded);
        a workload batch can only recompile by exceeding the margined bucket,
        and the monotone water then absorbs it after one compile."""
        n_pad = bucket_pow2(min(int(n_max * margin), self.node_cap),
                            self.NODE_BUCKET_FLOOR, self.node_cap)
        self._n_water = max(self._n_water, n_pad)
        for k, g in g_max.items():
            wkey = (self._n_water, k)
            g_pad = bucket_pow2(int(g * margin), self.GROUP_BUCKET_FLOOR)
            self._g_water[wkey] = max(self._g_water.get(wkey, 0), g_pad)


# ---------------------------------------------------------------------------
# Prepared batches — the extract-stage output of the serving pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StagedBatch:
    """One bucket-padded subgraph, ready for :meth:`ServeCore.launch`."""
    x_pad: np.ndarray               # (n_pad, F) zero-padded features
    adjs: Dict[str, dict]           # padded FRDC arrays per adjacency kind
    pos_pad: np.ndarray             # (max_batch,) seed positions, padded
    n_seeds: int


@dataclasses.dataclass
class PreparedGroup:
    """One serve core's share of a prepared batch: the staged subgraph of
    the uniq-seed subset ``sel`` (single-host sessions have one group; a
    sharded session one per owning shard)."""
    core: ServeCore
    sel: np.ndarray                 # positions inside the batch's uniq seeds
    staged: StagedBatch


@dataclasses.dataclass
class PreparedBatch:
    """Extract-stage output for one micro-batch of seeds: everything the
    compute stage needs, produced WITHOUT any device work — so building one
    can overlap the previous batch's in-flight jitted forward.

    ``inverse`` maps the uniq-seed rows back to request order;
    ``out_shape`` is the per-seed logits shape (used when ``groups`` is
    empty, i.e. zero seeds). ``bn`` is the frozen calibration CAPTURED AT
    EXTRACT TIME: a pipelined engine may see a feature update (and
    recalibration) land between staging batch i and launching it, and the
    launch must use the constants the features were staged under — never
    the session's live ``bn``."""
    n_uniq: int
    inverse: np.ndarray
    groups: List[PreparedGroup]
    out_shape: Tuple[int, ...] = ()
    bn: Optional[tuple] = None

    def launch(self) -> List[jax.Array]:
        """Dispatch every group's jitted forward (async under jax dispatch)
        with the CAPTURED calibration; returns the in-flight device results
        in group order. Deliberately takes no ``bn`` argument — passing the
        session's live stats here is exactly the staleness bug the capture
        prevents."""
        return [g.core.launch(g.staged, self.bn) for g in self.groups]

    def finish(self, devs: List[jax.Array]) -> np.ndarray:
        """Block on the device results and reassemble request-order logits."""
        out: Optional[np.ndarray] = None
        for g, dv in zip(self.groups, devs):
            logits = g.core.finish(dv, g.staged)
            if out is None:
                out = np.zeros((self.n_uniq,) + logits.shape[1:],
                               logits.dtype)
            out[g.sel] = logits
        if out is None:
            out = np.zeros((self.n_uniq,) + tuple(self.out_shape),
                           np.float32)
        return out[self.inverse]


def launch_prepared_many(prepared: List[PreparedBatch]
                         ) -> List[List[jax.Array]]:
    """Co-dispatch several prepared batches: every staged group is bucketed
    by its owning :class:`ServeCore` and each core issues ONE
    :meth:`ServeCore.launch_many` dispatch for its whole share — one device
    dispatch per core per tick instead of one per batch. Returns the
    per-batch device-handle lists in exactly the order
    ``[p.launch() for p in prepared]`` would, and each handle is bitwise
    identical to what the serial launches produce (the co-launched program
    is the serial bodies unrolled). Groups keep their batch's CAPTURED
    ``bn`` — co-launching never re-reads live calibration."""
    by_core: Dict[int, Tuple[ServeCore, list]] = {}
    slots: List[List[Optional[jax.Array]]] = []
    for bi, p in enumerate(prepared):
        slots.append([None] * len(p.groups))
        for gi, g in enumerate(p.groups):
            _, entries = by_core.setdefault(id(g.core), (g.core, []))
            entries.append((g.staged, p.bn, bi, gi))
    for core, entries in by_core.values():
        outs = core.launch_many([(s, bn) for s, bn, _, _ in entries])
        for (_, _, bi, gi), dv in zip(entries, outs):
            slots[bi][gi] = dv
    return slots


# ---------------------------------------------------------------------------
# Plan selection (default + tuner; paper §3.4)
# ---------------------------------------------------------------------------

def default_plan(family: str) -> SessionPlan:
    if family == "gcn":
        return SessionPlan(family, "bin",
                           layer_variants=GCN_SCHEME_VARIANTS["bin"])
    return SessionPlan(family, "fixed")


def tune_plan(data, family: str, qparams, repeats: int = 2) -> SessionPlan:
    """Time the legal end-to-end variant assignments on the actual graph
    (paper §3.4) and pick the fastest. ``data``: the host GraphData."""
    x = jnp.asarray(data.x)
    if family == "gcn":
        adj, adj_bin = data.adjacency("gcn"), data.adjacency("binary")
        cands = [
            tuner.Candidate(GCN_SCHEME_VARIANTS["full"], "s3_two_popc"),
            tuner.Candidate(GCN_SCHEME_VARIANTS["bin"], "s3_two_popc"),
            tuner.Candidate(GCN_SCHEME_VARIANTS["bin"], "s2_and_andnot"),
        ]

        def build(cand):
            scheme = ("bin" if cand.layer_variants[0][0] == "BMM.FBB"
                      else "full")
            def fwd(xx):
                return gnn.gcn_forward_bitgnn(
                    qparams, xx, adj, adj_bin, scheme=scheme,
                    trinary_mode=cand.trinary_mode)
            return fwd
    else:
        adj = data.adjacency("mean" if family == "sage" else "binary")
        fwd_fn = (gnn.sage_forward_bitgnn if family == "sage"
                  else gnn.saint_forward_bitgnn)
        cands = [tuner.Candidate(FIXED_VARIANTS, TRINARY_DEFAULT)]

        def build(cand):
            def fwd(xx):
                return fwd_fn(qparams, xx, adj)
            return fwd

    results = tuner.tune(build, (x,), cands, repeats=repeats)
    best = results[0]
    scheme = "fixed"
    if family == "gcn":
        scheme = ("bin" if best.candidate.layer_variants[0][0] == "BMM.FBB"
                  else "full")
    return SessionPlan(
        family=family, scheme=scheme,
        trinary_mode=best.candidate.trinary_mode,
        layer_variants=best.candidate.layer_variants,
        tuned_latency_s=best.latency_s,
        output_delta=best.output_delta)
