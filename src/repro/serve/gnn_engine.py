"""GNN serving engine: micro-batched node-level query scheduler.

Requests (``NodeQuery``: answer node-classification for one node of one
registered graph under one registered model) join per-session FIFO queues.
The serving hot path is an explicit two-stage pipeline:

  * **extract** — queue pick (incremental oldest-head heap) -> batch
    formation -> deterministic k-hop extraction around the batch's seeds ->
    subgraph FRDC build -> shape-bucket padding. Pure host work: producing a
    :class:`~repro.serve.session_core.PreparedBatch` touches no device.
  * **compute** — launch the jitted bucketed forward (async under jax
    dispatch), block on the result, gather per-query logits.

With ``pipeline_depth == 0`` (the default) each :meth:`tick` runs both
stages back-to-back — the serial loop. With ``pipeline_depth >= 1`` the
extract stage runs on a background worker and up to ``pipeline_depth``
launched forwards stay in flight, so extraction of batch *i+1* overlaps the
device computation of batch *i* (double-buffering at depth 1). Both loops
drive the SAME session stages in the SAME batch order, so their outputs are
bit-exact — the pipeline changes when work happens, never what is computed.

Two serve paths per batch: **full-cache** (the session's cached full-graph
inference; a numpy gather, resolved entirely in the extract stage) and
**micro-batched subgraph** (the prepared-batch path above). ``mode="auto"``
uses the full cache below ``full_cache_max_nodes`` and the subgraph path
above it. Latency is measured submit -> answer, so queueing delay is
included (p50/p99 are end-to-end); per-batch extract/compute stage times
and the overlap ratio land in :class:`~repro.serve.metrics.ServeMetrics`.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .admission import (DEFAULT_TENANT, SHED, AdmissionController,
                        AdmissionDecision)
from .cost import CostEstimate, CostEstimator
from .gnn_session import CompiledGraphSession, GraphStore
from .metrics import ServeMetrics
from .session_core import FAMILY_AGG_LAYERS, launch_prepared_many
from .slo import SLOTracker
from .trace import RecompileWatchdog, SpanTracer, TransferWatchdog


@dataclasses.dataclass(frozen=True)
class QueryFailure:
    """Typed terminal failure of one accepted query: the engine retried its
    batch ``attempts`` times and gave up (``reason="max_retries"``), so the
    query is dropped with this record attached instead of wedging the
    pipeline forever. ``stage`` names the pipeline stage of the final
    error, ``error`` its repr."""
    reason: str
    stage: str
    attempts: int
    error: str


@dataclasses.dataclass
class DrainReport:
    """Outcome of one :meth:`GNNServeEngine.drain`: queries answered during
    the drain window, accepted-but-unserved queries typed-shed at the
    deadline, queries that exhausted their retries while draining, and
    whether the deadline fired at all."""
    answered: int
    shed: int
    failed: int
    elapsed_s: float
    timed_out: bool

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class NodeQuery:
    """One node-classification request and, once served, its answer.

    ``tenant`` tags the submitter for admission control and weighted
    scheduling; ``admission`` is the typed decision its submission drew —
    a throttled/shed query is returned immediately (``rejected`` True,
    never queued, never ``done``) so the caller can back off or retry."""
    graph: str
    model: str
    node: int
    qid: int = -1
    t_submit: float = 0.0
    t_done: float = 0.0
    logits: Optional[np.ndarray] = None
    pred: Optional[int] = None
    tenant: str = DEFAULT_TENANT
    admission: Optional[AdmissionDecision] = None
    # submit-time predicted cost (None when the engine has no estimator)
    cost: Optional[CostEstimate] = None
    # trace context: submit() stamps qid/t_submit/admission above; when the
    # query is picked into a batch this links it to that batch's BatchTrace
    trace_id: int = -1
    # bounded-retry state: service attempts this query's batches have
    # burned, and the typed terminal failure once they exceed max_retries
    attempts: int = 0
    failure: Optional[QueryFailure] = None

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def done(self) -> bool:
        return self.pred is not None

    @property
    def rejected(self) -> bool:
        return self.admission is not None and not self.admission.accepted

    @property
    def failed(self) -> bool:
        """Accepted but terminally dropped (retries exhausted)."""
        return self.failure is not None

    @property
    def settled(self) -> bool:
        """Nothing more will happen to this query: answered, rejected at
        admission, or terminally failed."""
        return self.done or self.rejected or self.failed


@dataclasses.dataclass
class _Inflight:
    """One micro-batch moving through the pipeline: extract-stage output
    plus the in-flight device handles the compute stage fills in."""
    key: tuple
    batch: List[NodeQuery]
    session: object
    seeds: np.ndarray
    prepared: object                  # PreparedBatch, or None = full-cache
    result: Optional[np.ndarray]      # full-cache answer (extract-resolved)
    t_start: float
    extract_s: float
    t_launch: float = 0.0
    t_launch_end: float = 0.0
    devs: Optional[list] = None
    trace: Optional[object] = None    # BatchTrace (when tracing is on)
    coalesced: int = 1                # buckets sharing this batch's dispatch


class GNNServeEngine:
    """Micro-batching scheduler over a :class:`GraphStore`'s sessions."""

    # model-family namespace: stamped on the metrics snapshot (and from
    # there onto every Prometheus series) and on watchdog warning events,
    # so a GNN engine and a token engine exported from one process never
    # collide. Subclasses override (TokenServeEngine: per-store kind).
    family = "gnn"

    def __init__(self, store: GraphStore, max_batch: Optional[int] = None,
                 mode: str = "auto", full_cache_max_nodes: int = 200_000,
                 keep_finished: int = 100_000, pipeline_depth: int = 0,
                 admission: Optional[AdmissionController] = None,
                 tracer: Optional[SpanTracer] = None, trace: bool = True,
                 cost: Optional[CostEstimator] = None,
                 slo: Optional[SLOTracker] = None,
                 multi_bucket: bool = False, faults=None,
                 max_retries: int = 8, retry_backoff_s: float = 0.05,
                 retry_backoff_max_s: float = 2.0):
        if mode not in ("auto", "full", "subgraph"):
            raise ValueError(mode)
        if max_retries < 1:
            raise ValueError(f"max_retries must be >= 1, got {max_retries}")
        self.store = store
        self.max_batch = max_batch or store.max_batch
        if self.max_batch > store.max_batch:
            raise ValueError(
                f"engine max_batch {self.max_batch} exceeds the store's "
                f"session seed-slot width {store.max_batch}")
        self.mode = mode
        self.full_cache_max_nodes = full_cache_max_nodes
        self.pipeline_depth = int(pipeline_depth)
        # multi-bucket co-launch: a pipelined fill defers its launches and
        # dispatches every newly extracted bucket as ONE jitted program per
        # serve core (ServeCore.launch_many) — fewer dispatches per tick,
        # bit-exact vs serial launches. Needs pipeline_depth >= 2 to ever
        # coalesce; no effect on the serial (depth 0) loop.
        self.multi_bucket = bool(multi_bucket)
        self.metrics = ServeMetrics(family=self.family)
        self._queues: Dict[tuple, Deque[NodeQuery]] = {}
        self._next_qid = 0
        # queue-structure guard: the pipelined extract stage (pick + pop)
        # runs on the background worker concurrently with submit()
        self._qlock = threading.Lock()
        # tenancy: admission decisions at submit + the weighted virtual-time
        # scheduler that generalizes the old lazy oldest-head heap (every
        # mutating call happens under _qlock). The default controller admits
        # everything and weights every tenant equally — the pre-tenancy
        # engine behavior.
        self.admission = admission or AdmissionController()
        # pipeline state: one background extraction + launched batches
        self._pool: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._extract_future = None
        self._inflight: Deque[_Inflight] = deque()
        self._last_done = 0.0        # completion clock (compute attribution)
        self._unanswered = 0         # queued + in-flight (drain condition)
        # bounded: callers hold the authoritative NodeQuery objects from
        # submit(); this is a convenience tail for drain-style use, not an
        # unbounded log of every answer a long-running engine ever produced
        self.finished: Deque[NodeQuery] = deque(maxlen=keep_finished)
        # served batch compositions (most recent), the replay source for
        # bit-exactness oracles under reordering batch formation
        self.batch_log: Deque[List[NodeQuery]] = deque(maxlen=4096)
        # observability: the span tracer (every served batch flows through
        # it; retention is sampled) and the two serving watchdogs. Pass
        # trace=False to make the whole layer a no-op, or inject a
        # configured SpanTracer (capacity/sampling) to share one ring
        # buffer across engines.
        self.tracer = tracer if tracer is not None \
            else SpanTracer(enabled=trace)
        self.recompile_watchdog = RecompileWatchdog(self.tracer,
                                                    family=self.family)
        self.transfer_watchdog = TransferWatchdog(self.tracer,
                                                  family=self.family)
        self._wired_sessions: set = set()
        # closed-loop cost/SLO observability (both opt-in; None preserves
        # the cost-unaware engine exactly): the estimator predicts each
        # submission's cost units from host statics — admission charges
        # them, fair queueing weights by them, and measured batch time
        # calibrates them — while the SLO tracker turns the answered/
        # rejected stream into error budgets that feed back into admission
        # depth. Both are driven under _qlock.
        self.cost = cost
        self.slo = slo
        if slo is not None and slo.tracer is None:
            slo.tracer = self.tracer
        # chaos seam: a replica.FaultInjector (duck-typed: anything with
        # check(op, scope=...)) consulted at the extract/launch/complete
        # stage boundaries; None = no injection. fault_scope tags this
        # engine's checks (the replica tier sets it to the replica name so
        # per-replica fault rules match).
        self.faults = faults
        self.fault_scope: Optional[str] = None
        # bounded retry: a requeued batch backs its queue off exponentially
        # (+ deterministic jitter) and each member query burns one attempt;
        # past max_retries the query is dropped with a typed QueryFailure
        # instead of requeueing forever
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.retry_backoff_max_s = float(retry_backoff_max_s)
        self._retry_rng = np.random.default_rng(0)
        self._backoff: Dict[tuple, float] = {}   # key -> pickable-again time
        # drain/evacuate state: a draining engine typed-sheds new intake
        self._draining = False

    # ------------------------------------------------------------ intake ----
    def submit(self, graph: str, model: str, node: int,
               tenant: str = DEFAULT_TENANT) -> NodeQuery:
        """Enqueue one node query for ``tenant``. Request validation raises
        here (a malformed request is the submitting caller's bug); admission
        outcomes do NOT — a throttled or shed query comes back immediately
        with its typed :class:`AdmissionDecision` attached and is never
        queued, so one tenant over quota can never crash (or clog) a tick
        that is also carrying other tenants' queries."""
        if graph not in self.store.graphs:
            raise KeyError(f"unknown graph {graph!r}; "
                           f"have {sorted(self.store.graphs)}")
        if model not in self.store.models:
            raise KeyError(f"unknown model {model!r}; "
                           f"have {sorted(self.store.models)}")
        n = self.store.graphs[graph].data.n_nodes
        node = int(node)
        if not 0 <= node < n:
            raise ValueError(f"node {node} out of range for graph "
                             f"{graph!r} with {n} nodes")
        q = NodeQuery(graph=graph, model=model, node=node, tenant=tenant)
        # cost prediction is pure host work over cached topology statics —
        # never under the lock (first touch of a node walks its closure)
        q.cost = self._estimate_cost(graph, model, node)
        return self._admit_enqueue(q, self._queue_key(graph, model, node,
                                                      tenant))

    def _admit_enqueue(self, q, key: tuple):
        """Family-neutral intake tail shared by every engine's ``submit``:
        stamp the qid, run admission under the queue lock, and enqueue on
        acceptance. ``q`` needs the query protocol fields (tenant, cost,
        admission, t_submit) and ``q.cost`` already estimated."""
        tenant = q.tenant
        q.qid, self._next_qid = self._next_qid, self._next_qid + 1
        charge = q.cost.units if q.cost is not None else 1.0
        with self._qlock:
            q.t_submit = time.perf_counter()
            if self._draining:
                # intake is stopped (drain/evacuation): typed shed without
                # burning the tenant's tokens — resume_intake() re-opens
                q.admission = AdmissionDecision(
                    SHED, tenant, reason="engine draining: intake stopped")
                self.metrics.record_admission(tenant, SHED)
                return q
            q.admission = self.admission.admit(tenant, q.t_submit,
                                               cost=charge)
            self.metrics.record_admission(
                tenant, q.admission.action,
                cost=(charge if q.admission.accepted else 0.0),
                cost_limited=q.admission.cost_limited)
            if not q.admission.accepted:
                if self.slo is not None:
                    self.slo.observe(tenant, q.t_submit, rejected=True)
                    self.slo.check(q.t_submit, self.admission)
                return q
            self.admission.on_enqueued(tenant)
            dq = self._queues.setdefault(key, deque())
            dq.append(q)
            self._unanswered += 1
            if len(dq) == 1:                  # q became a queue head
                self._heap_push(key, q.t_submit)
        self.metrics.start_clock()
        return q

    def _queue_key(self, graph: str, model: str, node: int,
                   tenant: str = DEFAULT_TENANT) -> tuple:
        """Queue routing hook: one FIFO per (graph, model, tenant) here; the
        sharded engine additionally keys by the node's owning shard so every
        served micro-batch is a single-owner group. The tenant is always the
        LAST key component (the admission controller's convention), so
        batches never mix tenants — per-tenant latency attribution and the
        sharded engine's single-owner co-batching both survive tenancy."""
        return (graph, model, tenant)

    # ------------------------------------------------------- cost model ----
    def _estimate_cost(self, graph: str, model: str,
                       node: int) -> Optional[CostEstimate]:
        """Submit-time cost prediction (None without an estimator). Pure
        host statics: the graph entry's cached CSR index, the model
        family's aggregation depth, and the halo-row hook — no session is
        resolved, so submit never compiles anything."""
        if self.cost is None:
            return None
        entry = self.store.graphs[graph]
        if self.mode == "full" or (
                self.mode == "auto"
                and entry.data.n_nodes <= self.full_cache_max_nodes):
            return self.cost.estimate(graph, node, entry.csr,
                                      full_cache=True)
        family = self.store.models[model].family
        halo_rows, row_bytes = self._cost_halo_rows(graph, model, node)
        return self.cost.estimate(
            graph, node, entry.csr,
            khop=FAMILY_AGG_LAYERS.get(family, 2),
            halo_rows=halo_rows, row_bytes=row_bytes)

    def _cost_halo_rows(self, graph: str, model: str,
                        node: int) -> Tuple[int, int]:
        """(halo feature rows, bytes per row) the query's seed will pull
        from remote shards — 0 on the single-host path; the sharded engine
        overrides this from its static halo signatures."""
        return 0, 0

    def submit_many(self, graph: str, model: str, nodes: np.ndarray,
                    tenant: str = DEFAULT_TENANT) -> List[NodeQuery]:
        return [self.submit(graph, model, n, tenant=tenant)
                for n in np.asarray(nodes)]

    @property
    def pending(self) -> int:
        """Queries not yet ANSWERED — queued plus extracted/launched
        in-flight batches, so the classic ``while engine.pending:
        engine.tick()`` drain idiom cannot exit with launched batches
        still unanswered under pipelining."""
        with self._qlock:
            return self._unanswered

    def _queued(self) -> int:
        """Queries still sitting in the queues (the pipeline fill check —
        in-flight batches are NOT re-extractable)."""
        with self._qlock:
            return sum(len(q) for q in self._queues.values())

    def _sessions(self):
        """The store sessions this engine class serves from (the sharded
        engine overrides this to its partitioned sessions)."""
        return self.store._sessions.values()

    @property
    def compile_count(self) -> int:
        """Total jit traces across all sessions this engine has touched —
        the 'zero steady-state recompiles' acceptance counter."""
        return sum(s.compile_count for s in self._sessions())

    @property
    def dispatch_count(self) -> int:
        """Total device dispatches across the engine's sessions — the
        launches-per-tick regression counter (a multi-bucket co-launch of K
        buckets moves this by 1, not K)."""
        return sum(getattr(s, "dispatch_count", 0)
                   for s in self._sessions())

    # --------------------------------------------------------- scheduling ---
    def _heap_push(self, key: tuple, t: float) -> None:
        self.admission.push_head(key, key[-1], t)

    def _pick_queue(self) -> Optional[tuple]:
        """Next queue to serve (caller holds ``_qlock``): the admission
        controller's weighted virtual-time pick — oldest head within a
        tenant, weighted fair across tenants, overdue heads (past the
        staleness bound) globally FIFO. With a single tenant this is
        exactly the old lazy oldest-head heap pick.

        Queues inside a retry-backoff window are invisible to the pick (and
        to the staleness preemption — the backoff must win, and it is
        bounded by ``retry_backoff_max_s``); when a window expires its
        queue's head is re-pushed, since the scheduler's lazy heaps may
        have dropped it while the queue looked empty."""
        queues = self._queues
        if self._backoff:
            now = time.perf_counter()
            for k in [k for k, t in self._backoff.items() if t <= now]:
                del self._backoff[k]
                dq = self._queues.get(k)
                if dq:
                    self._heap_push(k, dq[0].t_submit)
            if self._backoff:
                queues = {k: dq for k, dq in self._queues.items()
                          if k not in self._backoff}
        return self.admission.pick(queues)

    def _backoff_hold_s(self) -> Optional[float]:
        """Seconds until the earliest backed-off queue with live work
        becomes pickable — but only when backed-off queues are the ONLY
        queued work (None otherwise): the drain loops sleep on this instead
        of spinning against an all-backed-off queue set."""
        with self._qlock:
            if not self._backoff:
                return None
            if any(dq and k not in self._backoff
                   for k, dq in self._queues.items()):
                return None
            held = [t for k, t in self._backoff.items()
                    if self._queues.get(k)]
            if not held:
                return None
            return max(0.0, min(held) - time.perf_counter())

    def _pop_batch(self, key: tuple, session) -> List[NodeQuery]:
        """Batch formation (caller holds ``_qlock``): FIFO pop of up to
        ``max_batch`` head requests. The sharded engine overrides this with
        halo-aware formation (``session`` is the already-resolved serving
        session, so no session work happens under the lock)."""
        dq = self._queues[key]
        return [dq.popleft() for _ in range(min(self.max_batch, len(dq)))]

    def _requeue(self, key: tuple, batch: List[NodeQuery],
                 stage: str = "", error: str = "") -> None:
        """Restore a popped-but-unserved batch to the FRONT of its queue
        (extract/compute failure path: the queries must not be lost) —
        under the BOUNDED retry discipline: each member query burns one
        attempt; queries past ``max_retries`` are dropped with a typed
        :class:`QueryFailure` (counted in ``metrics.retry_shed``) instead
        of requeueing forever, and the survivors' queue backs off
        exponentially with deterministic jitter before it becomes pickable
        again — a poison batch can no longer wedge the pipeline or starve
        its neighbors by hot-spinning the retry path."""
        now = time.perf_counter()
        survivors: List[NodeQuery] = []
        exhausted: List[NodeQuery] = []
        for q in batch:
            q.attempts += 1
            (exhausted if q.attempts > self.max_retries
             else survivors).append(q)
        with self._qlock:
            self.metrics.requeues += 1
            if survivors:
                dq = self._queues.setdefault(key, deque())
                for q in reversed(survivors):
                    dq.appendleft(q)
                self.admission.on_requeued(key[-1], len(survivors))
                self._heap_push(key, dq[0].t_submit)
                attempt = max(q.attempts for q in survivors)
                delay = min(self.retry_backoff_max_s,
                            self.retry_backoff_s * 2.0 ** (attempt - 1))
                delay *= 1.0 + 0.5 * float(self._retry_rng.random())
                self._backoff[key] = max(self._backoff.get(key, 0.0),
                                         now + delay)
            for q in exhausted:
                q.failure = QueryFailure(reason="max_retries", stage=stage,
                                         attempts=q.attempts, error=error)
                q.t_done = now
                self.metrics.retry_shed += 1
                self._unanswered -= 1
                self.finished.append(q)
                if self.slo is not None:
                    self.slo.observe(q.tenant, now, rejected=True)
        if exhausted:
            self.tracer.event(
                "retry_exhausted", key=list(key), stage=stage, error=error,
                qids=[q.qid for q in exhausted],
                attempts=exhausted[0].attempts)

    def _check_fault(self, op: str) -> None:
        """Chaos seam: consult the injected FaultInjector (if any) at a
        stage boundary — a matching rule raises InjectedFault, which flows
        through the SAME requeue/retry path as a real stage failure."""
        if self.faults is not None:
            self.faults.check(op, scope=self.fault_scope)

    def _use_full_cache(self, session) -> bool:
        if self.mode == "full":
            return True
        if self.mode == "subgraph":
            return False
        return session.graph.data.n_nodes <= self.full_cache_max_nodes

    def _get_session(self, key: Tuple[str, ...]):
        """Resolve a queue key (first two entries: graph, model) to the
        session answering it (hook: the sharded engine resolves to a
        partitioned session instead)."""
        return self.store.session(*key[:2])

    def _wire_session(self, session):
        """Wire the recompile watchdog into a session's jit-trace hook the
        first time this engine touches it (idempotent per session)."""
        if id(session) not in self._wired_sessions:
            self._wired_sessions.add(id(session))
            set_hook = getattr(session, "set_trace_hook", None)
            if set_hook is not None:
                set_hook(self.recompile_watchdog.on_recompile)
        return session

    # ------------------------------------------------------ trace hooks ----
    def _trace_shard(self, key: tuple) -> Optional[int]:
        """Owning shard of a queue key (None here; the sharded engine keys
        queues by owner)."""
        return None

    def _trace_bucket(self, prepared) -> dict:
        """Launch-shape summary of a PreparedBatch for its trace."""
        if prepared is None:
            return {}
        return dict(groups=[
            dict(n_pad=int(g.staged.x_pad.shape[0]),
                 g_pad={str(k): int(a["group_row"].shape[0])
                        for k, a in g.staged.adjs.items()})
            for g in prepared.groups])

    def _trace_halo_begin(self, session):
        """Pre-extraction token for per-batch halo attribution (the sharded
        engine snapshots the serve-path halo byte counters here)."""
        return None

    def _trace_halo_end(self, session, token) -> dict:
        return {}

    # ------------------------------------------------------------- stages ---
    def _extract_stage(self) -> Optional[_Inflight]:
        """EXTRACT: queue pick -> batch formation -> k-hop extraction ->
        FRDC build -> bucket pad. Pure host work — the pipelined engine runs
        this on the background worker while the previous batch's jitted
        forward is in flight. Full-cache batches resolve entirely here (the
        cached pass is a numpy gather; there is nothing to overlap).

        Only the queue surgery runs under ``_qlock`` — session resolution
        (which can compile on first touch) and extraction happen outside
        it, so submit() never blocks on them. A failure after the pop
        requeues the batch at the front of its queue before re-raising:
        queries are never silently lost."""
        with self._qlock:
            key = self._pick_queue()
        if key is None:
            return None
        # resolving the session may build/compile it — never under the
        # lock. The pick stays valid: only this (single) extractor pops,
        # and new submits are strictly newer than the picked head.
        session = self._wire_session(self._get_session(key))
        self._prepare_formation(key, session)
        with self._qlock:
            batch = self._pop_batch(key, session)
            if batch:
                # virtual-time + backlog accounting of the service start;
                # with a cost model the virtual charge is the batch's
                # predicted units, so expensive batches push their tenant
                # further back than cheap ones of the same size
                served_cost = None
                if self.cost is not None:
                    served_cost = sum(q.cost.units for q in batch
                                      if q.cost is not None)
                self.admission.on_served(key[-1], len(batch),
                                         cost=served_cost)
        if not batch:
            return None
        t0 = time.perf_counter()
        tr = None
        if self.tracer.enabled:
            # last_pick is this pick's decision: pick() is only ever called
            # from this (single) extract path, so nothing raced it
            pick = self.admission.last_pick or {}
            tr = self.tracer.begin(key, key[-1], self._trace_shard(key),
                                   batch, t0,
                                   vtime=float(pick.get("vtime", 0.0)),
                                   overdue=bool(pick.get("overdue", False)))
        try:
            self._check_fault("extract")
            halo_token = self._trace_halo_begin(session) \
                if tr is not None else None
            seeds, result, prepared = self._prepare_stage(session, batch)
            extract_s = time.perf_counter() - t0
            if tr is not None:
                tr.full_cache = prepared is None
                tr.bucket = self._trace_bucket(prepared)
                tr.halo = self._trace_halo_end(session, halo_token)
                tr.span("extract", t0, t0 + extract_s)
            if prepared is not None:
                self.transfer_watchdog.check_prepared(prepared)
            return _Inflight(key=key, batch=batch, session=session,
                             seeds=seeds, prepared=prepared, result=result,
                             t_start=t0, extract_s=extract_s, trace=tr)
        except BaseException as e:
            self._requeue(key, batch, stage="extract", error=repr(e))
            self.tracer.commit(tr, error=repr(e), requeued=True)
            raise

    def _prepare_formation(self, key: tuple, session) -> None:
        """Pre-formation hook, called OUTSIDE ``_qlock``: a subclass whose
        batch formation needs per-request metadata (the sharded engine's
        halo signatures) warms its caches here so the locked pop does no
        session work."""

    def _prepare_stage(self, session, batch):
        """Family-specific EXTRACT body: turn a popped batch into either an
        immediate result (full-cache gather) or a launch-ready
        ``PreparedBatch``; returns ``(seeds, result, prepared)`` with
        exactly one of result/prepared set. The token engine overrides
        this to stage prompt chunks instead of k-hop subgraphs."""
        seeds = np.asarray([q.node for q in batch], np.int64)
        if self._use_full_cache(session):
            return seeds, session.full_logits()[seeds], None
        return seeds, None, session.prepare_batch(seeds)

    def _launch_stage(self, inf: _Inflight) -> None:
        """COMPUTE head: dispatch the jitted forward(s). Async under jax
        dispatch — returns with the device work in flight. Deliberately
        counts NOTHING: a launch/complete failure requeues the batch and
        retries it, so the serve-path counters must only move in the
        (single) successful completion — counting here double-counted
        retried batches and drifted ``cache_hit_rate``."""
        self._check_fault("launch")
        inf.t_launch = time.perf_counter()
        if inf.prepared is not None:
            inf.devs = inf.session.launch_batch(inf.prepared)
            self.transfer_watchdog.check_launched(inf.devs)
        inf.t_launch_end = time.perf_counter()

    def _launch_coalesced(self, infs: List[_Inflight]) -> None:
        """Multi-bucket COMPUTE head: co-dispatch every deferred batch's
        staged buckets as one jitted program per serve core
        (:func:`~repro.serve.session_core.launch_prepared_many` — bit-exact
        vs the serial launches). Full-cache batches (already resolved at
        extract) just get their launch window stamped. A failure requeues
        EVERY deferred batch and drops them from the pipeline, mirroring
        the single-batch launch failure path."""
        t0 = time.perf_counter()
        device_infs = [inf for inf in infs if inf.prepared is not None]
        try:
            self._check_fault("launch")
            devs_lists = launch_prepared_many(
                [inf.prepared for inf in device_infs])
        except BaseException as e:
            for inf in infs:
                try:
                    self._inflight.remove(inf)
                except ValueError:
                    pass
                self._requeue(inf.key, inf.batch, stage="launch",
                              error=repr(e))
                self.tracer.commit(inf.trace, error=repr(e), requeued=True)
                inf.trace = None
            raise
        t1 = time.perf_counter()
        for inf, devs in zip(device_infs, devs_lists):
            inf.devs = devs
            self.transfer_watchdog.check_launched(inf.devs)
        for inf in infs:
            inf.t_launch, inf.t_launch_end = t0, t1
            inf.coalesced = len(device_infs) if inf.prepared is not None \
                else 1

    def _complete_stage(self, inf: _Inflight) -> int:
        """COMPUTE tail: block on the device result, gather per-query
        answers, record metrics. Returns queries answered.

        The compute-stage time attributed to THIS batch starts at its
        launch or at the previous batch's completion, whichever is later:
        completions are sequential, so in a saturated pipeline the span
        launch -> done would double-count the older batches' device time
        and inflate the overlap ratio."""
        self._check_fault("complete")
        if inf.prepared is None:
            logits = inf.result
        else:
            logits = inf.session.finish_batch(inf.prepared, inf.devs)
        t_done = time.perf_counter()
        # serve-path counters move here — after the batch can no longer
        # fail into the requeue/retry path — so they are retry-invariant
        if inf.prepared is None:
            self.metrics.full_cache_hits += len(inf.batch)
        else:
            self.metrics.subgraph_queries += len(inf.batch)
        self.metrics.batches += 1
        self.metrics.batch_latency.record(t_done - inf.t_start)
        compute_attr_s = t_done - max(inf.t_launch, self._last_done)
        self.metrics.record_stages(inf.extract_s, compute_attr_s)
        self._last_done = t_done
        # cost calibration + attribution: the batch's measured service
        # seconds (host extraction + de-overlapped device compute) fold
        # into the estimator's units-per-second EWMAs and split back
        # across the member queries pro rata by predicted units
        if self.cost is not None:
            units = [q.cost.units if q.cost is not None else 0.0
                     for q in inf.batch]
            pred_units = sum(units)
            service_s = inf.extract_s + compute_attr_s
            n_pad = 0
            if inf.prepared is not None:
                n_pad = max((int(g.staged.x_pad.shape[0])
                             for g in inf.prepared.groups), default=0)
            self.cost.observe_batch(pred_units, service_s, n_pad=n_pad)
            shares = self.cost.attribute(units, service_s)
            for q, share in zip(inf.batch, shares):
                self.metrics.record_tenant_cost_attributed(q.tenant, share)
            if inf.trace is not None:
                inf.trace.cost = dict(
                    pred_units=pred_units, measured_s=service_s,
                    n_pad=n_pad, units=units, attributed_s=shares)
        if inf.trace is not None:
            t_le = inf.t_launch_end or t_done
            # co-launched batches share one dispatch: their launch spans
            # carry the coalesced bucket count (and literally the same
            # [t0, t1) window) so a trace shows one device dispatch per
            # multi-bucket tick, not one per batch
            if inf.coalesced > 1:
                inf.trace.span("launch", inf.t_launch, t_le,
                               coalesced=inf.coalesced)
            else:
                inf.trace.span("launch", inf.t_launch, t_le)
            # the wall span launch_end -> done plus the de-overlapped time
            # this batch actually contributed (what record_stages summed)
            inf.trace.span("compute", t_le, t_done,
                           attributed_s=compute_attr_s)
            inf.trace.t_end = t_done
            self.tracer.commit(inf.trace)
            inf.trace = None
        self._deliver(inf, logits)
        for q in inf.batch:
            q.t_done = t_done
            self.metrics.queries += 1
            self.metrics.latency.record(q.latency_s)
            self.metrics.record_tenant_query(q.tenant, q.latency_s)
            self.finished.append(q)
        self.batch_log.append(list(inf.batch))
        with self._qlock:
            self._unanswered -= len(inf.batch)
            if self.slo is not None:
                for q in inf.batch:
                    self.slo.observe(q.tenant, t_done,
                                     latency_s=q.latency_s)
                self.slo.check(t_done, self.admission)
        return len(inf.batch)

    def _deliver(self, inf: _Inflight, result) -> None:
        """Family-specific answer delivery: write each member query's
        answer fields from the batch result. Node queries get their logits
        row + argmax class; the token engine writes generated-token arrays
        instead. Timing/metrics/finished bookkeeping stays in
        :meth:`_complete_stage` — this only fills the answers."""
        preds = np.argmax(result, axis=-1)
        for q, lg, p in zip(inf.batch, result, preds):
            q.logits = np.asarray(lg)
            q.pred = int(p)

    # ------------------------------------------------------------- serve ----
    def _worker(self) -> concurrent.futures.ThreadPoolExecutor:
        """The single extract worker (one thread: extraction order IS batch
        order, which the bit-exactness and water-mark guarantees key on)."""
        if self._pool is None:
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="serve-extract")
        return self._pool

    def _pump(self, block: bool) -> int:
        """Advance the pipeline: keep one extraction on the worker and up to
        ``pipeline_depth`` launched forwards in flight; complete the oldest
        batch when the pipeline is full (always, when ``block``). With
        ``multi_bucket`` the fill's launches are DEFERRED and every bucket
        extracted this tick goes out as one co-dispatch after the fill."""
        deferred: List[_Inflight] = []
        while len(self._inflight) < self.pipeline_depth:
            if self._extract_future is None:
                if not self._queued():
                    break
                self._extract_future = self._worker().submit(
                    self._extract_stage)
            try:
                inf = self._extract_future.result()
            finally:
                # a failed extraction must not wedge the pipeline: the
                # stage already requeued its batch, so clearing the future
                # lets the next tick retry after the caller sees the error
                self._extract_future = None
            if inf is None:
                break
            # hand the NEXT extraction to the worker BEFORE launching this
            # batch, so it overlaps the device time of everything in flight
            if self._queued():
                self._extract_future = self._worker().submit(
                    self._extract_stage)
            if self.multi_bucket:
                deferred.append(inf)
                self._inflight.append(inf)
            else:
                self._compute(inf, launch_only=True)
                self._inflight.append(inf)
        if deferred:
            self._launch_coalesced(deferred)
        # complete the oldest batch when the pipeline is full — or when the
        # input is drained AND its device result is already available:
        # light traffic must not strand launched batches behind a depth
        # gate only more traffic could open, but a momentarily empty queue
        # must not serialize the pipeline by blocking on in-flight work
        # the next wave could still overlap.
        drained_input = (not self._queued()
                         and self._extract_future is None)
        if self._inflight and (block
                               or len(self._inflight) >= self.pipeline_depth
                               or (drained_input and self._oldest_ready())):
            return self._compute(self._inflight.popleft(),
                                 complete_only=True)
        return 0

    def _oldest_ready(self) -> bool:
        """Whether the oldest in-flight batch can be completed without
        blocking (full-cache batches resolved at extract time; device
        batches via jax's is_ready, conservatively True where absent)."""
        inf = self._inflight[0]
        if inf.devs is None:
            return True
        try:
            return all(d.is_ready() for d in inf.devs)
        except AttributeError:
            return True

    def _compute(self, inf: _Inflight, launch_only: bool = False,
                 complete_only: bool = False) -> int:
        """Run the compute stage (launch and/or complete) with the
        never-lose-queries guarantee: a failure in either half requeues the
        batch at the front of its queue before re-raising, mirroring the
        extract stage's failure path."""
        try:
            if not complete_only:
                self._launch_stage(inf)
            if launch_only:
                return 0
            return self._complete_stage(inf)
        except BaseException as e:
            stage = "complete" if complete_only or inf.t_launch_end \
                else "launch"
            self._requeue(inf.key, inf.batch, stage=stage, error=repr(e))
            self.tracer.commit(inf.trace, error=repr(e), requeued=True)
            inf.trace = None
            raise

    def _step(self, block: bool) -> int:
        t0 = time.perf_counter()
        try:
            if self.pipeline_depth <= 0:
                inf = self._extract_stage()
                if inf is None:
                    return 0
                return self._compute(inf)
            return self._pump(block)
        finally:
            self.metrics.serve_wall_s += time.perf_counter() - t0

    def tick(self) -> int:
        """Serve ONE pipeline step. Serial engine: extract + compute one
        micro-batch (the oldest-waiting queue's head of line). Pipelined
        engine: fill the pipeline and complete the oldest batch once it is
        full — early ticks return 0 while the pipeline ramps; completions
        then stream one batch per tick. Returns queries answered."""
        return self._step(block=False)

    def run_until_drained(self, max_ticks: int = 100_000) -> List[NodeQuery]:
        ticks = 0
        while ticks < max_ticks and (
                self.pending or self._inflight
                or self._extract_future is not None):
            n = self._step(block=True)
            if (n == 0 and not self._inflight
                    and self._extract_future is None):
                # all remaining work is behind retry-backoff windows:
                # sleep toward the earliest expiry instead of spinning
                hold = self._backoff_hold_s()
                if hold:
                    time.sleep(min(hold, 0.05))
            ticks += 1
        self.metrics.stop_clock()
        return list(self.finished)

    def close(self) -> None:
        """Shut the background extract worker down (idempotent; the engine
        keeps working — a later pipelined tick restarts it)."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    # ------------------------------------------------ drain / evacuation ----
    def resume_intake(self) -> None:
        """Re-open intake after a :meth:`drain` or :meth:`evacuate` (the
        replica tier's recovery re-admission path)."""
        with self._qlock:
            self._draining = False

    def _shed_queued(self, reason: str) -> List[NodeQuery]:
        """Typed-shed every queued (not in-flight) query: each gets a SHED
        AdmissionDecision naming ``reason``, is counted in
        ``metrics.drain_shed``, and lands in ``finished`` so drain-style
        callers still see it. Caller must NOT hold ``_qlock``."""
        now = time.perf_counter()
        shed: List[NodeQuery] = []
        with self._qlock:
            for key, dq in self._queues.items():
                while dq:
                    q = dq.popleft()
                    q.admission = AdmissionDecision(
                        SHED, q.tenant, reason=reason)
                    q.t_done = now
                    # NOT record_admission: the submission was already
                    # counted as accepted — drain_shed is its own counter
                    self.metrics.drain_shed += 1
                    self._unanswered -= 1
                    self.admission.on_dequeued(q.tenant, 1)
                    self.finished.append(q)
                    if self.slo is not None:
                        self.slo.observe(q.tenant, now, rejected=True)
                    shed.append(q)
            self._backoff.clear()
        return shed

    def drain(self, timeout_s: float = 30.0) -> DrainReport:
        """Graceful drain: stop intake (new submits typed-shed), serve the
        backlog until empty or ``timeout_s``, then typed-shed whatever is
        still queued and flush the in-flight pipeline batches. Always
        terminates; never loses an accepted query silently — every query is
        answered, typed-shed (``drain_shed``), or typed-failed
        (``retry_shed``) by the time this returns. Intake stays stopped
        (see :meth:`resume_intake`)."""
        t0 = time.perf_counter()
        answered0 = self.metrics.queries
        failed0 = self.metrics.retry_shed
        with self._qlock:
            self._draining = True
        deadline = t0 + float(timeout_s)
        while (self.pending or self._inflight
               or self._extract_future is not None):
            if time.perf_counter() >= deadline:
                break
            try:
                n = self._step(block=True)
            except Exception:
                # stage failures already requeued their batch; keep draining
                n = 0
            if (n == 0 and not self._inflight
                    and self._extract_future is None):
                hold = self._backoff_hold_s()
                if hold:
                    left = deadline - time.perf_counter()
                    time.sleep(max(0.0, min(hold, 0.05, left)))
        # deadline path: shed the queues FIRST so _step can't refill the
        # pipeline, then flush launched/extracting batches; a flush failure
        # requeues, so re-shed each iteration until nothing is in flight
        shed: List[NodeQuery] = []
        if (self.pending or self._inflight
                or self._extract_future is not None):
            reason = f"drain timeout after {timeout_s:g}s"
            shed.extend(self._shed_queued(reason))
            while self._inflight or self._extract_future is not None:
                try:
                    self._step(block=True)
                except Exception:
                    pass
                shed.extend(self._shed_queued(reason))
        self.metrics.stop_clock()
        elapsed = time.perf_counter() - t0
        report = DrainReport(
            answered=self.metrics.queries - answered0, shed=len(shed),
            failed=self.metrics.retry_shed - failed0,
            elapsed_s=elapsed, timed_out=bool(shed))
        self.tracer.event("drain", **report.to_json())
        return report

    def evacuate(self) -> List[NodeQuery]:
        """Failover evacuation: stop intake, resolve the background
        extraction, and hand back EVERY accepted-but-unanswered query (in
        service order: in-flight batches oldest-first, then queued by
        submit order) with pipeline state cleared — the front door resubmits
        them to a surviving replica. Unlike :meth:`drain` this never runs
        another compute step: a dead/dying replica cannot be trusted to
        answer, only to surrender its queries."""
        with self._qlock:
            self._draining = True
        fut, self._extract_future = self._extract_future, None
        if fut is not None:
            try:
                inf = fut.result()
                if inf is not None:
                    self._inflight.append(inf)
            except BaseException:
                pass  # the stage already requeued its batch
        self.close()
        out: List[NodeQuery] = []
        while self._inflight:
            inf = self._inflight.popleft()
            self.tracer.commit(inf.trace, error="evacuated", requeued=True)
            inf.trace = None
            out.extend(inf.batch)
        with self._qlock:
            queued: List[NodeQuery] = []
            for key, dq in self._queues.items():
                while dq:
                    q = dq.popleft()
                    self.admission.on_dequeued(q.tenant, 1)
                    queued.append(q)
            queued.sort(key=lambda q: (q.t_submit, q.qid))
            out.extend(queued)
            self._unanswered -= len(out)
            self._backoff.clear()
        return out

    def engine_config(self) -> dict:
        """Constructor kwargs that rebuild an engine equivalent to this one
        (minus the store/topology args the caller supplies): the reshard
        path uses this to spin the P' engine up with the same admission
        policies, tracer ring, retry discipline, and chaos seam."""
        return dict(
            max_batch=self.max_batch, mode=self.mode,
            full_cache_max_nodes=self.full_cache_max_nodes,
            keep_finished=self.finished.maxlen,
            pipeline_depth=self.pipeline_depth,
            admission=self.admission.spawn(), tracer=self.tracer,
            cost=self.cost, slo=self.slo, multi_bucket=self.multi_bucket,
            faults=self.faults, max_retries=self.max_retries,
            retry_backoff_s=self.retry_backoff_s,
            retry_backoff_max_s=self.retry_backoff_max_s)

    # ------------------------------------------------------------ warmup ----
    def warmup(self, graph: str, model: str, probes: int = 16,
               seed: int = 0) -> int:
        """Pre-populate a session's jit shape buckets (and its full cache)
        so the serving loop runs with zero steady-state recompiles. Returns
        the number of compiles the warmup triggered.

        Also the recompile watchdog's arming point: compiles during warmup
        are expected (disarmed); once warmup returns, the engine is in
        steady state and any further jit trace fires a structured
        ``recompile`` warning."""
        self.recompile_watchdog.disarm()
        try:
            session = self._wire_session(self._get_session((graph, model)))
            session.sync()
            if self._use_full_cache(session):
                # steady state serves from the cache sync just built
                return 0
            return session.warmup(np.random.default_rng(seed),
                                  probes=probes)
        finally:
            self.recompile_watchdog.arm()

    def snapshot(self) -> dict:
        inval = sum(s.invalidations for s in self._sessions())
        extra = dict(
            compiles=self.compile_count, invalidations=inval,
            dispatches=self.dispatch_count,
            pending=self.pending, pipeline_depth=self.pipeline_depth,
            multi_bucket=self.multi_bucket,
            watchdogs=dict(recompile=self.recompile_watchdog.snapshot(),
                           transfer=self.transfer_watchdog.snapshot()),
            trace=self.tracer.snapshot())
        if self.cost is not None:
            extra["cost"] = self.cost.snapshot()
        if self.slo is not None:
            extra["slo"] = self.slo.snapshot(time.perf_counter())
        return self.metrics.snapshot(extra=extra)
