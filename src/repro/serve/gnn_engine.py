"""GNN serving engine: micro-batched node-level query scheduler.

Requests (``NodeQuery``: answer node-classification for one node of one
registered graph under one registered model) join per-session FIFO queues.
Each engine tick picks the session whose head request has waited longest,
pops up to ``max_batch`` requests, and answers them through one of two paths:

  * **full-cache** — the session's cached full-graph inference (computed once
    per feature version during BN calibration); a pure numpy gather, the
    steady-state fast path for graphs that fit a full pass;
  * **micro-batched subgraph** — deterministic k-hop extraction around the
    batch's seed nodes, shape-bucket padding, one jitted forward. This is the
    scale path (the full pass is amortized into calibration; per-query cost is
    neighborhood-sized) and the seam for future sharded serving.

``mode="auto"`` uses the full cache below ``full_cache_max_nodes`` and the
subgraph path above it. Latency is measured submit -> answer, so queueing
delay is included (p50/p99 are end-to-end).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .gnn_session import CompiledGraphSession, GraphStore
from .metrics import ServeMetrics


@dataclasses.dataclass
class NodeQuery:
    """One node-classification request and, once served, its answer."""
    graph: str
    model: str
    node: int
    qid: int = -1
    t_submit: float = 0.0
    t_done: float = 0.0
    logits: Optional[np.ndarray] = None
    pred: Optional[int] = None

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def done(self) -> bool:
        return self.pred is not None


class GNNServeEngine:
    """Micro-batching scheduler over a :class:`GraphStore`'s sessions."""

    def __init__(self, store: GraphStore, max_batch: Optional[int] = None,
                 mode: str = "auto", full_cache_max_nodes: int = 200_000,
                 keep_finished: int = 100_000):
        if mode not in ("auto", "full", "subgraph"):
            raise ValueError(mode)
        self.store = store
        self.max_batch = max_batch or store.max_batch
        if self.max_batch > store.max_batch:
            raise ValueError(
                f"engine max_batch {self.max_batch} exceeds the store's "
                f"session seed-slot width {store.max_batch}")
        self.mode = mode
        self.full_cache_max_nodes = full_cache_max_nodes
        self.metrics = ServeMetrics()
        self._queues: Dict[Tuple[str, str], Deque[NodeQuery]] = {}
        self._next_qid = 0
        # bounded: callers hold the authoritative NodeQuery objects from
        # submit(); this is a convenience tail for drain-style use, not an
        # unbounded log of every answer a long-running engine ever produced
        self.finished: Deque[NodeQuery] = deque(maxlen=keep_finished)

    # ------------------------------------------------------------ intake ----
    def submit(self, graph: str, model: str, node: int) -> NodeQuery:
        """Enqueue one node query. Validates here, not at serve time: a bad
        request must bounce back to the submitter, never crash a tick that
        is also carrying other callers' queries."""
        if graph not in self.store.graphs:
            raise KeyError(f"unknown graph {graph!r}; "
                           f"have {sorted(self.store.graphs)}")
        if model not in self.store.models:
            raise KeyError(f"unknown model {model!r}; "
                           f"have {sorted(self.store.models)}")
        n = self.store.graphs[graph].data.n_nodes
        node = int(node)
        if not 0 <= node < n:
            raise ValueError(f"node {node} out of range for graph "
                             f"{graph!r} with {n} nodes")
        q = NodeQuery(graph=graph, model=model, node=node)
        q.qid, self._next_qid = self._next_qid, self._next_qid + 1
        q.t_submit = time.perf_counter()
        key = self._queue_key(graph, model, node)
        self._queues.setdefault(key, deque()).append(q)
        self.metrics.start_clock()
        return q

    def _queue_key(self, graph: str, model: str, node: int) -> tuple:
        """Queue routing hook: one FIFO per (graph, model) here; the sharded
        engine additionally keys by the node's owning shard so every served
        micro-batch is a single-owner group."""
        return (graph, model)

    def submit_many(self, graph: str, model: str,
                    nodes: np.ndarray) -> List[NodeQuery]:
        return [self.submit(graph, model, n) for n in np.asarray(nodes)]

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _sessions(self):
        """The store sessions this engine class serves from (the sharded
        engine overrides this to its partitioned sessions)."""
        return self.store._sessions.values()

    @property
    def compile_count(self) -> int:
        """Total jit traces across all sessions this engine has touched —
        the 'zero steady-state recompiles' acceptance counter."""
        return sum(s.compile_count for s in self._sessions())

    # ------------------------------------------------------------- serve ----
    def _pick_queue(self) -> Optional[Tuple[str, str]]:
        best, best_t = None, float("inf")
        for key, dq in self._queues.items():
            if dq and dq[0].t_submit < best_t:
                best, best_t = key, dq[0].t_submit
        return best

    def _use_full_cache(self, session) -> bool:
        if self.mode == "full":
            return True
        if self.mode == "subgraph":
            return False
        return session.graph.data.n_nodes <= self.full_cache_max_nodes

    def _get_session(self, key: Tuple[str, ...]):
        """Resolve a queue key (first two entries: graph, model) to the
        session answering it (hook: the sharded engine resolves to a
        partitioned session instead)."""
        return self.store.session(*key[:2])

    def _serve_logits(self, session, seeds: np.ndarray) -> np.ndarray:
        if self._use_full_cache(session):
            self.metrics.full_cache_hits += len(seeds)
            return session.full_logits()[seeds]
        self.metrics.subgraph_queries += len(seeds)
        return session.serve_subgraph(seeds)

    def tick(self) -> int:
        """Serve ONE micro-batch (the oldest-waiting session's head of
        queue). Returns the number of queries answered."""
        key = self._pick_queue()
        if key is None:
            return 0
        dq = self._queues[key]
        batch = [dq.popleft() for _ in range(min(self.max_batch, len(dq)))]
        session = self._get_session(key)
        t0 = time.perf_counter()
        seeds = np.asarray([q.node for q in batch], np.int64)
        logits = self._serve_logits(session, seeds)
        t_done = time.perf_counter()
        self.metrics.batches += 1
        self.metrics.batch_latency.record(t_done - t0)
        preds = np.argmax(logits, axis=-1)
        for q, lg, p in zip(batch, logits, preds):
            q.logits = np.asarray(lg)
            q.pred = int(p)
            q.t_done = t_done
            self.metrics.queries += 1
            self.metrics.latency.record(q.latency_s)
            self.finished.append(q)
        return len(batch)

    def run_until_drained(self, max_ticks: int = 100_000) -> List[NodeQuery]:
        ticks = 0
        while self.pending and ticks < max_ticks:
            self.tick()
            ticks += 1
        self.metrics.stop_clock()
        return list(self.finished)

    # ------------------------------------------------------------ warmup ----
    def warmup(self, graph: str, model: str, probes: int = 16,
               seed: int = 0) -> int:
        """Pre-populate a session's jit shape buckets (and its full cache)
        so the serving loop runs with zero steady-state recompiles. Returns
        the number of compiles the warmup triggered."""
        session = self._get_session((graph, model))
        session.sync()
        if self._use_full_cache(session):
            return 0     # steady state serves from the cache sync just built
        return session.warmup(np.random.default_rng(seed), probes=probes)

    def snapshot(self) -> dict:
        inval = sum(s.invalidations for s in self._sessions())
        return self.metrics.snapshot(extra=dict(
            compiles=self.compile_count, invalidations=inval,
            pending=self.pending))
