"""Token serving engine: the LLM decode path on the SAME scheduler the GNN
engines run — queues, weighted fair pick, admission/tenancy, cost
attribution, span tracing, bounded retry, drain/evacuate all inherited from
:class:`~repro.serve.gnn_engine.GNNServeEngine` unchanged.

What changes is only the family-specific hooks: ``submit`` takes a prompt +
decode budget instead of a node id, the extract stage stages prompt chunks
(:meth:`TokenSession.prepare_batch`) instead of k-hop subgraphs, and
delivery writes each query's generated-token array (plus its
time-to-first-token, read off the prepared batch's per-chunk completion
stamps). Multi-bucket co-launch is forced off: a token batch's chunks are
a CHAIN (each launch consumes the previous chunk's device state), not
independent buckets.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .admission import DEFAULT_TENANT
from .cost import CostEstimate
from .gnn_engine import GNNServeEngine, NodeQuery
from .token_session import TokenStore


@dataclasses.dataclass
class TokenQuery(NodeQuery):
    """One generation request and, once served, its token stream.

    Shares the query protocol (qid, admission, cost, trace context, retry
    state) with :class:`NodeQuery`; ``node`` is unused (-1) and ``graph``
    empty — the queue key is (model, tenant). ``tokens`` is the generated
    int32 stream (argmax decoding, truncated at the session's eos
    inclusive); ``t_first_token`` the wall clock its first generated token
    became host-ready."""
    prompt: Optional[np.ndarray] = None
    max_new: int = 16
    tokens: Optional[np.ndarray] = None
    t_first_token: float = 0.0

    @property
    def done(self) -> bool:
        return self.tokens is not None

    @property
    def ttft_s(self) -> float:
        """Submit -> first generated token (0 until answered)."""
        if self.tokens is None or self.t_first_token <= 0.0:
            return 0.0
        return self.t_first_token - self.t_submit


class TokenServeEngine(GNNServeEngine):
    """Micro-batching scheduler over a :class:`TokenStore`'s sessions."""

    def __init__(self, store: TokenStore, **kw):
        # chunk launches are state-chained — never co-launchable buckets
        kw["multi_bucket"] = False
        kw.setdefault("mode", "subgraph")
        # metrics/trace namespace: the store's model kind (transformer/ssm)
        self.family = store.kind
        super().__init__(store, **kw)

    # ------------------------------------------------------------ intake ----
    def submit(self, model: str, prompt, max_new: int = 16,
               tenant: str = DEFAULT_TENANT) -> TokenQuery:
        """Enqueue one generation request. Validation raises (caller bug);
        admission outcomes come back typed on the query, exactly like the
        node path."""
        if model not in self.store.models:
            raise KeyError(f"unknown model {model!r}; "
                           f"have {sorted(self.store.models)}")
        prompt = np.asarray(prompt, np.int32).ravel()
        if prompt.size < 1:
            raise ValueError("empty prompt")
        max_new = int(max_new)
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if prompt.size + max_new - 1 > self.store.max_len:
            raise ValueError(
                f"prompt[{prompt.size}] + max_new {max_new} exceeds the "
                f"store's max_len {self.store.max_len}")
        q = TokenQuery(graph="", model=model, node=-1, tenant=tenant,
                       prompt=prompt, max_new=max_new)
        if self.cost is not None:
            q.cost = self.cost.estimate_flat(prompt.size + max_new)
        return self._admit_enqueue(q, (model, tenant))

    def submit_many(self, model: str, prompts, max_new: int = 16,
                    tenant: str = DEFAULT_TENANT) -> List[TokenQuery]:
        return [self.submit(model, p, max_new=max_new, tenant=tenant)
                for p in prompts]

    # ------------------------------------------------------------- hooks ----
    def _get_session(self, key):
        return self.store.session(key[0])

    def _use_full_cache(self, session) -> bool:
        return False

    def _estimate_cost(self, *a, **kw) -> Optional[CostEstimate]:
        raise NotImplementedError(
            "token cost prediction happens in submit()")

    def _prepare_stage(self, session, batch):
        seeds = np.asarray([q.qid for q in batch], np.int64)
        prepared = session.prepare_batch([q.prompt for q in batch],
                                         [q.max_new for q in batch])
        return seeds, None, prepared

    def _deliver(self, inf, result) -> None:
        p = inf.prepared
        done_t = getattr(p, "chunk_done_t", None) or []
        for i, (q, toks) in enumerate(zip(inf.batch, result)):
            q.tokens = np.asarray(toks, np.int32)
            if done_t:
                c = min(p.first_token_chunk(i), len(done_t) - 1)
                q.t_first_token = done_t[c]

    def _trace_bucket(self, prepared) -> dict:
        if prepared is None or not prepared.groups:
            return {}
        g0 = prepared.groups[0].staged
        return dict(chunks=len(prepared.groups),
                    batch=int(g0.x_pad.shape[0]),
                    chunk=int(g0.x_pad.shape[1]),
                    cache_len=int(prepared.cache_len))

    # ------------------------------------------------------------ warmup ----
    def warmup(self, model: str, probes: int = 2, seed: int = 0) -> int:
        """Pre-populate a session's jit cache / cache-length water, then arm
        the recompile watchdog (compiles during warmup are expected)."""
        self.recompile_watchdog.disarm()
        try:
            session = self._wire_session(self._get_session((model,)))
            session.sync()
            return session.warmup(np.random.default_rng(seed),
                                  probes=probes)
        finally:
            self.recompile_watchdog.arm()
