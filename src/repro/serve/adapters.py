"""Model-family adapters: the seam that makes :class:`ServeCore` generic.

The serving core (bucketed jit cache, high-water shape buckets, async
launch/finish, multi-bucket co-launch, trace hooks) is family-agnostic;
everything a model family actually computes lives behind a
:class:`ModelFamilyAdapter`:

  * **quantize** — params -> the bit-packed serving params the jitted body
    closes over (:func:`repro.serve.session_core.quantize_family` for GNNs,
    :func:`repro.quant.binary_linear.quantize_params` for token models);
  * **serve_body** — the TRACED forward/step program: called inside the
    core's jitted ``_serve`` with the staged operands, returns the launch
    result pytree. For GNNs this is rebuild-FRDC + ``family_forward`` +
    seed-row crop; for token models one chunk of exact single-token
    ``decode_step`` bodies scanned under teacher forcing;
  * **pad_operands** — bucket shaping: pad one extracted batch's operands
    up to the core's high-water pow2 marks so steady-state serving never
    recompiles (GNN: node + per-kind FRDC group water; token: the chunk
    width and cache length are already bucket-static, so it is identity);
  * **sub_operands / operand_like** — per-query state extraction: build
    the staged operands for an extracted closure, and the artifact
    template checkpoint restore validates against;
  * **state semantics** — the ``state`` argument threaded through
    ``launch(staged, state)`` and PINNED on a ``PreparedBatch`` at extract
    time (the calibration hook): the frozen BN tuple for GNNs, the
    ``(decode cache, previous-token)`` carry for token sessions;
  * **finish / trace_shape** — crop the launch result back to host answers
    and describe a staged batch's jit-cache shape key for the recompile
    watchdog.

``ServeCore`` takes an ``adapter=`` argument; when omitted it builds a
:class:`GNNAdapter` from its plan, so every pre-existing call site (and the
``batch_log`` replay oracle) is bitwise unchanged — the GNN body here IS
the old ``ServeCore._serve_one`` body, moved verbatim.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..core import frdc
from . import session_core


class ModelFamilyAdapter:
    """Contract one model family implements to ride the serving core.

    ``kind`` namespaces the family in metrics/trace exports (the ``family``
    label on every Prometheus series and watchdog event).
    """

    kind = "?"

    # -- params ------------------------------------------------------------
    def quantize(self, params):
        """Dense params -> the serving params the jitted body closes over."""
        raise NotImplementedError

    # -- traced program ----------------------------------------------------
    def serve_body(self, core, x, state, operands, seeds):
        """The traced launch body. ``x``/``seeds`` are the staged dense
        arrays, ``operands`` the (padded) per-batch operand dict, ``state``
        the pinned calibration/carry pytree. Returns the launch result."""
        raise NotImplementedError

    # -- bucket shaping ----------------------------------------------------
    def pad_operands(self, core, operands, n_sub):
        """Pad one batch's operands to the core's high-water buckets;
        returns ``(n_pad, padded_operands)``. Must be monotone in the
        water marks — staging order, not launch order, is what the
        zero-steady-state-recompile guarantee keys on."""
        raise NotImplementedError

    # -- per-query state extraction ---------------------------------------
    def sub_operands(self, *args, **kw):
        """Build the operand dict for one extracted per-query closure."""
        raise NotImplementedError

    def operand_like(self):
        """Template pytree for checkpoint restore validation."""
        raise NotImplementedError

    # -- result / observability -------------------------------------------
    def finish(self, out_dev, staged) -> Any:
        """Block on one launch result and crop it to host answers."""
        raise NotImplementedError

    def trace_shape(self, staged) -> dict:
        """Shape key of one staged batch (recompile-watchdog payload)."""
        raise NotImplementedError

    def trace_shape_many(self, stageds: List) -> dict:
        """Shape key of a co-launched bucket set."""
        shapes = [self.trace_shape(s) for s in stageds]
        out: Dict[str, Any] = dict(multi=len(stageds))
        for k in (shapes[0] if shapes else {}):
            out[k] = [s[k] for s in shapes]
        return out


class GNNAdapter(ModelFamilyAdapter):
    """The GNN serving specifics, moved verbatim out of ``ServeCore``.

    Stateless w.r.t. the core (water marks live on each ``ServeCore``), so
    one adapter is shared by every shard core of a sharded session.
    """

    kind = "gnn"

    def __init__(self, plan: "session_core.SessionPlan"):
        self.plan = plan

    def quantize(self, params):
        return session_core.quantize_family(self.plan.family, params)

    def serve_body(self, core, x, state, operands, seeds):
        n_pad = x.shape[0]
        mats = {k: session_core.frdc_rebuild(v, n_pad, n_pad)
                for k, v in operands.items()}
        out = session_core.family_forward(self.plan, core.qparams, x, mats,
                                          use_pallas=core.use_pallas,
                                          bn_stats=state)
        return out[seeds]

    def pad_operands(self, core, operands, n_sub):
        n_pad = session_core.bucket_pow2(max(n_sub, core._n_water),
                                         core.NODE_BUCKET_FLOOR,
                                         core.node_cap)
        core._n_water = n_pad
        adjs = {}
        for k, m in operands.items():
            wkey = (n_pad, k)
            g_pad = max(core._g_water.get(wkey, 0),
                        session_core.bucket_pow2(m.n_groups,
                                                 core.GROUP_BUCKET_FLOOR))
            core._g_water[wkey] = g_pad
            adjs[k] = session_core.frdc_arrays(
                frdc.pad_frdc(m, n_pad, n_groups=g_pad))
        return n_pad, adjs

    def sub_operands(self, n_sub: int, sub_edges, dinv_sub):
        return session_core.sub_adjacency(self.plan.family, n_sub,
                                          sub_edges, dinv_sub)

    def operand_like(self):
        return session_core.adj_like(self.plan.family)

    def finish(self, out_dev, staged) -> np.ndarray:
        return np.asarray(out_dev)[:staged.n_seeds]

    def trace_shape(self, staged) -> dict:
        return dict(
            n_pad=int(staged.x_pad.shape[0]),
            groups={str(k): int(a["group_row"].shape[0])
                    for k, a in staged.adjs.items()})


class TokenAdapter(ModelFamilyAdapter):
    """Autoregressive token serving for the binary transformer / SSM stack.

    One launch runs ONE CHUNK of the decode program: ``chunk`` exact
    single-token :func:`repro.models.transformer.decode_step` bodies scanned
    under teacher forcing — global step ``p`` consumes the slot's prompt
    token while ``p < len`` and its own previous argmax after — and each
    step's argmax is the slot's generated-token stream. Scanning the exact
    step bodies (never the O(T^2) chunked prefill paths) keeps the served
    stream BITWISE identical to a python loop of ``jit(decode_step)``; the
    session chains chunk launches by threading the ``(cache, prev)`` carry,
    so the whole decode stays async on device.

    Shape discipline: the launch operands are the (B, chunk) prompt slice
    (zero-padded), the (B,) prompt lengths, and the chunk's traced base
    position — all static-shaped, so every chunk of every batch hits ONE
    jit entry. The only growable shape is the decode-cache length, bucketed
    by the core's pow2 high-water mark (``pad_operands``): zero steady-state
    recompiles across varied prompt/decode lengths once warmup sets the
    water.

    ``kind`` namespaces metrics/traces: "ssm" when the config's block
    pattern contains any recurrent block (mamba / rwkv, including hybrids),
    else "transformer".
    """

    SSM_BLOCKS = ("mamba", "mamba_attn", "rwkv")

    def __init__(self, cfg):
        if getattr(cfg, "is_encdec", False):
            raise ValueError(
                "encoder-decoder configs need an encoded memory per request "
                "and are not servable through the token session")
        self.cfg = cfg
        pattern = cfg.block_pattern()
        self.kind = ("ssm" if any(k in self.SSM_BLOCKS for k in pattern)
                     else "transformer")

    def quantize(self, params):
        from ..quant.binary_linear import quantize_params
        return quantize_params(params)

    def init_state(self, batch: int, cache_len: int) -> dict:
        """Fresh decode carry for one batch: the KV/recurrent caches plus
        the previous-argmax feedback token (device work — built at LAUNCH,
        never in the extract stage)."""
        from ..models import transformer
        return {"cache": transformer.init_cache(self.cfg, batch, cache_len),
                "prev": jnp.zeros((batch,), jnp.int32)}

    def serve_body(self, core, x, state, operands, seeds):
        from ..models import transformer
        cfg = self.cfg
        lens = seeds                           # (B,) prompt lengths
        pos0 = jnp.asarray(operands["base"]["pos0"], jnp.int32)

        def body(carry, xs):
            cache, prev = carry
            tok_p, p = xs
            tok = jnp.where(p < lens, tok_p, prev)
            logits, cache = transformer.decode_step(
                core.qparams, cfg, cache, tok[:, None], p)
            nxt = jnp.argmax(logits[:, 0, :cfg.vocab],
                             axis=-1).astype(jnp.int32)
            return (cache, nxt), nxt

        q = x.shape[1]
        steps = pos0 + jnp.arange(q, dtype=jnp.int32)
        (cache, prev), gens = jax.lax.scan(
            body, (state["cache"], state["prev"]),
            (jnp.swapaxes(x, 0, 1), steps))
        return {"gens": jnp.swapaxes(gens, 0, 1),
                "state": {"cache": cache, "prev": prev}}

    def pad_operands(self, core, operands, n_sub):
        """Bucket the decode-cache length: ``n_sub`` is the batch's total
        step count, padded to the monotone pow2 water. A clamped cache
        would silently truncate the decode, so exceeding the cap raises."""
        if n_sub > core.node_cap:
            raise ValueError(
                f"decode needs {n_sub} cache positions but the session's "
                f"max_len is {core.node_cap}")
        n_pad = session_core.bucket_pow2(max(n_sub, core._n_water),
                                         core.NODE_BUCKET_FLOOR,
                                         core.node_cap)
        core._n_water = n_pad
        return n_pad, operands

    def sub_operands(self, pos0: int) -> dict:
        """Operand dict of one chunk: its base position, traced (values
        vary per chunk without touching the jit cache key)."""
        return {"base": {"pos0": np.int32(pos0)}}

    def operand_like(self) -> dict:
        return {"base": {"pos0": np.zeros((), np.int32)}}

    def finish(self, out_dev, staged) -> np.ndarray:
        return np.asarray(out_dev["gens"])

    def trace_shape(self, staged) -> dict:
        return dict(batch=int(staged.x_pad.shape[0]),
                    chunk=int(staged.x_pad.shape[1]))
