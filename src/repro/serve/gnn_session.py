"""Compiled graph sessions: the (graph, model) serving artifact.

A ``GraphStore`` registers graphs (host-side ``GraphData``) and models
(family + full-precision params) and compiles a ``CompiledGraphSession`` per
(graph, model) pair:

  * FRDC-encoded adjacencies of every kind the family's packed forward needs
    (GCN: normalized + 0/1; SAGE: mean-normalized; SAINT: 0/1 sum);
  * bit-packed quantized weights (``quantize_gcn`` / ``quantize_sage`` /
    ``quantize_saint``);
  * a tuner-selected variant plan (reusing :mod:`repro.core.tuner` over the
    legal :mod:`repro.core.abstraction` pairings), timed on the actual graph;
  * full-graph BN calibration: the per-site (mu, sd) batch-norm statistics —
    the ONLY cross-node statistic in any bitgnn forward — are frozen from one
    full-graph pass, so a k-hop subgraph forward reproduces the full-graph
    computation for the seed nodes exactly (fp-reassociation noise only);
  * a cached full-graph logits fast path, invalidated on feature update.

The compile/calibrate/bucketed-serve machinery itself lives in
:mod:`repro.serve.session_core` (shared with the partitioned sessions of
:mod:`repro.serve.sharded`); this module owns the single-host graph state.

Artifacts are serialized through the existing async checkpointer
(:mod:`repro.checkpoint.checkpointer`): array state in ``step_0/shard_0.npz``
plus a ``plan.json`` sidecar holding the plan, static FRDC dims and a feature
fingerprint; a store restart with an unchanged graph/model restores instead
of re-tuning.

Feature updates: ``GraphStore.update_features`` records WHICH rows changed.
A session in incremental mode keeps its frozen BN calibration and patches
only the ``FAMILY_AGG_LAYERS``-hop out-neighborhood of the changed nodes in
its cached full-graph logits (output rows outside that closure are provably
unchanged under frozen BN stats); the default mode recalibrates and recomputes
the whole cache.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import frdc
from repro.graphs import sampling
from repro.graphs.datasets import GraphData
from repro.serve import adapters, session_core
from repro.serve.session_core import (  # re-exported (stable import path)
    FAMILIES, FAMILY_AGG_LAYERS, ServeCore, SessionPlan, bucket_pow2)

# retained changelog entries per graph: an incremental session can catch up
# across at most this many feature versions before falling back to a full
# recompute.
CHANGELOG_KEEP = 64


@dataclasses.dataclass
class GraphEntry:
    name: str
    data: GraphData
    version: int = 0
    # (version, changed row ids) per update_features call, most recent last
    changelog: List[Tuple[int, np.ndarray]] = dataclasses.field(
        default_factory=list)
    _csr: Optional[sampling.CSRGraph] = None
    _csr_rev: Optional[sampling.CSRGraph] = None
    _dinv_gcn: Optional[np.ndarray] = None
    _dinv_mean: Optional[np.ndarray] = None

    @property
    def csr(self) -> sampling.CSRGraph:
        if self._csr is None:
            self._csr = sampling.to_csr(self.data.edges, self.data.n_nodes)
        return self._csr

    @property
    def csr_rev(self) -> sampling.CSRGraph:
        """Reverse CSR (sender -> receivers): who aggregates FROM a node —
        the out-neighborhood a feature change invalidates."""
        if self._csr_rev is None:
            e = self.data.edges
            self._csr_rev = sampling.to_csr(np.stack([e[1], e[0]]),
                                            self.data.n_nodes)
        return self._csr_rev

    @property
    def dinv_gcn(self) -> np.ndarray:
        """Full-graph D^-1/2 (self-loops included) — GCN factorization vector.
        Subgraph adjacencies index into THIS so seed rows aggregate with the
        exact full-graph normalization."""
        if self._dinv_gcn is None:
            n = self.data.n_nodes
            deg = np.bincount(self.data.edges[0], minlength=n) + 1.0
            self._dinv_gcn = 1.0 / np.sqrt(deg)
        return self._dinv_gcn

    @property
    def dinv_mean(self) -> np.ndarray:
        if self._dinv_mean is None:
            n = self.data.n_nodes
            deg = np.bincount(self.data.edges[0], minlength=n).astype(
                np.float64)
            self._dinv_mean = 1.0 / np.maximum(deg, 1.0)
        return self._dinv_mean

    def dinv_for(self, family: str) -> Optional[np.ndarray]:
        if family == "gcn":
            return self.dinv_gcn
        if family == "sage":
            return self.dinv_mean
        return None

    def record_change(self, changed: np.ndarray) -> None:
        self.changelog.append((self.version, np.asarray(changed, np.int64)))
        del self.changelog[:-CHANGELOG_KEEP]

    def changed_since(self, version: int) -> Optional[np.ndarray]:
        """Union of rows changed in (version, self.version], or None when the
        changelog no longer covers that span (caller must recompute fully)."""
        need = [v for v in range(version + 1, self.version + 1)]
        have = {v: c for v, c in self.changelog}
        if any(v not in have for v in need):
            return None
        if not need:
            return np.zeros(0, np.int64)
        return np.unique(np.concatenate([have[v] for v in need]))


@dataclasses.dataclass
class ModelEntry:
    name: str
    family: str
    params: object


_session_fingerprint = session_core.session_fingerprint


class CompiledGraphSession:
    """Per-(graph, model) compiled serving artifact. See module docstring."""

    def __init__(self, graph: GraphEntry, model: ModelEntry,
                 plan: SessionPlan, qparams, khop: int = 2,
                 max_batch: int = 32,
                 adj_full: Optional[Dict[str, frdc.FRDCMatrix]] = None,
                 use_pallas: bool = False, incremental: bool = False):
        self.graph = graph
        self.model = model
        self.plan = plan
        self.qparams = qparams
        self.khop = khop
        self.max_batch = max_batch
        self.use_pallas = use_pallas
        self.incremental = incremental
        self.key = f"{graph.name}__{model.name}"
        self.feature_version = -1          # forces first sync to calibrate
        self.bn: Optional[tuple] = None
        self._x_dev: Optional[jax.Array] = None
        self._full_cache: Optional[np.ndarray] = None
        self._invalidations = 0
        self._incremental_refreshes = 0
        # adj_full injected on artifact restore (skips re-encoding the graph)
        self._adj_full = (adj_full if adj_full is not None
                          else self._build_full_adjacencies())
        node_cap = self._adj_full[next(iter(self._adj_full))].n_tile_rows \
            * frdc.TILE
        self.adapter = adapters.GNNAdapter(plan)
        self.core = ServeCore(plan, qparams, max_batch, node_cap,
                              use_pallas=use_pallas, adapter=self.adapter)
        self._jit_full, self._jit_full_frozen = self._make_full_fns()

    # ------------------------------------------------------------ build ----
    def _build_full_adjacencies(self) -> Dict[str, frdc.FRDCMatrix]:
        d = self.graph.data
        fam = self.plan.family
        if fam == "gcn":
            return {"adj": d.adjacency("gcn"), "bin": d.adjacency("binary")}
        if fam == "sage":
            return {"mean": d.adjacency("mean")}
        return {"sum": d.adjacency("binary")}

    def _make_full_fns(self):
        # qparams/adjacencies are closed over (jit constants): BinTensor's
        # static ``n`` and FRDCMatrix's static dims must not be traced. The
        # jitted fns are recreated whenever qparams are swapped (load()).
        adjs, qparams, plan = self._adj_full, self.qparams, self.plan
        use_pallas = self.use_pallas

        def full(x):
            return session_core.family_forward(
                plan, qparams, x, adjs, use_pallas=use_pallas,
                return_bn_stats=True)

        def full_frozen(x, bn):
            return session_core.family_forward(
                plan, qparams, x, adjs, use_pallas=use_pallas, bn_stats=bn)

        return jax.jit(full), jax.jit(full_frozen)

    # ------------------------------------------------------------- sync ----
    def sync(self) -> None:
        """Adopt the store's current features. Default: re-upload,
        recalibrate BN and refresh the full-graph logits cache. Incremental
        mode: keep the frozen calibration and patch only the out-neighborhood
        of the changed rows. No-op when already current."""
        if self.feature_version == self.graph.version:
            return
        invalidated = self.feature_version >= 0
        changed = None
        if (self.incremental and invalidated and self.bn is not None
                and self._full_cache is not None):
            changed = self.graph.changed_since(self.feature_version)
        self._x_dev = jnp.asarray(self.graph.data.x)
        if changed is None:
            out, bn = self._jit_full(self._x_dev)
            self.bn = bn
            self._full_cache = np.array(out)   # writable: patched in place
        elif changed.size:
            self._refresh_incremental(changed)
        self.feature_version = self.graph.version
        if invalidated:
            self._invalidations += 1

    def _refresh_incremental(self, changed: np.ndarray) -> None:
        """Patch the cached logits of every node whose output can depend on
        a changed row: the FAMILY_AGG_LAYERS-hop closure of ``changed`` under
        REVERSE edges. BN stats stay frozen (they are calibration constants
        in this mode), so rows outside the closure are bitwise unchanged."""
        k = FAMILY_AGG_LAYERS[self.plan.family]
        affected = sampling.khop_nodes(self.graph.csr_rev, changed, k)
        n = self.graph.data.n_nodes
        # beyond ~12.5% of the graph the batched subgraph passes cost more
        # than one frozen-stats full pass — patch from that instead.
        if affected.size * 8 > n:
            out = np.asarray(self._jit_full_frozen(self._x_dev, self.bn))
            self._full_cache[affected] = out[affected]
        else:
            for i in range(0, affected.size, self.max_batch):
                chunk = affected[i:i + self.max_batch]
                self._full_cache[chunk] = self._serve_batch(chunk)
        self._incremental_refreshes += 1

    @property
    def invalidations(self) -> int:
        return self._invalidations

    @property
    def incremental_refreshes(self) -> int:
        return self._incremental_refreshes

    @property
    def compile_count(self) -> int:
        """Number of jit traces of the bucketed subgraph forward."""
        return self.core.compile_count

    @property
    def dispatch_count(self) -> int:
        """Device dispatches issued (a multi-bucket co-launch counts 1)."""
        return self.core.n_dispatches

    def set_trace_hook(self, cb) -> None:
        """Wire an observability callback ``cb(label, shape_dict)`` to fire
        on every NEW jit trace of this session's serve core (the engines'
        recompile watchdog). ``None`` unwires."""
        self.core.on_trace = (None if cb is None
                              else (lambda shape: cb("core", shape)))

    # ------------------------------------------------------ full path ------
    def full_logits(self) -> np.ndarray:
        """Cached full-graph inference (the fast path for small/warm graphs)."""
        self.sync()
        return self._full_cache

    # -------------------------------------------------- subgraph path ------
    def _extract(self, uniq_seeds: np.ndarray):
        """Host-side k-hop extraction + subgraph FRDC build (no device work
        — also used by warmup to probe steady-state shapes cheaply)."""
        ex = sampling.extract_khop(self.graph.csr, uniq_seeds, self.khop)
        dinv = self.graph.dinv_for(self.plan.family)
        mats = self.adapter.sub_operands(
            ex.sub_nodes.size, ex.sub_edges,
            None if dinv is None else dinv[ex.sub_nodes])
        return ex.sub_nodes, mats, ex.seed_pos

    def prepare_batch(self, seeds: np.ndarray) -> session_core.PreparedBatch:
        """EXTRACT stage: adopt current features, k-hop extract, build the
        subgraph FRDC and bucket-pad — pure host work producing the
        launch-ready :class:`~repro.serve.session_core.PreparedBatch` (the
        pipelined engine runs this on a background worker while the previous
        batch's forward is in flight)."""
        self.sync()
        seeds = np.asarray(seeds, np.int64)
        uniq, inverse = np.unique(seeds, return_inverse=True)
        sub_nodes, mats, seed_pos = self._extract(uniq)
        staged = self.core.stage(self.graph.data.x[sub_nodes], mats,
                                 seed_pos)
        group = session_core.PreparedGroup(
            core=self.core, sel=np.arange(uniq.size), staged=staged)
        return session_core.PreparedBatch(n_uniq=uniq.size, inverse=inverse,
                                          groups=[group], bn=self.bn)

    def launch_batch(self, prepared) -> list:
        """COMPUTE-stage head: dispatch the jitted forward(s) asynchronously
        (with the calibration captured when the batch was staged)."""
        return prepared.launch()

    def finish_batch(self, prepared, devs) -> np.ndarray:
        """COMPUTE-stage tail: block and reassemble request-order logits."""
        return prepared.finish(devs)

    def _serve_batch(self, uniq_seeds: np.ndarray) -> np.ndarray:
        """One extraction + bucketed forward for <= max_batch unique seeds,
        against the CURRENT features and frozen calibration (no sync)."""
        sub_nodes, mats, seed_pos = self._extract(uniq_seeds)
        return self.core.run(self.graph.data.x[sub_nodes], mats, seed_pos,
                             self.bn)

    def serve_subgraph(self, seeds: np.ndarray) -> np.ndarray:
        """Micro-batched node-level inference: k-hop extraction -> bucket
        padding -> jitted forward -> (len(seeds), n_out) logits. Runs the
        same prepare/launch/finish stages the pipelined engine drives, just
        serially — which is what keeps the two loops bit-exact."""
        prepared = self.prepare_batch(seeds)
        return self.finish_batch(prepared, self.launch_batch(prepared))

    def warmup(self, rng: Optional[np.random.Generator] = None,
               probes: int = 16, margin: float = 1.125) -> int:
        """Drive the high-water shape bucket to its steady value and compile
        it. Probes ``probes`` max-width batches HOST-SIDE ONLY (k-hop +
        subgraph FRDC build, no device work, milliseconds each) to find the
        largest node/group counts the workload produces, sets the water
        marks to ``margin`` above that (then pow2-rounded), and runs one
        real forward to compile the steady shape. A workload batch can only
        recompile by exceeding the margined pow2 bucket — and the monotone
        water then absorbs it after one compile. Returns compiles triggered."""
        rng = rng or np.random.default_rng(0)
        before = self.core.compile_count
        self.sync()
        n = self.graph.data.n_nodes
        n_max, g_max = 0, {}
        for _ in range(probes):
            seeds = np.unique(rng.integers(0, n, size=self.max_batch))
            sub_nodes, mats, _ = self._extract(seeds)
            n_max = max(n_max, sub_nodes.size)
            for k, m in mats.items():
                g_max[k] = max(g_max.get(k, 0), m.n_groups)
        self.core.preset_water(n_max, g_max, margin)
        self.serve_subgraph(rng.integers(0, n, size=self.max_batch))
        return self.core.compile_count - before

    # ------------------------------------------------------- artifact ------
    def _state(self) -> dict:
        # bn stats are NOT serialized: they are a pure function of
        # (qparams, features) and the first sync() after load recomputes
        # them in the same full-graph pass that fills the logits cache.
        return {"qparams": self.qparams,
                "adj": {k: session_core.frdc_arrays(m)
                        for k, m in self._adj_full.items()}}

    def fingerprint(self) -> dict:
        return _session_fingerprint(self.graph, self.model)

    def save(self, directory: Path) -> None:
        """Serialize the compiled artifact via the existing checkpointer:
        arrays in step_0, plan + static dims + fingerprint in plan.json."""
        self.sync()
        ckpt = Checkpointer(directory, keep=1)
        ckpt.save(0, self._state(), blocking=True)
        sidecar = dict(
            plan=self.plan.to_json(), fingerprint=self.fingerprint(),
            khop=self.khop, max_batch=self.max_batch,
            adj_dims={k: [m.n_rows, m.n_cols, m.nnz]
                      for k, m in self._adj_full.items()})
        (Path(directory) / "plan.json").write_text(json.dumps(sidecar))

    @classmethod
    def load(cls, directory: Path, graph: GraphEntry, model: ModelEntry,
             khop: Optional[int] = None, max_batch: Optional[int] = None,
             use_pallas: bool = False, incremental: bool = False,
             bspmm_block="unchanged", fused="unchanged",
             ) -> Optional["CompiledGraphSession"]:
        """Restore a session artifact; returns None on any mismatch (missing
        files, different graph/model/features, or a khop/max_batch that
        differs from what the caller wants — a narrower restored seed-slot
        buffer would overflow under a wider engine) so the caller recompiles.

        All mismatch checks run BEFORE anything is built; the adjacency
        encode (the expensive part of a cold session build on large graphs)
        is skipped entirely — the FRDC arrays come from the checkpoint."""
        directory = Path(directory)
        sidecar_path = directory / "plan.json"
        sidecar = session_core.load_sidecar(
            sidecar_path, required=("plan", "fingerprint", "khop",
                                    "max_batch", "adj_dims"))
        if sidecar is None:
            return None
        if khop is not None and sidecar["khop"] != khop:
            return None
        if max_batch is not None and sidecar["max_batch"] != max_batch:
            return None
        if _session_fingerprint(graph, model) != sidecar["fingerprint"]:
            return None
        try:
            plan = SessionPlan.from_json(sidecar["plan"])
        except (KeyError, TypeError, ValueError) as e:
            raise session_core.ArtifactError(sidecar_path, field="plan",
                                             detail=repr(e))
        # the block shape is baked into the compiled executables (trace-time
        # choice): a store asking for a different one must recompile
        if bspmm_block != "unchanged" and plan.bspmm_block != bspmm_block:
            return None
        # same trace-time-baked reasoning for the fused-kernel selection
        if fused != "unchanged" and plan.fused != fused:
            return None
        like = {"qparams": session_core.quantize_family(model.family,
                                                        model.params),
                "adj": session_core.adj_like(model.family)}
        # typed restore: missing/mismatched checkpoint -> None (recompile),
        # truncated/corrupt npz or manifest -> ArtifactError naming the file
        state = session_core.restore_artifact_state(directory, like)
        if state is None:
            return None
        dims = sidecar["adj_dims"]
        adj_full = {k: session_core.frdc_rebuild(v, *dims[k])
                    for k, v in state["adj"].items()}
        return cls(graph, model, plan,
                   session_core.coerce_quant(state["qparams"]),
                   khop=sidecar["khop"], max_batch=sidecar["max_batch"],
                   adj_full=adj_full, use_pallas=use_pallas,
                   incremental=incremental)


# ---------------------------------------------------------------------------
# Store
# ---------------------------------------------------------------------------

class GraphStore:
    """Registry of graphs + models producing cached compiled sessions."""

    def __init__(self, cache_dir: Optional[str] = None, khop: int = 2,
                 max_batch: int = 32, use_pallas: bool = False,
                 incremental: bool = False,
                 bspmm_block: Optional[Tuple[int, int]] = None,
                 fused: bool = False,
                 tuner_cache: Optional[str] = None):
        self.cache_dir = Path(cache_dir) if cache_dir else None
        self.khop = khop
        self.max_batch = max_batch
        self.use_pallas = use_pallas
        self.incremental = incremental
        # Pallas BSpMM block-shape selection, recorded in every plan this
        # store builds (and therefore in plan.json / routing.json); None =
        # kernel-native defaults. The TPU block-shape tuning seam.
        self.bspmm_block = (None if bspmm_block is None
                            else tuple(bspmm_block))
        # fused per-layer kernel selection (SessionPlan.fused), recorded in
        # every plan this store builds — trace-time choice like the block
        # shape, so it participates in artifact mismatch checks too.
        self.fused = bool(fused)
        # optional persistent tuner cache (benchmarks/perf_hillclimb.py
        # sweeps): when the store has NO explicit bspmm_block, a cache hit
        # for the graph's stats fingerprint seeds the plan's block shape.
        from repro.serve import tuner_cache as tuner_cache_mod
        self.tuner_cache = (tuner_cache_mod.TunerCache(tuner_cache)
                            if tuner_cache else None)
        self.graphs: Dict[str, GraphEntry] = {}
        self.models: Dict[str, ModelEntry] = {}
        self._sessions: Dict[Tuple[str, str], CompiledGraphSession] = {}
        self._sharded_sessions: Dict[Tuple[str, str, int], object] = {}

    # -------------------------------------------------------- registry ----
    def register_graph(self, name: str, data: GraphData) -> GraphEntry:
        entry = GraphEntry(name=name, data=data)
        self.graphs[name] = entry
        return entry

    def register_model(self, name: str, family: str, params) -> ModelEntry:
        if family not in FAMILIES:
            raise ValueError(f"unknown family {family!r}; have {FAMILIES}")
        entry = ModelEntry(name=name, family=family, params=params)
        self.models[name] = entry
        return entry

    def update_features(self, name: str, x: np.ndarray) -> None:
        """Swap node features in place; sessions recalibrate or patch their
        caches on next use (version-based invalidation). In incremental mode
        the CHANGED rows are diffed and recorded (the refresh changelog) —
        the O(n*F) compare and the retained id arrays are only paid when a
        session will actually consume them."""
        entry = self.graphs[name]
        x = np.asarray(x, np.float32)
        if x.shape != entry.data.x.shape:
            raise ValueError(f"feature shape {x.shape} != "
                             f"{entry.data.x.shape} (graph structure and "
                             f"feature width are fixed per registration)")
        changed = (np.nonzero((entry.data.x != x).any(axis=1))[0]
                   if self.incremental else None)
        entry.data.x = x
        entry.version += 1
        if changed is not None:
            entry.record_change(changed)

    def _plan_block(self, g: GraphEntry) -> Optional[Tuple[int, int]]:
        """The block shape new plans get: an explicit store override wins;
        otherwise a tuner-cache hit for this graph's stats fingerprint
        (same backend + fused flag) seeds it; else kernel defaults."""
        if self.bspmm_block is not None or self.tuner_cache is None:
            return self.bspmm_block
        from repro.serve.tuner_cache import graph_stats
        return self.tuner_cache.lookup(graph_stats(g.data),
                                       fused=self.fused)

    # --------------------------------------------------------- compile ----
    def session(self, graph: str, model: str, tune: bool = False,
                tune_repeats: int = 2) -> CompiledGraphSession:
        key = (graph, model)
        if key in self._sessions:
            return self._sessions[key]
        g, m = self.graphs[graph], self.models[model]

        sess = None
        sess_dir = (self.cache_dir / f"{graph}__{model}"
                    if self.cache_dir else None)
        blk = self._plan_block(g)
        if sess_dir is not None:
            sess = CompiledGraphSession.load(
                sess_dir, g, m, khop=self.khop, max_batch=self.max_batch,
                use_pallas=self.use_pallas, incremental=self.incremental,
                bspmm_block=blk, fused=self.fused)
        if sess is None:
            qparams = session_core.quantize_family(m.family, m.params)
            plan = (session_core.tune_plan(g.data, m.family, qparams,
                                           repeats=tune_repeats)
                    if tune else session_core.default_plan(m.family))
            plan = dataclasses.replace(plan, bspmm_block=blk,
                                       fused=self.fused)
            sess = CompiledGraphSession(
                g, m, plan, qparams, khop=self.khop,
                max_batch=self.max_batch, use_pallas=self.use_pallas,
                incremental=self.incremental)
            sess.sync()
            if sess_dir is not None:
                sess.save(sess_dir)
        self._sessions[key] = sess
        return sess

    def sharded_session(self, graph: str, model: str, n_shards: int,
                        tune: bool = False, tune_repeats: int = 2,
                        mesh=None, executor: str = "host",
                        bn_mode: str = "single_host"):
        """Compile (or restore) a partitioned session serving ``graph``
        from ``n_shards`` shards. ``executor``/``bn_mode`` select the
        distributed-pass implementation and the BN calibration source
        (sessions differing in either coexist — they are part of the cache
        key). See :mod:`repro.serve.sharded`."""
        from repro.serve.sharded import ShardedGraphSession, ShardPlanner
        key = (graph, model, int(n_shards), executor, bn_mode)
        if key in self._sharded_sessions:
            sess = self._sharded_sessions[key]
            if mesh is not None:       # caller asked for a specific transport
                sess.set_mesh(mesh)
            return sess
        g, m = self.graphs[graph], self.models[model]

        sess = None
        sess_dir = (self.cache_dir / f"{graph}__{model}__P{n_shards}"
                    if self.cache_dir else None)
        blk = self._plan_block(g)
        if sess_dir is not None:
            sess = ShardedGraphSession.load(
                sess_dir, g, m, khop=self.khop, max_batch=self.max_batch,
                use_pallas=self.use_pallas, mesh=mesh, executor=executor,
                bn_mode=bn_mode, bspmm_block=blk, fused=self.fused)
        if sess is None:
            qparams = session_core.quantize_family(m.family, m.params)
            plan = (session_core.tune_plan(g.data, m.family, qparams,
                                           repeats=tune_repeats)
                    if tune else session_core.default_plan(m.family))
            plan = dataclasses.replace(plan, bspmm_block=blk,
                                       fused=self.fused)
            shard_plan = ShardPlanner(n_shards).plan(g.data, m.family)
            sess = ShardedGraphSession(
                g, m, plan, qparams, shard_plan, khop=self.khop,
                max_batch=self.max_batch, use_pallas=self.use_pallas,
                mesh=mesh, executor=executor, bn_mode=bn_mode)
            sess.sync()
            if sess_dir is not None:
                sess.save(sess_dir)
        self._sharded_sessions[key] = sess
        return sess
